"""§Roofline report generator: reads the dry-run JSONL and prints the table.

For each (arch × shape): the three roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, per-device memory and
the fits-HBM verdict.  Used to build EXPERIMENTS.md §Roofline and to pick
the hillclimb targets.
"""
from __future__ import annotations

import json
import os
import sys
from typing import List

from benchmarks.common import emit

DEFAULT_PATH = "results/dryrun_singlepod.jsonl"


def load(path: str) -> List[dict]:
    recs = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r.get("kind"), r["mesh"])] = r
    return list(recs.values())


def main(path: str = DEFAULT_PATH) -> list:
    recs = load(path)
    if not recs:
        emit("roofline_missing", 0.0, f"no dry-run records at {path}")
        return []
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        tag = f"roofline_{r['arch']}_{r['shape']}"
        if r["status"] == "skipped":
            emit(tag, 0.0, f"SKIP {r.get('skip_reason','')}")
            continue
        if r["status"] != "ok":
            emit(tag, 0.0, f"ERROR {r.get('error','')[:80]}")
            continue
        rl = r.get("roofline")
        if not rl:
            emit(tag, r.get("compile_s", 0) * 1e6,
                 f"mem={r.get('per_device_gb')}GB fits={r.get('fits_hbm')}")
            continue
        ratio = r.get("useful_flops_ratio")
        emit(
            tag,
            r["compile_s"] * 1e6,
            f"compute_s={rl['compute_s']:.4f} memory_s={rl['memory_s']:.4f} "
            f"collective_s={rl['collective_s']:.4f} dom={rl['dominant']} "
            f"useful_ratio={ratio:.3f} mem_gb={r.get('per_device_gb')} "
            f"fits={r.get('fits_hbm')}",
        )
        rows.append(r)
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH)
