"""Paper Fig. 8: random-feature count sweep vs the exact-KRR ceiling."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, landmarks_like, timed
from repro.core import fed3r
from repro.core.random_features import rbf_kernel, rff_init, rff_map

from benchmarks.common import RF_LAMBDA, RF_SIGMA
SIGMA = RF_SIGMA
LAM = RF_LAMBDA


def krr_exact(f_tr, y_tr, f_te, C):
    """Exact kernel ridge regression on a subset (the paper's upper bound)."""
    K = rbf_kernel(f_tr, f_tr, SIGMA)
    Y = jax.nn.one_hot(y_tr, C)
    alpha = jnp.linalg.solve(K + LAM * jnp.eye(K.shape[0]), Y)
    K_te = rbf_kernel(f_te, f_tr, SIGMA)
    return jnp.argmax(K_te @ alpha, axis=-1)


def main() -> list:
    fed, test = landmarks_like(nonlinear=True)
    C = fed.n_classes
    sub = 3000  # KRR is O(n²) memory: subset ceiling, as in the paper App. F
    f_tr = jnp.asarray(fed.features[:sub])
    y_tr = jnp.asarray(fed.labels[:sub])
    f_te = jnp.asarray(np.asarray(test.features))
    rows = []

    with timed() as t:
        pred = krr_exact(f_tr, y_tr, f_te, C)
        krr_acc = float(jnp.mean((pred == test.labels).astype(jnp.float32)))
    emit("fig8_krr_exact_subset", t["s"] * 1e6, f"acc={krr_acc:.4f} n={sub}")

    accs = []
    for D_rf in (128, 512, 2048, 8192):
        p = rff_init(jax.random.PRNGKey(0), f_tr.shape[1], D_rf, SIGMA)
        with timed() as t:
            W = fed3r.solve(
                fed3r.client_stats(rff_map(p, f_tr), y_tr, C), LAM
            )
            acc = float(fed3r.accuracy(W, rff_map(p, f_te), test.labels))
        accs.append(acc)
        emit(f"fig8_rr_rf_{D_rf}", t["s"] * 1e6,
             f"acc={acc:.4f} gap_to_krr={krr_acc-acc:+.4f}")
        rows.append((D_rf, acc))
    # monotone improvement toward the KRR ceiling
    emit("fig8_monotonicity", 0.0,
         f"improving={bool(accs[0] <= accs[-1])} final_gap={krr_acc-accs[-1]:+.4f}")
    return rows


if __name__ == "__main__":
    main()
