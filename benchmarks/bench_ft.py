"""Paper Table 2 / Fig. 4/5/11: FED3R+FT strategies vs no-FED3R-init FT.

Grid: {FedAvg, FedAvgM} × {FT, FT-LP, FT-FEAT} × {FED3R init, random init}.
The paper's headline orderings to reproduce directionally:
  * FED3R init ≥ random init at equal budget;
  * FT-FEAT (classifier fixed) is the most stable under heterogeneity.
"""
from __future__ import annotations

from benchmarks.common import emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.federated import run_fed3r_ft

ROUNDS = 60


def main() -> list:
    fed, test = landmarks_like()
    rows = []
    for alg, smom in [("fedavg", 0.0), ("fedavgm", 0.9)]:
        for strategy in ("full", "lp", "feat"):
            for use_init in (True, False):
                if strategy == "feat" and not use_init:
                    # paper reports FT-FEAT only with the FED3R classifier
                    continue
                cfg = fed_cfg(algorithm=alg, n_rounds=ROUNDS,
                              server_momentum=smom)
                with timed() as t:
                    _, info = run_fed3r_ft(
                        fed, test.features, test.labels, f3_cfg(), cfg,
                        strategy=strategy, use_fed3r_init=use_init,
                        eval_every=10,
                    )
                h = info["ft_history"]
                tag = (
                    f"table2_{alg}_ft{strategy}_"
                    + ("fed3r_init" if use_init else "rand_init")
                )
                extra = (
                    f" fed3r_rounds={info['fed3r_rounds']}"
                    f" temp={info.get('temperature', '-')}"
                    if use_init else ""
                )
                emit(tag, t["s"] * 1e6 / ROUNDS,
                     f"final={h.accuracy[-1]:.4f}{extra}")
                rows.append((tag, h.accuracy[-1]))
    return rows


if __name__ == "__main__":
    main()
