"""Chaos-mode CI gate: replay seeded fault schedules, require bitwise W parity.

Each schedule is a deterministic fault-injected upload timeline (drops with
retransmit, duplicates, reordering, transient delay) over a fixed cohort
sequence.  For every schedule the gate runs the asynchronous merge-on-arrival
engine AND the synchronous barrier over the SAME timeline and asserts:

* final ``W`` is bitwise identical between the two runs, and
* the staleness window dropped zero uploads (exact-once delivery — the
  precondition for the parity claim).

On any divergence the offending schedule is persisted as JSON under
``chaos_failures/`` (uploaded as a CI artifact) and the process exits 1; the
schedule can then be rerun offline with ``--replay <file>``.  Alongside the
schedule, the run is replayed once more under a fresh flight recorder and
the full structured event stream (fault injections, staleness drops, health
transitions) is written as ``<name>.events.jsonl`` — the post-mortem log.

Usage:
    PYTHONPATH=src:. python benchmarks/chaos_replay.py            # all 8 gates
    PYTHONPATH=src:. python benchmarks/chaos_replay.py --replay chaos_failures/x.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Sequence, Tuple

import numpy as np

from repro.federated.arrivals import (
    ChaosSpec,
    UploadEvent,
    chaos_timeline,
    latency_profile,
    timeline_from_json,
    timeline_to_json,
)
from repro.federated.async_engine import (
    AsyncConfig,
    AsyncRoundEngine,
    client_payloads,
    run_chaos_timeline,
)
from repro.data.pipeline import make_federated_features
from repro.federated.telemetry import Telemetry, set_telemetry

D_FEAT = 32
N_CLASSES = 8
RIDGE_LAMBDA = 1e-2
N_CLIENTS = 16
COHORT = 6
N_ROUNDS = 6
DEADLINE = 1.0
STALENESS = 4

# 2 seeds x 4 fault profiles = the 8 schedules the CI job replays.  Each
# profile stresses one fault mode; rto/max_attempts bound the retransmit
# tail so every upload lands inside the staleness window.
PROFILES = {
    "drop_heavy": ChaosSpec(drop=0.5, duplicate=0.0, reorder=0.0, delay=0.0,
                            rto=0.1, max_attempts=6),
    "duplicate_heavy": ChaosSpec(drop=0.1, duplicate=0.6, reorder=0.1, delay=0.0,
                                 rto=0.1, max_attempts=4),
    "reorder_heavy": ChaosSpec(drop=0.1, duplicate=0.1, reorder=0.8, delay=0.0,
                               rto=0.1, max_attempts=4),
    "delay_heavy": ChaosSpec(drop=0.1, duplicate=0.1, reorder=0.2, delay=0.4,
                             delay_factor=2.0, rto=0.1, max_attempts=4),
}
SEEDS = (0, 1)


def _schedules() -> List[Tuple[str, List[List[int]], np.ndarray, ChaosSpec,
                               List[UploadEvent]]]:
    out = []
    for seed in SEEDS:
        latency = latency_profile(
            N_CLIENTS, 0.2, straggler_factor=4.0, base=0.3, jitter=0.5, seed=seed
        )
        cohorts = [
            sorted(
                np.random.default_rng((seed, r, 0xC0407))
                .choice(N_CLIENTS, size=COHORT, replace=False)
                .tolist()
            )
            for r in range(N_ROUNDS)
        ]
        for name, base_spec in PROFILES.items():
            spec = ChaosSpec(**{**base_spec.__dict__, "seed": seed})
            events = chaos_timeline(cohorts, latency, spec)
            out.append((f"{name}_seed{seed}", cohorts, latency, spec, events))
    return out


def _payloads():
    fed, _ = make_federated_features(
        seed=7, n=1200, d=D_FEAT, n_classes=N_CLASSES,
        n_clients=N_CLIENTS, alpha=0.3, noise=2.0,
    )
    return client_payloads(fed, N_CLASSES)


def _engine(synchronous: bool) -> AsyncRoundEngine:
    return AsyncRoundEngine(AsyncConfig(
        n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA, cohort=COHORT,
        deadline=DEADLINE, staleness_rounds=STALENESS,
        synchronous=synchronous, early_close=False, demote_after=10_000,
    ))


def check_schedule(
    name: str,
    cohorts: Sequence[Sequence[int]],
    events: Sequence[UploadEvent],
    payloads,
) -> Tuple[bool, str]:
    def payload_for(c, r):
        return payloads[c]

    e_async = _engine(synchronous=False)
    s_async, rep_async = run_chaos_timeline(
        e_async, e_async.init(D_FEAT), cohorts, events, payload_for
    )
    e_sync = _engine(synchronous=True)
    s_sync, _ = run_chaos_timeline(
        e_sync, e_sync.init(D_FEAT), cohorts, events, payload_for
    )
    Wa, Ws = np.asarray(s_async.W), np.asarray(s_sync.W)
    if rep_async["dropped_uploads"] != 0:
        return False, (
            f"{name}: {rep_async['dropped_uploads']} uploads fell outside "
            f"the staleness window"
        )
    if not np.array_equal(Wa, Ws):
        return False, (
            f"{name}: W diverged, max abs diff {np.abs(Wa - Ws).max():.3e}"
        )
    return True, (
        f"{name}: W bitwise equal  "
        f"(folds={rep_async['folded']} late={rep_async['late_folds']} "
        f"dups={rep_async['duplicates']})"
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--replay", metavar="JSON",
        help="rerun one persisted failure schedule instead of the full gate",
    )
    ap.add_argument(
        "--out-dir", default="chaos_failures",
        help="where offending schedules are written (CI artifact dir)",
    )
    args = ap.parse_args()

    payloads = _payloads()

    if args.replay:
        sched = timeline_from_json(Path(args.replay).read_text())
        ok, msg = check_schedule(
            Path(args.replay).stem, sched["cohorts"], sched["events"], payloads
        )
        print(msg)
        return 0 if ok else 1

    failures = 0
    for name, cohorts, latency, spec, events in _schedules():
        ok, msg = check_schedule(name, cohorts, events, payloads)
        print(("PASS  " if ok else "FAIL  ") + msg)
        if not ok:
            failures += 1
            out = Path(args.out_dir)
            out.mkdir(parents=True, exist_ok=True)
            path = out / f"{name}.json"
            path.write_text(timeline_to_json(cohorts, latency, spec, events))
            # replay under a fresh flight recorder so the artifact carries
            # the full fault-injection + engine event stream for post-mortem
            telemetry = Telemetry(ring=65536)
            prev = set_telemetry(telemetry)
            try:
                chaos_timeline(cohorts, latency, spec)
                check_schedule(name, cohorts, events, payloads)
            finally:
                set_telemetry(prev)
            log_path = out / f"{name}.events.jsonl"
            log_path.write_text(telemetry.events_jsonl())
            print(f"      schedule persisted to {path} (events: {log_path})")
    if failures:
        print(f"{failures} schedule(s) diverged")
        return 1
    print("all 8 chaos schedules: async W bitwise equal to the sync barrier")
    return 0


if __name__ == "__main__":
    sys.exit(main())
