"""Kernel micro-benchmarks: Pallas (interpret) vs XLA reference timings.

On this CPU container the Pallas timings are interpret-mode (correctness
path); the XLA reference gives the comparable compiled number.  The derived
column reports allclose-vs-oracle, which is the portable claim.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import fed3r_stats, flash_attention, rff_transform
from repro.kernels import ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def main() -> list:
    rng = jax.random.PRNGKey(0)
    rows = []

    # fed3r_stats at paper scale (d=1280, C=2028, batch of 1024 samples)
    Z = jax.random.normal(rng, (1024, 1280), jnp.bfloat16)
    Y = jax.nn.one_hot(jax.random.randint(rng, (1024,), 0, 2028), 2028)
    ref_t = _time(jax.jit(ref.fed3r_stats_ref), Z, Y)
    A, b = fed3r_stats(Z, Y)
    Ar, br = ref.fed3r_stats_ref(Z, Y)
    err = float(jnp.max(jnp.abs(A - Ar)))
    emit("kernel_fed3r_stats_xla_ref", ref_t, f"d=1280 C=2028 n=1024 max_err={err:.2e}")
    rows.append(("fed3r_stats", ref_t, err))

    # rff at paper scale (D=10k approximated by 4096 for CPU budget)
    om = jax.random.normal(rng, (1280, 4096)) / 1000.0
    be = jax.random.uniform(rng, (4096,), maxval=2 * np.pi)
    ref_t = _time(jax.jit(ref.rff_ref), Z, om, be)
    R = rff_transform(Z, om, be)
    err = float(jnp.max(jnp.abs(R - ref.rff_ref(Z, om, be))))
    emit("kernel_rff_xla_ref", ref_t, f"D=4096 max_err={err:.2e}")
    rows.append(("rff", ref_t, err))

    # flash attention (prefill tile)
    B, S, H, KV, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(rng, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd), jnp.bfloat16)
    ref_t = _time(jax.jit(lambda *a: ref.flash_attention_ref(*a)), q, k, v)
    o = flash_attention(q, k, v)
    err = float(jnp.max(jnp.abs(
        o.astype(jnp.float32) - ref.flash_attention_ref(q, k, v).astype(jnp.float32)
    )))
    emit("kernel_flash_attention_xla_ref", ref_t, f"S=512 GQA4 max_err={err:.2e}")
    rows.append(("flash_attention", ref_t, err))
    return rows


if __name__ == "__main__":
    main()
