"""Paper Table 7 / App. I: Batch Coupon Collector — rounds to sample a given
fraction of distinct clients with replacement (1000 trials, as the paper)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed

SETTINGS = [  # (dataset, K, kappa) — paper Table 7 rows
    ("landmarks", 1262, 10),
    ("landmarks", 1262, 20),
    ("landmarks", 1262, 50),
    ("inaturalist", 9275, 10),
    ("cifar100", 100, 10),
]
FRACTIONS = (0.25, 0.50, 0.75, 1.00)
TRIALS = 200  # paper uses 1000; CPU-budgeted


def simulate(K: int, kappa: int, rng: np.random.Generator) -> dict:
    seen = np.zeros(K, bool)
    hits = {}
    r = 0
    while not seen.all():
        r += 1
        seen[rng.choice(K, size=kappa, replace=False)] = True
        frac = seen.mean()
        for f in FRACTIONS:
            if f not in hits and frac >= f:
                hits[f] = r
    return hits


def main() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for name, K, kappa in SETTINGS:
        with timed() as t:
            all_hits = [simulate(K, kappa, rng) for _ in range(TRIALS)]
        parts = []
        for f in FRACTIONS:
            vals = np.asarray([h[f] for h in all_hits])
            parts.append(f"p{int(f*100)}={vals.mean():.0f}±{vals.std():.0f}")
        emit(f"table7_{name}_K{K}_k{kappa}", t["s"] * 1e6 / TRIALS, " ".join(parts))
        rows.append((name, K, kappa, parts))
    return rows


if __name__ == "__main__":
    main()
