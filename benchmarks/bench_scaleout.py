"""Weak scaling of the four one-dispatch engines over simulated pod meshes.

The claim under test (ISSUE 5 acceptance): with the shared distributed
execution layer (:mod:`repro.federated.dist`) owning the shard_map, every
engine — batch statistics, rounds, streaming, personalization — runs its
psum backend over an N-device data-parallel mesh in EXACTLY ONE host
dispatch per accumulate/step/absorb/solve call, at every N, with results
matching the single-device ``merge`` backend.

Weak scaling: the per-device work is held constant while N grows
(N× clients / wave width / cohort), so on real hardware the per-call wall
time should stay ~flat.  Simulated host devices share one CPU, so the
times here measure dispatch/collective overhead, not speedup — the
dispatch counts and parity errors are the gated contract, the times are
gated only loosely.

Each N runs in a SUBPROCESS: jax locks the device count at first init, so
the parent spawns one worker per N with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the same knob the
multi-pod dry run uses, see ``repro.launch.dryrun``).  Each worker ships
its own telemetry snapshot back in the result JSON and the parent merges
them into the harness registry
(:meth:`repro.federated.telemetry.Telemetry.merge_snapshot`), so the
persisted ``telemetry_scaleout.json`` carries the real dispatch counters
and ``check_regression`` gates them like every other bench.

Usage: PYTHONPATH=src:. python benchmarks/bench_scaleout.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 4, 8)
# per-device workload (weak scaling: totals are multiplied by N)
D_FEAT = 32
N_CLASSES = 10
SHARDS_PER_DEV = 4
CLIENTS_PER_SHARD = 2
SAMPLES_PER_CLIENT = 24
WAVES = 6
WAVE_WIDTH_PER_DEV = 2
COHORT_PER_DEV = 2
TENANTS_PER_DEV = 4
ROUND_BATCHES = 2
ROUND_BATCH_SIZE = 16
RIDGE_LAMBDA = 0.1


# ---------------------------------------------------------------------------
# worker: one device count, one process
# ---------------------------------------------------------------------------


def _timed_calls(fn, reps):
    """Median-free simple average of ``reps`` warm calls (trace excluded)."""
    import jax

    jax.block_until_ready(fn())  # warm the trace
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def worker(n_dev: int, reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fed3r
    from repro.data.pipeline import (
        pack_arrival_waves,
        pack_client_shards,
        pack_cohort_batches,
        pack_personal_cohort,
    )
    from repro.federated.dist import DistConfig
    from repro.federated.engine import AccumulationEngine, EngineConfig
    from repro.federated.personalization import (
        PersonalizationEngine,
        PersonalizeConfig,
    )
    from repro.federated.round_engine import RoundConfig, RoundEngine
    from repro.federated.algorithms import make_algorithm
    from repro.federated.streaming_engine import StreamConfig, StreamingEngine
    from repro.launch.mesh import make_host_mesh

    assert len(jax.devices()) == n_dev, (len(jax.devices()), n_dev)
    mesh = make_host_mesh()
    dist = DistConfig(aggregation="psum", mesh=mesh, donate=False)
    rng = np.random.default_rng(0)

    def make_clients(k):
        # features on a 1/8 grid in [-2, 2]: every product lands on a 1/64
        # grid and every partial Gram sum stays < 2^24/64, so fp32 addition
        # is EXACT at this scale — the psum tree order cannot change a bit,
        # which turns "sharded == single-device" into a bitwise contract
        # for A/b (and the factored L/W downstream of them)
        return [
            (
                (rng.integers(-16, 17, size=(SAMPLES_PER_CLIENT, D_FEAT)) / 8.0
                 ).astype(np.float32),
                rng.integers(0, N_CLASSES, size=SAMPLES_PER_CLIENT).astype(np.int32),
            )
            for _ in range(k)
        ]

    out: dict = {"n_devices": n_dev}

    # ---- 1) batch statistics engine --------------------------------------
    clients = make_clients(n_dev * SHARDS_PER_DEV * CLIENTS_PER_SHARD)
    packed = pack_client_shards(clients, CLIENTS_PER_SHARD, mesh=mesh)
    eng = AccumulationEngine(EngineConfig(n_classes=N_CLASSES, dist=dist))
    eng.accumulate(eng.init(D_FEAT), packed)
    eng.dispatches = 0
    acc = eng.accumulate(eng.init(D_FEAT), packed)
    disp = eng.dispatches
    ref_eng = AccumulationEngine(EngineConfig(n_classes=N_CLASSES))
    ref = ref_eng.accumulate(ref_eng.init(D_FEAT), packed)
    out["engine"] = {
        "dispatches": disp,
        "per_call_s": _timed_calls(
            lambda: eng.accumulate(eng.init(D_FEAT), packed).stats.A, reps
        ),
        "err": float(jnp.max(jnp.abs(acc.stats.A - ref.stats.A))),
        "bitwise_ab": bool(
            np.array_equal(np.asarray(acc.stats.A), np.asarray(ref.stats.A))
            and np.array_equal(np.asarray(acc.stats.b), np.asarray(ref.stats.b))
        ),
    }

    # ---- 2) streaming engine ---------------------------------------------
    waves = [make_clients(n_dev * WAVE_WIDTH_PER_DEV) for _ in range(WAVES)]
    arrivals = pack_arrival_waves(waves, mesh=mesh)
    scfg = dict(n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA)
    s_eng = StreamingEngine(StreamConfig(**scfg, dist=dist))
    s_eng.absorb(s_eng.init(D_FEAT), arrivals)
    s_eng.dispatches = 0
    state, _ = s_eng.absorb(s_eng.init(D_FEAT), arrivals)
    s_disp = s_eng.dispatches
    s_ref = StreamingEngine(StreamConfig(**scfg))
    ref_state, _ = s_ref.absorb(s_ref.init(D_FEAT), arrivals)
    out["streaming"] = {
        "dispatches": s_disp,
        "per_call_s": _timed_calls(
            lambda: s_eng.absorb(s_eng.init(D_FEAT), arrivals)[0].W, reps
        ),
        "err": float(jnp.max(jnp.abs(state.W - ref_state.W))),
        "bitwise_w": bool(np.array_equal(np.asarray(state.W), np.asarray(ref_state.W))),
    }

    # ---- 3) cohort round engine ------------------------------------------
    cohort_clients = make_clients(n_dev * COHORT_PER_DEV)
    cohort = pack_cohort_batches(
        cohort_clients, ROUND_BATCH_SIZE, ROUND_BATCHES, mesh=mesh
    )
    params0 = {"W": jnp.zeros((D_FEAT, N_CLASSES), jnp.float32)}
    freeze = jax.tree.map(lambda _: 1.0, params0)

    def per_example_loss(params, batch):
        logits = batch["x"] @ params["W"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    rcfg = dict(algo=make_algorithm("fedavg"), client_lr=0.1,
                n_total_clients=len(cohort_clients))
    r_eng = RoundEngine(RoundConfig(**rcfg, dist=dist), per_example_loss, freeze)
    r_eng.step(r_eng.init(params0), cohort)
    r_eng.dispatches = 0
    r_state = r_eng.step(r_eng.init(params0), cohort)
    r_disp = r_eng.dispatches
    r_ref = RoundEngine(RoundConfig(**rcfg), per_example_loss, freeze)
    r_ref_state = r_ref.step(r_ref.init(params0), cohort)
    out["rounds"] = {
        "dispatches": r_disp,
        "per_call_s": _timed_calls(
            lambda: r_eng.step(r_eng.init(params0), cohort).params["W"], reps
        ),
        "err": float(
            jnp.max(jnp.abs(r_state.params["W"] - r_ref_state.params["W"]))
        ),
    }

    # ---- 4) personalization engine ---------------------------------------
    tenants = make_clients(n_dev * TENANTS_PER_DEV)
    pcohort = pack_personal_cohort(tenants, mesh=mesh)
    fac = fed3r.init_factored(D_FEAT, N_CLASSES, RIDGE_LAMBDA)
    fac = fed3r.factored_update(
        fac,
        jnp.asarray(np.concatenate([x for x, _ in tenants])),
        jnp.asarray(np.concatenate([y for _, y in tenants])),
    )
    p_eng = PersonalizationEngine(PersonalizeConfig(n_classes=N_CLASSES, dist=dist))
    p_eng.solve_heads(fac, pcohort)
    p_eng.dispatches = 0
    heads = p_eng.solve_heads(fac, pcohort)
    p_disp = p_eng.dispatches
    p_ref = PersonalizationEngine(PersonalizeConfig(n_classes=N_CLASSES))
    ref_heads = p_ref.solve_heads(fac, pcohort)
    out["personalize"] = {
        "dispatches": p_disp,
        "per_call_s": _timed_calls(lambda: p_eng.solve_heads(fac, pcohort).W, reps),
        "err": float(jnp.max(jnp.abs(heads.W - ref_heads.W))),
    }
    # the worker's own registry rides home in the result JSON: the parent
    # merges it, so the scaleout dispatch counters land in the persisted
    # telemetry snapshot like every in-process bench's
    from repro.federated.telemetry import get_telemetry

    out["telemetry"] = get_telemetry().snapshot()
    return out


# ---------------------------------------------------------------------------
# parent: one subprocess per device count
# ---------------------------------------------------------------------------


def _run_worker(n_dev: int, reps: int) -> dict:
    env = dict(os.environ)
    # replace (not append) any inherited device-count flag; force the host
    # platform so simulated devices exist even on accelerator machines
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker",
         "--ndev", str(n_dev), "--reps", str(reps)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaleout worker (N={n_dev}) failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


ENGINES = ("engine", "streaming", "rounds", "personalize")


def main(smoke: bool = False) -> dict:
    from benchmarks.common import emit
    from repro.federated.telemetry import get_telemetry

    reps = 1 if smoke else 3
    result: dict = {"device_counts": list(DEVICE_COUNTS)}
    for n_dev in DEVICE_COUNTS:
        rec = _run_worker(n_dev, reps)
        worker_snap = rec.pop("telemetry", None)
        if worker_snap:
            get_telemetry().merge_snapshot(worker_snap)
        result[f"n{n_dev}"] = rec
        for name in ENGINES:
            r = rec[name]
            emit(
                f"scaleout_{name}_n{n_dev}", r["per_call_s"] * 1e6,
                f"devices={n_dev} dispatches={r['dispatches']} err={r['err']:.2e}",
            )
            assert r["dispatches"] == 1, (
                f"{name} at N={n_dev}: {r['dispatches']} dispatches "
                f"(the one-dispatch contract is the point)"
            )
    # weak-scaling dispatch invariance across N is the gated contract
    result["one_dispatch_at_every_n"] = all(
        result[f"n{n}"][e]["dispatches"] == 1
        for n in DEVICE_COUNTS for e in ENGINES
    )
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="1 rep (CI budget)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--ndev", type=int, default=1, help=argparse.SUPPRESS)
    ap.add_argument("--reps", type=int, default=1, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker:
        # ensure src/ is importable even when invoked by absolute path
        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        if os.path.isdir(os.path.join(here, "src")):
            sys.path.insert(0, os.path.join(here, "src"))
        print(json.dumps(worker(args.ndev, args.reps)))
    else:
        print(main(smoke=args.smoke))
