"""Paper Table 3: feature-extractor quality measured with the RR probe.

After each FT strategy, re-fit RR on the (fine-tuned) feature map and compare
softmax accuracy vs RR-probe accuracy.  The paper's finding: FED3R-initialized
FT (esp. FT-FEAT) yields more linearly-separable features.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.core import fed3r
from repro.federated import run_fed3r_ft

ROUNDS = 60


def main() -> list:
    fed, test = landmarks_like()
    C = fed.n_classes
    rows = []
    for strategy, use_init in [("full", False), ("full", True), ("feat", True)]:
        cfg = fed_cfg(algorithm="fedavg", n_rounds=ROUNDS)
        with timed() as t:
            params, info = run_fed3r_ft(
                fed, test.features, test.labels, f3_cfg(), cfg,
                strategy=strategy, use_fed3r_init=use_init, eval_every=ROUNDS,
            )
        softmax_acc = info["ft_history"].accuracy[-1]
        # RR probe on the fine-tuned feature map h = x·M
        M = np.asarray(params["M"])
        tr_h = jnp.asarray(fed.features @ M)
        te_h = jnp.asarray(np.asarray(test.features) @ M)
        W = fed3r.solve(fed3r.client_stats(tr_h, jnp.asarray(fed.labels), C), 0.01)
        rr_acc = float(fed3r.accuracy(W, te_h, test.labels))
        tag = f"table3_{strategy}_{'fed3r' if use_init else 'rand'}_init"
        emit(tag, t["s"] * 1e6 / ROUNDS,
             f"softmax={softmax_acc:.4f} rr_probe={rr_acc:.4f}")
        rows.append((tag, softmax_acc, rr_acc))
    return rows


if __name__ == "__main__":
    main()
