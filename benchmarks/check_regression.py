"""CI benchmark-regression gate over the BENCH_*.json perf records.

``benchmarks/run.py --smoke`` persists each engine benchmark's result dict
as ``BENCH_<name>.json``; this script compares those against the committed
baselines in ``benchmarks/baselines/`` and FAILS (exit 1) on regression.
The rules are keyed by metric name, so new benchmarks join the gate by
emitting a dict — no per-benchmark code here:

* ``*dispatch*``  — the dispatch-count contracts (1 per round/stream, K+1
  and T for the reference loops).  Integers, compared exactly downward:
  MORE dispatches than baseline is the regression the engines exist to
  prevent; fewer is an improvement.
* ``*speedup*``   — engine-vs-reference wall-time ratio.  Machine-
  normalized, so it gates meaningfully on shared CI runners; must stay
  above ``speedup_tol`` × baseline.
* ``*err*``       — parity / divergence numerics; must stay below
  ``err_tol`` × baseline (with an absolute ``err_floor`` so near-zero
  baselines don't fail on fp jitter).
* ``*_s`` / ``*_s_per_*`` — absolute wall-times; gated loosely
  (``time_tol`` ×) since absolute CI timing is noisy — order-of-magnitude
  blowups still fail.
* booleans        — exact (the bit-identical invariance flags).
* other integers  — exact (config echoes: waves, samples, cohort; a
  drifted smoke config silently invalidates every other comparison, so
  it must come with a re-seeded baseline).

Dispatch counts are preferentially read from the ``telemetry_<name>.json``
registry snapshot ``run.py`` writes next to each BENCH record (summed per
engine label, mirroring ``repro.federated.telemetry.dispatch_summary``);
when the snapshot is absent the legacy in-dict ``*dispatch*`` fields gate
alone, so old records remain comparable.

Usage:
  python benchmarks/check_regression.py            # after run.py --smoke
  python benchmarks/check_regression.py --baseline-dir benchmarks/baselines
  python benchmarks/check_regression.py --only BENCH_scaleout.json
                                                   # single-bench jobs (the
                                                   # multi-device CI smoke)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List


def flatten(d: dict, prefix: str = "") -> Dict[str, object]:
    out: Dict[str, object] = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, prefix=f"{key}."))
        else:
            out[key] = v
    return out


def telemetry_dispatches(snapshot: dict) -> Dict[str, int]:
    """Per-engine dispatch totals from a telemetry snapshot (pure JSON).

    Local mirror of ``repro.federated.telemetry.dispatch_summary`` so the
    gate runs without ``src`` on ``PYTHONPATH``.
    """
    out: Dict[str, int] = {}
    for c in snapshot.get("counters", []):
        if c.get("name") == "engine_dispatches_total":
            eng = c.get("labels", {}).get("engine", "engine")
            out[eng] = out.get(eng, 0) + int(c.get("value", 0))
    return out


def compare(
    current: dict,
    baseline: dict,
    *,
    time_tol: float = 10.0,
    speedup_tol: float = 0.25,
    err_tol: float = 100.0,
    err_floor: float = 1e-4,
    label: str = "",
) -> List[str]:
    """Rule-by-name comparison; returns human-readable violations."""
    cur = flatten(current)
    base = flatten(baseline)
    bad: List[str] = []

    def fail(key: str, msg: str) -> None:
        bad.append(f"{label}{key}: {msg}")

    for key, b in base.items():
        if key not in cur:
            fail(key, "missing from current results")
            continue
        c = cur[key]
        if isinstance(b, bool):
            if c != b:
                fail(key, f"flag flipped: {c!r} (baseline {b!r})")
        elif "dispatch" in key:
            if int(c) > int(b):
                fail(key, f"{int(c)} dispatches > baseline {int(b)}")
        elif "speedup" in key:
            if float(c) < float(b) * speedup_tol:
                fail(
                    key,
                    f"{float(c):.2f}x < {speedup_tol} * baseline {float(b):.2f}x",
                )
        elif "err" in key:
            limit = max(float(b) * err_tol, err_floor)
            if float(c) > limit:
                fail(key, f"{float(c):.3e} > limit {limit:.3e}")
        elif key.endswith("_s") or "_s_per" in key:
            if float(c) > float(b) * time_tol:
                fail(
                    key,
                    f"{float(c):.4f}s > {time_tol} * baseline {float(b):.4f}s",
                )
        elif isinstance(b, int) and isinstance(c, (int, float)):
            if int(c) != int(b):
                fail(key, f"config echo changed: {c!r} != baseline {b!r} (re-seed)")
        # other floats are informational only
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--current-dir", default=".")
    ap.add_argument("--time-tol", type=float, default=10.0)
    ap.add_argument("--speedup-tol", type=float, default=0.25)
    ap.add_argument("--err-tol", type=float, default=100.0)
    ap.add_argument("--err-floor", type=float, default=1e-4)
    ap.add_argument(
        "--only", action="append", default=None, metavar="BENCH_name.json",
        help="gate only these baseline basenames (repeatable); default: all",
    )
    args = ap.parse_args()

    paths = sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_*.json")))
    if args.only:
        wanted = set(args.only)
        paths = [p for p in paths if os.path.basename(p) in wanted]
        missing = wanted - {os.path.basename(p) for p in paths}
        if missing:
            print(f"no such baselines: {sorted(missing)}", file=sys.stderr)
            return 1
    if not paths:
        print(f"no baselines under {args.baseline_dir}", file=sys.stderr)
        return 1
    violations: List[str] = []
    for path in paths:
        name = os.path.basename(path)
        cur_path = os.path.join(args.current_dir, name)
        if not os.path.exists(cur_path):
            violations.append(f"{name}: not produced by this run")
            continue
        with open(path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        # prefer the registry snapshot for dispatch counts; fall back to
        # whatever legacy fields the BENCH dict itself carries
        suffix = name[len("BENCH_") : -len(".json")]
        snap_path = os.path.join(args.current_dir, f"telemetry_{suffix}.json")
        if os.path.exists(snap_path):
            with open(snap_path) as f:
                snap = json.load(f)
            current["telemetry"] = {"dispatches": telemetry_dispatches(snap)}
        violations.extend(
            compare(
                current,
                baseline,
                time_tol=args.time_tol,
                speedup_tol=args.speedup_tol,
                err_tol=args.err_tol,
                err_floor=args.err_floor,
                label=f"{name}:",
            )
        )
        print(f"checked {name} against {path}")
    if violations:
        print("\nBENCHMARK REGRESSIONS:", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print("benchmark gate: all baselines honored")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
