"""Streaming arrival engine vs per-arrival Woodbury loop.

The claims under test (ISSUE 3 acceptance):

* a T-wave arrival stream costs ONE jitted dispatch through the streaming
  engine (the whole timeline folds in a single donated lax.scan) vs the
  seed-era per-arrival loop's T subtractive-Woodbury dispatches;
* the factored-form W matches the batch re-solve in fp32 at λ = 1e-2 to
  ≤ 1e-4 max-abs error, at a scale where the legacy Woodbury path VISIBLY
  diverges (catastrophic fp32 cancellation of the carried A⁻¹).

Same protocol as bench_engine.py / bench_rounds.py, on the streaming side
of the paper (§6 future work / Eq. 3 recursive formulation).

Usage: PYTHONPATH=src:. python benchmarks/bench_streaming.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.pipeline import pack_arrival_waves
from repro.federated.streaming_engine import (
    ReferenceArrivalLoop,
    StreamConfig,
    StreamingEngine,
    batch_equivalent,
)

D_FEAT = 64
N_CLASSES = 10
CLIENTS_PER_WAVE = 4
RIDGE_LAMBDA = 1e-2  # small λ: the regime where the legacy path cancels


def _make_stream(n_waves, n_lo=40, n_hi=80, seed=0):
    rng = np.random.default_rng(seed)
    waves = []
    for _ in range(n_waves):
        k = int(rng.integers(1, CLIENTS_PER_WAVE + 1))
        wave = []
        for _ in range(k):
            n = int(rng.integers(n_lo, n_hi))
            wave.append((
                rng.normal(size=(n, D_FEAT)).astype(np.float32),
                rng.integers(0, N_CLASSES, size=n).astype(np.int32),
            ))
        waves.append(wave)
    return pack_arrival_waves(waves, clients_per_wave=CLIENTS_PER_WAVE)


def _time_engine(engine, packed, reps):
    state, _ = engine.absorb(engine.init(D_FEAT), packed)  # warm the trace
    jax.block_until_ready(state.W)
    engine.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        state, _ = engine.absorb(engine.init(D_FEAT), packed)
        jax.block_until_ready(state.W)
    return state, engine.dispatches // reps, (time.time() - t0) / reps


def _time_reference(loop, packed, reps):
    state = loop.absorb(loop.init(D_FEAT), packed)  # warm the trace
    jax.block_until_ready(state.Ainv)
    loop.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        state = loop.absorb(loop.init(D_FEAT), packed)
        jax.block_until_ready(state.Ainv)
    return state, loop.dispatches // reps, (time.time() - t0) / reps


def main(smoke: bool = False) -> dict:
    reps = 1 if smoke else 5
    n_waves = 8 if smoke else 32
    packed = _make_stream(n_waves)
    cfg = StreamConfig(n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA)

    eng_state, eng_disp, eng_s = _time_engine(StreamingEngine(cfg), packed, reps)
    ref_state, ref_disp, ref_s = _time_reference(
        ReferenceArrivalLoop(cfg), packed, reps
    )

    # numerics: factored engine vs batch re-solve vs legacy Woodbury, fp32
    W_batch, _ = batch_equivalent(packed, cfg)
    factored_err = float(jnp.max(jnp.abs(eng_state.W - W_batch)))
    legacy_err = float(jnp.max(jnp.abs(
        ReferenceArrivalLoop(cfg).classifier(ref_state) - W_batch
    )))

    speedup = ref_s / eng_s if eng_s > 0 else float("inf")
    emit(
        "streaming_reference_loop", ref_s * 1e6,
        f"T={packed.n_waves} dispatches={ref_disp} legacy_err={legacy_err:.2e}",
    )
    emit(
        "streaming_packed_engine", eng_s * 1e6,
        f"T={packed.n_waves} dispatches={eng_disp} speedup={speedup:.1f}x "
        f"factored_err={factored_err:.2e}",
    )

    assert eng_disp == 1, f"engine must cost 1 dispatch per stream, got {eng_disp}"
    assert ref_disp == packed.n_waves, (
        f"reference should cost T={packed.n_waves}, got {ref_disp}"
    )
    assert factored_err <= 1e-4, (
        f"factored W drifted from the batch solve: {factored_err:.2e}"
    )
    assert legacy_err > 10 * max(factored_err, 1e-7), (
        f"legacy path should visibly diverge at λ={RIDGE_LAMBDA}: "
        f"{legacy_err:.2e} vs factored {factored_err:.2e}"
    )
    return {
        "reference_s_per_stream": ref_s,
        "engine_s_per_stream": eng_s,
        "speedup": speedup,
        "reference_dispatches": ref_disp,
        "engine_dispatches": eng_disp,
        "factored_err": factored_err,
        "legacy_err": legacy_err,
        "waves": packed.n_waves,
        "samples": packed.n_samples,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
