"""Paper Fig. 3: participation rates and sampling with/without replacement.

FED3R's final accuracy is invariant to the sampling rate by construction;
with-replacement sampling merely delays full coverage (worst case analysed
by the Batch Coupon Collector, see bench_coupon).
"""
from __future__ import annotations

from benchmarks.common import emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.federated import run_fed3r


def main() -> list:
    fed, test = landmarks_like()
    rows = []
    finals = []
    for per_round in (5, 10, 20):
        for repl in (False, True):
            cfg = fed_cfg(clients_per_round=per_round, n_rounds=400,
                          sample_with_replacement=repl)
            with timed() as t:
                _, _, h = run_fed3r(fed, test.features, test.labels, f3_cfg(),
                                    cfg, eval_every=5)
            tag = f"fig3_fed3r_{per_round}clr_{'with' if repl else 'wo'}_repl"
            rounds_done = h.rounds[-1]
            emit(tag, t["s"] * 1e6 / rounds_done,
                 f"final={h.accuracy[-1]:.4f} rounds={rounds_done} "
                 f"clients_seen={h.clients_seen[-1]}")
            rows.append((tag, h.accuracy[-1], rounds_done))
            if not repl:
                finals.append(h.accuracy[-1])
    # invariance to the participation rate (paper §4.3)
    emit("fig3_rate_invariance", 0.0,
         f"spread={max(finals)-min(finals):.2e}")
    return rows


if __name__ == "__main__":
    main()
