"""Paper Fig. 1 / Fig. 9: FED3R(-RF) invariance to the federated split.

Four different partitions of the same dataset (different client counts and
heterogeneity levels) must converge to numerically identical accuracy —
and equal the centralized RR solution.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.core import fed3r
from repro.federated import run_fed3r

SPLITS = [(200, 0.0), (100, 0.0), (400, 0.0), (200, 100.0)]


def main() -> list:
    fed, test = landmarks_like()
    rows = []

    # centralized reference
    cen = fed3r.solve(
        fed3r.client_stats(jnp.asarray(fed.features), jnp.asarray(fed.labels),
                           fed.n_classes),
        0.01,
    )
    acc_cen = float(fed3r.accuracy(cen, test.features, test.labels))

    for use_rf in (False, True):
        accs = []
        with timed() as t:
            for n_cl, alpha in SPLITS:
                fed_s = fed.repartition(np.random.default_rng(n_cl), n_cl, alpha)
                f3 = f3_cfg(n_random_features=1024 if use_rf else 0, rff_sigma=50.0)
                _, _, hist = run_fed3r(
                    fed_s, test.features, test.labels, f3,
                    fed_cfg(n_clients=n_cl, n_rounds=1000), eval_every=10_000,
                )
                accs.append(hist.accuracy[-1])
        name = "fig1_invariance_" + ("fed3r_rf" if use_rf else "fed3r")
        spread = max(accs) - min(accs)
        us = t["s"] * 1e6 / len(SPLITS)
        derived = (
            f"acc={accs[0]:.4f} spread={spread:.2e}"
            + ("" if use_rf else f" centralized={acc_cen:.4f} gap={abs(accs[0]-acc_cen):.2e}")
        )
        emit(name, us, derived)
        rows.append((name, accs, spread))
    return rows


if __name__ == "__main__":
    main()
