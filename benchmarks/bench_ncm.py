"""Paper Table 1 / Table 6: FED3R family vs FedNCM final accuracy."""
from __future__ import annotations

from benchmarks.common import RF_LAMBDA, RF_SIGMA, emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.federated import run_fed3r, run_fedncm


def main() -> list:
    fed, test = landmarks_like(nonlinear=True)  # NCM's weakness shows off-linear
    rows = []
    results = {}
    for name, rf in [("fed3r", 0), ("fed3r_rf_1k", 1024), ("fed3r_rf_4k", 4096)]:
        f3 = f3_cfg(n_random_features=rf, rff_sigma=RF_SIGMA,
                    ridge_lambda=RF_LAMBDA if rf else 0.01)
        with timed() as t:
            _, _, h = run_fed3r(fed, test.features, test.labels, f3,
                                fed_cfg(n_rounds=1000), eval_every=10_000)
        results[name] = h.accuracy[-1]
        emit(f"table1_{name}", t["s"] * 1e6, f"final={h.accuracy[-1]:.4f}")
        rows.append((name, h.accuracy[-1]))

    with timed() as t:
        _, hn = run_fedncm(fed, test.features, test.labels, fed_cfg())
    results["fedncm"] = hn.accuracy[-1]
    emit("table1_fedncm", t["s"] * 1e6, f"final={hn.accuracy[-1]:.4f}")
    rows.append(("fedncm", hn.accuracy[-1]))

    margin = results["fed3r_rf_4k"] - results["fedncm"]
    emit("table1_rf_vs_ncm_margin", 0.0, f"margin={margin:.4f}")
    return rows


if __name__ == "__main__":
    main()
