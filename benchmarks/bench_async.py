"""Asynchronous merge-on-arrival rounds vs the synchronous barrier.

The claims under test (ISSUE 8 acceptance):

* at 20% simulated stragglers (8× slower uploads) the async engine's
  round-completion makespan is ≥ 1.5× faster than the synchronous
  barrier replaying the SAME chaos-injected upload timeline — the barrier
  waits for every straggler, the async cadence closes at the deadline and
  folds stragglers late under the staleness bound;
* the final W of the two runs is BITWISE identical (merge-on-arrival is a
  reordering of the same statistics sum, and the engine's slot/retire
  design makes the fp32 operand sequence identical) with zero dropped
  uploads;
* adaptive dropout: with per-client health demotion enabled, persistent
  stragglers leave the sampled cohorts after ``demote_after`` blown
  deadlines and the steady-state rounds complete at the fast cohort's
  pace — the completion-time-vs-dropout curve.

Simulated time is deterministic in the seeds (wall time appears only as
``wall_s``), so the speedup gates stably in CI via
``baselines/BENCH_async.json``.

Usage: PYTHONPATH=src:. python benchmarks/bench_async.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit
from repro.core import fed3r
from repro.data.pipeline import make_federated_features
from repro.federated.arrivals import ChaosSpec, chaos_timeline, latency_profile
from repro.federated.async_engine import (
    AsyncConfig,
    AsyncRoundEngine,
    client_payloads,
    run_adaptive_rounds,
    run_chaos_timeline,
)
from repro.federated.costs import CostModel

D_FEAT = 48
N_CLASSES = 10
RIDGE_LAMBDA = 1e-2
STRAGGLER_FRAC = 0.2
STRAGGLER_FACTOR = 8.0
BASE_LATENCY = 0.3
DEADLINE = 1.0


def _build(n_clients, cohort, *, synchronous, staleness=3, early_close=False,
           demote_after=10_000):
    # demote_after is effectively off for the parity legs: both runs must
    # sample identical cohorts, so health-based demotion stays out of them
    return AsyncRoundEngine(AsyncConfig(
        n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA, cohort=cohort,
        deadline=DEADLINE, staleness_rounds=staleness,
        synchronous=synchronous, early_close=early_close,
        demote_after=demote_after,
    ))


def main(smoke: bool = False) -> dict:
    n_rounds = 8 if smoke else 16
    n_clients = 24 if smoke else 48
    cohort = 10 if smoke else 16
    seed = 0

    fed, test = make_federated_features(
        seed=seed, n=3000, d=D_FEAT, n_classes=N_CLASSES,
        n_clients=n_clients, alpha=0.3, noise=2.0,
    )
    payloads = client_payloads(fed, N_CLASSES)
    # per-round draws without replacement (epoch-style sample_round can
    # repeat a client inside a round when the window spans an epoch edge)
    cohorts = [
        sorted(
            np.random.default_rng((seed + 1, r))
            .choice(n_clients, size=cohort, replace=False)
            .tolist()
        )
        for r in range(n_rounds)
    ]
    latency = latency_profile(
        n_clients, STRAGGLER_FRAC, straggler_factor=STRAGGLER_FACTOR,
        base=BASE_LATENCY, jitter=0.5, seed=seed + 2,
    )
    # bounded-tail chaos: drops retransmit within 3 RTOs, no transient delay
    # fault on top of the persistent straggler profile — so every upload
    # lands inside the staleness window and the parity claim is exact-once
    spec = ChaosSpec(
        drop=0.2, duplicate=0.1, reorder=0.3, rto=0.1, max_attempts=4,
        seed=seed + 3,
    )
    events = chaos_timeline(cohorts, latency, spec)

    def payload_for(c, r):
        return payloads[c]

    t0 = time.time()
    e_async = _build(n_clients, cohort, synchronous=False)
    s_async, rep_async = run_chaos_timeline(
        e_async, e_async.init(D_FEAT), cohorts, events, payload_for
    )
    async_wall = time.time() - t0

    t0 = time.time()
    e_sync = _build(n_clients, cohort, synchronous=True)
    s_sync, rep_sync = run_chaos_timeline(
        e_sync, e_sync.init(D_FEAT), cohorts, events, payload_for
    )
    sync_wall = time.time() - t0

    parity = bool(np.array_equal(np.asarray(s_async.W), np.asarray(s_sync.W)))
    speedup = rep_sync["makespan"] / rep_async["makespan"]
    acc = float(fed3r.accuracy(
        s_async.W, np.asarray(test.features), np.asarray(test.labels)
    ))

    # adaptive dropout: persistent stragglers demoted out of the cohorts;
    # steady-state rounds close at the fast cohort's early-close pace
    e_adapt = AsyncRoundEngine(AsyncConfig(
        n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA, cohort=cohort,
        deadline=DEADLINE, staleness_rounds=3, demote_after=2, cooldown=2 * n_rounds,
    ))
    _, rep_adapt = run_adaptive_rounds(
        e_adapt, e_adapt.init(D_FEAT), n_clients, cohort, n_rounds,
        latency, spec, payload_for, seed=seed + 4,
    )
    tail = rep_adapt["completion"][n_rounds // 2:]
    adaptive_tail = float(np.mean(tail))

    analytic = CostModel(b=2.22e6, d=D_FEAT, C=N_CLASSES).straggler_tail(
        cohort, STRAGGLER_FRAC, straggler_factor=STRAGGLER_FACTOR,
        base_s=BASE_LATENCY, deadline_s=DEADLINE,
    )

    emit(
        "async_sync_barrier", sync_wall * 1e6,
        f"R={n_rounds} K={cohort} makespan={rep_sync['makespan']:.2f}",
    )
    emit(
        "async_merge_on_arrival", async_wall * 1e6,
        f"R={n_rounds} K={cohort} makespan={rep_async['makespan']:.2f} "
        f"speedup={speedup:.2f}x parity={parity} acc={acc:.3f}",
    )
    emit(
        "async_adaptive_dropout", 0.0,
        f"demoted={len(rep_adapt['demoted'])} "
        f"tail_completion={adaptive_tail:.3f}s vs deadline={DEADLINE}",
    )

    assert parity, "async W diverged from the synchronous barrier (bitwise)"
    assert rep_async["dropped_uploads"] == 0, (
        f"staleness window dropped {rep_async['dropped_uploads']} uploads; "
        "the parity comparison needs exact-once delivery"
    )
    assert speedup >= 1.5, (
        f"async round completion must be >= 1.5x the barrier at "
        f"{STRAGGLER_FRAC:.0%} stragglers, got {speedup:.2f}x"
    )
    assert adaptive_tail < DEADLINE, (
        f"adaptive dropout should close steady-state rounds before the "
        f"deadline, got {adaptive_tail:.3f}s"
    )

    return {
        "rounds": n_rounds,
        "cohort": cohort,
        "n_clients": n_clients,
        "straggler_frac": STRAGGLER_FRAC,
        "sync_makespan": rep_sync["makespan"],
        "async_makespan": rep_async["makespan"],
        "round_speedup": speedup,
        "analytic_speedup": analytic["speedup"],
        "parity_bitwise": parity,
        "dropped_uploads": rep_async["dropped_uploads"],
        "late_folds": rep_async["late_folds"],
        "duplicates_deduped": rep_async["duplicates"],
        "async_dispatches": rep_async["dispatches"],
        "adaptive_demoted": len(rep_adapt["demoted"]),
        "adaptive_tail_completion": adaptive_tail,
        "acc_async": acc,
        "wall_s": async_wall + sync_wall,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
