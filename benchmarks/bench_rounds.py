"""Batched cohort round engine vs per-client round loop (gradient FL).

The claim under test (ISSUE 2 acceptance): a K-client FedAvg-family round
costs ONE jitted dispatch through the round engine — vmapped local updates
over the packed cohort, on-device weighted aggregation, server optimizer
step — vs the seed-era loop's K local-update dispatches + host-side Python
aggregation + 1 server dispatch (K+1).  And the engine matches the
per-client reference for fedavg / fedprox / scaffold within fp tolerance.

Same protocol as bench_engine.py, on the Fed3R+FT side of the paper.

Usage: PYTHONPATH=src:. python benchmarks/bench_rounds.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.data.pipeline import pack_cohort_batches
from repro.federated.algorithms import make_algorithm
from repro.federated.round_engine import ReferenceLoop, RoundConfig, RoundEngine
from repro.federated.sampling import sample_round
from repro.federated.simulator import linear_head_task

K = 48  # clients in the federation
COHORT = 16  # clients sampled per round
D_FEAT = 32
N_CLASSES = 10
BATCH = 16
N_BATCHES = 5  # ⌈80 / BATCH⌉


def _make_federation(n_lo=20, n_hi=80, seed=0):
    rng = np.random.default_rng(seed)
    clients = []
    for _ in range(K):
        n = int(rng.integers(n_lo, n_hi))
        clients.append((
            rng.normal(size=(n, D_FEAT)).astype(np.float32),
            rng.integers(0, N_CLASSES, size=n).astype(np.int32),
        ))
    return clients


def _task(clients):
    test_x = np.concatenate([x for x, _ in clients])[:256]
    test_y = np.concatenate([y for _, y in clients])[:256]
    return linear_head_task(D_FEAT, N_CLASSES, test_x, test_y)


def _cohorts(clients, rounds, seed=0):
    out = []
    for rnd in range(rounds):
        chosen = sample_round(K, COHORT, rnd, seed=seed)
        out.append(pack_cohort_batches(
            [clients[int(c)] for c in chosen], BATCH, N_BATCHES,
            client_ids=chosen, seed=(seed, rnd),
        ))
    return out


def _run(loop, task, cohorts, reps):
    """Time ``reps`` repetitions of the round sequence (post-warmup)."""
    state = loop.init(task.params0)
    for cohort in cohorts:  # warm every trace
        state = loop.step(state, cohort)
    jax.block_until_ready(state.params)
    loop.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        state = loop.init(task.params0)
        for cohort in cohorts:
            state = loop.step(state, cohort)
        jax.block_until_ready(state.params)
    per_round = (time.time() - t0) / (reps * len(cohorts))
    return state, loop.dispatches // (reps * len(cohorts)), per_round


def main(smoke: bool = False) -> dict:
    reps = 1 if smoke else 5
    rounds = 2 if smoke else 5
    clients = _make_federation()
    task = _task(clients)
    cohorts = _cohorts(clients, rounds)

    # parity: engine == per-client reference for the heterogeneity baselines
    parity = {}
    for name in ("fedavg", "fedprox", "scaffold"):
        rc = RoundConfig(algo=make_algorithm(name), client_lr=0.05,
                         n_total_clients=K)
        eng = RoundEngine(rc, task.per_example_loss, task.freeze)
        ref = ReferenceLoop(rc, task.per_example_loss, task.freeze)
        se, sr = eng.init(task.params0), ref.init(task.params0)
        for cohort in cohorts:
            se, sr = eng.step(se, cohort), ref.step(sr, cohort)
        err = max(
            float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
            for a, b in zip(jax.tree.leaves(se.params), jax.tree.leaves(sr.params))
        )
        parity[name] = err
        assert err < 1e-4, f"{name}: engine/reference divergence {err}"

    # timing: the fedavg round sequence, engine vs per-client loop
    rc = RoundConfig(algo=make_algorithm("fedavg"), client_lr=0.05,
                     n_total_clients=K)
    _, ref_disp, ref_s = _run(
        ReferenceLoop(rc, task.per_example_loss, task.freeze), task, cohorts, reps
    )
    _, eng_disp, eng_s = _run(
        RoundEngine(rc, task.per_example_loss, task.freeze), task, cohorts, reps
    )

    speedup = ref_s / eng_s if eng_s > 0 else float("inf")
    emit(
        "rounds_reference_loop", ref_s * 1e6,
        f"K={COHORT} dispatches_per_round={ref_disp}",
    )
    emit(
        "rounds_packed_engine", eng_s * 1e6,
        f"K={COHORT} dispatches_per_round={eng_disp} speedup={speedup:.1f}x "
        f"parity_max_err={max(parity.values()):.2e}",
    )

    assert eng_disp == 1, f"engine must cost 1 dispatch/round, got {eng_disp}"
    assert ref_disp == COHORT + 1, f"reference should cost K+1, got {ref_disp}"
    return {
        "reference_s_per_round": ref_s,
        "engine_s_per_round": eng_s,
        "speedup": speedup,
        "reference_dispatches_per_round": ref_disp,
        "engine_dispatches_per_round": eng_disp,
        "parity_max_err": parity,
        "cohort": COHORT,
        "rounds": rounds,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
