"""Paper App. D/E + Fig. 2 mid/right: communication & computation meters.

Reproduces the paper's cost accounting at PAPER scale (MobileNetV2 d=1280,
Landmarks C=2028 / iNaturalist C=1203, FP32) — these are exact analytic
quantities, so the reproduction is exact, not directional.

The tail section meters the model against REALITY: actual quantized-array
bytes vs ``stats_wire_bytes``, XLA ``cost_analysis`` FLOPs vs the analytic
solve/serve counts, and the committed serving-bench QPS vs the roofline
ceiling.  Each delta lands as a ``cost_model_drift`` telemetry gauge and
prints a WARNING line when measured/model leaves [0.5, 2.0]x — the early
tripwire for the cost model silently drifting away from the code it prices.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np

from benchmarks.common import emit
from repro.federated.compress import sketch_psd
from repro.federated.costs import INATURALIST, LANDMARKS, CostModel
from repro.federated.telemetry import get_telemetry
from repro.kernels.ref import quantize_tiles_ref

ALGS = ("fedavg", "fedavgm", "scaffold", "fedavg-lp", "scaffold-lp",
        "fed3r", "fed3r-rf", "fed3r-personalized", "personalized-ft")


def _drift(name: str, measured: float, model: float,
           warn_low: bool = True, note: str = "") -> None:
    """One measured-vs-CostModel meter: gauge + WARNING outside [0.5, 2.0]x.

    ``warn_low=False`` silences the under-count direction for meters where
    the measurement is a known lower bound (XLA ``cost_analysis`` omits
    custom-call FLOPs, so library Cholesky/triangular solves read low).
    """
    ratio = measured / model if model else float("inf")
    get_telemetry().gauge("cost_model_drift", meter=name).set(ratio)
    flag = ""
    if ratio > 2.0 or (warn_low and ratio < 0.5):
        flag = " WARNING_gt2x_drift"
        print(f"# WARNING drift_{name}: measured/model = {ratio:.3f}x "
              f"(outside [0.5, 2.0])", flush=True)
    extra = f" note={note}" if note else ""
    emit(f"drift_{name}", 0.0,
         f"measured={measured:.4e} model={model:.4e} "
         f"ratio={ratio:.3f}x{flag}{extra}")


def _xla_flops(fn, *xs) -> float | None:
    """FLOPs XLA attributes to the compiled fn, or None when unavailable."""
    try:
        c = jax.jit(fn).lower(*xs).compile().cost_analysis()
        if isinstance(c, list):
            c = c[0]
        f = c.get("flops")
        return None if f is None else float(f)
    except Exception:  # noqa: BLE001 — cost_analysis is backend-best-effort
        return None


def measured_vs_model() -> None:
    """Meter the CostModel against real arrays, XLA, and the committed bench."""
    d, C, tile, rank, q = 256, 64, 128, 16, 1024
    cm = CostModel(b=0.0, d=d, C=C)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((2 * d, d)).astype(np.float32)
    A = jnp.asarray(X.T @ X)  # a real PSD second moment
    b = jnp.asarray(rng.standard_normal((d, C)).astype(np.float32))

    # wire bytes: the bytes that actually cross the uplink per format
    _drift("wire_fp32_bytes", A.nbytes + b.nbytes,
           cm.compressed_stats_bytes("fp32", tile=tile, rank=rank))
    qa, sa = quantize_tiles_ref(A, tile=tile)
    qb, sb = quantize_tiles_ref(b, tile=tile)
    _drift("wire_int8_bytes", qa.nbytes + sa.nbytes + qb.nbytes + sb.nbytes,
           cm.compressed_stats_bytes("int8", tile=tile, rank=rank))
    Z = sketch_psd(A, rank)
    _drift("wire_sketch_bytes", Z.nbytes + b.nbytes,
           cm.compressed_stats_bytes("sketch", tile=tile, rank=rank))

    # serve/solve FLOPs: what XLA prices the compiled stages at
    xs = jnp.ones((q, d), jnp.float32)
    W = jnp.ones((d, C), jnp.float32)
    f_serve = _xla_flops(lambda x, w: x @ w, xs, W)
    if f_serve is not None:
        _drift("serve_flops", f_serve, cm.serve_flops(q))
    f_solve = _xla_flops(
        lambda a, rhs: jsl.cho_solve(jsl.cho_factor(a, lower=True), rhs),
        A + d * jnp.eye(d), b,
    )
    if f_solve is not None:
        _drift("solve_flops", f_solve, d**3 / 3.0 + 2.0 * d * d * C,
               warn_low=False, note="xla_omits_custom_call_flops")

    # QPS roofline: committed serving bench vs the model's chip ceiling —
    # a FRACTION of the ceiling is expected; above 1.0 the model is wrong
    base = os.path.join(os.path.dirname(__file__), "baselines",
                        "BENCH_serving.json")
    if os.path.exists(base):
        with open(base) as f:
            bench = json.load(f)
        cm_bench = CostModel(b=0.0, d=64, C=10)  # bench_serving full scale
        roof = cm_bench.serving_qps_roofline()["qps"]
        frac = float(bench["slots_qps"]) / roof
        get_telemetry().gauge("serving_qps_roofline_fraction").set(frac)
        flag = ""
        if frac > 1.0:
            flag = " WARNING_above_roofline"
            print(f"# WARNING serving qps above model roofline: "
                  f"{frac:.3f}x", flush=True)
        emit("drift_serving_qps_roofline", 0.0,
             f"bench_qps={bench['slots_qps']:.3e} roofline_qps={roof:.3e} "
             f"fraction={frac:.4f}{flag}")


def main() -> list:
    rows = []
    for ds_name, cm0, K, n_k in (
        ("landmarks", LANDMARKS, 1262, 119.9),
        ("inaturalist", INATURALIST, 9275, 13.0),
    ):
        cm = cm0.__class__(**{**cm0.__dict__, "D": 10_000})
        for alg in ALGS:
            comm = cm.comm_per_client(alg)
            comp = cm.comp_per_client(alg, n_k)
            emit(
                f"appD_{ds_name}_{alg}", 0.0,
                f"down_params={comm['down']:.3e} up_params={comm['up']:.3e} "
                f"comp_flops_per_round={comp:.3e}",
            )
            rows.append((ds_name, alg, comm, comp))

        # headline ratio (paper §5.2: up to two orders of magnitude)
        rounds_fed3r = -(-K // 10)  # ⌈K/κ⌉
        grad_total = cm.comp_per_client("fedavg", n_k) * 3000 * 10 / K
        f3_total = cm.comp_per_client("fed3r", n_k)
        emit(
            f"appE_{ds_name}_compute_ratio", 0.0,
            f"fedavg_vs_fed3r_x={grad_total / f3_total:.1f} "
            f"fed3r_rounds_to_exact={rounds_fed3r}",
        )
        comm_grad = (cm.comm_per_client("fedavg")["up"] * 2) * 4  # up+down
        comm_f3 = cm.comm_per_client("fed3r")["up"] * 4
        emit(
            f"appD_{ds_name}_comm_per_client_ratio", 0.0,
            f"fedavg_roundtrip_bytes={comm_grad:.3e} fed3r_once_bytes={comm_f3:.3e} "
            f"note=fed3r_pays_once_gradFL_pays_every_visit",
        )

        # multi-tenant personalized serving at planet scale (1M tenants):
        # head-cache + retained-stats memory, and the wire cost of the
        # closed form vs a full-model push per tenant
        M_TENANTS = 1_000_000
        emit(
            f"personalize_{ds_name}_serving_memory", 0.0,
            f"head_cache_gb_per_1M={cm.head_cache_bytes(M_TENANTS) / 1e9:.2f} "
            f"tenant_stats_gb_per_1M={cm.tenant_stats_bytes(M_TENANTS) / 1e9:.2f}",
        )
        emit(
            f"personalize_{ds_name}_wire_ratio", 0.0,
            f"ft_roundtrip_vs_onetime_stats_upload_x="
            f"{cm.personalization_vs_model_push_ratio():.2f} "
            f"note=lower_bound__closed_form_marginal_upload_is_zero_"
            f"and_ft_repays_per_refresh",
        )

        # continuous-batching slot serving: the fixed slot table vs a
        # full 1M-tenant head store, per-tick solve-vs-serve FLOPs, and
        # the serve stage's memory-bound QPS roofline
        S_SLOTS = 4096
        roof = cm.serving_qps_roofline()
        emit(
            f"serving_{ds_name}_slot_table", 0.0,
            f"slot_table_mb_at_{S_SLOTS}_slots="
            f"{cm.slot_table_bytes(S_SLOTS) / 1e6:.1f} "
            f"full_1M_head_store_gb={cm.head_cache_bytes(M_TENANTS) / 1e9:.2f} "
            f"solve_tick_gflops_64_misses={cm.slot_solve_flops(64, n_k) / 1e9:.2f} "
            f"serve_tick_mflops_4096_queries={cm.serve_flops(4096) / 1e6:.2f}",
        )
        emit(
            f"serving_{ds_name}_qps_roofline", 0.0,
            f"bound={roof['bound']} qps={roof['qps']:.3e} "
            f"bytes_per_query={roof['bytes_per_query']:.0f} "
            f"compute_qps={roof['compute_bound_qps']:.3e} "
            f"memory_qps={roof['memory_bound_qps']:.3e}",
        )

        # two-stage statistics all-reduce on the production meshes
        # (repro.federated.dist): intra-pod ICI stage vs cross-pod DCN
        # stage for the d² payload, vs the flat single-stage all-reduce
        for mesh_name, dp, pods in (("pod_16x16", 16, 1), ("multipod_2x16x16", 16, 2)):
            ar = cm.two_stage_allreduce(dp, pods)
            emit(
                f"dist_{ds_name}_allreduce_{mesh_name}", ar["total_s"] * 1e6,
                f"payload_mb={ar['payload_bytes'] / 1e6:.1f} "
                f"ici_bytes_per_chip={ar['ici_bytes_per_chip']:.3e} "
                f"dcn_bytes_per_pod={ar['dcn_bytes_per_pod']:.3e} "
                f"ici_us={ar['ici_s'] * 1e6:.1f} dcn_us={ar['dcn_s'] * 1e6:.1f} "
                f"flat_us={ar['flat_allreduce_s'] * 1e6:.1f}",
            )

        # compressed statistics wire formats (repro.federated.compress):
        # per-upload bytes at paper scale, the retained-stats figure per 1M
        # tenants, and the two-stage all-reduce re-priced under int8 tiles
        for kind in ("fp32", "int8", "fp8", "sketch"):
            emit(
                f"compress_{ds_name}_wire_{kind}", 0.0,
                f"upload_mb={cm.compressed_stats_bytes(kind) / 1e6:.2f} "
                f"ratio_vs_fp32={cm.wire_compression_ratio(kind):.2f}x "
                f"tenant_stats_gb_per_1M="
                f"{cm.compressed_stats_bytes(kind, M_TENANTS) / 1e9:.2f}",
            )
        ar8 = cm.two_stage_allreduce(16, 2, wire="int8")
        emit(
            f"compress_{ds_name}_allreduce_int8_multipod", ar8["total_s"] * 1e6,
            f"payload_mb={ar8['payload_bytes'] / 1e6:.1f} "
            f"ici_us={ar8['ici_s'] * 1e6:.1f} dcn_us={ar8['dcn_s'] * 1e6:.1f}",
        )
    measured_vs_model()
    return rows


if __name__ == "__main__":
    main()
