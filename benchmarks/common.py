"""Shared benchmark fixtures: the synthetic "Landmarks-like" federation.

Paper datasets (Landmarks/iNaturalist + ImageNet MobileNetV2) are not
available offline; every benchmark runs on a controlled synthetic federation
whose *exact* claims (invariance, equivalence, round counts, cost ratios)
are checkable analytically, and whose accuracy-shaped comparisons reproduce
the paper's orderings directionally.  Scale is CPU-budgeted.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.data import make_federated_features

# benchmark-wide synthetic federation scale (calibrated so FED3R lands
# mid-accuracy — nothing saturates — and the RBF-RF variant has headroom)
N, D, C, K = 16_000, 64, 50, 200
NOISE = 6.0
ALPHA = 0.0  # one-class-per-client: the paper's most heterogeneous split
CLIENTS_PER_ROUND = 10

# nonlinear (quadratic-boundary) federation for the RF/NCM benchmarks
NL_D, NL_C = 24, 10
RF_SIGMA = 15.0  # RBF bandwidth matched to the nonlinear feature scale
RF_LAMBDA = 1.0


def landmarks_like(nonlinear: bool = False, seed: int = 0):
    if nonlinear:
        return make_federated_features(
            seed=seed, n=N, d=NL_D, n_classes=NL_C, n_clients=K, alpha=ALPHA,
            nonlinear=True, noise=0.05,
        )
    return make_federated_features(
        seed=seed, n=N, d=D, n_classes=C, n_clients=K, alpha=ALPHA, noise=NOISE,
    )


def fed_cfg(**kw) -> FederatedConfig:
    base = dict(
        n_clients=K, clients_per_round=CLIENTS_PER_ROUND, n_rounds=60,
        local_epochs=1, local_batch_size=32, client_lr=0.05,
        client_weight_decay=4e-5, server_lr=1.0, algorithm="fedavg", seed=0,
    )
    base.update(kw)
    return FederatedConfig(**base)


def f3_cfg(**kw) -> Fed3RConfig:
    base = dict(ridge_lambda=0.01, n_classes=C)
    base.update(kw)
    return Fed3RConfig(**base)


@contextmanager
def timed():
    box = {}
    t0 = time.time()
    yield box
    box["s"] = time.time() - t0


def emit(name: str, us_per_call: float, derived: str) -> None:
    """Contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")
