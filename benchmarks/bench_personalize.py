"""Batched personalization engine vs per-client re-solve loop.

The claims under test (ISSUE 4 acceptance):

* a K=64-head cohort solves in ONE jitted dispatch through the
  personalization engine (grid-over-heads batched rank-n Cholesky updates
  + batched triangular solves + in-dispatch α selection) vs the reference
  loop's K+1 (one global solve + one re-solve per client);
* the engine's heads match the per-client reference re-solves to ≤ 1e-5
  max-abs in fp32 at λ = 1e-2 (same α_k handed to both);
* an α grid pinned to 0 reproduces the global ``factored_solution``
  BITWISE for every head.

Same protocol as bench_engine.py / bench_rounds.py / bench_streaming.py,
on the multi-tenant serving side of the ROADMAP.

Usage: PYTHONPATH=src:. python benchmarks/bench_personalize.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import fed3r
from repro.data.pipeline import pack_personal_cohort
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
    ReferencePersonalizedLoop,
    cohort_stats,
)

D_FEAT = 64
N_CLASSES = 10
COHORT = 64  # the K=64-head acceptance cohort
RIDGE_LAMBDA = 1e-2
ALPHA_GRID = (0.0, 0.5, 1.0, 2.0, 4.0)


def _make_cohort(seed=0, n_lo=40, n_hi=90):
    rng = np.random.default_rng(seed)
    clients = []
    for _ in range(COHORT):
        n = int(rng.integers(n_lo, n_hi))
        clients.append((
            rng.normal(size=(n, D_FEAT)).astype(np.float32),
            rng.integers(0, N_CLASSES, size=n).astype(np.int32),
        ))
    return pack_personal_cohort(clients)


def _global_state(packed):
    stats = cohort_stats(packed, N_CLASSES)
    L = jnp.linalg.cholesky(
        stats.A + RIDGE_LAMBDA * jnp.eye(D_FEAT, dtype=jnp.float32)
    )
    return fed3r.Fed3RFactored(L=L, b=stats.b)


def _time_engine(engine, state, packed, reps):
    heads = engine.solve_heads(state, packed)  # warm the trace
    jax.block_until_ready(heads.W)
    engine.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        heads = engine.solve_heads(state, packed)
        jax.block_until_ready(heads.W)
    sweep_s = (time.time() - t0) / reps
    sweep_disp = engine.dispatches // reps

    # the fixed-α batched solve — the apples-to-apples foil for the
    # reference loop, which also solves at given α_k (no selection)
    fixed = engine.solve_at(state, packed, heads.alpha)  # warm the trace
    jax.block_until_ready(fixed.W)
    engine.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        fixed = engine.solve_at(state, packed, heads.alpha)
        jax.block_until_ready(fixed.W)
    fixed_s = (time.time() - t0) / reps
    return heads, sweep_disp, sweep_s, engine.dispatches // reps, fixed_s


def _time_reference(loop, state, packed, alphas, reps):
    _, W = loop.solve_at(state, packed, alphas)  # warm the trace
    jax.block_until_ready(W)
    loop.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        _, W = loop.solve_at(state, packed, alphas)
        jax.block_until_ready(W)
    return W, loop.dispatches // reps, (time.time() - t0) / reps


def main(smoke: bool = False) -> dict:
    reps = 1 if smoke else 5
    packed = _make_cohort()
    state = _global_state(packed)
    cfg = PersonalizeConfig(n_classes=N_CLASSES, alpha_grid=ALPHA_GRID)

    engine = PersonalizationEngine(cfg)
    heads, sweep_disp, sweep_s, eng_disp, eng_s = _time_engine(
        engine, state, packed, reps
    )
    alphas = np.asarray(heads.alpha)
    W_ref, ref_disp, ref_s = _time_reference(
        ReferencePersonalizedLoop(cfg), state, packed, alphas, reps
    )

    # numerics: engine heads vs per-client re-solves at the same α_k
    personalize_err = float(jnp.max(jnp.abs(heads.W - W_ref)))

    # α grid pinned to 0 ⇒ every head IS the global factored_solution, bitwise
    eng0 = PersonalizationEngine(
        PersonalizeConfig(n_classes=N_CLASSES, alpha_grid=(0.0,))
    )
    W0 = eng0.solve_heads(state, packed).W
    W_global = fed3r.factored_solution(state)
    bit_identical_alpha0 = bool(
        np.array_equal(np.asarray(W0), np.broadcast_to(
            np.asarray(W_global)[None], W0.shape
        ))
    )

    speedup = ref_s / eng_s if eng_s > 0 else float("inf")
    sweep_speedup = ref_s / sweep_s if sweep_s > 0 else float("inf")
    emit(
        "personalize_reference_loop", ref_s * 1e6,
        f"K={packed.cohort} dispatches={ref_disp}",
    )
    emit(
        "personalize_batched_engine", eng_s * 1e6,
        f"K={packed.cohort} dispatches={eng_disp} speedup={speedup:.1f}x "
        f"personalize_err={personalize_err:.2e} "
        f"alpha0_bitwise={bit_identical_alpha0}",
    )
    emit(
        "personalize_engine_with_selection", sweep_s * 1e6,
        f"K={packed.cohort} grid={len(ALPHA_GRID)} dispatches={sweep_disp} "
        f"speedup_vs_fixed_alpha_loop={sweep_speedup:.1f}x",
    )

    assert eng_disp == 1, f"engine must cost 1 dispatch per cohort, got {eng_disp}"
    assert sweep_disp == 1, (
        f"α selection must stay inside the one dispatch, got {sweep_disp}"
    )
    assert ref_disp == packed.cohort + 1, (
        f"reference should cost K+1={packed.cohort + 1}, got {ref_disp}"
    )
    assert personalize_err <= 1e-5, (
        f"engine drifted from the per-client re-solves: {personalize_err:.2e}"
    )
    assert bit_identical_alpha0, "α=0 must reproduce factored_solution bitwise"
    return {
        "reference_s_per_cohort": ref_s,
        "engine_s_per_cohort": eng_s,
        "engine_with_selection_s_per_cohort": sweep_s,
        "speedup": speedup,
        "selection_speedup": sweep_speedup,
        "reference_dispatches": ref_disp,
        "engine_dispatches": eng_disp,
        "selection_dispatches": sweep_disp,
        "personalize_err": personalize_err,
        "bit_identical_alpha0": bit_identical_alpha0,
        "cohort": packed.cohort,
        "samples": packed.n_samples,
        "alpha_grid_size": len(ALPHA_GRID),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
