"""Hierarchical N-tier aggregation trees vs the flat/two-stage baseline.

The claims under test (ISSUE 10 acceptance):

* routing every engine's all-reduce through an fp32
  :class:`repro.federated.tiers.AggregationTree` is BITWISE identical to
  the two-stage psum AND to the single-device merge backend, at 1-, 2-
  and 3-tier mesh shapes, still in ONE host dispatch per call — measured
  on a subprocess worker with 8 simulated host devices (the same
  ``xla_force_host_platform_device_count`` knob as ``bench_scaleout``);
* the overlapped :class:`repro.federated.tiers.TieredAbsorber` (upper
  DCN/WAN reduction of segment t concurrent with the lower fold +
  extraction of segment t+1) sustains ≥ 1.3× the blocking two-stage
  throughput at the 8-leaf 3-tier CI shape.  Like ``bench_async``, the
  gated figure is the DETERMINISTIC scheduled makespan at
  ``CostModel``-priced tier times (on shared CI CPUs, host and "device"
  compute contend for the same cores, so wall time measures contention,
  not DCN overlap — wall times are still reported and loosely gated);
* blocking == overlapped == ``engine.absorb_stats`` of the flat sum,
  bitwise, and the absorber's host dispatch counts are EXACT: one fused
  dispatch per segment blocking (at every tier count), lower + upper per
  segment overlapped;
* the per-tier byte meters match ``CostModel.tiered_allreduce``:
  the measured-vs-model drift gauge must sit inside [0.5, 2.0]×, and the
  same pricer produces the 512-device × 2-pod dry-run figures.

Usage: PYTHONPATH=src:. python benchmarks/bench_tiers.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# ---- worker (8 simulated devices) workload ---------------------------------
N_DEV = 8
D_FEAT = 32
N_CLASSES = 10
SHARDS_PER_DEV = 2
CLIENTS_PER_SHARD = 2
SAMPLES_PER_CLIENT = 16
RIDGE_LAMBDA = 0.1
# the 1/2/3-tier shapes of the same 8 devices (outermost tier first)
TIER_SHAPES = {"tiers1": (8,), "tiers2": (2, 4), "tiers3": (2, 2, 2)}

# ---- host-absorber workload -------------------------------------------------
ABS_D = 64
ABS_C = 16
ABS_N = 128  # samples per edge block per segment


def _grid(rng, shape):
    # features on a 1/8 grid in [-2, 2]: fp32 partial sums are EXACT at
    # this scale, so every reduction order is bitwise identical (the same
    # contract bench_scaleout gates; see its make_clients note)
    return (rng.integers(-16, 17, size=shape) / 8.0).astype("float32")


# ---------------------------------------------------------------------------
# worker: mesh-routed trees on 8 simulated devices, one process
# ---------------------------------------------------------------------------


def worker() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fed3r
    from repro.data.pipeline import (
        pack_arrival_waves,
        pack_client_shards,
        pack_cohort_batches,
        pack_personal_cohort,
    )
    from repro.federated.algorithms import make_algorithm
    from repro.federated.arrivals import UploadEvent
    from repro.federated.async_engine import AsyncConfig, AsyncRoundEngine
    from repro.federated.dist import DistConfig
    from repro.federated.engine import AccumulationEngine, EngineConfig
    from repro.federated.personalization import (
        PersonalizationEngine,
        PersonalizeConfig,
    )
    from repro.federated.round_engine import RoundConfig, RoundEngine
    from repro.federated.streaming_engine import StreamConfig, StreamingEngine
    from repro.federated.telemetry import get_telemetry
    from repro.federated.tiers import mesh_tree
    from repro.launch.mesh import make_tier_host_mesh

    assert len(jax.devices()) == N_DEV, (len(jax.devices()), N_DEV)

    def make_clients(seed, k):
        rng = np.random.default_rng(seed)
        return [
            (
                _grid(rng, (SAMPLES_PER_CLIENT, D_FEAT)),
                rng.integers(0, N_CLASSES, size=SAMPLES_PER_CLIENT).astype(np.int32),
            )
            for _ in range(k)
        ]

    out: dict = {"n_devices": N_DEV}

    for key, shape in TIER_SHAPES.items():
        mesh = make_tier_host_mesh(shape)
        tree = mesh_tree(mesh)
        dist_tree = DistConfig(
            aggregation="psum", mesh=mesh, donate=False, tree=tree
        )
        dist_flat = DistConfig(aggregation="psum", mesh=mesh, donate=False)
        rec: dict = {"shape": list(shape), "axes": list(tree.axes)}

        # ---- batch statistics engine: tree vs two-stage vs merge ----------
        clients = make_clients(1, N_DEV * SHARDS_PER_DEV * CLIENTS_PER_SHARD)
        packed = pack_client_shards(clients, CLIENTS_PER_SHARD, mesh=mesh)
        accs = {}
        for name, dist in (("tree", dist_tree), ("flat", dist_flat), ("merge", None)):
            cfg = EngineConfig(n_classes=N_CLASSES) if dist is None else EngineConfig(
                n_classes=N_CLASSES, dist=dist
            )
            eng = AccumulationEngine(cfg)
            eng.accumulate(eng.init(D_FEAT), packed)  # warm the trace
            eng.dispatches = 0
            accs[name] = eng.accumulate(eng.init(D_FEAT), packed)
            if dist is not None:
                rec[f"engine_{name}_dispatches"] = eng.dispatches
        rec["engine_bitwise"] = bool(
            np.array_equal(np.asarray(accs["tree"].stats.A), np.asarray(accs["flat"].stats.A))
            and np.array_equal(np.asarray(accs["tree"].stats.A), np.asarray(accs["merge"].stats.A))
            and np.array_equal(np.asarray(accs["tree"].stats.b), np.asarray(accs["merge"].stats.b))
        )

        # ---- streaming engine: tree vs two-stage vs merge ------------------
        waves = [make_clients(10 + w, N_DEV) for w in range(3)]
        arrivals = pack_arrival_waves(waves, mesh=mesh)
        ws = {}
        for name, dist in (("tree", dist_tree), ("flat", dist_flat), ("merge", None)):
            scfg = dict(n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA)
            s_eng = StreamingEngine(
                StreamConfig(**scfg) if dist is None else StreamConfig(**scfg, dist=dist)
            )
            s_eng.absorb(s_eng.init(D_FEAT), arrivals)
            s_eng.dispatches = 0
            state, _ = s_eng.absorb(s_eng.init(D_FEAT), arrivals)
            ws[name] = np.asarray(state.W)
            if dist is not None:
                rec[f"streaming_{name}_dispatches"] = s_eng.dispatches
        rec["streaming_bitwise"] = bool(
            np.array_equal(ws["tree"], ws["flat"])
            and np.array_equal(ws["tree"], ws["merge"])
        )
        out[key] = rec

    # ---- rounds + personalization: tree == two-stage on the 3-tier mesh ----
    mesh = make_tier_host_mesh(TIER_SHAPES["tiers3"])
    tree = mesh_tree(mesh)
    dist_tree = DistConfig(aggregation="psum", mesh=mesh, donate=False, tree=tree)
    dist_flat = DistConfig(aggregation="psum", mesh=mesh, donate=False)
    rec = out["tiers3"]

    cohort_clients = make_clients(20, N_DEV)
    cohort = pack_cohort_batches(cohort_clients, 8, 2, mesh=mesh)
    params0 = {"W": jnp.zeros((D_FEAT, N_CLASSES), jnp.float32)}
    freeze = jax.tree.map(lambda _: 1.0, params0)

    def per_example_loss(params, batch):
        logits = batch["x"] @ params["W"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    r_ws = {}
    for name, dist in (("tree", dist_tree), ("flat", dist_flat)):
        rcfg = dict(algo=make_algorithm("fedavg"), client_lr=0.1,
                    n_total_clients=len(cohort_clients), dist=dist)
        r_eng = RoundEngine(RoundConfig(**rcfg), per_example_loss, freeze)
        r_eng.step(r_eng.init(params0), cohort)
        r_eng.dispatches = 0
        r_ws[name] = np.asarray(r_eng.step(r_eng.init(params0), cohort).params["W"])
        rec[f"rounds_{name}_dispatches"] = r_eng.dispatches
    rec["rounds_bitwise"] = bool(np.array_equal(r_ws["tree"], r_ws["flat"]))

    tenants = make_clients(30, N_DEV)
    pcohort = pack_personal_cohort(tenants, mesh=mesh)
    fac = fed3r.init_factored(D_FEAT, N_CLASSES, RIDGE_LAMBDA)
    fac = fed3r.factored_update(
        fac,
        jnp.asarray(np.concatenate([x for x, _ in tenants])),
        jnp.asarray(np.concatenate([y for _, y in tenants])),
    )
    p_ws = {}
    for name, dist in (("tree", dist_tree), ("flat", dist_flat)):
        p_eng = PersonalizationEngine(
            PersonalizeConfig(n_classes=N_CLASSES, dist=dist)
        )
        p_eng.solve_heads(fac, pcohort)
        p_eng.dispatches = 0
        p_ws[name] = np.asarray(p_eng.solve_heads(fac, pcohort).W)
        rec[f"personalize_{name}_dispatches"] = p_eng.dispatches
    rec["personalize_bitwise"] = bool(np.array_equal(p_ws["tree"], p_ws["flat"]))

    # ---- async engine: dist-owned mesh + tree == merge (PR-8 headroom) -----
    def client_payload(c):
        rng = np.random.default_rng((40, c))
        f = _grid(rng, (SAMPLES_PER_CLIENT, D_FEAT))
        y = rng.integers(0, N_CLASSES, size=SAMPLES_PER_CLIENT)
        return jax.tree.map(
            jax.block_until_ready,
            fed3r.client_stats(jnp.asarray(f), jnp.asarray(y), N_CLASSES),
        )

    payloads = {c: client_payload(c) for c in range(N_DEV)}

    def run_async(dist):
        acfg = dict(n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA, cohort=N_DEV)
        eng = AsyncRoundEngine(
            AsyncConfig(**acfg) if dist is None else AsyncConfig(**acfg, dist=dist)
        )
        st = eng.init(D_FEAT)
        eng.begin_round(0, list(range(N_DEV)), 0.0)
        for c in np.random.default_rng(41).permutation(N_DEV):
            st, status = eng.deliver(
                st, UploadEvent(round_id=0, client=int(c), t=0.1, attempt=0),
                payloads[int(c)],
            )
            assert status == "folded", status
        st = eng.close_round(st, 0, now=1.0)
        return np.asarray(eng.drain(st).W)

    w_async = {
        "merge": run_async(None),
        "mesh": run_async(dist_flat),
        "mesh_tree": run_async(dist_tree),
    }
    rec["async_bitwise"] = bool(
        np.array_equal(w_async["merge"], w_async["mesh"])
        and np.array_equal(w_async["merge"], w_async["mesh_tree"])
    )

    out["telemetry"] = get_telemetry().snapshot()
    return out


# ---------------------------------------------------------------------------
# parent: host-tier absorber, scheduled overlap makespan, dry-run pricing
# ---------------------------------------------------------------------------


def _run_worker() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEV}"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"tiers worker (N={N_DEV}) failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _ci_tree(tiers_mod, staleness=2, top_wire=None):
    """The 8-leaf 3-tier CI shape: 2 edge × 2 region × 2 cloud, ICI/DCN/WAN."""
    from repro.launch.mesh import DCN_BW, ICI_BW, WAN_BW

    return tiers_mod.AggregationTree((
        tiers_mod.TierSpec("edge", fan_in=2, bandwidth=ICI_BW),
        tiers_mod.TierSpec("region", fan_in=2, bandwidth=DCN_BW),
        tiers_mod.TierSpec(
            "cloud", fan_in=2, bandwidth=WAN_BW, staleness=staleness,
            **({"wire": top_wire} if top_wire is not None else {}),
        ),
    ))


def scheduled_makespan(
    cm, tree, *, n_segments: int, samples_per_leaf: int,
    flops_per_s: float = 1.97e14,
) -> dict:
    """Deterministic pipeline schedule at CostModel-priced leg times.

    LOWER leg per segment = feature extraction of every leaf block + the
    collective crossings below the top tier; UPPER leg = the top (WAN)
    crossing + the Gram refactorization/solve.  Blocking runs the legs
    serially per segment; the overlapped absorber is a two-stage pipeline
    (upper of segment t concurrent with lower of t+1), so its makespan is
    ``lower + (S-1)·max(lower, upper) + upper``.  All inputs are model
    constants — the speedup gates deterministically, like bench_async's
    simulated makespan.
    """
    priced = cm.tiered_allreduce(tree.as_cost_tiers())
    per_tier = {t["name"]: t["tier_s"] for t in priced["tiers"]}
    extract_s = tree.leaves * samples_per_leaf * cm.F_phi / flops_per_s
    solve_s = (cm.d**3 / 3.0 + 2.0 * cm.d**2 * cm.C) / flops_per_s
    lower_s = extract_s + sum(per_tier[t.name] for t in tree.tiers[:-1])
    upper_s = per_tier[tree.tiers[-1].name] + solve_s
    blocking = n_segments * (lower_s + upper_s)
    overlapped = lower_s + (n_segments - 1) * max(lower_s, upper_s) + upper_s
    return {
        "lower_s": lower_s,
        "upper_s": upper_s,
        "blocking_makespan_s": blocking,
        "overlap_makespan_s": overlapped,
        "overlap_speedup": blocking / overlapped,
        "priced": priced,
    }


def main(smoke: bool = False) -> dict:
    import numpy as np

    from benchmarks.common import emit
    from repro.federated import tiers
    from repro.federated.compress import WireFormat
    from repro.federated.costs import LANDMARKS, CostModel
    from repro.federated.engine import shard_stats
    from repro.federated.streaming_engine import StreamConfig, StreamingEngine
    from repro.federated.telemetry import get_telemetry
    from repro.launch.mesh import DCN_BW, ICI_BW, WAN_BW

    n_segments = 6 if smoke else 12
    result: dict = {"n_segments": n_segments}

    # ---- 1) mesh-routed trees on 8 simulated devices (subprocess) ----------
    rec = _run_worker()
    worker_snap = rec.pop("telemetry", None)
    if worker_snap:
        get_telemetry().merge_snapshot(worker_snap)
    result["mesh"] = rec
    for key in TIER_SHAPES:
        r = rec[key]
        emit(
            f"tiers_mesh_{key}", 0.0,
            f"shape={tuple(r['shape'])} engine_bitwise={r['engine_bitwise']} "
            f"streaming_bitwise={r['streaming_bitwise']}",
        )
        for flag in ("engine_bitwise", "streaming_bitwise"):
            assert r[flag], f"{key}: {flag} is False (tree != two-stage/merge)"
        for k, v in r.items():
            if k.endswith("_dispatches"):
                assert v == 1, f"{key}.{k} = {v} (one-dispatch contract)"
    for flag in ("rounds_bitwise", "personalize_bitwise", "async_bitwise"):
        assert rec["tiers3"][flag], f"tiers3: {flag} is False"

    # ---- 2) host-tier absorber: overlap == blocking == flat, exact counts --
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    tree = _ci_tree(tiers)
    leaves = tree.leaves
    segs = []
    for _ in range(n_segments):
        f = _grid(rng, (leaves, ABS_N, ABS_D))
        l = rng.integers(0, ABS_C, size=(leaves, ABS_N)).astype(np.int32)
        m = np.ones((leaves, ABS_N), np.float32)
        segs.append((f, l, m))

    eng = StreamingEngine(StreamConfig(n_classes=ABS_C, ridge_lambda=RIDGE_LAMBDA))
    tel = get_telemetry()

    def run_absorber(tree, overlap, cost_model=None):
        ab = eng.tiered_absorber(
            tree, overlap=overlap, cost_model=cost_model, telemetry=tel
        )
        f, l, m = segs[0]
        ab.absorb_segment(f, l, m)  # warm the traces
        ab.drain()
        ab.reset(ABS_D)
        before = ab.dist.dispatches
        t0 = time.time()
        for f, l, m in segs:
            ab.absorb_segment(f, l, m)
        state = ab.drain()
        return state, time.time() - t0, ab.dist.dispatches - before

    st_block, wall_block, disp_block = run_absorber(tree, overlap=False)
    st_over, wall_over, disp_over = run_absorber(tree, overlap=True)
    bitwise = bool(np.array_equal(np.asarray(st_block.W), np.asarray(st_over.W)))

    # flat reference: the same segments through absorb_stats of the flat sum
    st = eng.init(ABS_D)
    for f, l, m in segs:
        s = shard_stats(
            jnp.asarray(f).reshape(-1, ABS_D),
            jnp.asarray(l).reshape(-1),
            ABS_C,
            jnp.asarray(m).reshape(-1),
        )
        st = eng.absorb_stats(st, s.A, s.b, s.n)
    flat_bitwise = bool(np.array_equal(np.asarray(st.W), np.asarray(st_over.W)))

    assert bitwise, "overlapped W diverged from blocking (bitwise)"
    assert flat_bitwise, "tiered W diverged from the flat absorb_stats (bitwise)"
    assert disp_block == n_segments, (
        f"blocking: {disp_block} dispatches for {n_segments} segments "
        "(one fused dispatch per segment is the contract)"
    )
    assert disp_over == 2 * n_segments, (
        f"overlapped: {disp_over} dispatches for {n_segments} segments "
        "(one lower + one upper per segment is the contract)"
    )

    # one fused dispatch per segment at EVERY tier count (blocking path)
    per_tier_counts = {}
    for n_tiers, shapes in ((1, (8,)), (2, (4, 2)), (3, (2, 2, 2))):
        t = tiers.AggregationTree(tuple(
            tiers.TierSpec(f"t{i}", fan_in=k) for i, k in enumerate(shapes)
        ))
        _, _, disp = run_absorber(t, overlap=False)
        per_tier_counts[f"dispatches_{n_tiers}tier"] = disp
        assert disp == n_segments, (
            f"{n_tiers}-tier blocking absorb: {disp} dispatches "
            f"for {n_segments} segments"
        )
    result.update(per_tier_counts)

    # ---- 3) int8 top tier: byte meters vs the cost model (drift gauge) -----
    cm_abs = CostModel(b=2.22e6, d=ABS_D, C=ABS_C)
    tree8 = _ci_tree(tiers, top_wire=WireFormat(kind="int8"))
    st8_b, _, _ = run_absorber(tree8, overlap=False, cost_model=cm_abs)
    st8_o, _, _ = run_absorber(tree8, overlap=True, cost_model=cm_abs)
    int8_bitwise = bool(np.array_equal(np.asarray(st8_b.W), np.asarray(st8_o.W)))
    assert int8_bitwise, "int8-tier overlapped W diverged from blocking"
    drift = None
    for g in tel.snapshot()["gauges"]:
        if g["name"] == "tier_cost_model_drift":
            drift = float(g["value"])
    assert drift is not None, "tier_cost_model_drift gauge never published"
    assert 0.5 <= drift <= 2.0, (
        f"measured tier bytes drifted {drift:.3f}x from "
        "CostModel.tiered_allreduce (acceptance band [0.5, 2.0])"
    )

    # ---- 4) scheduled overlap speedup at the CI shape (the gated figure) ---
    sched = scheduled_makespan(
        LANDMARKS, _ci_tree(tiers, top_wire=WireFormat(kind="int8")),
        n_segments=n_segments, samples_per_leaf=256,
    )
    speedup = sched["overlap_speedup"]
    assert speedup >= 1.3, (
        f"overlapped tiered absorb must sustain >= 1.3x the blocking "
        f"two-stage throughput at the 3-tier CI shape, got {speedup:.2f}x"
    )

    # ---- 5) 512-device x 2-pod dry-run pricing -----------------------------
    dryrun_tree = tiers.AggregationTree((
        tiers.TierSpec("edge", fan_in=16, bandwidth=ICI_BW),
        tiers.TierSpec("region", fan_in=32, bandwidth=DCN_BW,
                       wire=WireFormat(kind="int8")),
        tiers.TierSpec("cloud", fan_in=2, bandwidth=WAN_BW,
                       wire=WireFormat(kind="int8"), staleness=2),
    ))
    dry = LANDMARKS.tiered_allreduce(dryrun_tree.as_cost_tiers())
    assert dry["leaves"] == 1024, dry["leaves"]  # 512 devices x 2 pods

    emit(
        "tiers_absorb_blocking", wall_block / n_segments * 1e6,
        f"S={n_segments} leaves={leaves} dispatches={disp_block}",
    )
    emit(
        "tiers_absorb_overlap", wall_over / n_segments * 1e6,
        f"S={n_segments} leaves={leaves} dispatches={disp_over} "
        f"bitwise={bitwise} sched_speedup={speedup:.2f}x",
    )
    emit(
        "tiers_dryrun_512x2", 0.0,
        f"total={dry['total_s']*1e3:.2f}ms vs flat={dry['flat_allreduce_s']*1e3:.2f}ms "
        f"({dry['speedup_vs_flat']:.1f}x) uplink={dry['uplink_bytes_total']/1e9:.2f}GB "
        f"drift={drift:.3f}",
    )

    result.update({
        "leaves": leaves,
        "tiered_bitwise": bitwise,
        "flat_bitwise": flat_bitwise,
        "int8_tiered_bitwise": int8_bitwise,
        "blocking_dispatches": disp_block,
        "overlap_dispatches": disp_over,
        "blocking_wall_s": wall_block,
        "overlap_wall_s": wall_over,
        "overlap_wall_ratio": wall_block / wall_over if wall_over > 0 else 0.0,
        "overlap_speedup": speedup,
        "sched_lower_s": sched["lower_s"],
        "sched_upper_s": sched["upper_s"],
        "cost_model_drift": drift,
        "dryrun_total_s": dry["total_s"],
        "dryrun_flat_s": dry["flat_allreduce_s"],
        "dryrun_speedup_vs_flat": dry["speedup_vs_flat"],
        "dryrun_uplink_gb": dry["uplink_bytes_total"] / 1e9,
    })
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker:
        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        if os.path.isdir(os.path.join(here, "src")):
            sys.path.insert(0, os.path.join(here, "src"))
        print(json.dumps(worker()))
    else:
        print(main(smoke=args.smoke))
