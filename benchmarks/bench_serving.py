"""Continuous-batching slot serving vs the synchronous LRU path.

Decode-style serving microbenchmark (ISSUE 6 acceptance): replay a seeded
Zipf-skewed query trace — tenant ids drawn from a simulated
millions-of-tenants universe (:class:`repro.federated.slots.TenantUniverse`
folds the universe onto the synthetic federation's statistics) — against
both serving paths, with arrival segments absorbed mid-stream:

* **slots** — :class:`repro.launch.serving_engine.ServingEngine`:
  S device-resident head slots, absorb/solve/serve one dispatch each,
  version-segmented invalidation (an absorb re-solves ONLY the tenants it
  touched);
* **lru** — :class:`repro.launch.serve_heads.HeadServer` under the strict
  policy: per-burst solve-on-miss with host-side head stacking, and every
  absorb dirty-marks the whole cache (the pre-slot serving semantics).

The trace replays TWICE per engine; the second (steady-state, traces
compiled, table warm) pass is timed.  Claims under test:

* the slot engine's serve stage costs EXACTLY one dispatch per in-flight
  batch, independent of the tenant-universe size (checked at two universe
  scales);
* sustained QPS >= 2x the synchronous LRU path under skewed load with
  interleaved absorbs;
* strict-mode slot serving matches the synchronous server's answers
  (bitwise for global-mode queries, <= 1e-5 for personalized ones);
* admission control: a burst beyond ``queue_depth`` sheds at enqueue, a
  ``deadline_ticks`` budget sheds stale queued requests, and every offered
  query is either served or accounted shed.

Usage: PYTHONPATH=src:. python benchmarks/bench_serving.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data.pipeline import make_federated_features
from repro.federated.arrivals import pack_schedule, poisson_schedule, zipf_traffic
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.slots import TenantUniverse
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.launch.serve_heads import HeadServer
from repro.launch.serving_engine import ServingConfig, ServingEngine

RIDGE_LAMBDA = 1e-2
ZIPF_EXPONENT = 1.6  # hot head fits the slot table, long cold tail of one-off tenants
ALPHA_GRID = (0.0, 0.5, 1.0, 2.0, 4.0)  # one grid for BOTH paths (parity)
COALESCE = 2  # in-flight bursts one slot tick drains (continuous batching)


def _workload(smoke: bool):
    """The shared fixture: base federation, tenant universe, traces, arrivals."""
    if smoke:
        scale = dict(n=3000, d=32, n_classes=8, n_clients=32)
        n_tenants, burst, n_bursts, n_slots = 50_000, 96, 10, 33
        small_universe = 1_000
    else:
        scale = dict(n=8000, d=64, n_classes=10, n_clients=64)
        n_tenants, burst, n_bursts, n_slots = 1_000_000, 256, 24, 65
        small_universe = 10_000
    fed, _ = make_federated_features(seed=0, alpha=0.1, noise=6.0, **scale)
    universe = TenantUniverse(fed, n_tenants)
    trace = zipf_traffic(
        n_tenants, burst * n_bursts, exponent=ZIPF_EXPONENT, seed=7
    )
    # arrival segments interleaved with the query bursts: each absorb
    # touches a FEW tenants — the strict policy still dirty-marks the
    # whole working set, which is the gap the segmented slots close
    schedule = poisson_schedule(fed.n_clients, n_bursts, rate=3.0, seed=3)
    packed = pack_schedule(fed, schedule)
    chunks = [packed.slice_waves(i, i + 1) for i in range(packed.n_waves)]
    # per-burst query features, precomputed so host-side data prep is
    # outside the timed path (identical for both engines anyway)
    d = scale["d"]
    xs_bursts = []
    for bidx in range(n_bursts):
        cids = trace[bidx * burst:(bidx + 1) * burst]
        xs = np.empty((burst, d), np.float32)
        for i, cid in enumerate(cids):
            cd = universe.client(int(cid))
            xs[i] = cd.features[int(cid) % cd.n]  # deterministic row choice
        xs_bursts.append(xs)
    return fed, universe, trace, chunks, xs_bursts, dict(
        n_tenants=n_tenants, burst=burst, n_bursts=n_bursts, n_slots=n_slots,
        small_universe=small_universe, **scale,
    )


def _replay(server, trace, chunks, xs_bursts, burst, coalesce=1):
    """One full pass of the trace: absorb one arrival segment per burst
    (the live-stream regime), answer the bursts, return (per-query
    latencies, wall).

    ``coalesce=1`` is the synchronous protocol (every burst answered
    before the next arrives — the only protocol the LRU path supports).
    ``coalesce>1`` exercises the slot engine's in-flight batching: bursts
    enqueue as they arrive and one solve+serve tick drains ``coalesce`` of
    them — per-query latency then INCLUDES the queueing wait (measured
    from admission), which is the decode-style throughput/latency trade.
    """
    lat: list = []
    a = 0
    t_start = time.perf_counter()
    n = len(xs_bursts)
    for bidx in range(n):
        if a < len(chunks):
            server.absorb(chunks[a])
            a += 1
        cids = trace[bidx * burst:(bidx + 1) * burst]
        if coalesce == 1:
            t0 = time.perf_counter()
            scores, _ = server.query(cids, xs_bursts[bidx])
            jax.block_until_ready(scores)
            lat.extend([time.perf_counter() - t0] * burst)
        else:
            server.enqueue(cids, xs_bursts[bidx])
            if (bidx + 1) % coalesce == 0 or bidx == n - 1:
                scores, rep = server.tick()
                jax.block_until_ready(scores)
                lat.extend(rep["latency_s"])
    return np.asarray(lat), time.perf_counter() - t_start


def _make_slots(fed, universe, cfg, n_slots, invalidation="segmented"):
    server = ServingEngine(
        ServingConfig(
            n_classes=cfg["n_classes"], ridge_lambda=RIDGE_LAMBDA,
            n_slots=n_slots, invalidation=invalidation,
            solve_bucket=8, serve_bucket=cfg["burst"], alpha_grid=ALPHA_GRID,
        ),
        universe,
    )
    server.init(cfg["d"])
    return server


def _make_lru(fed, universe, cfg, capacity, invalidation="strict"):
    server = HeadServer(
        StreamingEngine(StreamConfig(
            n_classes=cfg["n_classes"], ridge_lambda=RIDGE_LAMBDA,
        )),
        PersonalizationEngine(PersonalizeConfig(
            n_classes=cfg["n_classes"], alpha_grid=ALPHA_GRID,
        )),
        universe,
        cache_capacity=capacity,
        cohort_round_to=8,
        invalidation=invalidation,
    )
    server.init(cfg["d"])
    return server


def main(smoke: bool = False) -> dict:
    fed, universe, trace, chunks, xs_bursts, cfg = _workload(smoke)
    burst, n_bursts = cfg["burst"], cfg["n_bursts"]

    # ---- timed replay: slots (segmented) vs lru (strict) -------------------
    slots = _make_slots(fed, universe, cfg, cfg["n_slots"])
    lru = _make_lru(fed, universe, cfg, cfg["n_slots"] - 1)
    results = {}
    for name, server in (("slots", slots), ("lru", lru)):
        co = COALESCE if name == "slots" else 1
        _replay(server, trace, chunks, xs_bursts, burst, co)  # warmup pass
        if name == "slots":
            ticks0, serve0, solve0 = server.ticks, server.serve_dispatches, \
                server.solve_dispatches
        lat, wall = _replay(server, trace, chunks, xs_bursts, burst, co)  # timed
        results[name] = dict(
            lat=lat, wall=wall,
            qps=burst * n_bursts / wall,
            p50=float(np.percentile(lat, 50)),
            p99=float(np.percentile(lat, 99)),
        )
        emit(
            f"serving_{name}_steady_state", results[name]["p50"] * 1e6,
            f"qps={results[name]['qps']:.0f} "
            f"p50_ms={results[name]['p50'] * 1e3:.2f} "
            f"p99_ms={results[name]['p99'] * 1e3:.2f} "
            f"queries={burst * n_bursts} tenants={cfg['n_tenants']}",
        )
    serve_ticks = slots.ticks - ticks0
    serve_disp = slots.serve_dispatches - serve0
    solve_disp = slots.solve_dispatches - solve0
    disp_per_batch = serve_disp // max(serve_ticks, 1)
    qps_speedup = results["slots"]["qps"] / results["lru"]["qps"]
    emit(
        "serving_slots_dispatch_budget", 0.0,
        f"serve_dispatches={serve_disp} batches={serve_ticks} "
        f"per_batch={disp_per_batch} solve_dispatches={solve_disp} "
        f"qps_speedup_vs_lru={qps_speedup:.1f}x "
        f"hit_rate={slots.hits / max(slots.hits + slots.misses, 1):.2f} "
        f"evictions={slots.table.evictions} slot_overflow={slots.slot_overflow}",
    )

    # ---- O(1)-in-tenant-count: same serve-dispatch budget at a far smaller
    # universe (different trace over different ids, same batch count) -------
    small_n = cfg["small_universe"]
    small_uni = TenantUniverse(fed, small_n)
    small_trace = zipf_traffic(
        small_n, burst * n_bursts, exponent=ZIPF_EXPONENT, seed=7
    )
    small = _make_slots(fed, small_uni, cfg, cfg["n_slots"])
    _replay(small, small_trace, chunks, xs_bursts, burst)
    tenant_invariant = (
        small.serve_dispatches == small.ticks
        and small.serve_dispatches // max(small.ticks, 1) == disp_per_batch
    )
    emit(
        "serving_dispatch_tenant_invariance", 0.0,
        f"universe_{small_n}={small.serve_dispatches // max(small.ticks, 1)} "
        f"universe_{cfg['n_tenants']}={disp_per_batch} "
        f"invariant={tenant_invariant}",
    )

    # ---- answer parity: strict slots vs the synchronous server ------------
    p_slots = _make_slots(fed, universe, cfg, cfg["n_slots"], "strict")
    p_lru = _make_lru(fed, universe, cfg, cfg["n_slots"] - 1, "strict")
    parity_err = 0.0
    global_bitwise = True
    modes_match = True
    # overflow-free burst width (every miss gets a slot, so both paths
    # personalize the same tenants); every 5th query is an out-of-universe
    # tenant — no server-side data, both paths must serve the global head
    pb = min(burst, cfg["n_slots"] - 8)
    for bidx in range(3):
        p_slots.absorb(chunks[bidx])
        p_lru.absorb(chunks[bidx])
        cids = np.array(trace[bidx * burst:bidx * burst + pb])
        cids[::5] = cfg["n_tenants"] + bidx
        s1, r1 = p_slots.query(cids, xs_bursts[bidx][:pb])
        s2, r2 = p_lru.query(cids, xs_bursts[bidx][:pb])
        modes_match = modes_match and r1["modes"] == r2["modes"]
        parity_err = max(parity_err, float(jnp.max(jnp.abs(s1 - s2))))
        g = [i for i, m in enumerate(r1["modes"]) if m == "global"]
        if not g or not np.array_equal(np.asarray(s1)[g], np.asarray(s2)[g]):
            global_bitwise = False
    emit(
        "serving_parity_strict", 0.0,
        f"personalized_err={parity_err:.2e} global_bitwise={global_bitwise}",
    )

    # ---- admission control under overload ---------------------------------
    over = ServingEngine(
        ServingConfig(
            n_classes=cfg["n_classes"], ridge_lambda=RIDGE_LAMBDA,
            n_slots=cfg["n_slots"], queue_depth=64, max_batch=16,
            deadline_ticks=2, serve_bucket=16,
        ),
        universe,
    )
    over.init(cfg["d"])
    over.absorb(chunks[0])
    offered = 4 * burst
    over_trace = zipf_traffic(
        cfg["n_tenants"], offered, exponent=ZIPF_EXPONENT, seed=11
    )
    over_xs = np.concatenate(xs_bursts, axis=0)[:offered]
    admitted, shed_enq = over.enqueue(over_trace, over_xs)
    served = 0
    while over.queue:
        _, rep = over.tick()
        served += rep["queries"]
    accounted = served + shed_enq + over.shed_deadline == offered
    emit(
        "serving_admission_control", 0.0,
        f"offered={offered} admitted={admitted} served={served} "
        f"shed_overflow={shed_enq} shed_deadline={over.shed_deadline} "
        f"accounted={accounted}",
    )

    assert disp_per_batch == 1, (
        f"serve stage must cost 1 dispatch per batch, got {disp_per_batch}"
    )
    assert serve_disp == serve_ticks, (
        f"{serve_disp} serve dispatches over {serve_ticks} batches"
    )
    assert tenant_invariant, "serve dispatches must not scale with tenant count"
    assert qps_speedup >= 2.0, (
        f"slots must sustain >= 2x LRU QPS at skewed load, got {qps_speedup:.2f}x"
    )
    assert parity_err <= 1e-5, (
        f"strict slots drifted from the synchronous server: {parity_err:.2e}"
    )
    assert global_bitwise, "global-mode answers must match bitwise"
    assert modes_match, "strict slots must serve the same modes as the LRU path"
    assert shed_enq > 0 and over.shed_deadline > 0, (
        "overload phase must exercise both shedding paths"
    )
    assert accounted, "every offered query must be served or accounted shed"
    return {
        "slots_qps": results["slots"]["qps"],
        "lru_qps": results["lru"]["qps"],
        "qps_speedup": qps_speedup,
        "slots_p50_s": results["slots"]["p50"],
        "slots_p99_s": results["slots"]["p99"],
        "lru_p50_s": results["lru"]["p50"],
        "lru_p99_s": results["lru"]["p99"],
        "serve_dispatches_per_batch": disp_per_batch,
        "steady_serve_dispatches": serve_disp,
        "steady_solve_dispatches": solve_disp,
        "steady_batches": serve_ticks,
        "dispatch_tenant_invariant": tenant_invariant,
        "parity_err": parity_err,
        "global_bitwise": global_bitwise,
        "parity_modes_match": modes_match,
        "hit_rate": slots.hits / max(slots.hits + slots.misses, 1),
        "evictions": slots.table.evictions,
        "slot_overflow": slots.slot_overflow,
        "shed_overflow": shed_enq,
        "shed_deadline": over.shed_deadline,
        "overload_served": served,
        "overload_accounted": accounted,
        "queries": burst * n_bursts,
        "n_tenants": cfg["n_tenants"],
        "n_slots": cfg["n_slots"],
        "zipf_exponent_x10": int(ZIPF_EXPONENT * 10),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small config (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
