"""Paper Fig. 2 / Fig. 10: FED3R vs gradient-based FL baselines.

Accuracy-vs-rounds plus the communication/computation budget to reach a
target accuracy (App. D/E meters).  Baselines are the LP (linear-probe)
variants the paper compares against in the frozen-extractor regime:
FedAvg-LP, FedAvgM-LP, Scaffold-LP.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import C, D, K, emit, f3_cfg, fed_cfg, landmarks_like, timed
from repro.federated import run_fed3r
from repro.federated.costs import CostModel
from repro.federated.simulator import linear_head_task, run_federated

TARGET = 0.95  # fraction of the FED3R final accuracy used as the target
ROUNDS = 200


def rounds_to(hist_rounds, hist_acc, target):
    for r, a in zip(hist_rounds, hist_acc):
        if a >= target:
            return r
    return float("inf")


def main() -> list:
    fed, test = landmarks_like()
    cm = CostModel(b=2.22e6, d=D, C=C, E=1)
    rows = []

    # --- FED3R ---------------------------------------------------------------
    with timed() as t:
        _, _, h3 = run_fed3r(fed, test.features, test.labels, f3_cfg(),
                             fed_cfg(n_rounds=1000), eval_every=1)
    acc3 = h3.accuracy[-1]
    target = TARGET * acc3
    r3 = rounds_to(h3.rounds, h3.accuracy, target)
    comm3 = cm.comm_per_client("fed3r")["up"] * 4 * 10 * r3
    comp3 = cm.comp_per_client("fed3r", fed.client_sizes().mean())
    emit("fig2_fed3r", t["s"] * 1e6 / max(h3.rounds[-1], 1),
         f"final={acc3:.4f} rounds_to_target={r3} comm_bytes={comm3:.3e} comp_flops={comp3:.3e}")
    rows.append(("fed3r", acc3, r3, comm3, comp3))

    # --- gradient LP baselines ------------------------------------------------
    for alg, smom in [("fedavg", 0.0), ("fedavgm", 0.9), ("scaffold", 0.0)]:
        task = linear_head_task(D, C, test.features, test.labels)
        cfg = fed_cfg(algorithm=alg, n_rounds=ROUNDS, server_momentum=smom)
        with timed() as t:
            _, h = run_federated(task, fed, cfg, eval_every=2)
        r = rounds_to(h.rounds, h.accuracy, target)
        eff_r = r if np.isfinite(r) else ROUNDS
        comm = cm.comm_per_client(f"{'fedavg' if alg!='scaffold' else 'scaffold'}-lp")["up"] * 4 * 10 * eff_r
        comp = cm.cumulative_comp_flops_per_client(
            f"{'fedavg' if alg != 'scaffold' else 'scaffold'}-lp", int(eff_r), 10, K,
            fed.client_sizes().mean(),
        )[-1]
        speedup = (r / r3) if np.isfinite(r) else float("inf")
        emit(f"fig2_{alg}_lp", t["s"] * 1e6 / ROUNDS,
             f"final={h.accuracy[-1]:.4f} rounds_to_target={r} "
             f"fed3r_speedup_x={speedup:.1f} comm_bytes={comm:.3e} comp_flops={comp:.3e}")
        rows.append((alg, h.accuracy[-1], r, comm, comp))
    return rows


if __name__ == "__main__":
    main()
