"""Accuracy-vs-bytes of the compressed statistics uplink.

The claim under test (ISSUE 7 acceptance): with
``EngineConfig(wire=WireFormat(...))`` every (A_k, b_k) upload crosses the
wire as int8/fp8 per-tile absmax tiles or a rank-r sketch instead of dense
fp32 — ≥ 3.9× fewer uplink bytes under int8 — while the engines keep their
one-dispatch contract and the served classifier's synthetic-eval accuracy
stays within 0.5% of the fp32 engine; the ``fp32`` format itself stays
BITWISE identical to the uncompressed engines.  Error feedback
(:class:`repro.federated.compress.UplinkCompressor`) must strictly beat
the no-feedback uplink over repeated rounds (telescoping vs linear error
growth).

Usage: PYTHONPATH=src:. python benchmarks/bench_compress.py [--smoke]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import fed3r
from repro.core.fed3r import Fed3RStats
from repro.data.pipeline import pack_arrival_waves, pack_client_shards
from repro.federated.compress import UplinkCompressor, WireFormat
from repro.federated.costs import stats_wire_bytes
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.federated.streaming_engine import StreamConfig, StreamingEngine

D_FEAT = 64
N_CLASSES = 50
RIDGE_LAMBDA = 0.1
TILE = 32  # absmax granularity at bench scale (d=64 → 2×2 scale grid)
RANK = 48  # sketch rank at bench scale
PAPER_D, PAPER_C = 1280, 2028  # MobileNetV2 features × Landmarks classes

FORMATS = {
    "fp32": WireFormat(),
    "int8": WireFormat(kind="int8", tile=TILE),
    "fp8": WireFormat(kind="fp8", tile=TILE),
    "sketch": WireFormat(kind="sketch", rank=RANK),
}


def _make_federation(K, lo, hi, seed=0):
    """Clustered (separable, noisy) clients + a held-out eval set."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(N_CLASSES, D_FEAT)).astype(np.float32) * 2.0

    def draw(n):
        y = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
        # noise calibrated so accuracy lands mid-range — saturated evals
        # would make the compressed-vs-fp32 accuracy gate vacuous
        x = centers[y] + 7.0 * rng.normal(size=(n, D_FEAT)).astype(np.float32)
        return x, y

    clients = {k: draw(int(rng.integers(lo, hi))) for k in range(K)}
    eval_x, eval_y = draw(4000)
    return clients, jnp.asarray(eval_x), eval_y


def _accuracy(W, eval_x, eval_y) -> float:
    pred = np.argmax(np.asarray(fed3r.predict(W, eval_x)), axis=1)
    return float(np.mean(pred == eval_y))


def _client_stats(x, y):
    z, yh, n = fed3r.masked_design(
        jnp.asarray(x), jnp.asarray(y), N_CLASSES, None
    )
    return Fed3RStats(A=z.T @ z, b=z.T @ yh, n=n)


def main(smoke: bool = False) -> dict:
    K = 24 if smoke else 60
    ef_rounds = 6 if smoke else 12
    clients, eval_x, eval_y = _make_federation(K, lo=40, hi=120)
    packed = pack_client_shards(clients, clients_per_shard=6)

    # ---- wire-bytes table (exact analytic pricing, both scales) -----------
    ratios = {}
    for d, C, scale in ((D_FEAT, N_CLASSES, "bench"), (PAPER_D, PAPER_C, "paper")):
        fp32_bytes = stats_wire_bytes(d, C, "fp32")
        for name, fmt in FORMATS.items():
            by = stats_wire_bytes(d, C, fmt.kind, fmt.tile, fmt.rank)
            ratios[f"{scale}_{name}"] = fp32_bytes / by
            emit(
                f"compress_bytes_{scale}_{name}", 0.0,
                f"d={d} C={C} bytes={by:.3e} ratio_vs_fp32={fp32_bytes / by:.2f}x",
            )

    # ---- engine accuracy per format (one dispatch each) -------------------
    accs, a_errs, dispatches = {}, {}, {}
    acc_fp32_stats = None
    for name, fmt in FORMATS.items():
        eng = AccumulationEngine(
            EngineConfig(n_classes=N_CLASSES, use_kernel=False, wire=fmt)
        )
        acc = eng.accumulate(eng.init(D_FEAT), packed)
        jax.block_until_ready(acc.stats.A)
        dispatches[name] = eng.dispatches
        if name == "fp32":
            acc_fp32_stats = acc.stats
        W = fed3r.solve(acc.stats, RIDGE_LAMBDA)
        accs[name] = _accuracy(W, eval_x, eval_y)
        a_errs[name] = float(
            jnp.max(jnp.abs(acc.stats.A - acc_fp32_stats.A))
            / jnp.max(jnp.abs(acc_fp32_stats.A))
        )
        emit(
            f"compress_engine_{name}", 0.0,
            f"K={K} acc={accs[name]:.4f} A_rel_err={a_errs[name]:.3e} "
            f"dispatches={dispatches[name]} ratio={ratios[f'bench_{name}']:.2f}x",
        )

    # fp32 wire format must be BITWISE the uncompressed engine
    plain = AccumulationEngine(EngineConfig(n_classes=N_CLASSES, use_kernel=False))
    plain_acc = plain.accumulate(plain.init(D_FEAT), packed)
    fp32_bitwise = bool(
        jnp.array_equal(acc_fp32_stats.A, plain_acc.stats.A)
        and jnp.array_equal(acc_fp32_stats.b, plain_acc.stats.b)
    )

    # ---- error feedback vs no feedback over repeated rounds ---------------
    def ef_run(error_feedback):
        up = UplinkCompressor(
            WireFormat(kind="int8", tile=TILE, error_feedback=error_feedback),
            use_kernel=False,
        )
        tot = fed3r.init_stats(D_FEAT, N_CLASSES)
        exact = fed3r.init_stats(D_FEAT, N_CLASSES)
        for _ in range(ef_rounds):
            for k, (x, y) in clients.items():
                s = _client_stats(x, y)
                tot = fed3r.merge(tot, up.upload(k, s))
                exact = fed3r.merge(exact, s)
        err = float(
            jnp.max(jnp.abs(tot.A - exact.A)) / jnp.max(jnp.abs(exact.A))
        )
        return err, _accuracy(fed3r.solve(tot, RIDGE_LAMBDA), eval_x, eval_y), up

    ef_err, ef_acc, up = ef_run(True)
    noef_err, noef_acc, _ = ef_run(False)
    emit(
        "compress_error_feedback", 0.0,
        f"rounds={ef_rounds} ef_A_rel_err={ef_err:.3e} "
        f"noef_A_rel_err={noef_err:.3e} ef_acc={ef_acc:.4f} "
        f"noef_acc={noef_acc:.4f} wire_ratio={up.compression_ratio:.2f}x",
    )

    # ---- streaming engine under the int8 wire -----------------------------
    items = sorted(clients.items())
    waves = [
        [clients[k] for k, _ in items[t::8]] for t in range(8)
    ]
    packed_w = pack_arrival_waves([w for w in waves if w])

    def stream(fmt):
        eng = StreamingEngine(StreamConfig(
            n_classes=N_CLASSES, ridge_lambda=RIDGE_LAMBDA,
            use_kernel=False, wire=fmt,
        ))
        state, _ = eng.absorb(eng.init(D_FEAT), packed_w)
        jax.block_until_ready(state.W)
        return eng, state

    s_eng32, s32 = stream(WireFormat())
    s_eng8, s8 = stream(WireFormat(kind="int8", tile=TILE))
    stream_acc32 = _accuracy(s32.W, eval_x, eval_y)
    stream_acc8 = _accuracy(s8.W, eval_x, eval_y)
    stream_finite = bool(jnp.all(jnp.isfinite(s8.L)) and jnp.all(jnp.isfinite(s8.W)))
    emit(
        "compress_streaming_int8", 0.0,
        f"waves={packed_w.n_waves} acc_fp32={stream_acc32:.4f} "
        f"acc_int8={stream_acc8:.4f} finite={stream_finite} "
        f"dispatches={s_eng8.dispatches}",
    )

    # ---- acceptance gates -------------------------------------------------
    int8_ratio_ok = ratios["bench_int8"] >= 3.9 and ratios["paper_int8"] >= 3.9
    acc_gap = abs(accs["int8"] - accs["fp32"])
    acc_ok = acc_gap <= 0.005
    one_dispatch = all(v == 1 for v in dispatches.values())
    ef_beats_noef = ef_err < noef_err

    assert int8_ratio_ok, f"int8 wire ratio < 3.9x: {ratios}"
    assert acc_ok, f"int8 accuracy gap {acc_gap:.4f} > 0.005"
    assert fp32_bitwise, "fp32 wire format must be bitwise identical"
    assert one_dispatch, f"dispatch contract broken: {dispatches}"
    assert ef_beats_noef, f"EF ({ef_err}) must beat no-EF ({noef_err})"
    assert stream_finite, "compressed streaming produced non-finite state"

    return {
        "n_clients": K,
        "ef_rounds": ef_rounds,
        "ratio_bench_int8": ratios["bench_int8"],
        "ratio_paper_int8": ratios["paper_int8"],
        "ratio_paper_sketch": ratios["paper_sketch"],
        "int8_ratio_ge_3p9": int8_ratio_ok,
        "acc_fp32": accs["fp32"],
        "acc_int8": accs["int8"],
        "acc_fp8": accs["fp8"],
        "acc_sketch": accs["sketch"],
        "acc_within_half_pct": acc_ok,
        "fp32_bitwise": fp32_bitwise,
        "fp32_dispatches": dispatches["fp32"],
        "int8_dispatches": dispatches["int8"],
        "fp8_dispatches": dispatches["fp8"],
        "sketch_dispatches": dispatches["sketch"],
        "streaming_int8_dispatches": s_eng8.dispatches,
        "int8_A_rel_err": a_errs["int8"],
        "sketch_A_rel_err": a_errs["sketch"],
        "ef_A_rel_err": ef_err,
        "noef_A_rel_err": noef_err,
        "ef_beats_noef": ef_beats_noef,
        "streaming_finite": stream_finite,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small configs (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
