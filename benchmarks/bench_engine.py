"""Packed-engine vs per-client-loop FED3R statistics accumulation.

The claim under test (ISSUE 1 acceptance): on a 100-client synthetic
federation the engine folds the whole selection in O(K/clients_per_shard)
scan steps inside ONE dispatch per round, vs the naive loop's K jit
dispatches — and the accumulated A/b are *exactly* (bit-for-bit) invariant
to client reordering and re-sharding.

Usage: PYTHONPATH=src:. python benchmarks/bench_engine.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import fed3r
from repro.data.pipeline import pack_client_shards
from repro.federated.engine import AccumulationEngine, EngineConfig

K = 100  # clients
D_FEAT = 64
N_CLASSES = 10
CLIENTS_PER_SHARD = 10


def _make_federation(n_per_client_lo=20, n_per_client_hi=120, seed=0):
    rng = np.random.default_rng(seed)
    clients = []
    for _ in range(K):
        n = int(rng.integers(n_per_client_lo, n_per_client_hi))
        clients.append((
            rng.normal(size=(n, D_FEAT)).astype(np.float32),
            rng.integers(0, N_CLASSES, size=n).astype(np.int32),
        ))
    return clients


def run_naive(clients, reps):
    """The pre-engine path: one jit dispatch + host-level merge per client."""
    client_stats_j = jax.jit(lambda f, y: fed3r.client_stats(f, y, N_CLASSES))
    dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        stats = fed3r.init_stats(D_FEAT, N_CLASSES)
        for f, y in clients:
            stats = fed3r.merge(stats, client_stats_j(jnp.asarray(f), jnp.asarray(y)))
            dispatches += 1
        jax.block_until_ready(stats.A)
    return stats, dispatches // reps, (time.time() - t0) / reps


def run_packed(clients, reps, cps=CLIENTS_PER_SHARD, max_n=128, ids=None):
    engine = AccumulationEngine(EngineConfig(n_classes=N_CLASSES))
    packed = pack_client_shards(clients, cps, max_n=max_n, client_ids=ids)
    acc = engine.accumulate(engine.init(D_FEAT), packed)  # warm the trace
    jax.block_until_ready(acc.stats.A)
    engine.dispatches = 0
    t0 = time.time()
    for _ in range(reps):
        acc = engine.accumulate(engine.init(D_FEAT), packed)
        jax.block_until_ready(acc.stats.A)
    return acc, engine.dispatches // reps, (time.time() - t0) / reps


def main(smoke: bool = False) -> dict:
    reps = 1 if smoke else 5
    clients = _make_federation()
    n_samples = sum(len(y) for _, y in clients)

    naive_stats, naive_disp, naive_s = run_naive(clients, reps)
    packed_acc, packed_disp, packed_s = run_packed(clients, reps)

    # correctness: packed == naive (same associative sum, fp tolerance)
    np.testing.assert_allclose(
        np.asarray(packed_acc.stats.A), np.asarray(naive_stats.A),
        rtol=1e-5, atol=1e-4,
    )

    # exact invariance 1: client permutation → bit-identical A and b
    perm = np.random.default_rng(1).permutation(K)
    perm_acc, _, _ = run_packed(
        [clients[i] for i in perm], 1, ids=perm.tolist()
    )
    bit_perm = (
        np.array_equal(np.asarray(packed_acc.stats.A), np.asarray(perm_acc.stats.A))
        and np.array_equal(np.asarray(packed_acc.stats.b), np.asarray(perm_acc.stats.b))
    )

    # exact invariance 2: re-sharding (different clients_per_shard)
    reshard_acc, _, _ = run_packed(clients, 1, cps=4)
    bit_reshard = (
        np.array_equal(np.asarray(packed_acc.stats.A), np.asarray(reshard_acc.stats.A))
        and np.array_equal(np.asarray(packed_acc.stats.b), np.asarray(reshard_acc.stats.b))
    )

    speedup = naive_s / packed_s if packed_s > 0 else float("inf")
    emit(
        "engine_naive_loop", naive_s * 1e6,
        f"K={K} n={n_samples} dispatches={naive_disp}",
    )
    emit(
        "engine_packed_scan", packed_s * 1e6,
        f"K={K} n={n_samples} dispatches={packed_disp} "
        f"shards={-(-K // CLIENTS_PER_SHARD)} speedup={speedup:.1f}x "
        f"bit_identical_perm={bit_perm} bit_identical_reshard={bit_reshard}",
    )

    assert packed_disp * 2 <= naive_disp, (
        f"dispatch reduction claim violated: {packed_disp} vs {naive_disp}"
    )
    assert bit_perm, "A/b must be bit-identical under client permutation"
    assert bit_reshard, "A/b must be bit-identical under re-sharding"
    return {
        "naive_s": naive_s, "packed_s": packed_s, "speedup": speedup,
        "naive_dispatches": naive_disp, "packed_dispatches": packed_disp,
        "bit_identical_perm": bit_perm, "bit_identical_reshard": bit_reshard,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="1 rep (CI budget)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(out)
