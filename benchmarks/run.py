"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to paper artifacts:

  bench_invariance       Fig. 1 / Fig. 9   split-invariance & centralized eq.
  bench_vs_baselines     Fig. 2 / Fig. 10  FED3R vs FedAvg(M)/Scaffold-LP
  bench_sampling         Fig. 3            participation rates ± replacement
  bench_ncm              Table 1 / Table 6 FED3R family vs FedNCM
  bench_ft               Table 2 / Fig. 4/5/11  FT / FT-LP / FT-FEAT grid
  bench_feature_quality  Table 3           RR probe of fine-tuned features
  bench_rf               Fig. 8            RF sweep vs exact-KRR ceiling
  bench_costs            App. D/E          exact cost meters @ paper scale
  bench_coupon           Table 7 / App. I  batch coupon collector
  bench_kernels          (kernels)         Pallas-vs-oracle + XLA timing
  bench_engine           (engine)          packed scan vs per-client loop
  bench_rounds           (round engine)    packed FL round vs per-client loop
  bench_streaming        (streaming)       packed arrival scan vs Woodbury loop
  bench_personalize      (personalization) batched per-tenant heads vs re-solve loop
  bench_serving          (slot serving)    continuous-batching slots vs synchronous LRU
  bench_scaleout         (dist layer)      weak scaling of the one-dispatch engines
  bench_compress         (wire formats)    accuracy-vs-bytes of compressed uploads
  bench_async            (async engine)    merge-on-arrival vs sync barrier @ stragglers
  roofline               §Roofline         dry-run roofline table

Modules listed in ``JSON_OUT`` additionally persist their result dict as a
``BENCH_<name>.json`` next to the invocation — the perf trajectory record
that ``benchmarks/check_regression.py`` gates CI against (baselines live
in ``benchmarks/baselines/``).  Each JSON_OUT module runs under a fresh
``Telemetry`` registry whose snapshot is persisted alongside as
``telemetry_<name>.json`` (a CI artifact); the per-engine dispatch totals
from that snapshot are folded into the BENCH dict under ``telemetry``.

Usage: PYTHONPATH=src:. python benchmarks/run.py [--smoke] [names ...]
"""
from __future__ import annotations

import argparse
import inspect
import json
import time
import traceback

from repro.federated.telemetry import Telemetry, dispatch_summary, set_telemetry

MODULES = [
    "bench_costs",
    "bench_coupon",
    "bench_kernels",
    "bench_engine",
    "bench_rounds",
    "bench_streaming",
    "bench_personalize",
    "bench_serving",
    "bench_scaleout",
    "bench_compress",
    "bench_async",
    "bench_tiers",
    "bench_invariance",
    "bench_ncm",
    "bench_rf",
    "bench_sampling",
    "bench_vs_baselines",
    "bench_ft",
    "bench_feature_quality",
    "roofline",
]

# result dicts persisted as BENCH_<suffix>.json (perf trajectory record)
JSON_OUT = {
    "bench_engine": "engine",
    "bench_rounds": "rounds",
    "bench_streaming": "streaming",
    "bench_personalize": "personalize",
    "bench_serving": "serving",
    "bench_scaleout": "scaleout",
    "bench_compress": "compress",
    "bench_async": "async",
    "bench_tiers": "tiers",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", help="subset of benchmark modules")
    ap.add_argument("--smoke", action="store_true",
                    help="small configs (CI budget) where supported")
    args = ap.parse_args()
    only = args.names or None
    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        telemetry = None
        if name in JSON_OUT:
            # fresh registry per bench: the snapshot is that bench's own
            # dispatch/span record, unpolluted by earlier modules
            telemetry = Telemetry()
            set_telemetry(telemetry)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            kwargs = {}
            if args.smoke and "smoke" in inspect.signature(mod.main).parameters:
                kwargs["smoke"] = True
            result = mod.main(**kwargs)
            if name in JSON_OUT and isinstance(result, dict):
                snap = telemetry.snapshot()
                result["telemetry"] = {"dispatches": dispatch_summary(snap)}
                with open(f"BENCH_{JSON_OUT[name]}.json", "w") as f:
                    json.dump(result, f, indent=2, default=float)
                with open(f"telemetry_{JSON_OUT[name]}.json", "w") as f:
                    json.dump(snap, f, indent=2, default=float)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"failed benchmarks: {failures}")


if __name__ == "__main__":
    main()
