"""Quickstart: FED3R in ~40 lines.

A heterogeneous federation (one class per client), a frozen feature space,
and the closed-form federated ridge classifier — converging exactly in
⌈K/κ⌉ rounds and matching the centralized solution to float precision.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.core import fed3r
from repro.data import make_federated_features
from repro.federated import run_fed3r

# 100 clients, pathological heterogeneity: every client holds ONE class.
fed, test = make_federated_features(
    seed=0, n=8000, d=64, n_classes=10, n_clients=100, alpha=0.0, noise=2.0
)

f3 = Fed3RConfig(ridge_lambda=0.01, n_classes=10)
fc = FederatedConfig(n_clients=100, clients_per_round=10, n_rounds=100)

W, stats, hist = run_fed3r(fed, test.features, test.labels, f3, fc, eval_every=1)

print("round | clients seen | test accuracy")
for r, seen, acc in zip(hist.rounds, hist.clients_seen, hist.accuracy):
    print(f"{r:5d} | {seen:12d} | {acc:.4f}")

# exact equivalence with the centralized ridge solution (paper §4.3)
cen = fed3r.solve(
    fed3r.client_stats(jnp.asarray(fed.features), jnp.asarray(fed.labels), 10),
    f3.ridge_lambda,
)
gap = float(jnp.max(jnp.abs(W - cen)))
print(f"\nconverged in {hist.rounds[-1]} rounds (= ceil(100/10))")
print(f"max |W_federated - W_centralized| = {gap:.2e}  (exact aggregation)")
