"""Serve a batch of requests against any architecture family.

Exercises the inference substrate: batched prefill, ring-buffer KV caches,
SSM/RG-LRU constant-memory decode, sliding windows, enc-dec cross caches.

    PYTHONPATH=src python examples/serve_demo.py
"""
from repro.launch.serve import serve

for arch in (
    "qwen2-7b-smoke",          # dense GQA + ring KV cache
    "mamba2-1.3b-smoke",       # attention-free O(1)-state decode
    "recurrentgemma-9b-smoke", # hybrid RG-LRU + local attention
    "whisper-large-v3-smoke",  # enc-dec with cross-attention cache
):
    serve(arch, batch=2, prompt_len=32, gen=12)
