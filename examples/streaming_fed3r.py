"""Streaming FED3R — the paper's stated future work (§6), implemented.

Clients arrive over time with NEW data (not a fixed federation snapshot).
Because the statistics are an exact running sum, the server can refresh the
closed-form classifier after every arrival batch with zero re-training —
the recursive-least-squares formulation of §4.1.  Two server modes:

  * statistics mode: keep (A, b), re-solve on demand (O(d³) per refresh);
  * online mode:     keep (A+λI)⁻¹ directly and apply Sherman–Morrison–
                     Woodbury rank-n updates (O(n·d²) per arrival).

    PYTHONPATH=src python examples/streaming_fed3r.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.data.synthetic import make_feature_dataset

D, C = 32, 10
rng = np.random.default_rng(0)

# one underlying distribution; the first 2000 samples are held out, the rest
# arrive over time in cohorts (streaming clients with consistent classes)
pool = make_feature_dataset(jax.random.PRNGKey(99), 6000, D, C, noise=2.0)
test_x, test_y = pool.features[:2000], pool.labels[:2000]
stream_x, stream_y = pool.features[2000:], pool.labels[2000:]

stats = fed3r.init_stats(D, C)
online = fed3r.init_online(D, C, ridge_lambda=1.0)

print("arrival | samples seen | acc (re-solve) | acc (Woodbury online)")
seen = 0
for t in range(10):
    # a new cohort of clients streams in with fresh data
    lo, hi = t * 400, (t + 1) * 400
    cx, cy = stream_x[lo:hi], stream_y[lo:hi]
    stats = fed3r.merge(stats, fed3r.client_stats(cx, cy, C))
    online = fed3r.woodbury_update(online, cx, cy)
    seen += 400

    W_batch = fed3r.solve(stats, 1.0)
    W_online = fed3r.online_solution(online)
    acc_b = float(fed3r.accuracy(W_batch, test_x, test_y))
    acc_o = float(fed3r.accuracy(W_online, test_x, test_y))
    print(f"{t:7d} | {seen:12d} | {acc_b:14.4f} | {acc_o:.4f}")

gap = float(jnp.max(jnp.abs(fed3r.solve(stats, 1.0) - fed3r.online_solution(online))))
print(f"\nmax |W_resolve − W_woodbury| = {gap:.2e} (recursive form is exact)")
