"""Streaming FED3R — the paper's stated future work (§6), on the engine.

Clients arrive over time with NEW data (not a fixed federation snapshot).
Because the statistics are an exact running sum, the server can refresh the
closed-form classifier as arrivals land with zero re-training — the
recursive-least-squares formulation of §4.1.  This example runs the
arrival timeline through the STREAMING ENGINE
(repro.federated.streaming_engine): all T waves fold through one jitted
scan (1 dispatch instead of T), carrying the Cholesky factor of A + λI and
refreshing the served W by two triangular solves.

It also demos WHY the engine replaced the subtractive Woodbury loop: at
small λ the legacy path's carried A⁻¹ cancels catastrophically in fp32,
while the factored state tracks the batch re-solve to machine precision.

    PYTHONPATH=src python examples/streaming_fed3r.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.data.pipeline import pack_arrival_waves
from repro.data.synthetic import make_feature_dataset
from repro.federated.streaming_engine import (
    ReferenceArrivalLoop,
    StreamConfig,
    StreamingEngine,
    batch_equivalent,
)

D, C, LAM, T = 32, 10, 1e-2, 10

# one underlying distribution; the first 2000 samples are held out, the rest
# arrive over time in waves (streaming clients with consistent classes)
pool = make_feature_dataset(jax.random.PRNGKey(99), 6000, D, C, noise=2.0)
test_x, test_y = pool.features[:2000], pool.labels[:2000]
stream_x, stream_y = np.asarray(pool.features[2000:]), np.asarray(pool.labels[2000:])

# each wave: two clients with 200 fresh samples apiece
waves = []
for t in range(T):
    lo = t * 400
    waves.append([
        (stream_x[lo : lo + 200], stream_y[lo : lo + 200]),
        (stream_x[lo + 200 : lo + 400], stream_y[lo + 200 : lo + 400]),
    ])
packed = pack_arrival_waves(waves)

cfg = StreamConfig(n_classes=C, ridge_lambda=LAM, refresh_every=1)
engine = StreamingEngine(cfg)
state, trace = engine.absorb(engine.init(D), packed)  # T waves, ONE dispatch

legacy = ReferenceArrivalLoop(cfg)  # T subtractive Woodbury dispatches
W_legacy = legacy.classifier(legacy.absorb(legacy.init(D), packed))

print(f"{packed.n_waves} waves, {packed.n_samples} samples: "
      f"engine={engine.dispatches} dispatch, legacy loop={legacy.dispatches}")
print(f"served accuracy: {float(fed3r.accuracy(state.W, test_x, test_y)):.4f} "
      f"(refresh-on-arrival; staleness always 0)")

W_batch, _ = batch_equivalent(packed, cfg)
err_fac = float(jnp.max(jnp.abs(state.W - W_batch)))
err_leg = float(jnp.max(jnp.abs(W_legacy - W_batch)))
print(f"\nmax |W − W_batch|   factored engine: {err_fac:.2e}   "
      f"legacy Woodbury: {err_leg:.2e}")
print("(the subtractive fp32 path visibly diverges at small λ; "
      "the factored form is exact to fp32 round-off)")
