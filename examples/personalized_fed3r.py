"""Personalized FED3R — per-tenant closed-form heads over the global state.

The global ridge head is immune to heterogeneity because it ignores
per-client structure; cross-device serving wants the opposite — per-USER
heads.  The closed form makes both available from the SAME statistics:

    W_k = (A + α_k·A_k + λI)⁻¹ (b + α_k·b_k)

is a rank-n_k Cholesky update of the factored global state, so a whole
cohort of personalized heads solves in ONE jitted dispatch
(repro.federated.personalization), with each tenant's α_k selected inside
that dispatch by a closed-form held-out score (α = 0 falls back to the
global head, bitwise).

The scenario: tenants DISAGREE on labels — every other tenant swaps two
class labels (user-specific tastes / annotation conventions).  The global
head averages the conflicting concepts away; the personalized closed form
recovers each tenant's own mapping, and the α sweep automatically keeps
aligned tenants on the (bitwise) global head.

    PYTHONPATH=src python examples/personalized_fed3r.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.data.pipeline import make_federated_features, pack_personal_cohort
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
    ReferencePersonalizedLoop,
    cohort_stats,
)

D, C, LAM, K = 32, 10, 1e-2, 16

fed, test = make_federated_features(
    seed=3, n=6000, d=D, n_classes=C, n_clients=K, alpha=0.3, noise=2.0
)

# every other tenant relabels two classes: its concept differs from the
# federation's.  Half of each tenant's data builds statistics, half evaluates.
clients, eval_xy, drifted = [], [], []
for k in range(K):
    cd = fed.client(k)
    labels = np.asarray(cd.labels)
    if k % 2 == 1:
        rng = np.random.default_rng((3, k))
        i, j = rng.choice(C, size=2, replace=False)
        perm = np.arange(C)
        perm[[i, j]] = perm[[j, i]]
        labels = perm[labels]
        drifted.append(k)
    half = max(cd.n // 2, 1)
    clients.append((cd.features[:half], labels[:half]))
    eval_xy.append((cd.features[half:], labels[half:]))
packed = pack_personal_cohort(clients, client_ids=list(range(K)))

# the shared factored base: L Lᵀ = A + λI over ALL tenants' statistics
stats = cohort_stats(packed, C)
state = fed3r.Fed3RFactored(
    L=jnp.linalg.cholesky(stats.A + LAM * jnp.eye(D, dtype=jnp.float32)),
    b=stats.b,
)
W_global = fed3r.factored_solution(state)

engine = PersonalizationEngine(PersonalizeConfig(
    n_classes=C, alpha_grid=(0.0, 1.0, 4.0, 16.0, 64.0)
))
heads = engine.solve_heads(state, packed)  # K heads + α selection, ONE dispatch

reference = ReferencePersonalizedLoop(engine.cfg)  # K+1 dispatches
_, W_ref = reference.solve_at(state, packed, np.asarray(heads.alpha))

print(f"{K} tenants ({len(drifted)} with drifted label concepts): "
      f"engine={engine.dispatches} dispatch, "
      f"per-client loop={reference.dispatches} (K+1)")
print(f"engine vs per-client re-solves: "
      f"max|ΔW| = {float(jnp.max(jnp.abs(heads.W - W_ref))):.2e}\n")

print("tenant | drift | α_k   | acc(global) | acc(personalized)")
acc_p, acc_g = [], []
for k, (x, y) in enumerate(eval_xy):
    x, y = jnp.asarray(x), jnp.asarray(np.asarray(y))
    a_g = float(fed3r.accuracy(W_global, x, y))
    a_p = float(fed3r.accuracy(heads.W[k], x, y))
    acc_g.append(a_g)
    acc_p.append(a_p)
    print(f"{k:6d} | {'  yes' if k in drifted else '   no'} | "
          f"{float(heads.alpha[k]):5.1f} | {a_g:11.4f} | {a_p:.4f}")

n_global_heads = int(np.sum(np.asarray(heads.alpha) == 0.0))
print(f"\nmean over tenants: global={np.mean(acc_g):.4f}  "
      f"personalized={np.mean(acc_p):.4f}")
print(f"{n_global_heads} tenants selected α=0 — their served head IS the "
      f"global factored_solution, bitwise")
