"""FED3R vs gradient FL under pathological heterogeneity (paper Fig. 2).

Compares accuracy-vs-rounds and the App. D/E cost meters for FED3R,
FED3R-RF and the FedAvg/FedAvgM/Scaffold linear-probe baselines on the
same one-class-per-client federation.

    PYTHONPATH=src python examples/fed3r_vs_fedavg.py
"""

from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.data import make_federated_features
from repro.federated import run_fed3r
from repro.federated.costs import CostModel
from repro.federated.simulator import linear_head_task, run_federated

D, C, K = 48, 20, 100
fed, test = make_federated_features(
    seed=0, n=12_000, d=D, n_classes=C, n_clients=K, alpha=0.0, noise=2.5
)
cm = CostModel(b=2.22e6, d=D, C=C, E=1)
avg_nk = fed.client_sizes().mean()

print(f"{'method':14s} {'rounds':>7s} {'final acc':>9s} {'upload/client':>14s} "
      f"{'GFLOPs/client':>14s}")

# --- FED3R family ------------------------------------------------------------
for name, rf in (("fed3r", 0), ("fed3r-rf", 1024)):
    f3 = Fed3RConfig(n_classes=C, n_random_features=rf, rff_sigma=12.0)
    fc = FederatedConfig(n_clients=K, clients_per_round=10, n_rounds=100)
    _, _, h = run_fed3r(fed, test.features, test.labels, f3, fc, eval_every=1)
    up = cm.comm_per_client(name)["up"] * 4
    fl = cm.comp_per_client(name, avg_nk)
    print(f"{name:14s} {h.rounds[-1]:7d} {h.accuracy[-1]:9.4f} "
          f"{up/1e6:11.1f}MB {fl/1e9:13.2f}")

# --- gradient LP baselines -----------------------------------------------------
for alg, smom in (("fedavg", 0.0), ("fedavgm", 0.9), ("scaffold", 0.0)):
    task = linear_head_task(D, C, test.features, test.labels)
    fc = FederatedConfig(
        n_clients=K, clients_per_round=10, n_rounds=100, local_epochs=1,
        local_batch_size=32, client_lr=0.1, algorithm=alg,
        server_momentum=smom,
    )
    _, h = run_federated(task, fed, fc, eval_every=10)
    lp = ("fedavg" if alg != "scaffold" else "scaffold") + "-lp"
    up = cm.comm_per_client(lp)["up"] * 4 * 100  # pays every round
    fl = cm.cumulative_comp_flops_per_client(lp, 100, 10, K, avg_nk)[-1]
    print(f"{alg+'-lp':14s} {100:7d} {h.accuracy[-1]:9.4f} "
          f"{up/1e6:11.1f}MB {fl/1e9:13.2f}")
