"""End-to-end driver: FED3R + fine-tuning of a transformer backbone.

The full paper pipeline on a real model: (1) one statistics pass over every
client through the frozen backbone — closed-form classifier; (2) federated
fine-tuning of the backbone with the classifier FIXED (FT-FEAT, the paper's
most robust cross-device variant).

Default backbone is the reduced proxy for CPU speed; pass
``--arch fed3r-mnv2-proxy`` for the ~100M-parameter paper-scale extractor
(d=1280 feature space, as MobileNetV2) — same code, longer wall time.

    PYTHONPATH=src python examples/train_fed3r_ft.py --rounds 100
"""
import argparse

from repro.launch.train import run

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fed3r-mnv2-proxy-smoke")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--ft-strategy", default="feat", choices=["full", "lp", "feat"])
    ap.add_argument("--no-fed3r-init", action="store_true")
    args = ap.parse_args()

    log = run(
        args.arch,
        rounds=args.rounds,
        ft_strategy=args.ft_strategy,
        use_fed3r_init=not args.no_fed3r_init,
    )
    print("\nsummary:")
    print(f"  FED3R closed-form accuracy : {log['fed3r_acc']}")
    if log["ft_acc"]:
        print(f"  after {log['rounds'][-1]} FT rounds      : {log['ft_acc'][-1]:.4f}")
