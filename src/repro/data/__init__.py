from repro.data.partition import (  # noqa: F401
    dirichlet_partition,
    iid_partition,
    quantity_skew_sizes,
)
from repro.data.synthetic import (  # noqa: F401
    FeatureDataset,
    make_feature_dataset,
    make_token_dataset,
)
from repro.data.pipeline import ClientData, FederatedDataset, make_federated_features  # noqa: F401
