"""Synthetic federated data generators.

The offline container has no Landmarks/iNaturalist, so the paper's claims
are validated on controlled synthetic distributions where the *exact* claims
(split invariance, centralized equivalence, round counts, cost ratios) are
analytically checkable and the accuracy-shaped claims (FED3R > NCM,
RF > linear when the feature space is non-linearly separable, FT-FEAT
stability) are reproduced directionally.

Two generators:

* ``make_feature_dataset`` — "pre-extracted φ(x)" vectors: Gaussian class
  clusters on a hypersphere, optionally warped through a fixed random MLP so
  that classes are NOT linearly separable (this is what makes FED3R-RF beat
  plain FED3R, mirroring the paper's Fig. 8 mechanism).
* ``make_token_dataset`` — class-conditional token sequences for the
  end-to-end backbone drivers (each class has its own unigram distribution;
  a class-specific prefix token makes features informative).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FeatureDataset(NamedTuple):
    features: jax.Array  # (n, d) fp32
    labels: jax.Array  # (n,) int32
    n_classes: int


def make_feature_dataset(
    rng: jax.Array,
    n: int,
    d: int,
    n_classes: int,
    *,
    noise: float = 1.0,
    class_scale: float = 3.0,
    nonlinear: bool = False,
    class_imbalance: float = 0.0,  # 0 = balanced; >0 = Zipf-like skew exponent
) -> FeatureDataset:
    r_mean, r_lab, r_noise, r_mlp = jax.random.split(rng, 4)

    if nonlinear:
        # labels from random QUADRATIC forms: class = argmax_c xᵀQ_c x + q_cᵀx.
        # Decision boundaries are curved — linearly inseparable by
        # construction, but RBF-separable, so RR-RF beats plain RR
        # (the paper's Fig. 8 mechanism).
        x = jax.random.normal(r_noise, (n, d))
        kq, kl = jax.random.split(r_mlp)
        Q = jax.random.normal(kq, (n_classes, d, d)) / jnp.sqrt(d)
        q = 0.3 * jax.random.normal(kl, (n_classes, d))
        scores = jnp.einsum("nd,cde,ne->nc", x, Q, x) + x @ q.T
        labels = jnp.argmax(scores + noise * jax.random.normal(r_lab, (n, n_classes)),
                            axis=-1)
        return FeatureDataset(
            features=x * class_scale, labels=labels.astype(jnp.int32),
            n_classes=n_classes,
        )

    means = class_scale * jax.random.normal(r_mean, (n_classes, d))
    if class_imbalance > 0:
        w = 1.0 / (jnp.arange(1, n_classes + 1, dtype=jnp.float32) ** class_imbalance)
        labels = jax.random.categorical(r_lab, jnp.log(w), shape=(n,))
    else:
        labels = jax.random.randint(r_lab, (n,), 0, n_classes)
    x = means[labels] + noise * jax.random.normal(r_noise, (n, d))
    return FeatureDataset(features=x, labels=labels.astype(jnp.int32), n_classes=n_classes)


class TokenDataset(NamedTuple):
    tokens: jax.Array  # (n, S) int32
    labels: jax.Array  # (n,) int32 class labels
    lm_labels: jax.Array  # (n, S) next-token targets
    n_classes: int


def make_token_dataset(
    rng: jax.Array,
    n: int,
    seq_len: int,
    vocab_size: int,
    n_classes: int,
    *,
    sharpness: float = 2.0,
) -> TokenDataset:
    """Class-conditional unigram sequences with a class-id prefix token."""
    r_dist, r_lab, r_tok = jax.random.split(rng, 3)
    class_logits = sharpness * jax.random.normal(r_dist, (n_classes, vocab_size))
    labels = jax.random.randint(r_lab, (n,), 0, n_classes)
    toks = jax.random.categorical(
        r_tok, class_logits[labels][:, None, :], shape=(n, seq_len)
    ).astype(jnp.int32)
    # class prefix token (mod vocab) so even a mean-pooled feature is class-aware
    toks = toks.at[:, 0].set(labels % vocab_size)
    lm_labels = jnp.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
    return TokenDataset(
        tokens=toks, labels=labels.astype(jnp.int32), lm_labels=lm_labels,
        n_classes=n_classes,
    )
