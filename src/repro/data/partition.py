"""Federated partitioners — statistical heterogeneity control.

``dirichlet_partition`` follows Hsu et al. (2019): each client draws a
class-mixture q ~ Dir(α·prior) and samples its examples from it.  α → ∞
recovers IID; α = 0 degenerates to one-class-per-client (the paper's
"most heterogeneous" Cifar100 split, App. C).

``quantity_skew_sizes`` adds lognormal dataset-size skew across clients
(the paper's datasets have 13–327 avg samples/client, Table 4).
"""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(rng: np.random.Generator, n: int, n_clients: int) -> List[np.ndarray]:
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, n_clients)]


def dirichlet_partition(
    rng: np.random.Generator,
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    min_size: int = 1,
) -> List[np.ndarray]:
    """Label-skew partition. alpha=0 → each client gets a single class."""
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    for idxs in by_class:
        rng.shuffle(idxs)

    clients: List[list] = [[] for _ in range(n_clients)]

    if alpha <= 0.0:
        # one class per client, classes dealt round-robin
        per_client_class = np.arange(n_clients) % n_classes
        # split each class's examples evenly among clients owning it
        owners = [np.flatnonzero(per_client_class == c) for c in range(n_classes)]
        for c in range(n_classes):
            if len(owners[c]) == 0:
                continue
            parts = np.array_split(by_class[c], len(owners[c]))
            for o, part in zip(owners[c], parts):
                clients[o].extend(part.tolist())
        return [np.sort(np.asarray(cl, np.int64)) for cl in clients]

    # proportions per class over clients
    for c in range(n_classes):
        props = rng.dirichlet(alpha * np.ones(n_clients))
        counts = np.floor(props * len(by_class[c])).astype(int)
        # distribute the remainder
        rem = len(by_class[c]) - counts.sum()
        if rem > 0:
            extra = rng.choice(n_clients, size=rem, replace=True, p=props)
            np.add.at(counts, extra, 1)
        start = 0
        for k in range(n_clients):
            clients[k].extend(by_class[c][start : start + counts[k]].tolist())
            start += counts[k]

    # guarantee min_size by stealing from the largest clients
    for k in range(n_clients):
        while len(clients[k]) < min_size:
            donor = int(np.argmax([len(cl) for cl in clients]))
            clients[k].append(clients[donor].pop())
    return [np.sort(np.asarray(cl, np.int64)) for cl in clients]


def quantity_skew_sizes(
    rng: np.random.Generator, n: int, n_clients: int, sigma: float = 1.0
) -> np.ndarray:
    """Lognormal client sizes summing to n (each ≥ 1)."""
    raw = rng.lognormal(mean=0.0, sigma=sigma, size=n_clients)
    sizes = np.maximum(1, np.floor(raw / raw.sum() * n).astype(int))
    # fix rounding drift
    while sizes.sum() > n:
        sizes[int(np.argmax(sizes))] -= 1
    while sizes.sum() < n:
        sizes[int(np.argmin(sizes))] += 1
    return sizes
