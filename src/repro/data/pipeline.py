"""Client-side data pipeline: per-client views, batching, padding.

``FederatedDataset`` is the simulator's handle on a partitioned dataset:
one global array store + per-client index lists (zero-copy views).  The
distributed runtime instead consumes globally-sharded batches where each
data shard carries a *group* of clients with a client-id mask (see
federated/fed3r_driver.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import dirichlet_partition
from repro.data.synthetic import FeatureDataset, make_feature_dataset


@dataclass
class ClientData:
    features: np.ndarray  # (n_k, d) or tokens (n_k, S)
    labels: np.ndarray  # (n_k,)

    @property
    def n(self) -> int:
        return len(self.labels)

    def batches(
        self, batch_size: int, rng: Optional[np.random.Generator] = None,
        epochs: int = 1,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for _ in range(epochs):
            order = (
                rng.permutation(self.n) if rng is not None else np.arange(self.n)
            )
            for s in range(0, self.n, batch_size):
                sel = order[s : s + batch_size]
                yield self.features[sel], self.labels[sel]


@dataclass
class FederatedDataset:
    features: np.ndarray
    labels: np.ndarray
    client_indices: List[np.ndarray]
    n_classes: int

    @property
    def n_clients(self) -> int:
        return len(self.client_indices)

    def client(self, k: int) -> ClientData:
        idx = self.client_indices[k]
        return ClientData(self.features[idx], self.labels[idx])

    def client_sizes(self) -> np.ndarray:
        return np.array([len(ix) for ix in self.client_indices])

    def repartition(self, rng: np.random.Generator, n_clients: int, alpha: float
                    ) -> "FederatedDataset":
        """Same underlying D, different federated split — the Fig. 1 probe."""
        parts = dirichlet_partition(rng, self.labels, n_clients, alpha)
        return FederatedDataset(self.features, self.labels, parts, self.n_classes)


def make_federated_features(
    seed: int,
    n: int,
    d: int,
    n_classes: int,
    n_clients: int,
    alpha: float,
    *,
    nonlinear: bool = False,
    noise: float = 1.0,
    test_frac: float = 0.2,
) -> Tuple[FederatedDataset, FeatureDataset]:
    """Build a heterogeneous federated feature dataset + held-out test set."""
    ds = make_feature_dataset(
        jax.random.PRNGKey(seed), n, d, n_classes, nonlinear=nonlinear, noise=noise
    )
    feats = np.asarray(ds.features)
    labels = np.asarray(ds.labels)
    n_test = int(n * test_frac)
    test = FeatureDataset(
        features=jnp.asarray(feats[:n_test]),
        labels=jnp.asarray(labels[:n_test]),
        n_classes=n_classes,
    )
    tr_feats, tr_labels = feats[n_test:], labels[n_test:]
    rng = np.random.default_rng(seed + 1)
    parts = dirichlet_partition(rng, tr_labels, n_clients, alpha)
    fed = FederatedDataset(tr_feats, tr_labels, parts, n_classes)
    return fed, test
