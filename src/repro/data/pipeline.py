"""Client-side data pipeline: per-client views, batching, padding, packing.

``FederatedDataset`` is the simulator's handle on a partitioned dataset:
one global array store + per-client index lists (zero-copy views).

Four packers turn ragged per-client data into fixed-shape device arrays:

* :func:`pack_client_batches` — ONE client padded to a global
  ``(epochs·n_batches, batch_size)`` grid; the gradient-FL local-update
  shape (per-client reference path).
* :func:`pack_cohort_batches` — a SAMPLED COHORT of clients stacked into
  ``(cohort, epochs·n_batches, batch_size, ...)`` arrays with masks; the
  shape :mod:`repro.federated.round_engine` vmaps one whole FL round over.
  Canonical id order + per-(seed, client) shuffling make the packed arrays
  bitwise invariant to the order the cohort was sampled in.
* :func:`pack_client_shards` — MANY clients padded into
  ``(n_shards, clients_per_shard, max_n, ...)`` with masks; the statistics
  shape consumed by :mod:`repro.federated.engine`'s scan accumulation.
  Packing is canonical (clients sorted by id) so downstream accumulation is
  bitwise invariant to the order clients were sampled in.
* :func:`pack_arrival_waves` — a TIMELINE of arrival waves padded into
  ``(n_waves, clients_per_wave, max_n, ...)`` with masks; the streaming
  shape :mod:`repro.federated.streaming_engine` scans over.  Clients are
  canonically sorted by id WITHIN each wave (arrival order across waves is
  the semantics of the stream and is preserved), so the packed arrays —
  and the engine's folded state — are bitwise invariant to the order a
  wave's concurrent arrivals were presented in.
* :func:`pack_personal_cohort` — a COHORT of tenants padded into
  ``(cohort, max_n, ...)`` with masks plus a per-client HOLDOUT split for
  closed-form α selection; the personalization shape
  :mod:`repro.federated.personalization` solves K per-tenant heads over in
  one batched dispatch.  Built on :func:`pack_client_shards` (same
  canonical-id-order / round_to / ``-1``-empty-slot conventions), so the
  packed cohort — and the batched head solve — is bitwise invariant to
  the order the tenants were requested in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import dirichlet_partition
from repro.data.synthetic import FeatureDataset, make_feature_dataset
from repro.launch.mesh import data_parallel_size


def _data_parallel(
    mesh: Optional[jax.sharding.Mesh], num_shards: Optional[int]
) -> int:
    """The data-parallel way count a packed leading axis must divide.

    Every packer pads its sharded axis to a multiple of this with fully
    masked blocks (``client_ids == -1``, zero mask) so the dist layer
    (:mod:`repro.federated.dist`) can split it evenly over
    ``data_axes(mesh)``.  Masked blocks contribute exactly nothing to any
    statistic, so padding preserves canonical-order bit-invariance.
    """
    if num_shards is not None:
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        return int(num_shards)
    return 1 if mesh is None else data_parallel_size(mesh)


@dataclass
class ClientData:
    features: np.ndarray  # (n_k, d) or tokens (n_k, S)
    labels: np.ndarray  # (n_k,)

    @property
    def n(self) -> int:
        return len(self.labels)

    def batches(
        self, batch_size: int, rng: Optional[np.random.Generator] = None,
        epochs: int = 1,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        for _ in range(epochs):
            order = (
                rng.permutation(self.n) if rng is not None else np.arange(self.n)
            )
            for s in range(0, self.n, batch_size):
                sel = order[s : s + batch_size]
                yield self.features[sel], self.labels[sel]


@dataclass
class FederatedDataset:
    features: np.ndarray
    labels: np.ndarray
    client_indices: List[np.ndarray]
    n_classes: int

    @property
    def n_clients(self) -> int:
        return len(self.client_indices)

    def client(self, k: int) -> ClientData:
        idx = self.client_indices[k]
        return ClientData(self.features[idx], self.labels[idx])

    def client_sizes(self) -> np.ndarray:
        return np.array([len(ix) for ix in self.client_indices])

    def repartition(self, rng: np.random.Generator, n_clients: int, alpha: float
                    ) -> "FederatedDataset":
        """Same underlying D, different federated split — the Fig. 1 probe."""
        parts = dirichlet_partition(rng, self.labels, n_clients, alpha)
        return FederatedDataset(self.features, self.labels, parts, self.n_classes)


class PackedClients(NamedTuple):
    """Clients packed into dense shard arrays for scan accumulation.

    ``inputs``/``labels``/``mask`` share the leading
    ``(n_shards, clients_per_shard, max_n)`` layout; ``mask`` is 1.0 on real
    samples, 0.0 on padding.  Empty client slots (shard-count padding) have
    ``client_ids == -1`` and an all-zero mask, so they contribute exactly
    nothing to any masked statistic.
    """

    inputs: np.ndarray  # (S, P, N, ...) features or tokens
    labels: np.ndarray  # (S, P, N) int32
    mask: np.ndarray  # (S, P, N) float32
    client_ids: np.ndarray  # (S, P) int32, -1 = empty slot

    @property
    def n_shards(self) -> int:
        return self.inputs.shape[0]

    @property
    def clients_per_shard(self) -> int:
        return self.inputs.shape[1]

    @property
    def n_clients(self) -> int:
        return int((self.client_ids >= 0).sum())

    @property
    def n_samples(self) -> int:
        return int(self.mask.sum())


def pack_client_shards(
    clients: Sequence[Tuple[np.ndarray, np.ndarray]],
    clients_per_shard: int,
    *,
    client_ids: Optional[Sequence[int]] = None,
    max_n: Optional[int] = None,
    round_to: int = 8,
    canonical_order: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    num_shards: Optional[int] = None,
) -> PackedClients:
    """Pack ``[(inputs_k, labels_k), ...]`` into :class:`PackedClients`.

    ``max_n`` (the per-client sample capacity) is rounded up to a multiple of
    ``round_to`` so repeated rounds with slightly different client sizes hit
    one jit trace.  Pass a dataset-global ``max_n`` to guarantee a single
    trace across all rounds.  With ``canonical_order`` the clients are sorted
    by id before packing, which makes the packed arrays — and therefore every
    deterministic accumulation over them — invariant to sampling order.

    ``mesh`` (or an explicit ``num_shards`` way count) pads the leading
    shard axis to a multiple of the mesh's data-parallel size with fully
    masked empty shards, so the dist layer can split the scan evenly over
    the data axes; the padding blocks are exact no-ops, preserving the
    bit-invariance guarantees.
    """
    if not clients:
        raise ValueError("pack_client_shards: empty client list")
    if clients_per_shard < 1:
        raise ValueError(f"clients_per_shard must be >= 1, got {clients_per_shard}")
    ids = np.arange(len(clients), dtype=np.int32) if client_ids is None else (
        np.asarray(client_ids, np.int32)
    )
    if len(ids) != len(clients):
        raise ValueError("client_ids length mismatch")
    order = np.argsort(ids, kind="stable") if canonical_order else np.arange(len(ids))

    sizes = [len(clients[i][1]) for i in order]
    need = max(max(sizes), 1) if max_n is None else max_n
    if max(sizes) > need:
        raise ValueError(f"client with {max(sizes)} samples exceeds max_n={need}")
    cap = -(-need // round_to) * round_to

    n_shards = -(-len(clients) // clients_per_shard)
    dp = _data_parallel(mesh, num_shards)
    n_shards = -(-n_shards // dp) * dp  # pad with fully-masked shards
    n_slots = n_shards * clients_per_shard
    x0 = np.asarray(clients[order[0]][0])
    inputs = np.zeros((n_slots, cap) + x0.shape[1:], x0.dtype)
    labels = np.zeros((n_slots, cap), np.int32)
    mask = np.zeros((n_slots, cap), np.float32)
    slot_ids = np.full((n_slots,), -1, np.int32)
    for slot, i in enumerate(order):
        x, y = clients[i]
        n_k = len(y)
        inputs[slot, :n_k] = x
        labels[slot, :n_k] = y
        mask[slot, :n_k] = 1.0
        slot_ids[slot] = ids[i]

    def shard(a: np.ndarray) -> np.ndarray:
        return a.reshape((n_shards, clients_per_shard) + a.shape[1:])

    return PackedClients(
        inputs=shard(inputs), labels=shard(labels), mask=shard(mask),
        client_ids=slot_ids.reshape(n_shards, clients_per_shard),
    )


class PackedArrivals(NamedTuple):
    """Arrival waves packed into dense timeline arrays for scan streaming.

    ``inputs``/``labels``/``mask`` share the leading
    ``(n_waves, clients_per_wave, max_n)`` layout; ``mask`` is 1.0 on real
    samples, 0.0 on padding.  Empty client slots — wave-width padding, or
    whole waves with zero arrivals — have ``client_ids == -1`` and an
    all-zero mask, so they contribute exactly nothing to any masked
    statistic (a zero-arrival wave is an exact no-op that still advances
    the wave clock).
    """

    inputs: np.ndarray  # (T, P, N, ...) features or tokens
    labels: np.ndarray  # (T, P, N) int32
    mask: np.ndarray  # (T, P, N) float32
    client_ids: np.ndarray  # (T, P) int32, -1 = empty slot

    @property
    def n_waves(self) -> int:
        return self.inputs.shape[0]

    @property
    def clients_per_wave(self) -> int:
        return self.inputs.shape[1]

    @property
    def n_clients(self) -> int:
        return int((self.client_ids >= 0).sum())

    @property
    def n_samples(self) -> int:
        return int(self.mask.sum())

    def slice_waves(self, start: int, stop: int) -> "PackedArrivals":
        """A contiguous sub-stream (e.g. one serving segment) — zero-copy."""
        return PackedArrivals(
            inputs=self.inputs[start:stop],
            labels=self.labels[start:stop],
            mask=self.mask[start:stop],
            client_ids=self.client_ids[start:stop],
        )


def pack_arrival_waves(
    waves: Sequence[Sequence[Tuple[np.ndarray, np.ndarray]]],
    *,
    client_ids: Optional[Sequence[Sequence[int]]] = None,
    clients_per_wave: Optional[int] = None,
    max_n: Optional[int] = None,
    round_to: int = 8,
    canonical_order: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    num_shards: Optional[int] = None,
) -> PackedArrivals:
    """Pack a timeline ``[[(x_k, y_k), ...], ...]`` into :class:`PackedArrivals`.

    Wave ``t`` holds the clients that arrive at time-step ``t`` (possibly
    none).  All waves share one ``(clients_per_wave, max_n)`` grid — both
    default to the timeline maxima, ``max_n`` rounded up to a multiple of
    ``round_to`` — so the streaming engine scans a single fixed-shape array
    and the whole stream costs one jit trace.  ``client_ids`` assigns global
    ids per wave (default: arrival-order enumeration across the timeline).
    With ``canonical_order`` each wave's clients are sorted by id before
    packing, making the packed arrays bitwise invariant to the presentation
    order of concurrent arrivals.

    ``mesh`` (or ``num_shards``) pads ``clients_per_wave`` — the axis the
    dist layer shards, since the wave axis is the scanned arrival clock —
    to a multiple of the data-parallel size with fully masked slots (exact
    no-ops, bit-invariance preserved).
    """
    if not waves:
        raise ValueError("pack_arrival_waves: empty timeline")
    if client_ids is None:
        ids_per_wave: List[np.ndarray] = []
        nxt = 0
        for wave in waves:
            ids_per_wave.append(np.arange(nxt, nxt + len(wave), dtype=np.int32))
            nxt += len(wave)
    else:
        if len(client_ids) != len(waves):
            raise ValueError("client_ids timeline length mismatch")
        ids_per_wave = [np.asarray(ids, np.int32) for ids in client_ids]
        for wave, ids in zip(waves, ids_per_wave):
            if len(ids) != len(wave):
                raise ValueError("client_ids wave length mismatch")

    widths = [len(wave) for wave in waves]
    P = max(max(widths), 1) if clients_per_wave is None else clients_per_wave
    if max(widths) > P:
        raise ValueError(
            f"wave with {max(widths)} arrivals exceeds clients_per_wave={P}"
        )
    dp = _data_parallel(mesh, num_shards)
    P = -(-P // dp) * dp  # pad the sharded wave-width axis
    sizes = [len(y) for wave in waves for _, y in wave]
    need = max(sizes, default=1) if max_n is None else max_n
    if sizes and max(sizes) > need:
        raise ValueError(f"client with {max(sizes)} samples exceeds max_n={need}")
    cap = -(-max(need, 1) // round_to) * round_to

    x0 = None
    for wave in waves:
        if wave:
            x0 = np.asarray(wave[0][0])
            break
    if x0 is None:
        raise ValueError("pack_arrival_waves: no clients in any wave")

    T = len(waves)
    inputs = np.zeros((T, P, cap) + x0.shape[1:], x0.dtype)
    labels = np.zeros((T, P, cap), np.int32)
    mask = np.zeros((T, P, cap), np.float32)
    slot_ids = np.full((T, P), -1, np.int32)
    for t, (wave, ids) in enumerate(zip(waves, ids_per_wave)):
        order = (
            np.argsort(ids, kind="stable") if canonical_order
            else np.arange(len(ids))
        )
        for slot, i in enumerate(order):
            x, y = wave[i]
            n_k = len(y)
            inputs[t, slot, :n_k] = x
            labels[t, slot, :n_k] = y
            mask[t, slot, :n_k] = 1.0
            slot_ids[t, slot] = ids[i]
    return PackedArrivals(
        inputs=inputs, labels=labels, mask=mask, client_ids=slot_ids
    )


class PackedPersonalCohort(NamedTuple):
    """A tenant cohort packed for one batched personalized-head solve.

    ``inputs``/``labels``/``mask``/``holdout`` share the leading
    ``(cohort, max_n)`` layout; ``mask`` is 1.0 on real samples, 0.0 on
    padding, and ``holdout`` ⊆ ``mask`` marks the per-client validation
    samples the α sweep scores on (never the client's full data: index 0 of
    every client is always train).  Empty cohort slots (width padding) have
    ``client_ids == -1`` and all-zero masks, so their statistics vanish and
    their head degenerates to the global solution at any α.
    """

    inputs: np.ndarray  # (K, N, ...) features or tokens
    labels: np.ndarray  # (K, N) int32
    mask: np.ndarray  # (K, N) float32
    holdout: np.ndarray  # (K, N) float32, subset of mask (α-selection split)
    client_ids: np.ndarray  # (K,) int32, -1 = empty slot

    @property
    def cohort(self) -> int:
        return self.inputs.shape[0]

    @property
    def n_clients(self) -> int:
        return int((self.client_ids >= 0).sum())

    @property
    def n_samples(self) -> int:
        return int(self.mask.sum())

    @property
    def n_holdout(self) -> int:
        return int(self.holdout.sum())


def pack_personal_cohort(
    clients: Sequence[Tuple[np.ndarray, np.ndarray]],
    *,
    client_ids: Optional[Sequence[int]] = None,
    cohort_size: Optional[int] = None,
    max_n: Optional[int] = None,
    round_to: int = 8,
    holdout_frac: float = 0.25,
    canonical_order: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    num_shards: Optional[int] = None,
) -> PackedPersonalCohort:
    """Pack ``[(x_k, y_k), ...]`` into a :class:`PackedPersonalCohort`.

    Reuses :func:`pack_client_shards`'s padding conventions by construction
    (one shard of width ``cohort_size``): canonical id sort, ``round_to``
    sample-capacity rounding, ``-1``/zero-mask empty slots.  On top, every
    client with ≥ 2 samples gets a deterministic non-empty HOLDOUT split —
    every ``round(1/frac)``-th of its samples (its last sample if it has
    fewer than that), never index 0, so at least one sample remains on
    each side — which the personalization engine's α sweep scores against.
    Single-sample clients get no holdout (their sweep degenerates to
    ``alpha_grid[0]``).  The split is a pure function of the client's own
    sample order, never of cohort position, preserving bit-invariance to
    request order.

    ``mesh`` (or ``num_shards``) pads the cohort axis to a multiple of the
    data-parallel size with empty slots whose heads degenerate to the
    global solution — the dist layer shards the cohort over the data axes
    and gathers the solved heads back.
    """
    if not 0.0 <= holdout_frac < 1.0:
        raise ValueError(f"holdout_frac must be in [0, 1), got {holdout_frac}")
    K = len(clients) if cohort_size is None else cohort_size
    if K < len(clients):
        raise ValueError(f"cohort_size={K} < {len(clients)} clients")
    dp = _data_parallel(mesh, num_shards)
    K = -(-K // dp) * dp  # pad the sharded cohort axis
    shards = pack_client_shards(
        clients,
        clients_per_shard=K,
        client_ids=client_ids,
        max_n=max_n,
        round_to=round_to,
        canonical_order=canonical_order,
    )
    inputs = shards.inputs[0]
    labels = shards.labels[0]
    mask = shards.mask[0]
    ids = shards.client_ids[0]

    holdout = np.zeros_like(mask)
    if holdout_frac > 0.0:
        stride = max(int(round(1.0 / holdout_frac)), 2)
        for k in range(K):
            n_k = int(mask[k].sum())
            if n_k >= 2:
                idx = np.arange(stride - 1, n_k, stride)
                if len(idx) == 0:  # n_k < stride: still hold out ONE sample
                    idx = np.array([n_k - 1])
                holdout[k, idx] = 1.0
    return PackedPersonalCohort(
        inputs=inputs, labels=labels, mask=mask, holdout=holdout, client_ids=ids
    )


def pack_client_batches(
    x: np.ndarray, y: np.ndarray, batch_size: int, n_batches: int, epochs: int,
    rng: Optional[np.random.Generator] = None,
) -> Dict[str, np.ndarray]:
    """Pad one client's data to the global (epochs·n_batches, batch_size) grid.

    The gradient-FL local-update shape: every client fills the same padded
    grid (mask marks real samples) so one jitted ``local_update`` serves all
    clients without retracing.  Each epoch reshuffles with ``rng``.
    """
    total = n_batches * batch_size
    xs, ys, ms = [], [], []
    for _ in range(epochs):
        order = rng.permutation(len(y)) if rng is not None else np.arange(len(y))
        xe = np.zeros((total,) + x.shape[1:], x.dtype)
        ye = np.zeros((total,), y.dtype)
        me = np.zeros((total,), np.float32)
        k = min(len(y), total)
        xe[:k] = x[order[:k]]
        ye[:k] = y[order[:k]]
        me[:k] = 1.0
        xs.append(xe.reshape(n_batches, batch_size, *x.shape[1:]))
        ys.append(ye.reshape(n_batches, batch_size))
        ms.append(me.reshape(n_batches, batch_size))
    return {
        "x": np.concatenate(xs, 0),
        "y": np.concatenate(ys, 0),
        "mask": np.concatenate(ms, 0),
    }


class PackedCohort(NamedTuple):
    """A sampled cohort packed for one vmapped FL round.

    ``x``/``y``/``mask`` share the leading ``(cohort, n_steps, batch_size)``
    layout (``n_steps = epochs·n_batches``); ``mask`` is 1.0 on real samples,
    0.0 on padding.  Padded cohort slots have ``client_ids == -1`` and an
    all-zero mask, so their local update is an exact no-op with aggregation
    weight 0.
    """

    x: np.ndarray  # (K, n_steps, B, ...) features or tokens
    y: np.ndarray  # (K, n_steps, B) int32
    mask: np.ndarray  # (K, n_steps, B) float32
    client_ids: np.ndarray  # (K,) int32, -1 = padded slot

    @property
    def cohort(self) -> int:
        return self.x.shape[0]

    @property
    def n_clients(self) -> int:
        return int((self.client_ids >= 0).sum())

    @property
    def n_samples(self) -> int:
        return int(self.mask.sum())

    def batches(self) -> Dict[str, np.ndarray]:
        """The stacked batch dict the round engine's vmapped update eats."""
        return {"x": self.x, "y": self.y, "mask": self.mask}


def pack_cohort_batches(
    clients: Sequence[Tuple[np.ndarray, np.ndarray]],
    batch_size: int,
    n_batches: int,
    epochs: int = 1,
    *,
    client_ids: Optional[Sequence[int]] = None,
    seed: Optional[Sequence[int]] = None,
    cohort_size: Optional[int] = None,
    canonical_order: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    num_shards: Optional[int] = None,
) -> PackedCohort:
    """Stack ``[(x_k, y_k), ...]`` into a :class:`PackedCohort`.

    Each client is padded through :func:`pack_client_batches` onto the same
    ``(epochs·n_batches, batch_size)`` grid, then the cohort is stacked on a
    new leading axis — the dimension the round engine vmaps ``local_update``
    over.  With ``canonical_order`` clients are sorted by id, and each
    client's epoch shuffles draw from ``default_rng((*seed, client_id))`` —
    a pure function of (seed, id), never of cohort position — so the packed
    arrays (and therefore the whole aggregated round) are bitwise invariant
    to sampling order.  ``cohort_size`` pads the cohort with empty slots
    (``client_ids == -1``, zero mask) up to a fixed vmap width; ``mesh``
    (or ``num_shards``) additionally pads it to a multiple of the mesh's
    data-parallel size so the dist layer can shard the cohort axis evenly
    (padded slots have aggregation weight 0 — exact no-ops).
    """
    if not clients:
        raise ValueError("pack_cohort_batches: empty cohort")
    ids = np.arange(len(clients), dtype=np.int32) if client_ids is None else (
        np.asarray(client_ids, np.int32)
    )
    if len(ids) != len(clients):
        raise ValueError("client_ids length mismatch")
    K = len(clients) if cohort_size is None else cohort_size
    if K < len(clients):
        raise ValueError(f"cohort_size={K} < {len(clients)} clients")
    dp = _data_parallel(mesh, num_shards)
    K = -(-K // dp) * dp  # pad the sharded cohort axis
    order = np.argsort(ids, kind="stable") if canonical_order else np.arange(len(ids))

    n_steps = epochs * n_batches
    x0 = np.asarray(clients[order[0]][0])
    xs = np.zeros((K, n_steps, batch_size) + x0.shape[1:], x0.dtype)
    ys = np.zeros((K, n_steps, batch_size), np.int32)
    ms = np.zeros((K, n_steps, batch_size), np.float32)
    slot_ids = np.full((K,), -1, np.int32)
    for slot, i in enumerate(order):
        x, y = clients[i]
        rng = (
            np.random.default_rng(tuple(seed) + (int(ids[i]),))
            if seed is not None else None
        )
        b = pack_client_batches(
            np.asarray(x), np.asarray(y), batch_size, n_batches, epochs, rng
        )
        xs[slot], ys[slot], ms[slot] = b["x"], b["y"], b["mask"]
        slot_ids[slot] = ids[i]
    return PackedCohort(x=xs, y=ys, mask=ms, client_ids=slot_ids)


def make_federated_features(
    seed: int,
    n: int,
    d: int,
    n_classes: int,
    n_clients: int,
    alpha: float,
    *,
    nonlinear: bool = False,
    noise: float = 1.0,
    test_frac: float = 0.2,
) -> Tuple[FederatedDataset, FeatureDataset]:
    """Build a heterogeneous federated feature dataset + held-out test set."""
    ds = make_feature_dataset(
        jax.random.PRNGKey(seed), n, d, n_classes, nonlinear=nonlinear, noise=noise
    )
    feats = np.asarray(ds.features)
    labels = np.asarray(ds.labels)
    n_test = int(n * test_frac)
    test = FeatureDataset(
        features=jnp.asarray(feats[:n_test]),
        labels=jnp.asarray(labels[:n_test]),
        n_classes=n_classes,
    )
    tr_feats, tr_labels = feats[n_test:], labels[n_test:]
    rng = np.random.default_rng(seed + 1)
    parts = dirichlet_partition(rng, tr_labels, n_clients, alpha)
    fed = FederatedDataset(tr_feats, tr_labels, parts, n_classes)
    return fed, test
