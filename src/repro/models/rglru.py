"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrent block is:

    x ── proj_main ── causal-conv1d(4) ── RG-LRU ──┐
                                                    ⊙ ── proj_out ──> y
    x ── proj_gate ── GeLU ───────────────────────┘

with the Real-Gated LRU recurrence (elementwise over the lru_width channels):

    r_t = σ(W_a x_t + b_a)                    recurrence gate
    i_t = σ(W_x x_t + b_x)                    input gate
    log a_t = −c · softplus(Λ) · r_t          (c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` (parallel prefix — log-depth on the sequence)
instead of a CUDA sequential kernel; decode is a single elementwise update.
State is carried in fp32 (the paper keeps the recurrence in fp32 as well).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d_apply,
    causal_conv1d_init,
    causal_conv1d_step,
    dense_init,
)
from repro.sharding.hints import hint

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def rglru_init(rng, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    r = jax.random.split(rng, 6)
    # Λ initialised so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix).
    u = jax.random.uniform(r[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "proj_main": dense_init(r[0], (d, w)),
        "proj_gate": dense_init(r[1], (d, w)),
        "conv": causal_conv1d_init(r[2], w, 4),
        "w_a": dense_init(r[3], (w, w)),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": dense_init(r[4], (w, w)),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "proj_out": dense_init(jax.random.fold_in(rng, 7), (w, d)),
    }


def _gates(p: dict, x: jax.Array):
    """x: (..., w) fp32 -> (log_a, gated_input) both fp32."""
    r = jax.nn.sigmoid(x @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(x @ p["w_x"] + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return log_a, beta * (i * x)


def _linear_scan(log_a: jax.Array, b: jax.Array, h0: Optional[jax.Array]):
    """h_t = exp(log_a_t)·h_{t-1} + b_t via associative parallel prefix.

    log_a, b: (B, S, w) fp32; h0: (B, w) or None. Returns h: (B, S, w).
    """
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * h0)

    def combine(left, right):
        la1, b1 = left
        la2, b2 = right
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    build_cache: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """Sequence mode. x: (B, S, d) -> (y, cache?)."""
    dt = x.dtype
    gate = hint(jax.nn.gelu(x @ p["proj_gate"].astype(dt)), "batch", None, "model")
    main_raw = hint(x @ p["proj_main"].astype(dt), "batch", None, "model")
    main = causal_conv1d_apply(p["conv"], main_raw)

    m32 = main.astype(jnp.float32)
    log_a, b = _gates(p, m32)
    log_a = hint(log_a, "batch", None, "model")
    b = hint(b, "batch", None, "model")
    h = hint(_linear_scan(log_a, b, None), "batch", None, "model")  # fp32

    y = (h.astype(dt) * gate) @ p["proj_out"].astype(dt)

    cache = None
    if build_cache:
        w_conv = p["conv"]["kernel"].shape[0]
        S = x.shape[1]
        tail = main_raw[:, max(0, S - (w_conv - 1)) :, :]
        pad = jnp.zeros((x.shape[0], (w_conv - 1) - tail.shape[1], tail.shape[-1]), dt)
        cache = {
            "h": h[:, -1, :],  # (B, w) fp32
            "conv": jnp.concatenate([pad, tail], axis=1),
        }
    return y, cache


def rglru_decode_step(
    cfg: ModelConfig, p: dict, x_t: jax.Array, cache: dict
) -> Tuple[jax.Array, dict]:
    """One-token update. x_t: (B, 1, d)."""
    dt = x_t.dtype
    xt = x_t[:, 0, :]
    gate = jax.nn.gelu(xt @ p["proj_gate"].astype(dt))
    main_raw = xt @ p["proj_main"].astype(dt)
    conv_state, main = causal_conv1d_step(p["conv"], cache["conv"], main_raw)

    m32 = main.astype(jnp.float32)
    log_a, b = _gates(p, m32)
    h = jnp.exp(log_a) * cache["h"] + b  # (B, w) fp32

    y = ((h.astype(dt) * gate) @ p["proj_out"].astype(dt))[:, None, :]
    return y, {"h": h, "conv": conv_state}
