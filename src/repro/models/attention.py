"""Attention: GQA/MHA/MQA with KV cache, sliding window, chunked prefill.

Design notes
------------
* Layout: q ``(B, Sq, H, hd)``, k/v ``(B, Sk, KV, hd)``; heads dim is the
  tensor-parallel shard axis on the production mesh.
* Masking is *position-based*: every key slot carries its absolute position
  (``k_pos``; -1 = empty slot).  A query at position p attends to slots with
  ``0 <= k_pos <= p`` and, for sliding-window variants, ``k_pos > p - W``.
  This one rule covers train, prefill, ring-buffer decode and local attention.
* The KV cache is a ring buffer of capacity ``Scap`` (= window for
  sliding-window archs): slot ``j`` holds the latest position ``p`` with
  ``p % Scap == j``.  RoPE is applied to keys at *write* time, so cached keys
  never need re-rotation.
* Prefill uses a q-chunked exact softmax (memory O(B·H·chunk·Sk) instead of
  O(B·H·S²)); with a sliding window the key range per chunk is dynamically
  sliced, making prefill O(S·W).  The Pallas flash-attention kernel
  (kernels/flash_attention.py) is the TPU fast path for the same contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init
from repro.sharding.hints import hint, mesh_axis_size

Q_CHUNK = 1024  # prefill query-chunk size

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def attn_init(rng, cfg: ModelConfig, cross: bool = False) -> dict:
    """QKV/O projection parameters. ``cross``: k/v consume encoder states."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    r = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(r[0], (d, H, hd)),
        "wk": dense_init(r[1], (d, KV, hd)),
        "wv": dense_init(r[2], (d, KV, hd)),
        "wo": dense_init(r[3], (H, hd, d), in_axis=0),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _scores_softmax_values(q, k, v, q_pos, k_pos, window, bidirectional):
    """Exact attention for one q block against a key range.

    q: (B, Sq, KV, G, hd)   k/v: (B, Sk, KV, hd)
    q_pos: (Sq,) int32      k_pos: (Sk,) int32 (−1 = empty slot)
    returns (B, Sq, KV, G, hd)
    """
    hd = q.shape[-1]
    KV, Sk = k.shape[2], k.shape[1]
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale  # (B,KV,G,Sq,Sk)
    scores = scores.astype(jnp.float32)
    # Shard the fp32 score block: kv-heads when they divide the TP axis,
    # else the key/sequence axis (context-parallel attention — softmax and
    # the value contraction reduce over the sharded axis via small psums).
    if KV % max(mesh_axis_size("model"), 1) == 0:
        scores = hint(scores, "batch", "model", None, None, None)
    elif Sk % max(mesh_axis_size("model"), 1) == 0:
        scores = hint(scores, "batch", None, None, None, "model")
    else:
        scores = hint(scores, "batch", None, None, None, None)

    valid = k_pos[None, :] >= 0  # (1, Sk)
    if not bidirectional:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def multihead_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    window: Optional[int] = None,
    bidirectional: bool = False,
) -> jax.Array:
    """Chunked exact GQA attention.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); q_pos: (Sq,); k_pos: (Sk,).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)

    if Sq <= 2 * Q_CHUNK:
        out = _scores_softmax_values(qg, k, v, q_pos, k_pos, window, bidirectional)
        return out.reshape(B, Sq, H, hd)

    assert Sq % Q_CHUNK == 0, f"Sq={Sq} not divisible by Q_CHUNK={Q_CHUNK}"
    n_chunks = Sq // Q_CHUNK
    q_chunks = qg.reshape(B, n_chunks, Q_CHUNK, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pos_chunks = q_pos.reshape(n_chunks, Q_CHUNK)

    # With a sliding window each q chunk only needs keys in
    # [chunk_start - window + 1, chunk_end); slice that range (static length).
    use_slice = window is not None and not bidirectional and Sk > window + Q_CHUNK
    slice_len = min(Sk, (window + Q_CHUNK)) if use_slice else Sk

    def body(_, xs):
        qc, pc = xs  # (B, Q_CHUNK, KV, G, hd), (Q_CHUNK,)
        if use_slice:
            start = jnp.clip(pc[0] - (window - 1), 0, Sk - slice_len)
            kc = jax.lax.dynamic_slice_in_dim(k, start, slice_len, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, slice_len, axis=1)
            kpc = jax.lax.dynamic_slice_in_dim(k_pos, start, slice_len, axis=0)
        else:
            kc, vc, kpc = k, v, k_pos
        out = _scores_softmax_values(qc, kc, vc, pc, kpc, window, bidirectional)
        return None, out

    # flash-style memory discipline: per-chunk scores/probs are recomputed in
    # the backward pass instead of being saved across the whole q sweep
    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = jax.lax.scan(body, None, (q_chunks, pos_chunks))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out


# ---------------------------------------------------------------------------
# KV cache (ring buffer, optionally int8-quantized)
# ---------------------------------------------------------------------------


def _quantize(x: jax.Array):
    """Symmetric per-(batch, token, head) int8 quantization over hd."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(cache: dict, name: str, dtype) -> jax.Array:
    """Read k/v back to compute dtype (no-op for unquantized caches)."""
    arr = cache[name]
    if arr.dtype == jnp.int8:
        return (arr.astype(jnp.float32) * cache[name + "_scale"]).astype(dtype)
    return arr.astype(dtype)


def init_cache(cfg: ModelConfig, batch: int, capacity: int, dtype) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    if cfg.kv_cache_quant:
        return {
            "k": jnp.zeros((batch, capacity, KV, hd), jnp.int8),
            "v": jnp.zeros((batch, capacity, KV, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, capacity, KV, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, capacity, KV, 1), jnp.float32),
            "pos": jnp.full((capacity,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, capacity, KV, hd), dtype),
        "v": jnp.zeros((batch, capacity, KV, hd), dtype),
        "pos": jnp.full((capacity,), -1, jnp.int32),
    }


def fill_cache_from_prefill(cache: dict, k: jax.Array, v: jax.Array, seq_len: int) -> dict:
    """Scatter the last ``capacity`` keys of a prefill into ring slots."""
    cap = cache["k"].shape[1]
    keep = min(seq_len, cap)
    ps = jnp.arange(seq_len - keep, seq_len, dtype=jnp.int32)
    slots = ps % cap
    k_w, v_w = k[:, seq_len - keep :], v[:, seq_len - keep :]
    out = {"pos": cache["pos"].at[slots].set(ps)}
    if cache["k"].dtype == jnp.int8:
        kq, ks = _quantize(k_w)
        vq, vs = _quantize(v_w)
        out.update(
            k=cache["k"].at[:, slots].set(kq),
            v=cache["v"].at[:, slots].set(vq),
            k_scale=cache["k_scale"].at[:, slots].set(ks),
            v_scale=cache["v_scale"].at[:, slots].set(vs),
        )
    else:
        out.update(
            k=cache["k"].at[:, slots].set(k_w.astype(cache["k"].dtype)),
            v=cache["v"].at[:, slots].set(v_w.astype(cache["v"].dtype)),
        )
    return out


def cache_decode_update(cache: dict, k_t: jax.Array, v_t: jax.Array, pos: jax.Array) -> dict:
    """Write one token (k_t/v_t: (B, 1, KV, hd)) at ring slot pos % cap."""
    cap = cache["k"].shape[1]
    slot = (pos % cap).astype(jnp.int32)

    def upd(buf, val):
        return jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)

    out = {
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], pos[None].astype(jnp.int32), slot, axis=0
        )
    }
    if cache["k"].dtype == jnp.int8:
        kq, ks = _quantize(k_t)
        vq, vs = _quantize(v_t)
        out.update(
            k=upd(cache["k"], kq), v=upd(cache["v"], vq),
            k_scale=upd(cache["k_scale"], ks), v_scale=upd(cache["v_scale"], vs),
        )
    else:
        out.update(
            k=upd(cache["k"], k_t.astype(cache["k"].dtype)),
            v=upd(cache["v"], v_t.astype(cache["v"].dtype)),
        )
    return out


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + cache + attention + out-proj)
# ---------------------------------------------------------------------------


def _project_q(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    return hint(q, "batch", None, "model", None)


def _project_kv(p, x, cfg):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    ms = max(mesh_axis_size("model"), 1)
    if k.shape[2] % ms == 0:  # kv heads shard evenly
        return (hint(k, "batch", None, "model", None),
                hint(v, "batch", None, "model", None))
    # context-parallel fallback: shard the sequence dim
    return (hint(k, "batch", "model", None, None),
            hint(v, "batch", "model", None, None))


def attn_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    angles: Optional[jax.Array] = None,
    window: Optional[int] = None,
    bidirectional: bool = False,
    cache: Optional[dict] = None,
    decode_pos: Optional[jax.Array] = None,
    build_cache: bool = False,
    cache_capacity: Optional[int] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention layer.

    Modes:
      * train/encoder: ``cache=None, build_cache=False`` -> (y, None)
      * prefill:       ``build_cache=True``              -> (y, filled cache)
      * decode:        ``cache`` set, x is (B, 1, d), ``decode_pos`` scalar
                       -> (y, updated cache)
    """
    B, S, _ = x.shape
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)

    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)

    if cache is not None:  # decode: one new token against the ring buffer
        assert S == 1 and decode_pos is not None
        cache = cache_decode_update(cache, k, v, decode_pos)
        q_pos = decode_pos[None].astype(jnp.int32)
        y = multihead_attention(
            q, dequantize_kv(cache, "k", x.dtype), dequantize_kv(cache, "v", x.dtype),
            q_pos, cache["pos"], window=window, bidirectional=False,
        )
    else:
        q_pos = jnp.arange(S, dtype=jnp.int32)
        y = multihead_attention(q, k, v, q_pos, q_pos, window=window,
                                bidirectional=bidirectional)
        if build_cache:
            cap = cache_capacity or (window if window else S)
            new = init_cache(cfg, B, cap, k.dtype)
            cache = fill_cache_from_prefill(new, k, v, S)

    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(x.dtype))
    return out, cache


def cross_attn_apply(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    enc_states: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Encoder-decoder cross attention (whisper).

    Either ``enc_states`` (first pass: project and return reusable kv) or
    ``enc_kv`` (cached projections) must be given.
    """
    if enc_kv is None:
        assert enc_states is not None
        enc_kv = _project_kv(p, enc_states, cfg)
    k, v = enc_kv
    q = _project_q(p, x, cfg)
    Sk = k.shape[1]
    q_pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    k_pos = jnp.arange(Sk, dtype=jnp.int32)
    y = multihead_attention(q, v_cast(k, x.dtype), v_cast(v, x.dtype), q_pos, k_pos,
                            bidirectional=True)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(x.dtype))
    return out, enc_kv


def v_cast(a: jax.Array, dtype) -> jax.Array:
    return a.astype(dtype)
