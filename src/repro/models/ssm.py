"""Mamba2 mixer — SSD (state-space duality) chunked scan + O(1) decode.

Follows the Mamba2 paper (arXiv:2405.21060) "fully recurrent <-> quadratic
dual" chunked algorithm:

  * within a chunk of length Q, the output is an attention-like quadratic
    form  Y_intra = (C Bᵀ ∘ L) (Δ·X)  with L the decay-weighted causal mask;
  * across chunks a tiny recurrence carries the (H, P, N) state
    h_{c+1} = (Π decay) h_c + states_c, run with ``jax.lax.scan``;
  * decode is a rank-1 state update per token — the sub-quadratic path that
    makes the long_500k shape feasible for this architecture.

TPU adaptation: the intra-chunk term is MXU-shaped matmuls over (Q, Q) and
(Q, N)/(Q, P) tiles (Q = cfg.ssm_chunk = 256, N = 128, P = 64 — all
128-friendly); the inter-chunk scan carries only B·H·P·N floats.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    causal_conv1d_apply,
    causal_conv1d_init,
    causal_conv1d_step,
    dense_init,
)
from repro.sharding.hints import hint


def ssm_init(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner = cfg.d_inner
    H = cfg.ssm_nheads
    N = cfg.ssm_state
    g = cfg.ssm_ngroups
    conv_ch = d_inner + 2 * g * N
    d_in_proj = 2 * d_inner + 2 * g * N + H
    r = jax.random.split(rng, 4)
    return {
        "in_proj": dense_init(r[0], (d, d_in_proj)),
        "conv": causal_conv1d_init(r[1], conv_ch, cfg.ssm_conv),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(r[3], (d_inner, d)),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k].

    a: (..., Q) -> (..., Q, Q) lower-triangular (−inf above diagonal).
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # cs_i - cs_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)  inputs already weighted by Δ
    a: jax.Array,  # (B, S, H)     log-decay per step (Δ·A, negative)
    Bm: jax.Array,  # (B, S, H, N)
    Cm: jax.Array,  # (B, S, H, N)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    # reshape to chunks: (B, nc, Q, ...); heads stay tensor-parallel
    xc = hint(x.reshape(B, nc, Q, H, P), "batch", None, None, "model", None)
    ac = hint(a.reshape(B, nc, Q, H).transpose(0, 1, 3, 2), "batch", None, "model", None)
    Bc = hint(Bm.reshape(B, nc, Q, H, N), "batch", None, None, "model", None)
    Cc = hint(Cm.reshape(B, nc, Q, H, N), "batch", None, None, "model", None)

    a_cum = jnp.cumsum(ac, axis=-1)  # (B, nc, H, Q)

    # ---- intra-chunk (quadratic, attention-like) --------------------------
    L = hint(jnp.exp(_segsum(ac)), "batch", None, "model", None, None)
    scores = hint(
        jnp.einsum("bclhn,bcshn->bchls", Cc, Bc), "batch", None, "model", None, None
    )
    y_diag = hint(
        jnp.einsum("bchls,bchls,bcshp->bclhp", scores, L, xc),
        "batch", None, None, "model", None,
    )

    # ---- per-chunk states (fp32 carry for numerical stability) -------------
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B, nc, H, Q)
    states = hint(
        jnp.einsum(
            "bchl,bclhn,bclhp->bchpn",
            decay_states,
            Bc.astype(jnp.float32),
            xc.astype(jnp.float32),
        ),
        "batch", None, "model", None, None,
    )

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B, nc, H)
    h0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def step(h, inp):
        dec, st = inp  # (B, H), (B, H, P, N)
        h_new = h * dec[..., None, None] + st
        return h_new, h  # emit the state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    # ---- contribution of carried state to each position --------------------
    state_decay = jnp.exp(a_cum)  # (B, nc, H, Q)
    prev_states = hint(prev_states, "batch", None, "model", None, None)
    y_off = jnp.einsum(
        "bclhn,bchpn,bchl->bclhp", Cc.astype(jnp.float32), prev_states, state_decay
    ).astype(x.dtype)

    y = (y_diag.astype(x.dtype) + y_off).reshape(B, S, H, P)
    return y, final_state


def _split_zxbcdt(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner = cfg.d_inner
    g, N, H = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * g * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * g * N :]
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC: jax.Array):
    d_inner = cfg.d_inner
    g, N = cfg.ssm_ngroups, cfg.ssm_state
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner : d_inner + g * N]
    Cm = xBC[..., d_inner + g * N :]
    return x, Bm, Cm


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    dt = y.dtype
    y = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(ms + 1e-6) * scale).astype(dt)


def ssm_apply(
    cfg: ModelConfig,
    p: dict,
    u: jax.Array,
    *,
    build_cache: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """Sequence-mode Mamba2 mixer. u: (B, S, d)."""
    B, S, _ = u.shape
    H, P, N, g = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    dt_ = u.dtype

    zxbcdt = hint(u @ p["in_proj"].astype(dt_), "batch", None, "model")
    z, xBC_raw, dtr = _split_zxbcdt(cfg, zxbcdt)
    xBC = jax.nn.silu(causal_conv1d_apply(p["conv"], xBC_raw))
    x, Bm, Cm = _split_xbc(cfg, xBC)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    A = -jnp.exp(p["A_log"])  # (H,)

    xh = x.reshape(B, S, H, P)
    Bh = jnp.repeat(Bm.reshape(B, S, g, N), H // g, axis=2)
    Ch = jnp.repeat(Cm.reshape(B, S, g, N), H // g, axis=2)

    y, final_state = ssd_chunked(
        xh * dt[..., None].astype(dt_), (dt * A).astype(jnp.float32), Bh, Ch,
        cfg.ssm_chunk,
    )
    y = y + xh * p["D"][None, None, :, None].astype(dt_)
    y = y.reshape(B, S, H * P)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = y @ p["out_proj"].astype(dt_)

    cache = None
    if build_cache:
        w = cfg.ssm_conv
        tail = xBC_raw[:, max(0, S - (w - 1)) :, :]
        pad = jnp.zeros((B, (w - 1) - tail.shape[1], tail.shape[-1]), dt_)
        cache = {
            "state": final_state.astype(jnp.float32),
            "conv": jnp.concatenate([pad, tail], axis=1),
        }
    return out, cache


def ssm_decode_step(
    cfg: ModelConfig, p: dict, u_t: jax.Array, cache: dict
) -> Tuple[jax.Array, dict]:
    """One-token recurrent update. u_t: (B, 1, d); O(B·H·P·N) work."""
    B = u_t.shape[0]
    H, P, N, g = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    dt_ = u_t.dtype

    zxbcdt = (u_t[:, 0, :] @ p["in_proj"].astype(dt_))  # (B, dproj)
    z, xBC, dtr = _split_zxbcdt(cfg, zxbcdt)
    conv_state, xBC = causal_conv1d_step(p["conv"], cache["conv"], xBC)
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = _split_xbc(cfg, xBC)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B, H)

    xh = x.reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(B, g, N), H // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, g, N), H // g, axis=1).astype(jnp.float32)

    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xh * p["D"][None, :, None]
    y = y.reshape(B, H * P).astype(dt_)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"state": state, "conv": conv_state}
