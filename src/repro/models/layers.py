"""Common neural-net layers: norms, MLPs, embeddings, rotary (+M-RoPE), conv.

Conventions
-----------
* activations: ``(batch, seq, d_model)`` in ``cfg.dtype`` (bf16 by default);
* parameters: fp32, cast to compute dtype at use;
* every layer is a pair of functions ``<layer>_init(rng, cfg, ...) -> params``
  and ``<layer>_apply(params, x, ...) -> y`` over plain dict pytrees — no
  framework objects, so the whole stack pjit/shard_maps transparently.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    """Fan-in truncated-normal initializer (maxtext-style)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """RMSNorm or LayerNorm computed in fp32, returned in input dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    """Gated (swiglu/geglu) or plain (gelu) MLP parameters."""
    d, f = cfg.d_model, d_ff or cfg.d_ff
    r = jax.random.split(rng, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(r[0], (d, f)),
            "w_up": dense_init(r[1], (d, f)),
            "w_down": dense_init(r[2], (f, d)),
        }
    return {
        "w_up": dense_init(r[0], (d, f)),
        "b_up": jnp.zeros((f,), jnp.float32),
        "w_down": dense_init(r[1], (f, d)),
        "b_down": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = act(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        return (g * u) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary half-dims: (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (..., S) -> angles (..., S, head_dim//2), fp32."""
    inv = rope_frequencies(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs. x: (B, S, H, hd); angles: (B, S, hd//2) or (S, hd//2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]  # (B, S, 1, hd//2)
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def mrope_angles(
    positions_3d: jax.Array, head_dim: int, theta: float, sections: Tuple[int, ...]
) -> jax.Array:
    """M-RoPE (Qwen2-VL): three position streams share the rotary dims.

    positions_3d: (3, B, S) — temporal / height / width position ids.
    sections: how many of the head_dim//2 rotary dims each stream owns,
    e.g. (16, 24, 24) for head_dim=128.

    Returns angles (B, S, head_dim//2).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_frequencies(head_dim, theta)  # (hd//2,)
    # angles per stream: (3, B, S, hd//2)
    ang = positions_3d.astype(jnp.float32)[..., None] * inv
    pieces = []
    start = 0
    for i, sec in enumerate(sections):
        pieces.append(ang[i, ..., start : start + sec])
        start += sec
    return jnp.concatenate(pieces, axis=-1)


def sinusoidal_positions(n_pos: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (n_pos, d), fp32."""
    half = d // 2
    log_timescale = math.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba2 / RG-LRU temporal conv)
# ---------------------------------------------------------------------------


def causal_conv1d_init(rng, channels: int, width: int) -> dict:
    return {
        "kernel": dense_init(rng, (width, channels), in_axis=0),
        "bias": jnp.zeros((channels,), jnp.float32),
    }


def causal_conv1d_apply(p: dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C) -> (B, S, C)."""
    width = p["kernel"].shape[0]
    dt = x.dtype
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    ker = p["kernel"].astype(dt)
    out = jnp.zeros_like(x)
    for i in range(width):  # width is small (4): unrolled taps
        out = out + pad[:, i : i + x.shape[1], :] * ker[i]
    return out + p["bias"].astype(dt)


def causal_conv1d_step(p: dict, conv_state: jax.Array, x_t: jax.Array):
    """Single decode step. conv_state: (B, width-1, C); x_t: (B, C)."""
    width = p["kernel"].shape[0]
    dt = x_t.dtype
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, W, C)
    ker = p["kernel"].astype(dt)
    y = jnp.einsum("bwc,wc->bc", window, ker) + p["bias"].astype(dt)
    new_state = window[:, 1:, :] if width > 1 else conv_state
    return new_state, y


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embedding_init(rng, cfg: ModelConfig) -> dict:
    p = {"embedding": embed_init(rng, (cfg.vocab_size, cfg.d_model))}
    return p


def embed_apply(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype)


def unembed_apply(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    """Project hidden states to vocab logits (tied or separate head).

    The table is padded to ``cfg.padded_vocab`` for even sharding; padded
    columns are masked to −inf so softmax/CE semantics are unchanged.
    """
    if cfg.tie_embeddings:
        w = params["embed"]["embedding"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = x @ params["lm_head"]["kernel"].astype(x.dtype)
    if cfg.attn_logit_softcap:  # reuse as final-logit softcap when configured
        cap = cfg.attn_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    if cfg.padded_vocab > cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab_size, logits, jnp.asarray(-1e30, logits.dtype))
    return logits
