"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

Faithful to the two assigned MoE families:
  * DeepSeekMoE 16B  — 64 fine-grained routed experts, top-6, 2 shared experts
    (arXiv:2401.06066).
  * Llama-4 Scout    — 16 experts, top-1, 1 shared expert.

Implementation: Gshard-style capacity dispatch via scatter-add into an
``(E, C, d)`` expert buffer (the token-permutation formulation — memory
O(T·k·capacity_factor·d), never O(T·E)).  On the production mesh the expert
dim E is sharded over the "model" axis (expert parallelism); GSPMD lowers the
dispatch/combine scatters into all-to-all-style collectives.

Shared experts are algebraically fused into a single wide gated MLP: the sum
of S swiglu experts equals one swiglu MLP with the gate/up matrices
concatenated on the hidden axis and the down matrices stacked — exact, not an
approximation.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init
from repro.sharding.hints import hint, mesh_axis_size


def moe_init(rng, cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    r = jax.random.split(rng, 5)
    p = {
        "router": dense_init(r[0], (d, E)),
        "w_gate": dense_init(r[1], (E, d, f), in_axis=1),
        "w_up": dense_init(r[2], (E, d, f), in_axis=1),
        "w_down": dense_init(r[3], (E, f, d), in_axis=1),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = mlp_init(r[4], cfg, d_ff=cfg.n_shared_experts * cfg.d_expert)
    return p


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to a lane-friendly multiple


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_load_balance_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    # ---- routing (fp32 for numerics) -------------------------------------
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)  # (T, k)

    # ---- load-balance auxiliary loss (Switch/Gshard form) ------------------
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    dispatch_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=1), axis=0
    ) / k
    aux = E * jnp.sum(me * dispatch_frac)

    # ---- capacity positions (GROUP-LOCAL, Gshard-style) ---------------------
    # Positions are computed with a cumsum *within* data-shard-aligned token
    # groups, never across shards: a cross-shard cumsum forces the SPMD
    # partitioner into a pathological dense lowering (measured: 95% of all
    # HLO FLOPs at prefill_32k — see EXPERIMENTS.md §Perf H1).  Each group
    # owns C/G capacity slots per expert; dropping becomes group-local,
    # which is the standard Gshard/Switch semantics.
    # Groups align with the INNERMOST data axis only — never the DCN "pod"
    # axis: a (pod,data)-wide group sharding makes the partitioner emit
    # cross-pod reshards (measured 47.6 GB/chip at 2×16×16; pinned to
    # "data": 9.8 GB — EXPERIMENTS.md §Perf H1/known-items).
    G = max(mesh_axis_size("data"), 1)
    while T % G != 0:  # tiny batches in tests: fall back to fewer groups
        G //= 2
    G = max(G, 1)
    Tg = T // G
    C = _capacity(cfg, T)
    Cg = max(8, -(-C // G))

    idx_g = top_idx.reshape(G, Tg * k)  # (G, Tg*k) routing per group
    onehot = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot  # group-local prefix sums
    pos_g = jnp.sum(pos_in_e * onehot, axis=-1)  # (G, Tg*k)
    keep_g = (pos_g < Cg).astype(x.dtype)
    pos_g = jnp.minimum(pos_g, Cg - 1)

    # ---- dispatch: BATCHED scatter over the group dim ------------------------
    # The scatter is vmapped over G with G sharded on the data axes, so its
    # locality is structural (each shard scatters only its own group) — GSPMD
    # cannot prove locality of value-dependent flat indices, and the unbatched
    # formulations lower to a full-buffer all-reduce (15.6 GB/layer wire) or
    # dense masked updates (95% of HLO FLOPs).  See EXPERIMENTS.md §Perf H1.
    dt = x.dtype
    x_g = hint(jnp.repeat(xf, k, axis=0).reshape(G, Tg * k, d), "batch", None, None)

    def scatter_group(xg, ig, pg, kg):
        bufg = jnp.zeros((E, Cg, d), dt)
        return bufg.at[ig, pg].add(xg * kg[:, None])

    buf = jax.vmap(scatter_group)(x_g, idx_g, pos_g, keep_g)  # (G, E, Cg, d)
    buf = hint(buf, "batch", "model", None, None)

    # ---- expert FFN (2-D parallel: groups over data × experts over model) ---
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    h = hint(
        jnp.einsum("gecf,efd->gecd", g * u, p["w_down"].astype(dt)),
        "batch", "model", None, None,
    )

    # ---- combine: batched gather back to tokens ------------------------------
    y_rep = jax.vmap(lambda hg, ig, pg: hg[ig, pg])(h, idx_g, pos_g)
    y_rep = hint(y_rep * keep_g[..., None], "batch", None, None)  # (G, Tg*k, d)
    w = top_p.reshape(G, Tg * k).astype(dt)[..., None]
    y = jnp.sum((y_rep * w).reshape(T, k, d), axis=1)

    if "shared" in p:
        y = y + mlp_apply(cfg.replace(mlp_type="swiglu"), p["shared"], xf)

    return y.reshape(B, S, d), aux
