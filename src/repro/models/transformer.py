"""Backbone stacks: blocks, scan-over-layers, caches, and forward modes.

All six architecture families reduce to three stack shapes:

* **decoder-only homogeneous** (dense / moe / vlm / ssm) — a single
  ``jax.lax.scan`` over stacked layer parameters;
* **hybrid** (RecurrentGemma) — a scan over homogeneous *super-blocks*
  (one (rec, rec, attn) pattern repetition each) plus an unrolled remainder;
* **encoder-decoder** (Whisper) — two scans plus per-layer cross-attention.

Modes: ``train`` (causal, no cache), ``prefill`` (build KV/state caches),
``decode`` (one token, consume+update caches).  Remat (``jax.checkpoint``)
wraps the scan body in train mode.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import attn_apply, attn_init, cross_attn_apply
from repro.models.layers import (
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
)
from repro.sharding.hints import hint


class ForwardOut(NamedTuple):
    hidden: jax.Array  # (B, S, d) post-final-norm hidden states
    logits: Optional[jax.Array]
    cache: Optional[Any]
    aux_loss: jax.Array  # MoE load-balance scalar (0 for non-MoE)


# ---------------------------------------------------------------------------
# block init
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, kind: str) -> dict:
    r = jax.random.split(rng, 8)
    if kind == "attn":
        p = {"norm1": norm_init(cfg), "attn": attn_init(r[0], cfg)}
        if cfg.arch_type == "moe":
            p["moe"] = moe_mod.moe_init(r[1], cfg)
        else:
            p["mlp"] = mlp_init(r[1], cfg)
        if not cfg.parallel_block:
            p["norm2"] = norm_init(cfg)
        return p
    if kind == "ssm":
        return {"norm1": norm_init(cfg), "ssm": ssm_mod.ssm_init(r[0], cfg)}
    if kind == "rec":
        return {
            "norm1": norm_init(cfg),
            "rec": rglru_mod.rglru_init(r[0], cfg),
            "norm2": norm_init(cfg),
            "mlp": mlp_init(r[1], cfg),
        }
    if kind == "enc":
        return {
            "norm1": norm_init(cfg),
            "attn": attn_init(r[0], cfg),
            "norm2": norm_init(cfg),
            "mlp": mlp_init(r[1], cfg),
        }
    if kind == "dec":
        return {
            "norm1": norm_init(cfg),
            "self_attn": attn_init(r[0], cfg),
            "norm2": norm_init(cfg),
            "cross_attn": attn_init(r[1], cfg, cross=True),
            "norm3": norm_init(cfg),
            "mlp": mlp_init(r[2], cfg),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _ffn(cfg: ModelConfig, p: dict, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if "moe" in p:
        return moe_mod.moe_apply(cfg, p["moe"], h)
    return mlp_apply(cfg, p["mlp"], h), jnp.zeros((), jnp.float32)


def block_apply(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: jax.Array,
    *,
    angles: Optional[jax.Array],
    window: Optional[int],
    mode: str,
    cache: Optional[dict] = None,
    decode_pos: Optional[jax.Array] = None,
    cache_capacity: Optional[int] = None,
    enc_states: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Any], jax.Array]:
    """Apply one block. Returns (x', new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    build = mode == "prefill"

    if kind == "attn":
        h = norm_apply(cfg, p["norm1"], x)
        a, new_cache = attn_apply(
            cfg, p["attn"], h, angles=angles, window=window,
            cache=cache, decode_pos=decode_pos,
            build_cache=build, cache_capacity=cache_capacity,
        )
        if cfg.parallel_block:
            f, aux = _ffn(cfg, p, h)
            return x + a + f, new_cache, aux
        x = x + a
        h = norm_apply(cfg, p["norm2"], x)
        f, aux = _ffn(cfg, p, h)
        return x + f, new_cache, aux

    if kind == "ssm":
        h = norm_apply(cfg, p["norm1"], x)
        if mode == "decode":
            y, new_cache = ssm_mod.ssm_decode_step(cfg, p["ssm"], h, cache)
        else:
            y, new_cache = ssm_mod.ssm_apply(cfg, p["ssm"], h, build_cache=build)
        return x + y, new_cache, aux

    if kind == "rec":
        h = norm_apply(cfg, p["norm1"], x)
        if mode == "decode":
            y, new_cache = rglru_mod.rglru_decode_step(cfg, p["rec"], h, cache)
        else:
            y, new_cache = rglru_mod.rglru_apply(cfg, p["rec"], h, build_cache=build)
        x = x + y
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(cfg, p["mlp"], h), new_cache, aux

    if kind == "enc":
        h = norm_apply(cfg, p["norm1"], x)
        a, _ = attn_apply(cfg, p["attn"], h, angles=None, bidirectional=True)
        x = x + a
        h = norm_apply(cfg, p["norm2"], x)
        return x + mlp_apply(cfg, p["mlp"], h), None, aux

    if kind == "dec":
        # cache = {"self": attn ring cache, "cross": (k, v)} per layer
        self_cache = cache["self"] if cache is not None else None
        cross_kv = cache["cross"] if cache is not None and mode == "decode" else None
        h = norm_apply(cfg, p["norm1"], x)
        a, new_self = attn_apply(
            cfg, p["self_attn"], h, angles=None,
            cache=self_cache if mode == "decode" else None,
            decode_pos=decode_pos, build_cache=build,
            cache_capacity=cache_capacity,
        )
        x = x + a
        h = norm_apply(cfg, p["norm2"], x)
        c, new_cross = cross_attn_apply(
            cfg, p["cross_attn"], h, enc_kv=cross_kv, enc_states=enc_states
        )
        x = x + c
        h = norm_apply(cfg, p["norm3"], x)
        x = x + mlp_apply(cfg, p["mlp"], h)
        new_cache = None
        if mode in ("prefill", "decode"):
            new_cache = {"self": new_self, "cross": new_cross}
        return x, new_cache, aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacked parameter / cache construction
# ---------------------------------------------------------------------------


def stacked_block_init(rng, cfg: ModelConfig, kind: str, n: int) -> dict:
    keys = jax.random.split(rng, n)
    return jax.vmap(lambda k: block_init(k, cfg, kind))(keys)


def stacked_attn_cache(cfg: ModelConfig, n: int, batch: int, cap: int, dtype) -> dict:
    one = attn_mod.init_cache(cfg, batch, cap, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)


def stacked_ssm_cache(cfg: ModelConfig, n: int, batch: int, dtype) -> dict:
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "state": jnp.zeros((n, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def stacked_rec_cache(cfg: ModelConfig, n: int, batch: int, dtype) -> dict:
    return {
        "h": jnp.zeros((n, batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((n, batch, 3, cfg.lru_width), dtype),
    }


# ---------------------------------------------------------------------------
# homogeneous stack application (dense / moe / vlm / ssm, and whisper stacks)
# ---------------------------------------------------------------------------


def apply_stack(
    cfg: ModelConfig,
    kind: str,
    stacked: dict,
    x: jax.Array,
    *,
    angles=None,
    window=None,
    mode="train",
    cache=None,
    decode_pos=None,
    cache_capacity=None,
    enc_states=None,
):
    """Scan one homogeneous stack. Returns (x, stacked_new_cache, aux)."""

    def body(carry, xs):
        h, aux = carry
        if cache is not None:
            p, c = xs
        else:
            p, c = xs, None
        h, new_c, a = block_apply(
            cfg, kind, p, h, angles=angles, window=window, mode=mode,
            cache=c, decode_pos=decode_pos, cache_capacity=cache_capacity,
            enc_states=enc_states,
        )
        if mode != "decode" and cfg.sequence_parallel:
            # keep the residual stream (the per-layer remat save) seq-sharded
            h = hint(h, "batch", "model", None)
        return (h, aux + a), new_c

    bs = cfg.remat_block_size
    use_block_remat = (
        cfg.remat and mode == "train" and cfg.scan_layers and bs > 1
        and cache is None
    )

    if cfg.remat and mode == "train" and not use_block_remat:
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (stacked, cache) if cache is not None else stacked
    if use_block_remat:
        n = jax.tree.leaves(stacked)[0].shape[0]
        assert n % bs == 0, (n, bs)
        blocked = jax.tree.map(
            lambda a: a.reshape((n // bs, bs) + a.shape[1:]), stacked
        )

        def block_body(carry, ps):
            return jax.lax.scan(body, carry, ps)[0], None

        block_body = jax.checkpoint(block_body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(
            block_body, (x, jnp.zeros((), jnp.float32)), blocked
        )
        return x, None, aux
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    else:
        n = jax.tree.leaves(stacked)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        caches_out = []
        for i in range(n):
            xs_i = jax.tree.map(lambda a: a[i], xs)
            (x, aux), c = body((x, aux), xs_i)
            caches_out.append(c)
        new_caches = (
            jax.tree.map(lambda *ls: jnp.stack(ls), *caches_out)
            if caches_out and caches_out[0] is not None
            else None
        )
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# hybrid stack (RecurrentGemma): scan over super-blocks + unrolled remainder
# ---------------------------------------------------------------------------


def hybrid_init(rng, cfg: ModelConfig) -> dict:
    pat = cfg.block_pattern
    nb = cfg.n_superblocks
    rem = cfg.pattern_for(cfg.n_layers)[nb * len(pat) :]
    r = jax.random.split(rng, len(pat) + len(rem) + 1)
    kind_of = {"rec": "rec", "attn": "attn"}
    super_p = {
        f"b{i}_{k}": stacked_block_init(r[i], cfg, kind_of[k], nb)
        for i, k in enumerate(pat)
    }
    rem_p = {
        f"rem{i}_{k}": block_init(r[len(pat) + i], cfg, kind_of[k])
        for i, k in enumerate(rem)
    }
    return {"super": super_p, "rem": rem_p}


def hybrid_cache(cfg: ModelConfig, batch: int, cap: int, dtype) -> dict:
    pat = cfg.block_pattern
    nb = cfg.n_superblocks
    rem = cfg.pattern_for(cfg.n_layers)[nb * len(pat) :]

    def one(kind, n=None):
        if kind == "rec":
            return (
                stacked_rec_cache(cfg, n, batch, dtype)
                if n
                else jax.tree.map(lambda a: a[0], stacked_rec_cache(cfg, 1, batch, dtype))
            )
        return (
            stacked_attn_cache(cfg, n, batch, cap, dtype)
            if n
            else jax.tree.map(lambda a: a[0], stacked_attn_cache(cfg, 1, batch, cap, dtype))
        )

    return {
        "super": {f"b{i}_{k}": one(k, nb) for i, k in enumerate(pat)},
        "rem": {f"rem{i}_{k}": one(k) for i, k in enumerate(rem)},
    }


def apply_hybrid(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    *,
    angles,
    mode,
    cache=None,
    decode_pos=None,
    cache_capacity=None,
):
    pat = cfg.block_pattern
    kind_of = {"rec": "rec", "attn": "attn"}
    aux_total = jnp.zeros((), jnp.float32)

    def superblock(carry, xs):
        h, aux = carry
        new_caches = {}
        for i, k in enumerate(pat):
            key = f"b{i}_{k}"
            p = xs[0][key] if cache is not None else xs[key]
            c = xs[1][key] if cache is not None else None
            h, nc, a = block_apply(
                cfg, kind_of[k], p, h, angles=angles,
                window=cfg.local_window if k == "attn" else None,
                mode=mode, cache=c, decode_pos=decode_pos,
                cache_capacity=cache_capacity,
            )
            new_caches[key] = nc
            aux = aux + a
        if mode == "train":
            new_caches = None
        return (h, aux), new_caches

    body = superblock
    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (params["super"], cache["super"]) if cache is not None else params["super"]
    if cfg.scan_layers:
        (x, aux_total), new_super = jax.lax.scan(body, (x, aux_total), xs)
    else:
        nb = cfg.n_superblocks
        outs = []
        for i in range(nb):
            xs_i = jax.tree.map(lambda a: a[i], xs)
            (x, aux_total), c_i = body((x, aux_total), xs_i)
            outs.append(c_i)
        new_super = (
            jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
            if outs and outs[0] is not None
            else None
        )

    new_rem = {}
    nb = cfg.n_superblocks
    rem = cfg.pattern_for(cfg.n_layers)[nb * len(pat) :]
    for i, k in enumerate(rem):
        key = f"rem{i}_{k}"
        c = cache["rem"][key] if cache is not None else None
        x, nc, a = block_apply(
            cfg, kind_of[k], params["rem"][key], x, angles=angles,
            window=cfg.local_window if k == "attn" else None,
            mode=mode, cache=c, decode_pos=decode_pos,
            cache_capacity=cache_capacity,
        )
        new_rem[key] = nc
        aux_total = aux_total + a

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"super": new_super, "rem": new_rem}
    return x, new_cache, aux_total
