"""Model facade: init / forward / prefill / decode / extract_features.

``build_model(cfg)`` returns a :class:`Model` of pure functions over plain
pytrees — the single entry point used by the launcher, the federated runtime,
the FED3R driver and the tests.

Batch dict contract (see launch/shapes.py for the ShapeDtypeStruct specs):
  * ``tokens``        (B, S) int32 — always present (decode: (B, 1))
  * ``labels``        (B, S) int32 — train mode (next-token targets)
  * ``patch_embeds``  (B, n_patches, d) — vlm only (stub vision frontend)
  * ``audio_frames``  (B, n_frames, d) — audio only (stub conv frontend)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    embed_apply,
    mrope_angles,
    norm_apply,
    norm_init,
    rope_angles,
    sinusoidal_positions,
    unembed_apply,
)
from repro.models.transformer import ForwardOut
from repro.sharding.hints import hint


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, rng: jax.Array) -> dict:
    r = jax.random.split(rng, 8)
    params: Dict[str, Any] = {
        "embed": embed_init_params(cfg, r[0]),
        "final_norm": norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "kernel": 0.02 * jax.random.normal(r[1], (cfg.d_model, cfg.padded_vocab))
        }

    if cfg.arch_type in ("dense", "moe", "vlm"):
        params["layers"] = tfm.stacked_block_init(r[2], cfg, "attn", cfg.n_layers)
    elif cfg.arch_type == "ssm":
        params["layers"] = tfm.stacked_block_init(r[2], cfg, "ssm", cfg.n_layers)
    elif cfg.arch_type == "hybrid":
        params["layers"] = tfm.hybrid_init(r[2], cfg)
    elif cfg.arch_type == "audio":
        params["enc_layers"] = tfm.stacked_block_init(r[2], cfg, "enc", cfg.n_encoder_layers)
        params["enc_norm"] = norm_init(cfg)
        params["dec_layers"] = tfm.stacked_block_init(r[3], cfg, "dec", cfg.n_layers)
        params["dec_pos"] = {
            "embedding": 0.02 * jax.random.normal(r[4], (cfg.n_positions, cfg.d_model))
        }
    else:
        raise ValueError(cfg.arch_type)
    return params


def embed_init_params(cfg: ModelConfig, rng) -> dict:
    return {"embedding": 0.02 * jax.random.normal(rng, (cfg.padded_vocab, cfg.d_model))}


# ---------------------------------------------------------------------------
# position streams
# ---------------------------------------------------------------------------


def vlm_positions_3d(cfg: ModelConfig, seq_idx: jax.Array) -> jax.Array:
    """Map flat sequence indices to Qwen2-VL (t, h, w) M-RoPE positions.

    Image tokens occupy seq indices [0, n_patches) on a g×g grid with t=0;
    text tokens at index i ≥ n_patches get all three streams equal to
    ``g + (i − n_patches)`` (text positions continue after the spatial extent).
    """
    g = int(round(cfg.n_patches ** 0.5))
    is_img = seq_idx < cfg.n_patches
    t = jnp.where(is_img, 0, g + (seq_idx - cfg.n_patches))
    h = jnp.where(is_img, seq_idx // g, g + (seq_idx - cfg.n_patches))
    w = jnp.where(is_img, seq_idx % g, g + (seq_idx - cfg.n_patches))
    return jnp.stack([t, h, w], axis=0)  # (3, S)


def _angles_for(cfg: ModelConfig, seq_idx: jax.Array) -> Optional[jax.Array]:
    """Rotary angles for a run of sequence indices. seq_idx: (S,) int32."""
    if cfg.arch_type == "ssm" or cfg.arch_type == "audio":
        return None
    if cfg.arch_type == "vlm":
        pos3 = vlm_positions_3d(cfg, seq_idx)
        return mrope_angles(pos3, cfg.hd, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(seq_idx, cfg.hd, cfg.rope_theta)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: dict,
    batch: Dict[str, jax.Array],
    *,
    mode: str = "train",
    cache: Optional[Any] = None,
    decode_pos: Optional[jax.Array] = None,
    cache_capacity: Optional[int] = None,
    return_logits: bool = True,
) -> ForwardOut:
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape

    if cfg.arch_type == "audio":
        return _forward_encdec(
            cfg, params, batch, mode=mode, cache=cache, decode_pos=decode_pos,
            cache_capacity=cache_capacity, return_logits=return_logits,
        )

    x = embed_apply(params["embed"], tokens, dtype)
    if cfg.arch_type == "hybrid":
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)  # gemma-style scaling

    if cfg.arch_type == "vlm" and mode != "decode":
        patches = batch["patch_embeds"].astype(dtype)
        x = jnp.concatenate([patches, x], axis=1)
    # Sequence parallelism (Korthikanti et al., opt-in per config): the
    # residual stream is seq-sharded over the TP axis, shrinking per-layer
    # remat saves by the TP degree.  No-op at decode (S=1).
    if mode != "decode" and cfg.sequence_parallel:
        x = hint(x, "batch", "model", None)
    else:
        x = hint(x, "batch", None, None)
    S = x.shape[1]

    if mode == "decode":
        assert decode_pos is not None
        seq_idx = decode_pos[None].astype(jnp.int32)
    else:
        seq_idx = jnp.arange(S, dtype=jnp.int32)
    angles = _angles_for(cfg, seq_idx)

    window = cfg.sliding_window
    capacity = cache_capacity
    if capacity is not None and window is not None:
        capacity = min(capacity, window)

    if cfg.arch_type == "hybrid":
        h, new_cache, aux = tfm.apply_hybrid(
            cfg, params["layers"], x, angles=angles, mode=mode, cache=cache,
            decode_pos=decode_pos,
            cache_capacity=min(capacity, cfg.local_window) if capacity else None,
        )
    else:
        kind = "ssm" if cfg.arch_type == "ssm" else "attn"
        h, new_cache, aux = tfm.apply_stack(
            cfg, kind, params["layers"], x, angles=angles, window=window,
            mode=mode, cache=cache, decode_pos=decode_pos, cache_capacity=capacity,
        )

    h = norm_apply(cfg, params["final_norm"], h)
    logits = None
    if return_logits:
        logits = hint(unembed_apply(cfg, params, h), "batch", None, "model")
    return ForwardOut(h, logits, new_cache, aux)


def _forward_encdec(
    cfg: ModelConfig,
    params: dict,
    batch: Dict[str, jax.Array],
    *,
    mode: str,
    cache,
    decode_pos,
    cache_capacity,
    return_logits: bool,
) -> ForwardOut:
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B = tokens.shape[0]

    enc_states = None
    if mode != "decode":
        frames = batch["audio_frames"].astype(dtype)
        F = frames.shape[1]
        enc_x = frames + sinusoidal_positions(F, cfg.d_model).astype(dtype)
        enc_x, _, _ = tfm.apply_stack(cfg, "enc", params["enc_layers"], enc_x, mode="train")
        enc_states = norm_apply(cfg, params["enc_norm"], enc_x)

    x = embed_apply(params["embed"], tokens, dtype)
    if mode == "decode":
        pos_emb = jnp.take(params["dec_pos"]["embedding"], decode_pos[None], axis=0)
    else:
        S = tokens.shape[1]
        pos_emb = params["dec_pos"]["embedding"][:S]
    x = x + pos_emb.astype(dtype)

    h, new_cache, aux = tfm.apply_stack(
        cfg, "dec", params["dec_layers"], x, mode=mode, cache=cache,
        decode_pos=decode_pos, cache_capacity=cache_capacity,
        enc_states=enc_states,
    )
    h = norm_apply(cfg, params["final_norm"], h)
    logits = None
    if return_logits:
        logits = hint(unembed_apply(cfg, params, h), "batch", None, "model")
    return ForwardOut(h, logits, new_cache, aux)


# ---------------------------------------------------------------------------
# caches (also used by launch/shapes.py under jax.eval_shape — no allocation)
# ---------------------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, capacity: int) -> Any:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.sliding_window is not None:
        capacity = min(capacity, cfg.sliding_window)
    if cfg.arch_type in ("dense", "moe", "vlm"):
        return tfm.stacked_attn_cache(cfg, cfg.n_layers, batch, capacity, dtype)
    if cfg.arch_type == "ssm":
        return tfm.stacked_ssm_cache(cfg, cfg.n_layers, batch, dtype)
    if cfg.arch_type == "hybrid":
        return tfm.hybrid_cache(cfg, batch, min(capacity, cfg.local_window), dtype)
    if cfg.arch_type == "audio":
        self_c = tfm.stacked_attn_cache(cfg, cfg.n_layers, batch, capacity, dtype)
        F = cfg.n_audio_frames
        KV, hd = cfg.n_kv_heads, cfg.hd
        cross = (
            jnp.zeros((cfg.n_layers, batch, F, KV, hd), dtype),
            jnp.zeros((cfg.n_layers, batch, F, KV, hd), dtype),
        )
        return {"self": self_c, "cross": cross}
    raise ValueError(cfg.arch_type)


# ---------------------------------------------------------------------------
# losses & features
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array]) -> jax.Array:
    """Mean next-token cross-entropy (+ MoE aux). fp32 log-softmax."""
    out = forward(cfg, params, batch, mode="train")
    logits = out.logits.astype(jnp.float32)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":  # logits cover [patches|text]; labels cover text
        logits = logits[:, cfg.n_patches :, :]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    return ce + cfg.router_aux_coef * out.aux_loss


def extract_features(
    cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array]
) -> jax.Array:
    """φ(x): pooled final hidden state, (B, d_feat) fp32 — the FED3R feature map."""
    out = forward(cfg, params, batch, mode="train", return_logits=False)
    h = out.hidden.astype(jnp.float32)
    if cfg.arch_type == "vlm":  # pool text positions only
        h = h[:, cfg.n_patches :, :]
    if cfg.feature_pooling == "last":
        return h[:, -1, :]
    return jnp.mean(h, axis=1)


def prefill(
    cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array], cache_capacity: int
) -> Tuple[jax.Array, Any]:
    out = forward(
        cfg, params, batch, mode="prefill", cache_capacity=cache_capacity,
        return_logits=False,  # unembed only the last position (B·V, not B·S·V)
    )
    logits = unembed_apply(cfg, params, out.hidden[:, -1:, :])
    return hint(logits, "batch", None, "model")[:, 0, :], out.cache


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: Any,
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # scalar int32 — absolute position of this token
) -> Tuple[jax.Array, Any]:
    out = forward(
        cfg, params, {"tokens": token}, mode="decode", cache=cache, decode_pos=pos
    )
    return out.logits[:, 0, :], out.cache


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------


class Model:
    """Bound pure-function bundle for one architecture config."""

    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.init = functools.partial(init_params, cfg)
        self.forward = functools.partial(forward, cfg)
        self.loss = functools.partial(lm_loss, cfg)
        self.extract_features = functools.partial(extract_features, cfg)
        self.prefill = functools.partial(prefill, cfg)
        self.decode_step = functools.partial(decode_step, cfg)
        self.make_cache = functools.partial(make_cache, cfg)

    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
