"""Backbone zoo: pure-JAX implementations of the assigned architectures."""
from repro.models.model import (  # noqa: F401
    Model,
    build_model,
)
