"""PartitionSpec rules for the production mesh: params, activations,
caches, statistics — and the distributed-engine specs of the dist layer
(:func:`replicated` / :func:`data_parallel_spec`, consumed by
``repro.federated.dist`` to build the engines' shard_map programs: the
batch-carrying leading axis sharded over the data axes, carried state and
all-reduced statistics replicated).

Tensor-parallel convention (Megatron-style, adapted to GSPMD):
  * attention q/k/v projections shard the (kv-)head axis on "model";
  * MLP shards the hidden (d_ff) axis; down-projection is contracted back
    (GSPMD inserts the reduce-scatter/all-reduce);
  * embeddings and LM head shard the vocab axis;
  * MoE experts shard the expert axis (expert parallelism);
  * Mamba2 / RG-LRU shard their inner width / head axes;
  * batch dims shard over ("pod", "data").

**Divisibility fallback chains.**  ``jax.jit`` input shardings require each
sharded dim to divide the mesh axis.  Several assigned configs violate the
primary choice (qwen2-7b: 28 heads on a 16-way axis; whisper: 20 heads,
vocab 51866; mamba2: vocab 50280; GQA kv=2/4/8 < 16).  Each rule therefore
lists *preference-ordered* candidate specs; the first one whose sharded dims
all divide evenly is used, else the leaf is replicated:

  * projection weights: head dim → d_model (row-parallel: the contraction
    over sharded d makes GSPMD emit one activation all-reduce — correct,
    bounded cost; revisited in the perf pass);
  * embeddings: vocab → d_model;
  * KV caches: kv-head dim → sequence dim (context-parallel attention: the
    softmax/value contractions over the sharded key axis reduce to small
    per-query psums — an efficient decode layout) → replicated.
"""
from __future__ import annotations

import re
from typing import Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# (path regex, preference-ordered trailing-dim spec candidates).
_PARAM_RULES: Sequence[Tuple[str, Sequence[Tuple]]] = (
    # embeddings / unembedding
    (r"embed/embedding$", [("model", None), (None, "model")]),  # (V, d)
    (r"dec_pos/embedding$", [(None, None)]),  # learned positions: replicated
    (r"lm_head/kernel$", [(None, "model"), ("model", None)]),  # (d, V)
    # attention projections
    (r"(attn|self_attn|cross_attn)/wq$", [(None, "model", None), ("model", None, None)]),
    (r"(attn|self_attn|cross_attn)/wk$", [(None, "model", None), ("model", None, None)]),
    (r"(attn|self_attn|cross_attn)/wv$", [(None, "model", None), ("model", None, None)]),
    (r"(attn|self_attn|cross_attn)/wo$", [("model", None, None), (None, None, "model")]),
    (r"(attn|self_attn|cross_attn)/b[qkv]$", [("model", None), (None, None)]),
    # dense MLP (and MoE shared-expert MLP)
    (r"(mlp|shared)/w_gate$", [(None, "model")]),
    (r"(mlp|shared)/w_up$", [(None, "model")]),
    (r"(mlp|shared)/w_down$", [("model", None)]),
    (r"(mlp|shared)/b_up$", [("model",)]),
    (r"(mlp|shared)/b_down$", [(None,)]),
    # MoE routed experts: expert-parallel on the leading E axis
    (r"moe/router$", [(None, None)]),  # (d, E) tiny: replicated
    (r"moe/w_gate$", [("model", None, None), (None, None, "model")]),
    (r"moe/w_up$", [("model", None, None), (None, None, "model")]),
    (r"moe/w_down$", [("model", None, None), (None, "model", None)]),
    # Mamba2
    (r"ssm/in_proj$", [(None, "model"), ("model", None)]),
    (r"ssm/conv/kernel$", [(None, "model")]),
    (r"ssm/conv/bias$", [("model",)]),
    (r"ssm/A_log$", [("model",)]),
    (r"ssm/dt_bias$", [("model",)]),
    (r"ssm/D$", [("model",)]),
    (r"ssm/norm_scale$", [("model",)]),
    (r"ssm/out_proj$", [("model", None), (None, None)]),
    # RG-LRU
    (r"rec/proj_main$", [(None, "model")]),
    (r"rec/proj_gate$", [(None, "model")]),
    (r"rec/conv/kernel$", [(None, "model")]),
    (r"rec/conv/bias$", [("model",)]),
    (r"rec/w_a$", [(None, "model")]),
    (r"rec/w_x$", [(None, "model")]),
    (r"rec/b_a$", [("model",)]),
    (r"rec/b_x$", [("model",)]),
    (r"rec/lambda$", [("model",)]),
    (r"rec/proj_out$", [("model", None), (None, None)]),
    # norms: replicated
    (r"(norm\d?|final_norm|enc_norm)/(scale|bias)$", [(None,)]),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fits(shape, trailing, axis_sizes) -> bool:
    """Every sharded trailing dim must divide the mesh axis size."""
    off = len(shape) - len(trailing)
    for i, ax in enumerate(trailing):
        if ax is None:
            continue
        size = axis_sizes[ax] if isinstance(ax, str) else 1
        if isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= axis_sizes[a]
        if shape[off + i] % size != 0:
            return False
    return True


def _pick(shape, candidates, axis_sizes) -> P:
    for trailing in candidates:
        if len(trailing) > len(shape):
            continue
        if _fits(shape, trailing, axis_sizes):
            n_lead = len(shape) - len(trailing)
            return P(*((None,) * n_lead + tuple(trailing)))
    return P()  # replicate


_FSDP_MIN_DIM = 1024  # don't FSDP-shard tiny dims


def _add_fsdp(shape, spec: P, axis_sizes, fsdp_axis="data") -> P:
    """Shard the largest eligible unsharded *trailing-rule* dim over data.

    FSDP (ZeRO-3 style): parameters additionally sharded over the data axis;
    GSPMD all-gathers each layer's weights inside the scan — required for
    the ≥33B configs whose fp32 params exceed HBM under 16-way TP alone.
    The leading layer-stack dim is never touched (scan axis).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # spec from _pick is full-rank; guard anyway
    entries = list(spec)
    if len(entries) != len(shape):
        return spec
    if isinstance(fsdp_axis, str):
        dsize = axis_sizes.get(fsdp_axis, 1)
    else:
        dsize = 1
        for a in fsdp_axis:
            dsize *= axis_sizes.get(a, 1)
        fsdp_axis = tuple(fsdp_axis)
    best, best_dim = -1, None
    for i in range(len(shape)):
        # skip leading stack dims: only dims addressed by the rule's trailing
        # spec are eligible — approximated as "dims not equal to a small L".
        if entries[i] is not None:
            continue
        if shape[i] >= _FSDP_MIN_DIM and shape[i] % dsize == 0 and shape[i] > best:
            # never shard dim 0 of stacked leaves (ndim>=3 heuristics: dim 0
            # of a >=3D leaf with small size is the layer stack)
            if i == 0 and len(shape) >= 3:
                continue
            best, best_dim = shape[i], i
    if best_dim is None:
        return spec
    entries[best_dim] = fsdp_axis
    return P(*entries)


def param_specs(cfg: ModelConfig, params, axis_sizes=None, *, fsdp: bool = False,
                fsdp_axis="data") -> object:
    """PartitionSpec pytree matching ``params`` (works on abstract trees).

    ``axis_sizes``: {"model": 16, "data": 16, ...}; defaults to 16-way model.
    ``fsdp``: additionally shard big dims over ``fsdp_axis`` (str or tuple —
    pass ("pod", "data") for 512-way multi-pod ZeRO-3; see _add_fsdp).
    """
    axis_sizes = axis_sizes or {"model": 16, "data": 16, "pod": 2}

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        for pattern, candidates in _PARAM_RULES:
            if re.search(pattern, ps):
                spec = _pick(leaf.shape, candidates, axis_sizes)
                # embedding-family tables are excluded from FSDP: gathers on
                # doubly-sharded tables trip an XLA SPMD partitioner bug, and
                # the vocab-sharded tables are small enough per chip anyway.
                if fsdp and not re.search(r"(embedding|lm_head)", ps):
                    spec = _add_fsdp(leaf.shape, spec, axis_sizes, fsdp_axis)
                return spec
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# distributed-engine specs (repro.federated.dist)
# ---------------------------------------------------------------------------


def replicated() -> P:
    """The replicated spec — carried engine state, backbone params, and the
    all-reduced outputs of the dist-layer shard_map programs."""
    return P()


def data_parallel_spec(axes: Sequence[str], axis: int = 0) -> P:
    """Shard dim ``axis`` over the (possibly multiple) data axes.

    The one spec shape every engine's packed arrays use under the dist
    layer: the batch-carrying axis — shards for the statistics engine,
    cohort for rounds/personalization, wave width for streaming — sharded
    over ``data_axes(mesh)`` (a single axis, or ``("pod", "data")`` on the
    multi-pod mesh, which partitions pod-major so the intra-pod psum stage
    reduces neighboring shards first).  Trailing dims are unsharded.
    """
    axes = tuple(axes)
    if not axes:
        raise ValueError("data_parallel_spec needs at least one mesh axis")
    entry = axes if len(axes) > 1 else axes[0]
    return P(*((None,) * axis + (entry,)))


# ---------------------------------------------------------------------------
# batch / cache / statistics specs
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, batch, data_axes: Tuple[str, ...], axis_sizes=None
                ) -> object:
    axis_sizes = axis_sizes or {"model": 16, "data": 16, "pod": 2}
    da = tuple(data_axes)
    da_size = 1
    for a in da:
        da_size *= axis_sizes[a]

    def spec(path, leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % da_size == 0:
            return P(*((da,) + (None,) * (leaf.ndim - 1)))
        return P()  # e.g. long_500k global_batch=1: replicated

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(cfg: ModelConfig, cache, data_axes: Tuple[str, ...], axis_sizes=None
                ) -> object:
    """KV/state cache specs with fallback chains (see module docstring)."""
    axis_sizes = axis_sizes or {"model": 16, "data": 16, "pod": 2}
    da = tuple(data_axes)
    da_size = 1
    for a in da:
        da_size *= axis_sizes[a]

    def batch_ax(b):
        return da if b % da_size == 0 else None

    def spec(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        last = ps.rsplit("/", 1)[-1]
        sizes = dict(axis_sizes)

        if last in ("k", "v", "k_scale", "v_scale") or (
            last in ("0", "1") and "cross" in ps
        ):
            # (..., B, cap, KV, hd|1): kv-heads -> sequence -> replicated
            b, cap, kv = leaf.shape[-4], leaf.shape[-3], leaf.shape[-2]
            ba = batch_ax(b)
            cands = [
                (ba, None, "model", None),
                (ba, "model", None, None),  # context-parallel keys
                (ba, None, None, None),
            ]
            return _pick(leaf.shape, cands, sizes) if ba else _pick(
                leaf.shape, [(None,) + c[1:] for c in cands], sizes
            )
        if last == "pos":
            return P()
        if last == "state":  # (..., B, H, P, N)
            ba = batch_ax(leaf.shape[-4])
            return _pick(leaf.shape, [(ba, "model", None, None),
                                      (ba, None, None, None)], sizes)
        if last == "conv":  # (..., B, w, ch)
            ba = batch_ax(leaf.shape[-3])
            return _pick(leaf.shape, [(ba, None, "model"), (ba, None, None)], sizes)
        if last == "h":  # (..., B, w)
            ba = batch_ax(leaf.shape[-2])
            return _pick(leaf.shape, [(ba, "model"), (ba, None)], sizes)
        if nd >= 2:
            ba = batch_ax(leaf.shape[-2])
            return _pick(leaf.shape, [(None, ba) + (None,) * (nd - 2)], sizes)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def stats_specs(d: int = 0, axis_sizes=None, shard_rows: bool = True):
    """FED3R statistics: A (d,d) and b (d,C) row-sharded over "model"."""
    axis_sizes = axis_sizes or {"model": 16}
    row = "model" if (shard_rows and (d == 0 or d % axis_sizes["model"] == 0)) else None
    from repro.core.fed3r import Fed3RStats

    return Fed3RStats(A=P(row, None), b=P(row, None), n=P())
