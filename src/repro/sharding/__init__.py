from repro.sharding.specs import (  # noqa: F401
    batch_specs,
    cache_specs,
    param_specs,
    stats_specs,
)
