from repro.sharding.specs import (  # noqa: F401
    batch_specs,
    cache_specs,
    data_parallel_spec,
    param_specs,
    replicated,
    stats_specs,
)
