"""Version-compat shims for the ambient-mesh API surface.

The repo targets the modern ambient-mesh workflow (``jax.set_mesh`` +
``jax.sharding.get_abstract_mesh``), but the pinned container ships a jax
where neither symbol is public yet.  This module papers over the gap:

* ``set_mesh(mesh)``   — public API when present; otherwise records the mesh
  in a module-level slot (the repo's own ambient-mesh state).
* ``get_abstract_mesh()`` — public API when present; otherwise checks, in
  order, jax's internal ambient mesh, this module's slot, and the legacy
  ``with mesh:`` thread-resource context.  Returns ``None`` when no mesh is
  ambient, so callers get one uniform "no mesh ⇒ no-op" signal.
* ``axis_sizes(mesh)`` / ``named_sharding(mesh, spec)`` — normalize over
  concrete ``Mesh`` vs ``AbstractMesh`` return types.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
from jax.sharding import PartitionSpec as P

# Ambient mesh recorded by the set_mesh fallback (newest wins, like the
# public global setter).
_AMBIENT: List[object] = []


def set_mesh(mesh: jax.sharding.Mesh) -> None:
    """``jax.set_mesh`` when available, else record as the ambient mesh."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        setter(mesh)
        return
    _AMBIENT[:] = [mesh]


def _nonempty(mesh) -> Optional[object]:
    return mesh if mesh is not None and getattr(mesh, "axis_names", ()) else None


def get_abstract_mesh():
    """The ambient (abstract or concrete) mesh, or ``None`` if unset."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return _nonempty(getter())
    try:  # jax 0.4.x keeps the ambient mesh in an internal module
        from jax._src import mesh as mesh_lib
    except Exception:
        mesh_lib = None
    if mesh_lib is not None:
        try:
            m = _nonempty(mesh_lib.get_abstract_mesh())
            if m is not None:
                return m
        except Exception:
            pass
    if _AMBIENT:
        return _nonempty(_AMBIENT[-1])
    if mesh_lib is not None:  # legacy ``with mesh:`` blocks
        try:
            pm = mesh_lib.thread_resources.env.physical_mesh
            if pm is not None and not pm.empty:
                return pm
        except Exception:
            pass
    return None


def axis_sizes(mesh) -> Dict[str, int]:
    """{axis name: size} for a concrete Mesh or AbstractMesh."""
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return dict(getattr(mesh, "shape", {}))


def sharding_for(mesh, spec: P):
    """What to hand ``with_sharding_constraint`` for this mesh flavor.

    A concrete Mesh needs an explicit NamedSharding on older jax (a bare
    PartitionSpec only resolves once specs-carrying ambient meshes exist);
    an AbstractMesh resolves the spec itself.
    """
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.sharding.NamedSharding(mesh, spec)
    return spec
