"""Logical sharding hints for model intermediates (maxtext-style).

GSPMD propagation alone picks pathological layouts for some of our layers
(observed: involuntary full rematerialization/replication of SSD states and
MoE dispatch buffers).  ``hint(x, *tokens)`` places an explicit
``with_sharding_constraint`` using *logical* dim tokens:

    "batch"  -> sharded over the data axes ("pod","data") when divisible
    "model"  -> sharded over the tensor-parallel axis when divisible
    None     -> unconstrained... replicated along that dim

Hints resolve against the *ambient* abstract mesh (``jax.set_mesh``, via the
version-compat layer in :mod:`repro.sharding.compat`); when no mesh is set
(unit tests, the CPU simulator) they are exact no-ops, so model code stays
mesh-agnostic.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding import compat


def _resolve(shape, tokens, axis_names, axis_sizes):
    data_axes = tuple(a for a in axis_names if a != "model")
    spec = []
    for i, tok in enumerate(tokens):
        if tok is None or i >= len(shape):
            spec.append(None)
            continue
        if tok == "batch":
            # try full data product, then single trailing data axis
            for axes in (data_axes,) + tuple((a,) for a in data_axes[::-1]):
                size = 1
                for a in axes:
                    size *= axis_sizes[a]
                if size > 1 and shape[i] % size == 0:
                    spec.append(axes if len(axes) > 1 else axes[0])
                    break
            else:
                spec.append(None)
        elif tok == "model":
            ms = axis_sizes.get("model", 1)
            spec.append("model" if ms > 1 and shape[i] % ms == 0 else None)
        else:
            raise ValueError(tok)
    # pad to full rank
    spec += [None] * (len(shape) - len(spec))
    return P(*spec)


def data_shards() -> int:
    """Product of the non-"model" (batch-carrying) mesh axis sizes; 1 if none."""
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return 1
    s = 1
    for name, size in compat.axis_sizes(mesh).items():
        if name != "model":
            s *= size
    return s


def mesh_axis_size(name: str) -> int:
    """Size of an ambient-mesh axis (1 when no mesh is set)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return 1
    return compat.axis_sizes(mesh).get(name, 1)


def hint(x: jax.Array, *tokens) -> jax.Array:
    """Constrain ``x``'s sharding by logical dim tokens; no-op without mesh."""
    mesh = compat.get_abstract_mesh()
    if mesh is None:
        return x
    axis_sizes = compat.axis_sizes(mesh)
    spec = _resolve(x.shape, tokens, tuple(mesh.axis_names), axis_sizes)
    if all(entry is None for entry in spec):
        return x  # fully replicated constraint ⇒ exact no-op
    return jax.lax.with_sharding_constraint(x, compat.sharding_for(mesh, spec))
