"""Logical sharding hints for model intermediates (maxtext-style).

GSPMD propagation alone picks pathological layouts for some of our layers
(observed: involuntary full rematerialization/replication of SSD states and
MoE dispatch buffers).  ``hint(x, *tokens)`` places an explicit
``with_sharding_constraint`` using *logical* dim tokens:

    "batch"  -> sharded over the data axes ("pod","data") when divisible
    "model"  -> sharded over the tensor-parallel axis when divisible
    None     -> unconstrained... replicated along that dim

Hints resolve against the *ambient* abstract mesh (``jax.set_mesh``); when no
mesh is set (unit tests, the CPU simulator) they are exact no-ops, so model
code stays mesh-agnostic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _resolve(shape, tokens, axis_names, axis_sizes):
    data_axes = tuple(a for a in axis_names if a != "model")
    spec = []
    for i, tok in enumerate(tokens):
        if tok is None or i >= len(shape):
            spec.append(None)
            continue
        if tok == "batch":
            # try full data product, then single trailing data axis
            for axes in (data_axes,) + tuple((a,) for a in data_axes[::-1]):
                size = 1
                for a in axes:
                    size *= axis_sizes[a]
                if size > 1 and shape[i] % size == 0:
                    spec.append(axes if len(axes) > 1 else axes[0])
                    break
            else:
                spec.append(None)
        elif tok == "model":
            ms = axis_sizes.get("model", 1)
            spec.append("model" if ms > 1 and shape[i] % ms == 0 else None)
        else:
            raise ValueError(tok)
    # pad to full rank
    spec += [None] * (len(shape) - len(spec))
    return P(*spec)


def data_shards() -> int:
    """Product of the non-"model" (batch-carrying) mesh axis sizes; 1 if none."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return 1
    s = 1
    for name, size in zip(mesh.axis_names, mesh.axis_sizes):
        if name != "model":
            s *= size
    return s


def mesh_axis_size(name: str) -> int:
    """Size of an ambient-mesh axis (1 when no mesh is set)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get(name, 1)


def hint(x: jax.Array, *tokens) -> jax.Array:
    """Constrain ``x``'s sharding by logical dim tokens; no-op without mesh."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = _resolve(x.shape, tokens, mesh.axis_names, axis_sizes)
    return jax.lax.with_sharding_constraint(x, spec)
