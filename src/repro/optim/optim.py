"""Optimizers as pure pytree transforms (no external deps).

Used by (a) the federated client local steps (SGD, per App. C: lr=0.1,
wd=4e-5), (b) the server optimizer (SGD with optional momentum — FedAvgM),
and (c) the centralized training driver (AdamW).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptimizerSpec(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, new_state)


# ---------------------------------------------------------------------------
# SGD (+ momentum, + decoupled weight decay)
# ---------------------------------------------------------------------------


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {}
    return {"mu": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(grads, state, params, lr, *, momentum: float = 0.0, weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum:
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        updates = jax.tree.map(lambda m: -lr * m, mu)
        return updates, {"mu": mu}
    return jax.tree.map(lambda g: -lr * g, grads), state


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, state, params, lr, *, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0
):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads)
    tc = t.astype(jnp.float32)
    bc1 = 1 - b1**tc
    bc2 = 1 - b2**tc

    def upd(m_, v_, p):
        step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
        return -lr * (step + weight_decay * p)

    updates = jax.tree.map(upd, m, v, params)
    return updates, {"m": m, "v": v, "t": t}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def make_optimizer(
    name: str,
    *,
    momentum: float = 0.0,
    weight_decay: float = 0.0,
) -> OptimizerSpec:
    if name == "sgd":
        return OptimizerSpec(
            init=functools.partial(sgd_init, momentum=momentum),
            update=functools.partial(
                sgd_update, momentum=momentum, weight_decay=weight_decay
            ),
        )
    if name == "adamw":
        return OptimizerSpec(
            init=adamw_init,
            update=functools.partial(adamw_update, weight_decay=weight_decay),
        )
    raise ValueError(name)
