from repro.optim.optim import (  # noqa: F401
    OptimizerSpec,
    adamw_init,
    adamw_update,
    apply_updates,
    make_optimizer,
    sgd_init,
    sgd_update,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
