"""Pallas kernels: fused rank-n Cholesky-Gram updates G = L Lᵀ + ZᵀZ, B = ZᵀY.

The streaming arrival engine's hot spot (repro.federated.streaming_engine):
every arrival wave refactors the carried Cholesky factor of A + λI through
the Gram reconstruction G = L Lᵀ + ZᵀZ and accumulates the class sums
B = ZᵀY.  Both right-hand contributions are contractions over a "row"
dimension — d rows of Lᵀ for the reconstruction, n sample rows of [Z | Y]
for the rank-n arrival update — so the whole update is ONE blocked GEMM
whose k-sweep walks the Lᵀ rows first and the sample rows second, into a
single fp32 accumulator tile resident in VMEM.  No (d+n, d+C) stacked
operand is ever materialized in HBM (contrast the XLA reference, which
concatenates).

Grid (d/bm, (d+C)/bn, kL + kZ): phase one (k < kL) contracts
Lᵀ·[Lᵀ | 0], phase two contracts Zᵀ·[Z | Y]; each phase has its own block
size (BKL for the d-row factor sweep, BKZ for the sample sweep) and
clamped index maps keep the off-phase operand block loads in range.
MXU-shaped tiles with fp32 accumulation, as in kernels/fed3r_stats.py.

The BATCHED variant (:func:`batched_chol_gram_pallas`) is the
personalization engine's hot spot (repro.federated.personalization): one
grid-over-heads pallas_call computes K per-tenant Gram updates
G_k = L Lᵀ + Z_kᵀZ_k, B_k = Z_kᵀY_k against ONE shared global factor L.
The head index is the leading (outermost) grid axis, so the k-sweep of
each head runs to completion in its private VMEM accumulator before the
grid advances to the next head; the shared Lᵀ blocks are re-walked per
head (they index-map independently of the head axis).  Per-head scaling
α_k Z_kᵀZ_k is folded in by pre-scaling Z_k ← √α_k·Z_k outside the kernel
(both Gram contributions are bilinear in Z), keeping the kernel body
scale-free.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 128  # rows of the output tile (d dim)
BN = 128  # cols of the output tile (d+C dim)
BKL = 128  # Lᵀ rows per accumulation step (factor sweep, ≤ d typically)
BKZ = 512  # samples per accumulation step (arrival sweep)


def _chol_gram_kernel(
    lt_ref, ltw_ref, z_ref, zw_ref, out_ref, acc_ref, *, n_k_l: int, n_k: int
):
    """One (i, j) output tile; grid axis 2 sweeps Lᵀ rows, then sample rows.

    lt_ref:  (BKL, BM) block of Lᵀ          (factor rows × features)
    ltw_ref: (BKL, BN) block of [Lᵀ | 0]    (factor rows × features+classes)
    z_ref:   (BKZ, BM) block of Z           (samples × features)
    zw_ref:  (BKZ, BN) block of [Z | Y]     (samples × features+classes)
    out_ref: (BM, BN) fp32 output tile
    acc_ref: (BM, BN) fp32 VMEM scratch accumulator
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < n_k_l)
    def _factor_phase():
        acc_ref[...] += jax.lax.dot_general(
            lt_ref[...], ltw_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k >= n_k_l)
    def _arrival_phase():
        acc_ref[...] += jax.lax.dot_general(
            z_ref[...], zw_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def chol_gram_pallas(
    L: jax.Array, Z: jax.Array, Y: jax.Array, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Compute (G, B) = (L Lᵀ + ZᵀZ, ZᵀY).  L: (d, d); Z: (n, d); Y: (n, C).

    fp32 outputs.  Shapes are padded up to tile multiples — zero rows/cols
    contribute nothing to either Gram, so padding is exact.
    """
    d = L.shape[0]
    C = Y.shape[1]
    if Z.shape[0] == 0:
        # an empty arrival batch still needs one (all-zero, hence exact)
        # sample block so the z-phase BlockSpecs have rows to load
        Z = jnp.zeros((1, d), Z.dtype)
        Y = jnp.zeros((1, C), Y.dtype)
    Lt = L.T.astype(jnp.float32)  # contract over factor ROWS, like samples
    LtW = jnp.concatenate(
        [Lt, jnp.zeros((d, C), jnp.float32)], axis=1
    )  # (d, d+C): the factor sweep adds nothing to the B columns
    ZW = jnp.concatenate([Z, Y.astype(Z.dtype)], axis=1)  # (n, d+C)

    def pad_to(a, m0, m1):
        p0 = (-a.shape[0]) % m0
        p1 = (-a.shape[1]) % m1
        return jnp.pad(a, ((0, p0), (0, p1))) if (p0 or p1) else a

    Ltp = pad_to(Lt, BKL, BM)
    LtWp = pad_to(LtW, BKL, BN)
    Zp = pad_to(Z, BKZ, BM)
    ZWp = pad_to(ZW, BKZ, BN)
    dp = Ltp.shape[1]
    ep = LtWp.shape[1]
    n_k_l = Ltp.shape[0] // BKL
    n_k_z = Zp.shape[0] // BKZ
    n_k = n_k_l + n_k_z

    def clamp_l(k):
        return jnp.minimum(k, n_k_l - 1)

    def clamp_z(k):
        return jnp.clip(k - n_k_l, 0, n_k_z - 1)

    out = pl.pallas_call(
        functools.partial(_chol_gram_kernel, n_k_l=n_k_l, n_k=n_k),
        grid=(dp // BM, ep // BN, n_k),
        in_specs=[
            pl.BlockSpec((BKL, BM), lambda i, j, k: (clamp_l(k), i)),
            pl.BlockSpec((BKL, BN), lambda i, j, k: (clamp_l(k), j)),
            pl.BlockSpec((BKZ, BM), lambda i, j, k: (clamp_z(k), i)),
            pl.BlockSpec((BKZ, BN), lambda i, j, k: (clamp_z(k), j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, ep), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(Ltp, LtWp, Zp, ZWp)

    M = out[:d, :]
    return M[:, :d], M[:, d : d + C]


def _batched_chol_gram_kernel(
    lt_ref, ltw_ref, z_ref, zw_ref, out_ref, acc_ref, *, n_k_l: int, n_k: int
):
    """One (h, i, j) output tile; grid axis 3 sweeps Lᵀ rows, then head h's
    sample rows.  Identical algebra to :func:`_chol_gram_kernel`, plus the
    leading head axis: the factor operands are shared (their index maps drop
    ``h``) while the sample operands and the output carry a size-1 head
    block.

    lt_ref:  (BKL, BM)    block of Lᵀ            (factor rows × features)
    ltw_ref: (BKL, BN)    block of [Lᵀ | 0]      (factor rows × features+classes)
    z_ref:   (1, BKZ, BM) block of Z_h           (head × samples × features)
    zw_ref:  (1, BKZ, BN) block of [Z_h | Y_h]   (head × samples × feats+classes)
    out_ref: (1, BM, BN)  fp32 output tile of head h
    acc_ref: (BM, BN)     fp32 VMEM scratch accumulator
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < n_k_l)
    def _factor_phase():
        acc_ref[...] += jax.lax.dot_general(
            lt_ref[...], ltw_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k >= n_k_l)
    def _arrival_phase():
        acc_ref[...] += jax.lax.dot_general(
            z_ref[0], zw_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_chol_gram_pallas(
    L: jax.Array, Z: jax.Array, Y: jax.Array, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Batched (G_k, B_k) = (L Lᵀ + Z_kᵀZ_k, Z_kᵀY_k) over K heads.

    L: (d, d) shared global factor; Z: (K, n, d); Y: (K, n, C).  Returns
    G: (K, d, d), B: (K, d, C), both fp32.  Shapes are padded up to tile
    multiples — zero rows/cols contribute nothing to either Gram, so
    padding is exact.  Per-head α_k scaling is the caller's pre-scaling
    Z_k ← √α_k·Z_k, Y_k ← √α_k·Y_k.
    """
    d = L.shape[0]
    K, n, _ = Z.shape
    C = Y.shape[2]
    if n == 0:
        # an empty cohort batch still needs one (all-zero, hence exact)
        # sample block so the z-phase BlockSpecs have rows to load
        Z = jnp.zeros((K, 1, d), Z.dtype)
        Y = jnp.zeros((K, 1, C), Y.dtype)
    Lt = L.T.astype(jnp.float32)
    LtW = jnp.concatenate([Lt, jnp.zeros((d, C), jnp.float32)], axis=1)
    ZW = jnp.concatenate([Z, Y.astype(Z.dtype)], axis=2)  # (K, n, d+C)

    def pad2(a, m0, m1):
        p0 = (-a.shape[0]) % m0
        p1 = (-a.shape[1]) % m1
        return jnp.pad(a, ((0, p0), (0, p1))) if (p0 or p1) else a

    def pad3(a, m1, m2):
        p1 = (-a.shape[1]) % m1
        p2 = (-a.shape[2]) % m2
        return jnp.pad(a, ((0, 0), (0, p1), (0, p2))) if (p1 or p2) else a

    Ltp = pad2(Lt, BKL, BM)
    LtWp = pad2(LtW, BKL, BN)
    Zp = pad3(Z, BKZ, BM)
    ZWp = pad3(ZW, BKZ, BN)
    dp = Ltp.shape[1]
    ep = LtWp.shape[1]
    n_k_l = Ltp.shape[0] // BKL
    n_k_z = Zp.shape[1] // BKZ
    n_k = n_k_l + n_k_z

    def clamp_l(k):
        return jnp.minimum(k, n_k_l - 1)

    def clamp_z(k):
        return jnp.clip(k - n_k_l, 0, n_k_z - 1)

    out = pl.pallas_call(
        functools.partial(_batched_chol_gram_kernel, n_k_l=n_k_l, n_k=n_k),
        grid=(K, dp // BM, ep // BN, n_k),
        in_specs=[
            pl.BlockSpec((BKL, BM), lambda h, i, j, k: (clamp_l(k), i)),
            pl.BlockSpec((BKL, BN), lambda h, i, j, k: (clamp_l(k), j)),
            pl.BlockSpec((1, BKZ, BM), lambda h, i, j, k: (h, clamp_z(k), i)),
            pl.BlockSpec((1, BKZ, BN), lambda h, i, j, k: (h, clamp_z(k), j)),
        ],
        out_specs=pl.BlockSpec((1, BM, BN), lambda h, i, j, k: (h, i, j)),
        out_shape=jax.ShapeDtypeStruct((K, dp, ep), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(Ltp, LtWp, Zp, ZWp)

    M = out[:, :d, :]
    return M[:, :, :d], M[:, :, d : d + C]
