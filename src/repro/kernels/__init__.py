"""Pallas TPU kernels for the framework's compute hot spots.

Four kernels, each with a pure-jnp oracle in ref.py and a jit'd public
wrapper in ops.py:

  * fed3r_stats     — the paper's client-side hot spot: fused A += ZᵀZ,
                      b += ZᵀY accumulation (one blocked GEMM over [Z|Y]).
  * chol_gram       — the streaming engine's rank-n Cholesky-Gram update
                      G = L Lᵀ + ZᵀZ, B = ZᵀY (one two-phase blocked GEMM,
                      no stacked HBM operand).
  * batched_chol_gram — the personalization engine's grid-over-heads
                      variant: K per-tenant updates G_k = L Lᵀ + Z_kᵀZ_k,
                      B_k = Z_kᵀY_k against one shared factor L, in one
                      pallas_call (head index = outermost grid axis).
  * rff             — fused random-features map √(2/D)·cos(ZΩ + β).
  * flash_attention — online-softmax causal GQA attention (prefill path),
                      with sliding-window masking.
  * quantize_tiles / dequant_accumulate — the compressed statistics uplink
                      (repro.federated.compress): per-tile absmax int8
                      quantize+pack on the client, fused dequantize-
                      accumulate into the fp32 A accumulator on the server
                      (no dense dequantized intermediate in HBM).

All kernels use explicit BlockSpec VMEM tiling with 128-aligned MXU tile
shapes; on this CPU container they are validated in interpret mode
(pl.pallas_call(..., interpret=True) executes the kernel body on CPU).
"""
from repro.kernels.ops import (  # noqa: F401
    batched_chol_gram,
    chol_gram,
    dequant_accumulate,
    fed3r_stats,
    flash_attention,
    quantize_tiles,
    rff_transform,
)
