"""Pallas kernel: fused FED3R statistics A = ZᵀZ, b = ZᵀY.

The paper's client-side hot spot (App. E charges ½·n·d(d+1) + n·d·C FLOPs
for it).  Key insight for the fused form: stacking the one-hot targets next
to the features, W = [Z | Y] ∈ R^{n×(d+C)}, turns both statistics into ONE
blocked GEMM  M = Zᵀ W, with A = M[:, :d] and b = M[:, d:].

TPU adaptation (vs. the paper's cuBLAS call on A100):
  * grid (d/bm, (d+C)/bn, n/bk): each (i, j) owns one fp32 accumulator tile
    resident in VMEM scratch across the k-sweep — A is up to 12288² fp32
    (576 MB), so tiles must stream; HBM sees each Z tile once per j-pass.
  * MXU-shaped tiles (128×512×128); bf16 inputs with fp32 accumulation
    (matching the MXU's native bf16×bf16→fp32 mode) — ridge conditioning
    needs the fp32 accumulator, not fp32 inputs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 128  # rows of the output tile (d dim)
BN = 128  # cols of the output tile (d+C dim)
BK = 512  # samples per accumulation step


def _stats_kernel(zt_ref, w_ref, out_ref, acc_ref, *, n_k_steps: int):
    """One (i, j) output tile; grid axis 2 sweeps the n (sample) dim.

    zt_ref: (BK, BM) block of Z        (samples × features)
    w_ref:  (BK, BN) block of W=[Z|Y]  (samples × features+classes)
    out_ref: (BM, BN) fp32 output tile
    acc_ref: (BM, BN) fp32 VMEM scratch accumulator
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = zt_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        z, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k_steps - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fed3r_stats_pallas(
    Z: jax.Array, Y: jax.Array, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Compute (A, b) = (ZᵀZ, ZᵀY). Z: (n, d); Y: (n, C). fp32 outputs.

    Shapes are padded up to tile multiples (zero rows/cols are exact:
    they contribute nothing to either statistic).
    """
    n, d = Z.shape
    C = Y.shape[1]
    W = jnp.concatenate([Z, Y.astype(Z.dtype)], axis=1)  # (n, d+C)

    def pad_to(a, m0, m1):
        p0 = (-a.shape[0]) % m0
        p1 = (-a.shape[1]) % m1
        return jnp.pad(a, ((0, p0), (0, p1))) if (p0 or p1) else a

    Zp = pad_to(Z, BK, BM)
    Wp = pad_to(W, BK, BN)
    np_, dp = Zp.shape
    ep = Wp.shape[1]
    n_k = np_ // BK

    out = pl.pallas_call(
        functools.partial(_stats_kernel, n_k_steps=n_k),
        grid=(dp // BM, ep // BN, n_k),
        in_specs=[
            pl.BlockSpec((BK, BM), lambda i, j, k: (k, i)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, ep), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(Zp, Wp)

    M = out[:d, :]
    return M[:, :d], M[:, d : d + C]
