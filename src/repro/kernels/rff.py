"""Pallas kernel: fused random-features map ψ(Z) = √(2/D)·cos(ZΩ + β).

FED3R-RF maps features through D ∈ {5k, 10k} random features before the
statistics pass.  Unfused, the (n × D) pre-activation ZΩ round-trips HBM
between the GEMM and the cos — at D=10k that is 40 MB per 1k samples.  The
kernel keeps the GEMM accumulator tile in VMEM and applies bias + cos + scale
in-register before the single writeback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 128  # sample rows per tile
BN = 128  # feature cols per tile
BK = 512  # d contraction step


def _rff_kernel(z_ref, om_ref, beta_ref, out_ref, acc_ref, *, n_k_steps: int, d_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        z_ref[...], om_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k_steps - 1)
    def _done():
        coef = jnp.sqrt(2.0 / d_total)
        out_ref[...] = coef * jnp.cos(acc_ref[...] + beta_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def rff_pallas(
    Z: jax.Array, omega: jax.Array, beta: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """ψ(Z): (n, d) -> (n, D) fp32."""
    n, d = Z.shape
    D = omega.shape[1]

    pad_n = (-n) % BM
    pad_d = (-d) % BK
    pad_D = (-D) % BN
    Zp = jnp.pad(Z, ((0, pad_n), (0, pad_d)))
    Op = jnp.pad(omega, ((0, pad_d), (0, pad_D)))
    Bp = jnp.pad(beta, ((0, pad_D),))[None, :]  # (1, Dp) for block tiling

    n_k = Zp.shape[1] // BK
    out = pl.pallas_call(
        functools.partial(_rff_kernel, n_k_steps=n_k, d_total=D),
        grid=(Zp.shape[0] // BM, Op.shape[1] // BN, n_k),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, BN), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Zp.shape[0], Op.shape[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(Zp, Op, Bp)
    return out[:n, :D]
