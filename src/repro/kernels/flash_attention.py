"""Pallas kernel: online-softmax causal GQA flash attention (prefill path).

Standard two-pass-free flash attention: for each query tile, sweep key tiles
keeping the running max m, normalizer l, and output accumulator in VMEM
scratch; rescale on every new tile.  The (S × S) score matrix never exists
in HBM — the XLA fallback path needs O(B·H·chunk·S) for it.

GQA: query head h reads kv head h // (H/KV); the wrapper folds (B, H) into
the grid's first axis and maps kv blocks through the group index.
Sliding-window masking shares the position rule used across the framework:
keys with  q_pos − window < k_pos ≤ q_pos.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128  # query rows per tile
BK = 128  # key cols per tile
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int], n_k_steps: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip key tiles strictly in the causal future of the whole query tile
    run = jnp.logical_or(not causal, ki * BK <= qi * BQ + BQ - 1)
    if window is not None:
        # ... and tiles entirely before every query's window start
        run = jnp.logical_and(run, (ki + 1) * BK - 1 > qi * BQ - window)

    @pl.when(run)
    def _update():
        q = q_ref[0]  # (BQ, hd)
        k = k_ref[0]  # (BK, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)

        q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        k_pos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        valid = jnp.ones((BQ, BK), bool)
        if causal:
            valid &= k_pos <= q_pos
        if window is not None:
            valid &= k_pos > q_pos - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)  # (BQ,)
        p = jnp.exp(s - m_new[:, None])  # (BQ, BK) fp32
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k_steps - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % BQ == 0 and S % BK == 0, f"seq {S} must divide tiles ({BQ},{BK})"

    # fold (B, H) into one grid axis; layout (BH, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * KV + h // G, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
            n_k_steps=S // BK,
        ),
        grid=(B * H, S // BQ, S // BK),
        in_specs=[
            pl.BlockSpec((1, BQ, hd), q_map),
            pl.BlockSpec((1, BK, hd), kv_map),
            pl.BlockSpec((1, BK, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ,), jnp.float32),
            pltpu.VMEM((BQ, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
