"""Pallas kernels: tile-wise int8 (de)quantization of the statistics uplink.

The compressed-uplink layer (:mod:`repro.federated.compress`) ships every
(A_k, b_k) statistics upload as symmetric per-tile absmax int8 instead of
dense fp32.  Two kernels cover the hot path on both ends of the wire:

* :func:`quantize_tiles_pallas` — the CLIENT side: one grid pass over
  (tile × tile) blocks; each block computes its own absmax scale
  s = max|x| / 127 in VMEM and writes the packed int8 payload plus the
  (M/tile, N/tile) fp32 scale grid.  Per-TILE scales (not per-tensor) keep
  the quantization error local: one hot diagonal block of A_k does not
  wash out the resolution of every other block.
* :func:`dequant_acc_pallas` — the AGGREGATOR side: the fused
  dequantize-accumulate acc ← acc + q·s.  Each grid step loads the fp32
  accumulator tile, the int8 payload tile, and its scalar scale, and
  writes the updated accumulator directly — the dense fp32 dequantized
  intermediate is never materialized in HBM (contrast the XLA reference,
  which expands q·s to a full (d, d) array before the add).  This is the
  merge-side primitive of every compressed engine fold: the server's A
  accumulator advances one compressed client payload at a time.

Rounding is round-half-to-even (``jnp.round``), matching the jnp oracles
in :mod:`repro.kernels.ref` BITWISE — kernel-vs-oracle parity tests compare
the int8 payloads exactly, not approximately.  All-zero tiles take scale 1
so q = 0 and dequantization is exact.  Shapes pad up to tile multiples
(zero padding quantizes to zero exactly); fp8 wire formats share the same
tiling algebra through the pure-jnp path in ``repro.federated.compress``
(the MXU has no fp8 VPU story worth a separate kernel body — the payload
byte count is identical to int8).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128  # absmax granularity: one fp32 scale per (TILE, TILE) block
INT8_QMAX = 127.0  # symmetric int8 range (−127 … 127; −128 unused)


def _quantize_kernel(x_ref, q_ref, s_ref):
    """One (i, j) tile: absmax scale + packed int8 payload.

    x_ref: (T, T) fp32 input tile
    q_ref: (T, T) int8 quantized output tile
    s_ref: (1, 1) fp32 per-tile scale
    """
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0.0, absmax / INT8_QMAX, 1.0)
    s_ref[...] = jnp.reshape(scale, (1, 1))
    q = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX)
    q_ref[...] = q.astype(jnp.int8)


def _dequant_acc_kernel(acc_ref, q_ref, s_ref, out_ref):
    """One (i, j) tile of the fused accumulate out = acc + q·s.

    acc_ref: (T, T) fp32 accumulator tile
    q_ref:   (T, T) int8 payload tile
    s_ref:   (1, 1) fp32 per-tile scale
    out_ref: (T, T) fp32 updated accumulator tile
    """
    out_ref[...] = acc_ref[...] + q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    return jnp.pad(a, ((0, p0), (0, p1))) if (p0 or p1) else a


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def quantize_tiles_pallas(
    x: jax.Array, *, tile: int = TILE, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Per-tile absmax int8 quantization of x (M, N).

    Returns ``(q, scales)``: q (M, N) int8 and scales
    (⌈M/tile⌉, ⌈N/tile⌉) fp32 — together the wire payload (1 byte/element
    + one fp32 per tile).  Zero padding up to tile multiples quantizes to
    zero exactly and never moves a tile's absmax.
    """
    M, N = x.shape
    xp = _pad_to(x.astype(jnp.float32), tile, tile)
    Mt, Nt = xp.shape[0] // tile, xp.shape[1] // tile
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(Mt, Nt),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, jnp.int8),
            jax.ShapeDtypeStruct((Mt, Nt), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:M, :N], s


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def dequant_acc_pallas(
    acc: jax.Array,
    q: jax.Array,
    scales: jax.Array,
    *,
    tile: int = TILE,
    interpret: bool = False,
) -> jax.Array:
    """Fused dequantize-accumulate acc + q·s (M, N) fp32.

    The aggregator-side merge primitive: the int8 payload lands directly
    in the fp32 accumulator, one tile at a time — no dense dequantized
    intermediate in HBM.  ``scales`` is the (⌈M/tile⌉, ⌈N/tile⌉) grid from
    :func:`quantize_tiles_pallas`.
    """
    M, N = acc.shape
    accp = _pad_to(acc.astype(jnp.float32), tile, tile)
    qp = _pad_to(q, tile, tile)
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(accp.shape[0] // tile, accp.shape[1] // tile),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(accp.shape, jnp.float32),
        interpret=interpret,
    )(accp, qp, scales)
    return out[:M, :N]
