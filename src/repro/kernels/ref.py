"""Pure-jnp oracles for every kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def fed3r_stats_ref(Z: jax.Array, Y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """A = ZᵀZ, b = ZᵀY in fp32. Z: (n, d); Y: (n, C) one-hot/targets."""
    Zf = Z.astype(jnp.float32)
    return Zf.T @ Zf, Zf.T @ Y.astype(jnp.float32)


def chol_gram_ref(
    L: jax.Array, Z: jax.Array, Y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """G = L Lᵀ + ZᵀZ, B = ZᵀY in fp32. L: (d, d); Z: (n, d); Y: (n, C)."""
    Lf = L.astype(jnp.float32)
    Zf = Z.astype(jnp.float32)
    return Lf @ Lf.T + Zf.T @ Zf, Zf.T @ Y.astype(jnp.float32)


def batched_chol_gram_ref(
    L: jax.Array, Z: jax.Array, Y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """G_k = L Lᵀ + Z_kᵀZ_k, B_k = Z_kᵀY_k over K heads sharing one L.

    L: (d, d); Z: (K, n, d); Y: (K, n, C).  Returns ((K, d, d), (K, d, C)).
    """
    return jax.vmap(chol_gram_ref, in_axes=(None, 0, 0))(L, Z, Y)


def quantize_tiles_ref(
    x: jax.Array, tile: int = 128, qmax: float = 127.0
) -> Tuple[jax.Array, jax.Array]:
    """Per-tile absmax symmetric int8 quantization: (q, scales).

    x: (M, N) → q (M, N) int8, scales (⌈M/tile⌉, ⌈N/tile⌉) fp32 with
    s = max|tile| / qmax (1.0 for all-zero tiles so q = 0 exactly).
    Round-half-to-even, matching the Pallas kernel bitwise.
    """
    M, N = x.shape
    xf = x.astype(jnp.float32)
    p0, p1 = (-M) % tile, (-N) % tile
    xp = jnp.pad(xf, ((0, p0), (0, p1))) if (p0 or p1) else xf
    Mt, Nt = xp.shape[0] // tile, xp.shape[1] // tile
    blocks = xp.reshape(Mt, tile, Nt, tile)
    absmax = jnp.max(jnp.abs(blocks), axis=(1, 3))
    scales = jnp.where(absmax > 0.0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(blocks / scales[:, None, :, None]), -qmax, qmax)
    q = q.astype(jnp.int8).reshape(xp.shape)[:M, :N]
    return q, scales


def dequant_acc_ref(
    acc: jax.Array, q: jax.Array, scales: jax.Array, tile: int = 128
) -> jax.Array:
    """acc + dequantize(q, scales): the unfused oracle of the fused kernel.

    Expands the per-tile scales to a dense (M, N) fp32 array — exactly the
    HBM intermediate the Pallas kernel avoids.
    """
    M, N = acc.shape
    s = jnp.repeat(jnp.repeat(scales, tile, axis=0), tile, axis=1)[:M, :N]
    return acc.astype(jnp.float32) + q.astype(jnp.float32) * s


def rff_ref(Z: jax.Array, omega: jax.Array, beta: jax.Array) -> jax.Array:
    """√(2/D)·cos(ZΩ + β) in fp32. Z: (n, d); Ω: (d, D); β: (D,)."""
    D = omega.shape[1]
    proj = Z.astype(jnp.float32) @ omega.astype(jnp.float32) + beta
    return jnp.sqrt(2.0 / D) * jnp.cos(proj)


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    """Masked softmax attention oracle (fp32 softmax), GQA-aware."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * (hd ** -0.5)
    scores = scores.astype(jnp.float32)
    pos = jnp.arange(S)
    valid = jnp.ones((S, S), bool)
    if causal:
        valid &= pos[None, :] <= pos[:, None]
    if window is not None:
        valid &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, S, H, hd)
