"""Public jit'd wrappers for the Pallas kernels.

Each wrapper dispatches: TPU → compiled Pallas kernel; anything else →
interpret mode (the kernel body executed on CPU — used for validation in
this container) — the pure-jnp oracles live in ref.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.chol_update import batched_chol_gram_pallas, chol_gram_pallas
from repro.kernels.fed3r_stats import fed3r_stats_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quant import dequant_acc_pallas, quantize_tiles_pallas
from repro.kernels.rff import rff_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fed3r_stats(Z: jax.Array, Y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Fused FED3R statistics (A, b) = (ZᵀZ, ZᵀY)."""
    return fed3r_stats_pallas(Z, Y, interpret=_interpret())


def chol_gram(
    L: jax.Array, Z: jax.Array, Y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Fused rank-n Cholesky-Gram update (G, B) = (L Lᵀ + ZᵀZ, ZᵀY)."""
    return chol_gram_pallas(L, Z, Y, interpret=_interpret())


def batched_chol_gram(
    L: jax.Array, Z: jax.Array, Y: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Grid-over-heads Gram updates (G_k, B_k) = (L Lᵀ + Z_kᵀZ_k, Z_kᵀY_k)."""
    return batched_chol_gram_pallas(L, Z, Y, interpret=_interpret())


def quantize_tiles(x: jax.Array, *, tile: int = 128) -> Tuple[jax.Array, jax.Array]:
    """Per-tile absmax int8 quantization (q, scales) of the wire payload."""
    return quantize_tiles_pallas(x, tile=tile, interpret=_interpret())


def dequant_accumulate(
    acc: jax.Array, q: jax.Array, scales: jax.Array, *, tile: int = 128
) -> jax.Array:
    """Fused dequantize-accumulate acc + q·s (no dense HBM intermediate)."""
    return dequant_acc_pallas(acc, q, scales, tile=tile, interpret=_interpret())


def rff_transform(Z: jax.Array, omega: jax.Array, beta: jax.Array) -> jax.Array:
    """Fused random-features map √(2/D)·cos(ZΩ + β)."""
    return rff_pallas(Z, omega, beta, interpret=_interpret())


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """Online-softmax GQA attention (prefill)."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, interpret=_interpret()
    )
