"""Dependency-free pytree checkpointing (npz + key-path manifest).

Trees of nested dicts / lists / tuples with array (or scalar) leaves are
flattened to ``/``-joined key paths and stored in a single compressed npz.
NamedTuples are stored as dicts tagged with their field order, restored as
plain dicts (callers rewrap if needed).  Round-trips params, optimizer
states, FED3R statistics and server state.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

_LIST_TAG = "__list__"
_TUPLE_TAG = "__tuple__"


def _flatten(tree: Any, prefix: str, out: Dict[str, np.ndarray], meta: Dict[str, str]):
    if isinstance(tree, dict):
        meta[prefix or "."] = "dict"
        for k in sorted(tree):
            _flatten(tree[k], f"{prefix}/{k}" if prefix else str(k), out, meta)
    elif isinstance(tree, (list, tuple)):
        is_nt = hasattr(tree, "_fields")
        meta[prefix or "."] = (
            "dict" if is_nt else (_LIST_TAG if isinstance(tree, list) else _TUPLE_TAG)
        )
        if is_nt:
            for k, v in zip(tree._fields, tree):
                _flatten(v, f"{prefix}/{k}" if prefix else k, out, meta)
        else:
            for i, v in enumerate(tree):
                _flatten(v, f"{prefix}/{i}" if prefix else str(i), out, meta)
    elif tree is None:
        meta[prefix or "."] = "none"
    else:
        out[prefix] = np.asarray(tree)


def _unflatten(store: Dict[str, np.ndarray], meta: Dict[str, str]) -> Any:
    root: Dict[str, Any] = {}
    for path, arr in store.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def fix(node: Any, prefix: str) -> Any:
        kind = meta.get(prefix or ".", None)
        if kind == "none":
            return None
        if isinstance(node, dict):
            fixed = {
                k: fix(v, f"{prefix}/{k}" if prefix else k) for k, v in node.items()
            }
            # re-insert explicit Nones recorded in meta
            for mpath, mkind in meta.items():
                if mkind == "none" and mpath.startswith(prefix) and mpath != prefix:
                    rel = mpath[len(prefix) + 1 :] if prefix else mpath
                    if "/" not in rel and rel not in fixed:
                        fixed[rel] = None
            if kind in (_LIST_TAG, _TUPLE_TAG):
                seq = [fixed[str(i)] for i in range(len(fixed))]
                return seq if kind == _LIST_TAG else tuple(seq)
            return fixed
        return node

    return fix(root, "")


def save_pytree(path: str, tree: Any) -> None:
    out: Dict[str, np.ndarray] = {}
    meta: Dict[str, str] = {}
    _flatten(jax.tree.map(np.asarray, tree), "", out, meta)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    np.savez_compressed(tmp, __meta__=json.dumps(meta), **out)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_pytree(path: str) -> Any:
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        store = {k: z[k] for k in z.files if k != "__meta__"}
    return _unflatten(store, meta)


def latest_checkpoint(directory: str, pattern: str = r"ckpt_(\d+)\.npz") -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    best: Optional[str] = None
    best_step = -1
    for f in os.listdir(directory):
        m = re.fullmatch(pattern, f)
        if m and int(m.group(1)) > best_step:
            best_step = int(m.group(1))
            best = os.path.join(directory, f)
    return best
