from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_checkpoint,
    load_pytree,
    save_pytree,
)
