"""FED3R core — the paper's contribution as composable JAX modules."""
from repro.core import calibration, fed3r, ncm, probe, random_features  # noqa: F401
from repro.core.fed3r import (  # noqa: F401
    Fed3RFactored,
    Fed3ROnline,
    Fed3RStats,
    aggregate_mesh,
    client_stats,
    init_stats,
    merge,
    solve,
)
from repro.core.random_features import RFFParams, rff_init, rff_map  # noqa: F401
