"""FedNCM baseline (Legate et al. 2023a) — Nearest Class Mean classifier.

The paper's Table 1/6 ablation: like FED3R, FedNCM aggregates exactly
(per-class feature sums + counts are associative), but the classifier is the
matrix of normalized class centroids instead of the ridge solution.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class NCMStats(NamedTuple):
    sums: jax.Array  # (C, d) per-class feature sums
    counts: jax.Array  # (C,) per-class sample counts


def init_stats(d: int, n_classes: int) -> NCMStats:
    return NCMStats(
        sums=jnp.zeros((n_classes, d), jnp.float32),
        counts=jnp.zeros((n_classes,), jnp.float32),
    )


def client_stats(
    features: jax.Array, labels: jax.Array, n_classes: int,
    mask: Optional[jax.Array] = None,
) -> NCMStats:
    z = features.astype(jnp.float32)
    oh = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)  # (n, C)
    if mask is not None:
        oh = oh * mask.astype(jnp.float32)[:, None]
    return NCMStats(sums=oh.T @ z, counts=jnp.sum(oh, axis=0))


def merge(*stats: NCMStats) -> NCMStats:
    return NCMStats(
        sums=sum(s.sums for s in stats), counts=sum(s.counts for s in stats)
    )


def solve(stats: NCMStats, normalize: bool = True) -> jax.Array:
    """Classifier W (d, C): column c = (normalized) class centroid."""
    means = stats.sums / jnp.maximum(stats.counts, 1.0)[:, None]  # (C, d)
    W = means.T
    if normalize:
        norms = jnp.linalg.norm(W, axis=0, keepdims=True)
        W = W / jnp.maximum(norms, 1e-12)
    return W


def accuracy(W: jax.Array, features: jax.Array, labels: jax.Array) -> jax.Array:
    scores = features.astype(jnp.float32) @ W
    return jnp.mean((jnp.argmax(scores, -1) == labels).astype(jnp.float32))
