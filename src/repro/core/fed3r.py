"""FED3R — Federated Recursive Ridge Regression (paper §4).

The paper's contribution, as a composable JAX module.  Everything here is a
pure function over a tiny ``Fed3RStats`` pytree so the same code runs:

* in the **simulator** (python round loop, ``merge`` = server aggregation),
* in the **distributed runtime** (``aggregate_mesh`` = ``psum`` over the
  ("pod", "data") mesh axes — the paper's client→server aggregation mapped
  onto an all-reduce; exactness of the sum *is* the paper's immunity claim),
* in **streaming/online** mode (``Fed3RFactored`` — the recursive
  least-squares formulation of Eq. (3) kept in Cholesky-factored form;
  the subtractive Sherman–Morrison–Woodbury path ``woodbury_update`` is
  retained as a deprecated compat path),
* in **multi-tenant personalized** mode (``personalized_solution`` /
  ``batched_personalized_solution`` — per-client heads
  W_k = (A + α_k·A_k + λI)⁻¹(b + α_k·b_k) as rank-n updates of the shared
  factored state; the batched engine with α selection is
  :mod:`repro.federated.personalization`).

Statistics (Eq. 5/6):
    A = Σ_k Σ_{(x,y)∈D_k} φ(x)φ(x)ᵀ          (d×d, fp32)
    b = Σ_k Σ_{(x,y)∈D_k} φ(x) e_yᵀ           (d×C, fp32)
Solve (Eq. 4):  W* = (A + λI)⁻¹ b, then per-class column normalization.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp


class Fed3RStats(NamedTuple):
    """Sufficient statistics of the ridge-regression classifier."""

    A: jax.Array  # (d, d) fp32 feature second moment
    b: jax.Array  # (d, C) fp32 class-conditional feature sums
    n: jax.Array  # () fp32 sample count (diagnostics / NCM reuse)


def init_stats(d: int, n_classes: int) -> Fed3RStats:
    return Fed3RStats(
        A=jnp.zeros((d, d), jnp.float32),
        b=jnp.zeros((d, n_classes), jnp.float32),
        n=jnp.zeros((), jnp.float32),
    )


def masked_design(
    features: jax.Array,  # (n, d) — φ(x), any float dtype
    labels: jax.Array,  # (n,) int32
    n_classes: int,
    mask: Optional[jax.Array] = None,  # (n,) 1.0 = real sample, 0.0 = padding
) -> tuple:
    """Masked fp32 design matrices (Z, Y) and exact sample count n.

    The single source of truth for the masking semantics of Eq. 5/6:
    every statistics backend (XLA GEMMs here, the Pallas kernel in
    repro.federated.engine) consumes these so padded rows contribute
    exactly nothing to A, b, or n.
    """
    z = features.astype(jnp.float32)
    y = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)[:, None]
        z = z * m
        y = y * m
        n = jnp.sum(m)
    else:
        n = jnp.asarray(float(features.shape[0]), jnp.float32)
    return z, y, n


def client_stats(
    features: jax.Array,  # (n, d) — φ(x), any float dtype
    labels: jax.Array,  # (n,) int32
    n_classes: int,
    mask: Optional[jax.Array] = None,  # (n,) 1.0 = real sample, 0.0 = padding
) -> Fed3RStats:
    """Local statistics A_k, b_k of one client (Algorithm 1, client side).

    ``mask`` lets several clients share one padded batch (clients-per-shard
    batching in the distributed runtime) while keeping the sums exact.
    """
    z, y, n = masked_design(features, labels, n_classes, mask)
    return Fed3RStats(A=z.T @ z, b=z.T @ y, n=n)


def merge(*stats: Fed3RStats) -> Fed3RStats:
    """Server aggregation: associative+commutative sum of client statistics.

    Invariance to the client split and sampling order (paper §4.3) is the
    reassociation freedom of this sum.
    """
    return Fed3RStats(
        A=sum(s.A for s in stats),
        b=sum(s.b for s in stats),
        n=sum(s.n for s in stats),
    )


def aggregate_mesh(stats: Fed3RStats, axis_names: Sequence[str]) -> Fed3RStats:
    """Distributed aggregation: psum over mesh axes (inside shard_map)."""
    return jax.tree.map(lambda a: jax.lax.psum(a, tuple(axis_names)), stats)


def normalize_columns(W: jax.Array, axis: int = 0) -> jax.Array:
    """Per-class column normalization W_c ← W_c / max(‖W_c‖, 1e-12).

    The single definition every solve path shares (batched callers pass the
    feature axis of their layout) — the α=0 bitwise-parity contract of the
    personalization engine depends on all sites computing exactly this.
    """
    norms = jnp.linalg.norm(W, axis=axis, keepdims=True)
    return W / jnp.maximum(norms, 1e-12)


def solve(
    stats: Fed3RStats,
    ridge_lambda: float,
    normalize: bool = True,
) -> jax.Array:
    """Closed-form classifier W* = (A + λI)⁻¹ b (Eq. 4) via Cholesky.

    A + λI ≻ 0 for λ > 0, so the Cholesky factorization always exists.
    Optional per-class column normalization (paper, after Eq. 6):
    W*_c ← W*_c / ‖W*_c‖.
    """
    d = stats.A.shape[0]
    A_reg = stats.A + ridge_lambda * jnp.eye(d, dtype=jnp.float32)
    L = jax.scipy.linalg.cho_factor(A_reg, lower=True)
    W = jax.scipy.linalg.cho_solve(L, stats.b)
    if normalize:
        W = normalize_columns(W)
    return W


def predict(W: jax.Array, features: jax.Array) -> jax.Array:
    """One-vs-rest scores f(x) = Wᵀφ(x): (n, C)."""
    return features.astype(jnp.float32) @ W


def accuracy(W: jax.Array, features: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(predict(W, features), axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Recursive (online) formulation — factored rank-n updates
# ---------------------------------------------------------------------------


class Fed3RFactored(NamedTuple):
    """Online RR state in Cholesky-factored form: L Lᵀ = A + λI.

    The numerically stable recursive-least-squares formulation of Eq. (3):
    every arrival performs the ADDITIVE rank-n update L ← chol(L Lᵀ + ZᵀZ)
    (no subtraction, hence no fp32 cancellation — contrast ``Fed3ROnline``),
    and the solution W = (A + λI)⁻¹ b is two triangular solves against L.
    This is the state carried by the streaming arrival engine
    (:mod:`repro.federated.streaming_engine`) and the shared base every
    personalized head is a rank-n update away from
    (:func:`personalized_solution`, :mod:`repro.federated.personalization`).

    Fields:
      L: (d, d) fp32 lower-triangular Cholesky factor of A + λI, where
         A = Σ ZᵀZ is the global feature second moment over everything
         absorbed so far and λ is the ridge coefficient baked in at
         :func:`init_factored` time (L = √λ·I before any data).  Only the
         lower triangle is meaningful; consumers must pass ``lower=True``
         to the triangular solves.
      b: (d, C) fp32 class-conditional feature sums Σ ZᵀY (Y one-hot),
         the right-hand side of the closed-form solve.  Unlike L it is a
         plain running sum, so it merges/psums exactly like
         :class:`Fed3RStats` and composes with secure aggregation.
    """

    L: jax.Array  # (d, d) fp32 lower Cholesky factor of A + λI
    b: jax.Array  # (d, C)


def init_factored(d: int, n_classes: int, ridge_lambda: float) -> Fed3RFactored:
    return Fed3RFactored(
        L=jnp.sqrt(jnp.float32(ridge_lambda)) * jnp.eye(d, dtype=jnp.float32),
        b=jnp.zeros((d, n_classes), jnp.float32),
    )


def factored_update(
    state: Fed3RFactored,
    features: jax.Array,  # (n, d)
    labels: jax.Array,  # (n,) int32
    mask: Optional[jax.Array] = None,  # (n,) 1.0 real / 0.0 padding
) -> Fed3RFactored:
    """Stable rank-n update with a new arrival batch Z (n, d):

    L ← chol(L Lᵀ + ZᵀZ),  b ← b + ZᵀY.

    Both Gram contributions are PSD and the ridge floor λI ⪯ L Lᵀ keeps the
    refactorization positive definite, so the update is additions-only —
    exact in the same sense as the batch statistics path.  The fused Pallas
    form of the two GEMMs lives in :func:`repro.kernels.chol_gram`.
    """
    z, y, _ = masked_design(features, labels, state.b.shape[1], mask)
    G = state.L @ state.L.T + z.T @ z
    return Fed3RFactored(L=jnp.linalg.cholesky(G), b=state.b + z.T @ y)


def factored_solution(state: Fed3RFactored, normalize: bool = True) -> jax.Array:
    """W = (A + λI)⁻¹ b by two triangular solves against the carried factor."""
    W = jax.scipy.linalg.cho_solve((state.L, True), state.b)
    if normalize:
        W = normalize_columns(W)
    return W


# ---------------------------------------------------------------------------
# Personalized heads — per-client closed forms over the shared factored state
# ---------------------------------------------------------------------------


def personalized_solution(
    state: Fed3RFactored,
    client: Fed3RStats,
    alpha: Union[float, jax.Array],
    normalize: bool = True,
) -> jax.Array:
    """Per-client closed-form head W_k = (A + α·A_k + λI)⁻¹ (b + α·b_k).

    The personalization closed form over the shared factored state: client
    k's own statistics (A_k, b_k) are re-weighted by α ≥ 0 on top of the
    global sums, so the head interpolates from the heterogeneity-immune
    global classifier (α = 0) toward a local-emphasis one.  Cost: one d×d
    Cholesky refactorization G = L Lᵀ + α·A_k plus two triangular solves —
    no gradient step, no retraining, and the upload is the (A_k, b_k) the
    client already sent.

    α = 0 reproduces :func:`factored_solution` BITWISE: the carried factor
    L and right-hand side b are selected unchanged (not recomputed through
    chol(L Lᵀ) / b + 0, whose roundings could differ), so the downstream
    solves see identical operands.

    The batched form over a packed cohort — K heads in one dispatch, with
    per-client α selection — is
    :class:`repro.federated.personalization.PersonalizationEngine`.
    """
    a = jnp.asarray(alpha, jnp.float32)
    L_pers = jnp.linalg.cholesky(state.L @ state.L.T + a * client.A)
    L_use = jnp.where(a == 0.0, state.L, L_pers)
    rhs = jnp.where(a == 0.0, state.b, state.b + a * client.b)
    W = jax.scipy.linalg.cho_solve((L_use, True), rhs)
    if normalize:
        W = normalize_columns(W)
    return W


def batched_personalized_solution(
    state: Fed3RFactored,
    A_k: jax.Array,  # (K, d, d) per-client second moments
    b_k: jax.Array,  # (K, d, C) per-client class-conditional sums
    alphas: jax.Array,  # (K,) per-client interpolation weights
    normalize: bool = True,
) -> jax.Array:
    """K personalized heads (K, d, C) in one vmapped batch of solves.

    Semantics per head follow :func:`personalized_solution`: α = 0 rows
    select the global (L, b) operands unchanged, but the solve itself is
    BATCHED, and XLA's batched triangular solve may lower differently from
    the unbatched one — so α = 0 here agrees with ``factored_solution`` to
    the last ulp of the solver, NOT bitwise.  When the exact-bitwise α = 0
    fallback matters (serving), use the engine
    (:class:`repro.federated.personalization.PersonalizationEngine`),
    which substitutes an unbatched global solve for those rows.  The
    global ``state`` is broadcast, so the Gram reconstructions, Cholesky
    refactorizations, and triangular solves all batch into single XLA ops.
    """
    return jax.vmap(
        lambda A, b, a: personalized_solution(
            state, Fed3RStats(A=A, b=b, n=jnp.zeros((), jnp.float32)), a, normalize
        )
    )(A_k, b_k, jnp.asarray(alphas, jnp.float32))


# ---------------------------------------------------------------------------
# Deprecated: subtractive Sherman–Morrison–Woodbury compat path
# ---------------------------------------------------------------------------


class Fed3ROnline(NamedTuple):
    """DEPRECATED online RR state carrying A⁻¹ directly.

    With λ ≪ tr(A)/d the initial A⁻¹ = I/λ is orders of magnitude larger
    than the converged inverse, so the subtractive Woodbury update suffers
    catastrophic cancellation in fp32 (observed ~1e-2 max-abs error on W at
    λ = 1e-2 where :class:`Fed3RFactored` stays ≤ 1e-6).  Kept only as a
    compat path; use ``init_factored``/``factored_update`` instead.
    """

    Ainv: jax.Array  # (d, d) fp32 — (A + λI)⁻¹
    b: jax.Array  # (d, C)


# fp32 cancellation becomes visible once 1/λ dwarfs the converged inverse;
# below this λ the legacy path is known-bad even at modest sample counts
_SMALL_LAMBDA = 0.1


def _warn_legacy_woodbury(ridge_lambda: Optional[float] = None) -> None:
    hazard = (
        " At small ridge_lambda the subtractive update CANCELS"
        " catastrophically in fp32 — expect a visibly wrong W."
        if ridge_lambda is not None and ridge_lambda < _SMALL_LAMBDA
        else ""
    )
    warnings.warn(
        "Fed3ROnline/woodbury_update is deprecated: the subtractive Woodbury"
        " update is numerically unstable in fp32. Use the factored state"
        " (init_factored/factored_update/factored_solution) or the streaming"
        " engine (repro.federated.streaming_engine)." + hazard,
        DeprecationWarning,
        stacklevel=3,
    )


def init_online(d: int, n_classes: int, ridge_lambda: float) -> Fed3ROnline:
    _warn_legacy_woodbury(ridge_lambda)
    return Fed3ROnline(
        Ainv=jnp.eye(d, dtype=jnp.float32) / ridge_lambda,
        b=jnp.zeros((d, n_classes), jnp.float32),
    )


def woodbury_update(state: Fed3ROnline, features: jax.Array, labels: jax.Array) -> Fed3ROnline:
    """DEPRECATED rank-n update with a new client's batch Z (n, d):

    (A + ZᵀZ)⁻¹ = A⁻¹ − A⁻¹Zᵀ (I + Z A⁻¹ Zᵀ)⁻¹ Z A⁻¹

    The subtraction is the fp32 hazard; prefer :func:`factored_update`.
    """
    Z = features.astype(jnp.float32)
    n = Z.shape[0]
    C = state.b.shape[1]
    AiZt = state.Ainv @ Z.T  # (d, n)
    K = jnp.eye(n, dtype=jnp.float32) + Z @ AiZt  # (n, n)
    L = jax.scipy.linalg.cho_factor(K, lower=True)
    Ainv = state.Ainv - AiZt @ jax.scipy.linalg.cho_solve(L, AiZt.T)
    b = state.b + Z.T @ jax.nn.one_hot(labels, C, dtype=jnp.float32)
    return Fed3ROnline(Ainv=Ainv, b=b)


def online_solution(
    state: Union[Fed3RFactored, Fed3ROnline], normalize: bool = True
) -> jax.Array:
    """Solution of either online state; routes through the factored path.

    Given a :class:`Fed3RFactored` this IS :func:`factored_solution` (two
    triangular solves).  The legacy :class:`Fed3ROnline` branch is kept for
    compatibility and warns: its W inherits the accumulated cancellation
    error of the carried A⁻¹.
    """
    if isinstance(state, Fed3RFactored):
        return factored_solution(state, normalize)
    _warn_legacy_woodbury()
    W = state.Ainv @ state.b
    if normalize:
        W = normalize_columns(W)
    return W
