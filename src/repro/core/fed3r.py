"""FED3R — Federated Recursive Ridge Regression (paper §4).

The paper's contribution, as a composable JAX module.  Everything here is a
pure function over a tiny ``Fed3RStats`` pytree so the same code runs:

* in the **simulator** (python round loop, ``merge`` = server aggregation),
* in the **distributed runtime** (``aggregate_mesh`` = ``psum`` over the
  ("pod", "data") mesh axes — the paper's client→server aggregation mapped
  onto an all-reduce; exactness of the sum *is* the paper's immunity claim),
* in **streaming/online** mode (``woodbury_update`` — the recursive
  least-squares formulation of Eq. (3), Sherman–Morrison–Woodbury).

Statistics (Eq. 5/6):
    A = Σ_k Σ_{(x,y)∈D_k} φ(x)φ(x)ᵀ          (d×d, fp32)
    b = Σ_k Σ_{(x,y)∈D_k} φ(x) e_yᵀ           (d×C, fp32)
Solve (Eq. 4):  W* = (A + λI)⁻¹ b, then per-class column normalization.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


class Fed3RStats(NamedTuple):
    """Sufficient statistics of the ridge-regression classifier."""

    A: jax.Array  # (d, d) fp32 feature second moment
    b: jax.Array  # (d, C) fp32 class-conditional feature sums
    n: jax.Array  # () fp32 sample count (diagnostics / NCM reuse)


def init_stats(d: int, n_classes: int) -> Fed3RStats:
    return Fed3RStats(
        A=jnp.zeros((d, d), jnp.float32),
        b=jnp.zeros((d, n_classes), jnp.float32),
        n=jnp.zeros((), jnp.float32),
    )


def masked_design(
    features: jax.Array,  # (n, d) — φ(x), any float dtype
    labels: jax.Array,  # (n,) int32
    n_classes: int,
    mask: Optional[jax.Array] = None,  # (n,) 1.0 = real sample, 0.0 = padding
) -> tuple:
    """Masked fp32 design matrices (Z, Y) and exact sample count n.

    The single source of truth for the masking semantics of Eq. 5/6:
    every statistics backend (XLA GEMMs here, the Pallas kernel in
    repro.federated.engine) consumes these so padded rows contribute
    exactly nothing to A, b, or n.
    """
    z = features.astype(jnp.float32)
    y = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)[:, None]
        z = z * m
        y = y * m
        n = jnp.sum(m)
    else:
        n = jnp.asarray(float(features.shape[0]), jnp.float32)
    return z, y, n


def client_stats(
    features: jax.Array,  # (n, d) — φ(x), any float dtype
    labels: jax.Array,  # (n,) int32
    n_classes: int,
    mask: Optional[jax.Array] = None,  # (n,) 1.0 = real sample, 0.0 = padding
) -> Fed3RStats:
    """Local statistics A_k, b_k of one client (Algorithm 1, client side).

    ``mask`` lets several clients share one padded batch (clients-per-shard
    batching in the distributed runtime) while keeping the sums exact.
    """
    z, y, n = masked_design(features, labels, n_classes, mask)
    return Fed3RStats(A=z.T @ z, b=z.T @ y, n=n)


def merge(*stats: Fed3RStats) -> Fed3RStats:
    """Server aggregation: associative+commutative sum of client statistics.

    Invariance to the client split and sampling order (paper §4.3) is the
    reassociation freedom of this sum.
    """
    return Fed3RStats(
        A=sum(s.A for s in stats),
        b=sum(s.b for s in stats),
        n=sum(s.n for s in stats),
    )


def aggregate_mesh(stats: Fed3RStats, axis_names: Sequence[str]) -> Fed3RStats:
    """Distributed aggregation: psum over mesh axes (inside shard_map)."""
    return jax.tree.map(lambda a: jax.lax.psum(a, tuple(axis_names)), stats)


def solve(
    stats: Fed3RStats,
    ridge_lambda: float,
    normalize: bool = True,
) -> jax.Array:
    """Closed-form classifier W* = (A + λI)⁻¹ b (Eq. 4) via Cholesky.

    A + λI ≻ 0 for λ > 0, so the Cholesky factorization always exists.
    Optional per-class column normalization (paper, after Eq. 6):
    W*_c ← W*_c / ‖W*_c‖.
    """
    d = stats.A.shape[0]
    A_reg = stats.A + ridge_lambda * jnp.eye(d, dtype=jnp.float32)
    L = jax.scipy.linalg.cho_factor(A_reg, lower=True)
    W = jax.scipy.linalg.cho_solve(L, stats.b)
    if normalize:
        norms = jnp.linalg.norm(W, axis=0, keepdims=True)
        W = W / jnp.maximum(norms, 1e-12)
    return W


def predict(W: jax.Array, features: jax.Array) -> jax.Array:
    """One-vs-rest scores f(x) = Wᵀφ(x): (n, C)."""
    return features.astype(jnp.float32) @ W


def accuracy(W: jax.Array, features: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(predict(W, features), axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Recursive (online) formulation — Sherman–Morrison–Woodbury updates
# ---------------------------------------------------------------------------


class Fed3ROnline(NamedTuple):
    """Online RR state carrying A⁻¹ directly (recursive least squares).

    Equivalent to the batch statistics path; useful when a deployment wants
    O(d²) per-round updates of the *solution* instead of re-solving.

    Numerical caution: with λ ≪ tr(A)/d the initial A⁻¹ = I/λ is orders of
    magnitude larger than the converged inverse, so the subtractive Woodbury
    update suffers catastrophic cancellation in fp32.  Production use should
    either keep this state in float64 (enable jax_enable_x64) or prefer the
    batch-statistics path (init_stats/client_stats/merge/solve), which is the
    paper's Algorithm 1 and has no such issue.
    """

    Ainv: jax.Array  # (d, d) fp32 — (A + λI)⁻¹
    b: jax.Array  # (d, C)


def init_online(d: int, n_classes: int, ridge_lambda: float) -> Fed3ROnline:
    return Fed3ROnline(
        Ainv=jnp.eye(d, dtype=jnp.float32) / ridge_lambda,
        b=jnp.zeros((d, n_classes), jnp.float32),
    )


def woodbury_update(state: Fed3ROnline, features: jax.Array, labels: jax.Array) -> Fed3ROnline:
    """Rank-n update with a new client's batch Z (n, d):

    (A + ZᵀZ)⁻¹ = A⁻¹ − A⁻¹Zᵀ (I + Z A⁻¹ Zᵀ)⁻¹ Z A⁻¹
    """
    Z = features.astype(jnp.float32)
    n = Z.shape[0]
    C = state.b.shape[1]
    AiZt = state.Ainv @ Z.T  # (d, n)
    K = jnp.eye(n, dtype=jnp.float32) + Z @ AiZt  # (n, n)
    L = jax.scipy.linalg.cho_factor(K, lower=True)
    Ainv = state.Ainv - AiZt @ jax.scipy.linalg.cho_solve(L, AiZt.T)
    b = state.b + Z.T @ jax.nn.one_hot(labels, C, dtype=jnp.float32)
    return Fed3ROnline(Ainv=Ainv, b=b)


def online_solution(state: Fed3ROnline, normalize: bool = True) -> jax.Array:
    W = state.Ainv @ state.b
    if normalize:
        norms = jnp.linalg.norm(W, axis=0, keepdims=True)
        W = W / jnp.maximum(norms, 1e-12)
    return W
