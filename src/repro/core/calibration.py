"""Softmax-temperature calibration of the FED3R initialization (paper §4.4).

The RR solution minimizes squared loss, so its score scale does not match the
cross-entropy landscape used in fine-tuning.  The paper calibrates by scanning
softmax temperatures and picking the one minimizing training CE (App. C,
Fig. 7 — best temperature 0.1 on both datasets).  We fold 1/T into the
classifier weights so the FT phase starts from W/T with an ordinary softmax.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_TEMPERATURES = (3.0, 1.0, 0.3, 0.1, 0.03, 0.01)


def ce_at_temperature(scores: jax.Array, labels: jax.Array, temp: jax.Array) -> jax.Array:
    logits = scores.astype(jnp.float32) / temp
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def calibrate_temperature(
    scores: jax.Array,  # (n, C) RR scores on (a sample of) the training set
    labels: jax.Array,  # (n,)
    temperatures=DEFAULT_TEMPERATURES,
) -> Tuple[jax.Array, jax.Array]:
    """Grid-search the temperature. Returns (best_temp, per-temp CE)."""
    temps = jnp.asarray(temperatures, jnp.float32)
    ces = jax.vmap(lambda t: ce_at_temperature(scores, labels, t))(temps)
    return temps[jnp.argmin(ces)], ces


def fold_temperature(W: jax.Array, temp: jax.Array) -> jax.Array:
    """Return the calibrated softmax-classifier init W/T."""
    return W / temp
