"""Random Fourier Features for FED3R-RF (paper §4.2, Rahimi & Recht 2007).

Approximates the RBF kernel k(z, ζ) = exp(−‖z−ζ‖²/2σ²) with the feature map

    ψ(z) = √(2/D) · cos(Ωᵀ z + β),    Ω_ij ~ N(0, σ⁻²),  β_j ~ U[0, 2π).

ψ is data-independent, so all clients share one (Ω, β) drawn by the server —
FED3R-RF keeps the exact-aggregation property in the D-dimensional space.
The paper uses σ = 1000 and D ∈ {5k, 10k} (App. C/F).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RFFParams(NamedTuple):
    omega: jax.Array  # (d, D) fp32
    beta: jax.Array  # (D,) fp32
    sigma: jax.Array  # () fp32 (kept for bookkeeping)


def rff_init(rng: jax.Array, d: int, n_features: int, sigma: float) -> RFFParams:
    r1, r2 = jax.random.split(rng)
    omega = jax.random.normal(r1, (d, n_features), jnp.float32) / sigma
    beta = jax.random.uniform(r2, (n_features,), jnp.float32, 0.0, 2.0 * jnp.pi)
    return RFFParams(omega=omega, beta=beta, sigma=jnp.asarray(sigma, jnp.float32))


def rff_map(params: RFFParams, z: jax.Array) -> jax.Array:
    """ψ(z): (n, d) -> (n, D), fp32."""
    D = params.omega.shape[1]
    proj = z.astype(jnp.float32) @ params.omega + params.beta
    return jnp.sqrt(2.0 / D) * jnp.cos(proj)


def rbf_kernel(z1: jax.Array, z2: jax.Array, sigma: float) -> jax.Array:
    """Exact RBF kernel matrix (for validating the RFF approximation)."""
    z1 = z1.astype(jnp.float32)
    z2 = z2.astype(jnp.float32)
    sq = (
        jnp.sum(z1**2, -1)[:, None]
        - 2.0 * z1 @ z2.T
        + jnp.sum(z2**2, -1)[None, :]
    )
    return jnp.exp(-sq / (2.0 * sigma**2))
