"""RR as a feature-quality probe (paper §5.4, Table 3).

Fitting the closed-form RR classifier on a (possibly fine-tuned) extractor's
features gives a deterministic, hyper-parameter-free measure of feature
linear separability — decoupling extractor quality from classifier quality.
In federated settings the probe is computed through the FED3R formulation,
so it is itself unaffected by heterogeneity.
"""
from __future__ import annotations

from typing import Callable, Iterable, NamedTuple, Tuple

import jax

from repro.core import fed3r


class ProbeResult(NamedTuple):
    accuracy: jax.Array
    W: jax.Array


def fit_probe(
    features: jax.Array,
    labels: jax.Array,
    n_classes: int,
    ridge_lambda: float = 0.01,
) -> jax.Array:
    """Fit RR on (features, labels); returns the classifier W."""
    stats = fed3r.client_stats(features, labels, n_classes)
    return fed3r.solve(stats, ridge_lambda)


def probe_quality(
    train_features: jax.Array,
    train_labels: jax.Array,
    test_features: jax.Array,
    test_labels: jax.Array,
    n_classes: int,
    ridge_lambda: float = 0.01,
) -> ProbeResult:
    """Train-on-train, evaluate-on-test RR accuracy — the Table-3 number."""
    W = fit_probe(train_features, train_labels, n_classes, ridge_lambda)
    acc = fed3r.accuracy(W, test_features, test_labels)
    return ProbeResult(accuracy=acc, W=W)


def probe_extractor(
    extract_fn: Callable[[dict], jax.Array],
    batches: Iterable[Tuple[dict, jax.Array]],
    n_classes: int,
    d: int,
    ridge_lambda: float = 0.01,
) -> jax.Array:
    """Streaming probe: accumulate FED3R stats over an extractor's batches."""
    stats = fed3r.init_stats(d, n_classes)
    for batch, labels in batches:
        feats = extract_fn(batch)
        stats = fed3r.merge(stats, fed3r.client_stats(feats, labels, n_classes))
    return fed3r.solve(stats, ridge_lambda)
