"""Live-refresh classifier serving over a streaming FED3R arrival process.

The serving-side driver of the streaming engine
(:mod:`repro.federated.streaming_engine`): clients arrive over time
(Poisson or label-skewed schedule), the server folds each arrival SEGMENT
through one jitted dispatch, and between segments it answers queries with
the currently served classifier — which is as fresh as the refresh policy
paid for:

* ``--policy arrival``  refresh-on-arrival (``refresh_every=1``): every
  wave re-solves W by two triangular solves; queries never see stale
  weights;
* ``--policy every-k``  refresh every k-th wave (``--k``): cheaper
  refresh cadence, and the reported STALENESS metric (waves / samples
  absorbed since the last re-solve) quantifies what queries see.

``--engine slots`` routes the same loop through the continuous-batching
slot engine (:mod:`repro.launch.serving_engine`): absorbs go through its
absorb stage, query bursts are admitted to its queue and answered by the
one-dispatch serve stage against the pinned global slot (refreshed at
tick time whenever the stream advanced — the slot engine's solve stage
owns the refresh, so the ``--policy`` staleness knobs report the stream
state's lag while queries see a tick-fresh head).  ``--engine lru``
(default) is the legacy synchronous driver.  Same log/report shape either
way.

``--engine async`` serves over ASYNCHRONOUS merge-on-arrival rounds
(:mod:`repro.federated.async_engine`): per round a cohort (~``--rate``
clients, sampled from the health tracker's currently-eligible set) uploads
through a seeded chaos schedule (duplicates deduped, reordered and delayed
arrivals folding late under the staleness bound), rounds close at their
deadline instead of waiting for stragglers, and query bursts are answered
by the LIVE classifier — retired state plus every open partial cohort.
The staleness columns report open (unretired) rounds and the samples
sitting in their slots; the final report carries the chaos counters.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_stream --waves 24 --rate 4 \
      --policy every-k --k 4 --segment 6 --engine slots
  PYTHONPATH=src python -m repro.launch.serve_stream --waves 20 --rate 6 \
      --segment 5 --engine async
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.data.pipeline import make_federated_features
from repro.federated.arrivals import (
    dominant_labels,
    pack_schedule,
    poisson_schedule,
    skewed_schedule,
)
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.federated.telemetry import get_telemetry


def serve_stream(
    n_waves: int = 24,
    rate: float = 4.0,
    policy: str = "arrival",
    k: int = 4,
    segment: int = 6,
    skew: float = 0.0,
    n_clients: int = 64,
    d: int = 64,
    n_classes: int = 10,
    ridge_lambda: float = 1e-2,
    engine: str = "lru",
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Run the arrival → absorb → query loop; returns the serving log.

    ``engine="lru"`` is the legacy synchronous driver; ``engine="slots"``
    rides the continuous-batching slot engine (absorb/serve stages, one
    dispatch each) behind the same log shape; ``engine="async"`` serves
    the live classifier of the merge-on-arrival round engine under a
    seeded chaos arrival schedule.
    """
    if engine not in ("lru", "slots", "async"):
        raise ValueError(f"unknown serving engine: {engine!r}")
    # noise calibrated so the served accuracy GROWS over the stream —
    # stale refreshes are then visible in the query-burst numbers
    fed, test = make_federated_features(
        seed=seed, n=8000, d=d, n_classes=n_classes, n_clients=n_clients,
        alpha=0.1, noise=7.0,
    )
    if engine == "async":
        return _serve_async(
            fed, jnp.asarray(test.features), jnp.asarray(test.labels),
            n_rounds=n_waves, rate=rate, segment=segment, d=d,
            n_classes=n_classes, ridge_lambda=ridge_lambda, seed=seed,
            verbose=verbose,
        )
    if skew > 0.0:
        schedule = skewed_schedule(
            dominant_labels(fed), n_waves, skew=skew, seed=seed
        )
    else:
        schedule = poisson_schedule(fed.n_clients, n_waves, rate, seed=seed)
    packed = pack_schedule(fed, schedule)

    refresh_every = 1 if policy == "arrival" else k
    test_x = jnp.asarray(test.features)
    test_y = jnp.asarray(test.labels)
    test_np = np.asarray(test.features)

    slot_server = None
    if engine == "slots":
        from repro.launch.serving_engine import ServingConfig, ServingEngine

        # global-only traffic: a tiny table (slot 0 + one spare) suffices,
        # and every query carries tenant -1 (no server-side data)
        slot_server = ServingEngine(
            ServingConfig(
                n_classes=n_classes, ridge_lambda=ridge_lambda, n_slots=2,
                queue_depth=max(4096, len(test_np)),
            ),
            fed,
        )
        slot_server.init(d)
        stream_engine = slot_server.stream
        state = slot_server.state
    else:
        stream_engine = StreamingEngine(StreamConfig(
            n_classes=n_classes, ridge_lambda=ridge_lambda,
            refresh_every=refresh_every,
        ))
        state = stream_engine.init(d)

    log: dict = {
        "wave": [], "clients_seen": [], "samples_seen": [],
        "stale_waves": [], "stale_samples": [], "acc_served": [],
        # this driver serves ONE global head to all tenants; per-tenant
        # heads (with their own cache staleness) are repro.launch.serve_heads
        "served_head": "global",
        "engine": engine,
    }
    seen = 0
    t0 = time.perf_counter()  # monotonic: wall clock steps under NTP
    if verbose:
        print(f"engine={engine} policy={policy} refresh_every={refresh_every} "
              f"waves={packed.n_waves} clients={packed.n_clients}")
        print("served head: GLOBAL (one W for all tenants; staleness below "
              "is refresh-policy lag — for per-tenant heads and their cache "
              "staleness see repro.launch.serve_heads)")
        print("wave | arrived | samples seen | stale (waves/samples) | acc(served W)")
    for lo in range(0, packed.n_waves, segment):
        chunk = packed.slice_waves(lo, min(lo + segment, packed.n_waves))
        if engine == "slots":
            slot_server.absorb(chunk)  # ONE dispatch per segment
            state = slot_server.state
            # the query burst: every test row admitted with tenant -1 →
            # served by the pinned global slot in ONE serve dispatch
            scores, _ = slot_server.query(
                np.full((len(test_np),), -1, np.int64), test_np
            )
            acc = float(jnp.mean(
                (jnp.argmax(scores, axis=-1) == test_y).astype(jnp.float32)
            ))
        else:
            state, trace = stream_engine.absorb(state, chunk)
            # a query burst against the served (possibly stale) classifier
            acc = float(fed3r.accuracy(
                stream_engine.classifier(state), test_x, test_y
            ))
        seen += chunk.n_clients
        log["wave"].append(int(state.wave))
        log["clients_seen"].append(seen)
        log["samples_seen"].append(float(state.n))
        log["stale_waves"].append(int(state.stale_waves))
        log["stale_samples"].append(float(state.stale_samples))
        log["acc_served"].append(acc)
        if verbose:
            print(f"{int(state.wave):4d} | {chunk.n_clients:7d} | "
                  f"{float(state.n):12.0f} | {int(state.stale_waves):5d} /"
                  f"{float(state.stale_samples):8.0f} | {acc:.4f}")
    if engine == "slots":
        state = slot_server.state
        acc = log["acc_served"][-1]  # slot ticks already serve a fresh head
        log["dispatches"] = (
            slot_server.absorb_dispatches + slot_server.solve_dispatches
            + slot_server.serve_dispatches
        )
        log["serve_dispatches"] = slot_server.serve_dispatches
        log["stage_s"] = dict(slot_server.stage_s)
    else:
        state = stream_engine.refresh(state)  # final sync before reporting
        acc = float(fed3r.accuracy(
            stream_engine.classifier(state), test_x, test_y
        ))
        log["dispatches"] = stream_engine.dispatches
    log["acc_final"] = acc
    log["wall_s"] = time.perf_counter() - t0
    get_telemetry().gauge(
        "driver_wall_seconds", driver="serve_stream", engine=engine
    ).set(log["wall_s"])
    if verbose:
        print(f"final sync: acc={acc:.4f}  "
              f"({log['dispatches']} dispatches for {packed.n_waves} waves, "
              f"{log['wall_s']:.2f}s)")
    return log


def _serve_async(
    fed, test_x, test_y, *, n_rounds, rate, segment, d, n_classes,
    ridge_lambda, seed, verbose,
) -> dict:
    """The ``--engine async`` loop: chaos-injected merge-on-arrival rounds
    with query bursts served from the LIVE classifier between segments."""
    import time as _time

    from repro.federated.arrivals import (
        ChaosSpec,
        chaos_round_events,
        latency_profile,
    )
    from repro.federated.async_engine import (
        AsyncConfig,
        AsyncRoundEngine,
        client_payloads,
    )

    t0 = _time.perf_counter()
    per_round = max(1, int(round(rate)))
    eng = AsyncRoundEngine(AsyncConfig(
        n_classes=n_classes, ridge_lambda=ridge_lambda, cohort=per_round,
        deadline=1.0, staleness_rounds=1,
    ))
    state = eng.init(d)
    payloads = client_payloads(fed, n_classes)
    latency = latency_profile(fed.n_clients, 0.2, seed=seed)
    spec = ChaosSpec(duplicate=0.05, reorder=0.2, delay=0.1, seed=seed)
    log: dict = {
        "wave": [], "clients_seen": [], "samples_seen": [],
        "stale_waves": [], "stale_samples": [], "acc_served": [],
        "served_head": "global", "engine": "async",
    }
    seen = 0
    if verbose:
        print(f"engine=async rounds={n_rounds} cohort~{per_round} "
              f"deadline={eng.cfg.deadline} staleness={eng.cfg.staleness_rounds}")
        print("round | arrived | samples retired | open (rounds/samples) | acc(live W)")
    for lo in range(0, n_rounds, segment):
        for r in range(lo, min(lo + segment, n_rounds)):
            eligible = [
                c for c in range(fed.n_clients) if eng.health.is_eligible(c, r)
            ]
            rng = np.random.default_rng((seed, r, 0xA51))
            take = min(per_round, len(eligible))
            cohort = sorted(
                int(eligible[i])
                for i in rng.choice(len(eligible), size=take, replace=False)
            )
            eng.begin_round(r, cohort, float(r))
            events = chaos_round_events(cohort, latency, spec, r)
            on_time = [e for e in events if e.t <= eng.cfg.deadline]
            late = [e for e in events if e.t > eng.cfg.deadline]
            for ev in sorted(on_time):
                state, _ = eng.deliver(state, ev, payloads[ev.client],
                                       now=float(r) + ev.t)
            state = eng.close_round(state, r, now=float(r) + eng.cfg.deadline)
            # stragglers past the deadline keep merging (staleness bound)
            for ev in sorted(late):
                state, _ = eng.deliver(state, ev, payloads[ev.client],
                                       now=float(r) + ev.t)
            seen += len(cohort)
        acc = float(fed3r.accuracy(eng.live_classifier(state), test_x, test_y))
        open_rounds = eng._next_begin - eng._next_retire
        open_samples = float(jnp.sum(state.n_slots))
        log["wave"].append(eng._next_begin)
        log["clients_seen"].append(seen)
        log["samples_seen"].append(float(state.n))
        log["stale_waves"].append(open_rounds)
        log["stale_samples"].append(open_samples)
        log["acc_served"].append(acc)
        if verbose:
            print(f"{eng._next_begin:5d} | {seen:7d} | {float(state.n):15.0f} | "
                  f"{open_rounds:5d} /{open_samples:8.0f} | {acc:.4f}")
    state = eng.drain(state)
    acc = float(fed3r.accuracy(eng.classifier(state), test_x, test_y))
    log["acc_final"] = acc
    log["dispatches"] = eng.dispatches
    log["chaos"] = eng.report()
    log["wall_s"] = _time.perf_counter() - t0
    get_telemetry().gauge(
        "driver_wall_seconds", driver="serve_stream", engine="async"
    ).set(log["wall_s"])
    if verbose:
        rep = log["chaos"]
        print(f"final drain: acc={acc:.4f}  ({eng.dispatches} dispatches; "
              f"folded={rep['folded']} late={rep['late_folds']} "
              f"dup={rep['duplicates']} stale={rep['stale_rejected']} "
              f"dropped={rep['dropped_uploads']}, {log['wall_s']:.2f}s)")
    return log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--policy", choices=("arrival", "every-k"), default="arrival")
    ap.add_argument("--k", type=int, default=4, help="refresh cadence (every-k)")
    ap.add_argument("--segment", type=int, default=6,
                    help="waves absorbed per dispatch between query bursts")
    ap.add_argument("--skew", type=float, default=0.0,
                    help="label-skewed arrival order in [0, 1]")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--ridge-lambda", type=float, default=1e-2)
    ap.add_argument("--engine", choices=("lru", "slots", "async"),
                    default="lru",
                    help="legacy synchronous driver, slot-serving engine, "
                         "or chaos-injected async merge-on-arrival rounds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_stream(
        n_waves=args.waves, rate=args.rate, policy=args.policy, k=args.k,
        segment=args.segment, skew=args.skew, n_clients=args.clients,
        d=args.d, n_classes=args.classes, ridge_lambda=args.ridge_lambda,
        engine=args.engine, seed=args.seed,
    )


if __name__ == "__main__":
    main()
