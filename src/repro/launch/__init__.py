"""Launch layer: production mesh, sharding, step functions, dry-run, drivers."""
