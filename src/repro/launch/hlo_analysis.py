"""Post-SPMD HLO analysis: collective bytes, op census, roofline terms.

``collective_stats`` parses the compiled (partitioned) HLO text and sums, per
collective kind, the *wire bytes per chip* using standard ring-algorithm
factors:

    all-reduce        2·(n−1)/n · buffer
    all-gather        (n−1)/n · result        (result = gathered buffer)
    reduce-scatter    (n−1)   · result        (operand = n·result)
    all-to-all        (n−1)/n · buffer
    collective-permute  1 · buffer

where n is the replica-group size parsed from the op.

NOTE on loops: ``cost_analysis`` and a single text parse both count a
while-loop (scan) body exactly once.  The dry-run therefore derives
whole-program totals by the **delta method**: compile unrolled 1-layer and
2-layer variants, take the difference as the exact per-layer cost, and
extrapolate — see launch/dryrun.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
# iota format: replica_groups=[8,64]<=[512] → 8 groups of 64
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit format: replica_groups={{0,1,2,3},{...}}
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _all_shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue  # layout annotations like {1,0} don't match dtype names
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # collective-permute / unknown: factor-1 wire anyway


@dataclass
class CollectiveStats:
    wire_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    buffer_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        for k in self.counts:
            out.counts[k] = int(self.counts[k] * factor)
            out.buffer_bytes[k] = self.buffer_bytes[k] * factor
            out.wire_bytes[k] = self.wire_bytes[k] * factor
        return out

    def minus(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats()
        keys = set(self.counts) | set(other.counts)
        for k in keys:
            out.counts[k] = self.counts.get(k, 0) - other.counts.get(k, 0)
            out.buffer_bytes[k] = self.buffer_bytes.get(k, 0.0) - other.buffer_bytes.get(k, 0.0)
            out.wire_bytes[k] = self.wire_bytes.get(k, 0.0) - other.wire_bytes.get(k, 0.0)
        return out

    def plus_scaled(self, other: "CollectiveStats", factor: float) -> "CollectiveStats":
        # clamped at zero: layout differences between depth variants can give
        # slightly negative per-layer deltas for rare collective kinds
        out = CollectiveStats()
        keys = set(self.counts) | set(other.counts)
        for k in keys:
            out.counts[k] = max(
                int(self.counts.get(k, 0) + factor * other.counts.get(k, 0)), 0
            )
            out.buffer_bytes[k] = max(
                self.buffer_bytes.get(k, 0.0) + factor * other.buffer_bytes.get(k, 0.0), 0.0
            )
            out.wire_bytes[k] = max(
                self.wire_bytes.get(k, 0.0) + factor * other.wire_bytes.get(k, 0.0), 0.0
            )
        return out

    def summary(self) -> str:
        lines = []
        for k in sorted(self.counts):
            lines.append(
                f"{k:20s} n={self.counts[k]:4d} buffer={self.buffer_bytes[k]/1e6:10.1f}MB"
                f" wire={self.wire_bytes[k]/1e6:10.1f}MB"
            )
        lines.append(f"{'TOTAL':20s} wire={self.total_wire_bytes/1e6:10.1f}MB")
        return "\n".join(lines)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse partitioned HLO; sums per-chip wire bytes per collective kind."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rest = line.split(" = ", 1)[1]
        # cut metadata/backend config tails to avoid false matches
        rest = rest.split(", metadata=")[0]
        for kind in _COLLECTIVES:
            pos = rest.find(kind + "(")
            if pos < 0:
                pos = rest.find(kind + "-start(")
            if pos <= 0:
                continue
            # require the match to be the op name: preceded by whitespace
            if rest[pos - 1] not in (" ", "\t"):
                continue
            type_part = rest[:pos]
            buf = _all_shapes_bytes(type_part)
            n = _group_size(line)
            if kind == "all-reduce":
                wire = 2.0 * (n - 1) / max(n, 1) * buf
            elif kind == "all-gather":
                wire = (n - 1) / max(n, 1) * buf
            elif kind == "reduce-scatter":
                wire = float(n - 1) * buf
            elif kind == "all-to-all":
                wire = (n - 1) / max(n, 1) * buf
            else:  # collective-permute
                wire = float(buf)
            stats.counts[kind] += 1
            stats.buffer_bytes[kind] += buf
            stats.wire_bytes[kind] += wire
            break
    return stats


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    collective_wire_bytes_per_chip: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    n_chips: int,
    *,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> RooflineTerms:
    """Three-term roofline (§Roofline contract).

    compute   = HLO_FLOPs / (chips × peak)   [= flops_pd / peak]
    memory    = HLO_bytes / (chips × HBM_bw) [= bytes_pd / bw]
    collective= wire_bytes_pd / link_bw
    """
    return RooflineTerms(
        compute_s=flops_per_device / peak_flops,
        memory_s=bytes_per_device / hbm_bw,
        collective_s=wire_bytes_per_device / ici_bw,
        hlo_flops_global=flops_per_device * n_chips,
        hlo_bytes_global=bytes_per_device * n_chips,
        collective_wire_bytes_per_chip=wire_bytes_per_device,
        n_chips=n_chips,
    )
