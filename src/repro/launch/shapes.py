"""ShapeDtypeStruct input specs for every (architecture × input shape).

``input_specs(cfg, shape)`` returns abstract stand-ins (no device allocation)
for the step function that the shape's kind lowers:

  train_4k     -> train_step   {tokens, labels [, patch_embeds | audio_frames]}
  prefill_32k  -> prefill_step {tokens [, patch_embeds | audio_frames]}
  decode_32k   -> decode_step  {token, pos, cache}
  long_500k    -> decode_step  (sub-quadratic archs; dense archs use the
                                sliding-window variant — see variant_for)

For VLM the text length is ``seq_len − n_patches`` so the total processed
sequence equals the assigned seq_len exactly; for audio the encoder frames
are the stub frontend's output (B, 1500, d) and seq_len applies to the
decoder tokens.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib

SDS = jax.ShapeDtypeStruct

# Sliding-window width used for the long_500k variant of full-attention archs.
LONG_CONTEXT_WINDOW = 8192

# Archs that cannot run long_500k at all (full-attn enc-dec decoder; the
# cross-attention source is fixed 1500 frames and a 500k autoregressive
# transcript has no modeling meaning). Recorded as a skip in DESIGN.md.
LONG_500K_SKIPS = ("whisper-large-v3",)

# Archs that are natively sub-quadratic (no variant needed for long_500k).
NATIVE_SUBQUADRATIC = ("mamba2-1.3b", "recurrentgemma-9b")


def variant_for(cfg: ModelConfig, shape: ShapeConfig) -> Optional[ModelConfig]:
    """Config actually lowered for (arch, shape); None => skip (documented)."""
    if shape.name != "long_500k":
        return cfg
    if cfg.name in LONG_500K_SKIPS:
        return None
    if cfg.arch_type in ("ssm", "hybrid"):
        return cfg  # natively sub-quadratic decode
    # dense/moe/vlm: sliding-window variant (ring-buffer KV cache)
    return cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)


def _tok(b: int, s: int) -> SDS:
    return SDS((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the step lowered by ``shape.kind``."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        specs: Dict[str, Any] = {}
        if cfg.arch_type == "vlm":
            s_text = S - cfg.n_patches
            specs["tokens"] = _tok(B, s_text)
            specs["labels"] = _tok(B, s_text)
            specs["patch_embeds"] = SDS((B, cfg.n_patches, cfg.d_model), dt)
        elif cfg.arch_type == "audio":
            specs["tokens"] = _tok(B, S)
            specs["labels"] = _tok(B, S)
            specs["audio_frames"] = SDS((B, cfg.n_audio_frames, cfg.d_model), dt)
        else:
            specs["tokens"] = _tok(B, S)
            specs["labels"] = _tok(B, S)
        return {"batch": specs}

    if shape.kind == "prefill":
        specs = {}
        if cfg.arch_type == "vlm":
            specs["tokens"] = _tok(B, S - cfg.n_patches)
            specs["patch_embeds"] = SDS((B, cfg.n_patches, cfg.d_model), dt)
        elif cfg.arch_type == "audio":
            specs["tokens"] = _tok(B, S)
            specs["audio_frames"] = SDS((B, cfg.n_audio_frames, cfg.d_model), dt)
        else:
            specs["tokens"] = _tok(B, S)
        return {"batch": specs}

    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: model_lib.make_cache(cfg, B, S))
        return {
            "cache": cache,
            "token": _tok(B, 1),
            "pos": SDS((), jnp.int32),
        }

    raise ValueError(shape.kind)


def abstract_params(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of the full model parameters (no allocation)."""
    return jax.eval_shape(lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))
