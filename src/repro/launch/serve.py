"""Serving driver: batched prefill + autoregressive decode on a mesh.

Demonstrates the inference path of every architecture family, including
ring-buffer KV caches, SSM/RG-LRU state decode and sliding windows.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b-smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          greedy: bool = True, verbose: bool = True) -> jax.Array:
    cfg = get_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)
    fed = {"tokens": prompts}
    if cfg.arch_type == "vlm":
        fed["patch_embeds"] = 0.1 * jax.random.normal(
            rng, (batch, cfg.n_patches, cfg.d_model)
        )
    if cfg.arch_type == "audio":
        fed["audio_frames"] = 0.1 * jax.random.normal(
            rng, (batch, cfg.n_audio_frames, cfg.d_model)
        )
    off = cfg.n_patches if cfg.arch_type == "vlm" else 0

    t0 = time.time()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_capacity=off + prompt_len + gen))
    logits, cache = prefill(params, fed)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(off + prompt_len + i))
        tok = (jnp.argmax(logits, -1) if greedy
               else jax.random.categorical(jax.random.fold_in(rng, i), logits)
               )[:, None].astype(jnp.int32)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    if verbose:
        print(f"[{arch}] prefill({batch}x{prompt_len}): {t_prefill*1e3:.1f}ms  "
              f"decode {gen-1} steps: {t_decode*1e3:.1f}ms "
              f"({(gen-1)*batch/max(t_decode,1e-9):.1f} tok/s)")
        print("generated:", toks[0].tolist())
    return toks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
