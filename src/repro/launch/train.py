"""Federated training driver on a jax mesh (the datacenter path).

Phase 1 (FED3R, Algorithm 1): statistics pass over packed client shards
through the accumulation engine — ONE jitted scan, backbone features
batched per shard.  Solve → temperature-calibrate → install the classifier.

Phase 2 (FED3R+FT, §4.4): federated fine-tuning through the batched cohort
round engine (:mod:`repro.federated.round_engine`) — the sampled cohort is
packed into stacked ``(cohort, n_steps, batch)`` token arrays and the WHOLE
round (vmapped local updates over the cohort dim, on-device weighted
aggregation, server optimizer step) runs as one jitted dispatch, with the
cohort dim sharded over the mesh's data axes (the weighted-delta
contraction lowers to the hierarchical all-reduce that IS the server
aggregation).  The full :class:`ServerState` — backbone+head params,
optimizer buffers, round index — checkpoints every eval; ``--resume``
continues from the latest snapshot and reproduces the uninterrupted run
(cohorts and shuffles are pure functions of the round index).

On this CPU container the driver runs reduced configs on the host mesh;
on TPU the same code takes ``--mesh pod|multipod``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch fed3r-mnv2-proxy-smoke \
      --rounds 30 --ft-strategy feat [--algorithm fedavg] [--resume]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, load_pytree, save_pytree
from repro.configs import get_config
from repro.core import calibration, fed3r
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_token_dataset
from repro.data.pipeline import pack_client_shards, pack_cohort_batches
from repro.federated.algorithms import make_algorithm, server_state_from_tree
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.federated.round_engine import RoundConfig, RoundEngine
from repro.federated.sampling import sample_round
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_cls_per_example_loss
from repro.models import build_model
from repro.sharding import compat

_FT_SEED = 3  # phase-2 sampling/shuffle seed (pure function of the round)


def run(
    arch: str,
    *,
    n_classes: int = 16,
    n_clients: int = 40,
    clients_per_round: int = 8,
    rounds: int = 30,
    seq_len: int = 32,
    n_samples: int = 2048,
    lr: float = 0.05,
    local_batch_size: int = 64,
    algorithm: str = "fedavg",
    ft_strategy: str = "feat",
    use_fed3r_init: bool = True,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    compat.set_mesh(mesh)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    ds = make_token_dataset(jax.random.PRNGKey(1), n_samples, seq_len,
                            cfg.vocab_size, n_classes)
    parts = dirichlet_partition(
        np.random.default_rng(2), np.asarray(ds.labels), n_clients, alpha=0.0
    )
    n_test = n_samples // 5
    test_tokens, test_labels = ds.tokens[:n_test], ds.labels[:n_test]
    tokens_np, labels_np = np.asarray(ds.tokens), np.asarray(ds.labels)

    log = {"fed3r_acc": None, "ft_acc": [], "rounds": []}

    # Resuming from a full-state snapshot makes phase 1 dead work: the
    # loaded ServerState overwrites whatever head it would produce.
    resume_path = latest_checkpoint(ckpt_dir) if (resume and ckpt_dir) else None

    # ---- phase 1: FED3R statistics pass -------------------------------------
    W_head = None
    if use_fed3r_init and resume_path is None:
        t0 = time.time()
        # Every client contributes exactly once.  The engine packs clients
        # into shards and folds them in ONE jitted scan (backbone feature
        # extraction batched per shard) — the datacenter-scale replacement
        # for the former per-client stats_step dispatch loop.
        engine = AccumulationEngine(
            EngineConfig(n_classes=n_classes),
            feature_fn=lambda p, toks: model.extract_features(
                p, {"tokens": toks}
            ),
        )
        packed = pack_client_shards(
            [(tokens_np[parts[k]], labels_np[parts[k]]) for k in range(n_clients)],
            clients_per_shard=clients_per_round,
        )
        acc = engine.accumulate(engine.init(cfg.d_feat), packed, params)
        stats = acc.stats
        W = fed3r.solve(stats, 0.01)
        feats_test = model.extract_features(params, {"tokens": test_tokens})
        acc = float(fed3r.accuracy(W, feats_test, test_labels))
        scores = fed3r.predict(W, model.extract_features(params, {"tokens": ds.tokens[n_test:n_test+512]}))
        temp, _ = calibration.calibrate_temperature(scores, ds.labels[n_test:n_test+512])
        W_head = calibration.fold_temperature(W, temp)
        log["fed3r_acc"] = acc
        if verbose:
            print(f"[fed3r] classifier in {n_clients} client visits "
                  f"({time.time()-t0:.1f}s)  acc={acc:.4f}  T={float(temp):.2f}")

    # ---- phase 2: federated fine-tuning on the cohort round engine ----------
    head = {"W": W_head if W_head is not None
            else 0.01 * jax.random.normal(rng, (cfg.d_feat, n_classes)),
            "b": jnp.zeros((n_classes,), jnp.float32)}
    full = {"backbone": params, "head": head}

    freeze = {
        "backbone": jax.tree.map(
            lambda _: 0.0 if ft_strategy == "lp" else 1.0, params
        ),
        "head": jax.tree.map(
            lambda _: 0.0 if ft_strategy == "feat" else 1.0, head
        ),
    }

    algo = make_algorithm(algorithm)
    round_engine = RoundEngine(
        RoundConfig(
            algo=algo, client_lr=lr, n_total_clients=n_clients,
        ),
        make_cls_per_example_loss(cfg),
        freeze,
    )
    if resume_path is not None:
        state = server_state_from_tree(load_pytree(resume_path))
        start_round = int(state.round)
        if verbose:
            print(f"[ft:{ft_strategy}] resuming from {resume_path} (round {start_round})")
    else:
        state = round_engine.init(full)
        start_round = 0

    @jax.jit
    def evaluate(p):
        feats = model.extract_features(p["backbone"], {"tokens": test_tokens})
        logits = feats @ p["head"]["W"] + p["head"]["b"]
        return jnp.mean((jnp.argmax(logits, -1) == test_labels).astype(jnp.float32))

    max_nk = max(len(parts[k]) for k in range(n_clients))
    n_batches = -(-max_nk // local_batch_size)
    for rnd in range(start_round, rounds):
        chosen = sample_round(n_clients, clients_per_round, rnd, seed=_FT_SEED)
        cohort = pack_cohort_batches(
            [(tokens_np[parts[int(k)]], labels_np[parts[int(k)]]) for k in chosen],
            local_batch_size, n_batches, client_ids=chosen, seed=(_FT_SEED, rnd),
        )
        state = round_engine.step(state, cohort)  # ONE dispatch per round
        if (rnd + 1) % 5 == 0 or rnd == rounds - 1:
            acc = float(evaluate(state.params))
            log["rounds"].append(rnd + 1)
            log["ft_acc"].append(acc)
            if verbose:
                print(f"[ft:{ft_strategy}] round {rnd+1:4d}  acc={acc:.4f}")
            if ckpt_dir:
                # round-resumable: the FULL server state, not just the head
                save_pytree(os.path.join(ckpt_dir, f"ckpt_{rnd+1}.npz"), state)
    return log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fed3r-mnv2-proxy-smoke")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--per-round", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--local-batch", type=int, default=64)
    ap.add_argument("--algorithm", default="fedavg",
                    choices=["fedavg", "fedavgm", "fedprox", "scaffold",
                             "fedadam", "fedyogi"])
    ap.add_argument("--ft-strategy", default="feat", choices=["full", "lp", "feat"])
    ap.add_argument("--no-fed3r-init", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    run(
        args.arch, rounds=args.rounds, n_clients=args.clients,
        clients_per_round=args.per_round, seq_len=args.seq_len,
        local_batch_size=args.local_batch, algorithm=args.algorithm,
        ft_strategy=args.ft_strategy, use_fed3r_init=not args.no_fed3r_init,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
    )


if __name__ == "__main__":
    main()
