"""Federated training driver on a jax mesh (the datacenter path).

Phase 1 (FED3R, Algorithm 1): statistics pass over client-sharded batches —
the ZᵀZ/ZᵀY contraction over the data axis IS the server aggregation
(all-reduce).  Solve → temperature-calibrate → install the classifier.

Phase 2 (FED3R+FT, §4.4): federated fine-tuning rounds with ``train_step``
(FedAvg-style local steps; freeze mask per FT strategy).

On this CPU container the driver runs reduced configs on the host mesh;
on TPU the same code takes ``--mesh pod|multipod``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch fed3r-mnv2-proxy-smoke \
      --rounds 30 --ft-strategy feat
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.core import calibration, fed3r
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import make_token_dataset
from repro.data.pipeline import pack_client_shards
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.sharding import compat


def run(
    arch: str,
    *,
    n_classes: int = 16,
    n_clients: int = 40,
    clients_per_round: int = 8,
    rounds: int = 30,
    seq_len: int = 32,
    n_samples: int = 2048,
    lr: float = 0.05,
    ft_strategy: str = "feat",
    use_fed3r_init: bool = True,
    ckpt_dir: Optional[str] = None,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    compat.set_mesh(mesh)

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    ds = make_token_dataset(jax.random.PRNGKey(1), n_samples, seq_len,
                            cfg.vocab_size, n_classes)
    parts = dirichlet_partition(
        np.random.default_rng(2), np.asarray(ds.labels), n_clients, alpha=0.0
    )
    n_test = n_samples // 5
    test_tokens, test_labels = ds.tokens[:n_test], ds.labels[:n_test]

    log = {"fed3r_acc": None, "ft_acc": [], "rounds": []}

    # ---- phase 1: FED3R statistics pass -------------------------------------
    W_head = None
    if use_fed3r_init:
        t0 = time.time()
        # Every client contributes exactly once.  The engine packs clients
        # into shards and folds them in ONE jitted scan (backbone feature
        # extraction batched per shard) — the datacenter-scale replacement
        # for the former per-client stats_step dispatch loop.
        engine = AccumulationEngine(
            EngineConfig(n_classes=n_classes),
            feature_fn=lambda p, toks: model.extract_features(
                p, {"tokens": toks}
            ),
        )
        tokens_np, labels_np = np.asarray(ds.tokens), np.asarray(ds.labels)
        packed = pack_client_shards(
            [(tokens_np[parts[k]], labels_np[parts[k]]) for k in range(n_clients)],
            clients_per_shard=clients_per_round,
        )
        acc = engine.accumulate(engine.init(cfg.d_feat), packed, params)
        stats = acc.stats
        W = fed3r.solve(stats, 0.01)
        feats_test = model.extract_features(params, {"tokens": test_tokens})
        acc = float(fed3r.accuracy(W, feats_test, test_labels))
        scores = fed3r.predict(W, model.extract_features(params, {"tokens": ds.tokens[n_test:n_test+512]}))
        temp, _ = calibration.calibrate_temperature(scores, ds.labels[n_test:n_test+512])
        W_head = calibration.fold_temperature(W, temp)
        log["fed3r_acc"] = acc
        if verbose:
            print(f"[fed3r] classifier in {n_clients} client visits "
                  f"({time.time()-t0:.1f}s)  acc={acc:.4f}  T={float(temp):.2f}")

    # ---- phase 2: federated fine-tuning --------------------------------------
    head = {"W": W_head if W_head is not None
            else 0.01 * jax.random.normal(rng, (cfg.d_feat, n_classes)),
            "b": jnp.zeros((n_classes,), jnp.float32)}
    full = {"backbone": params, "head": head}

    def cls_loss(p, batch):
        feats = model.extract_features(p["backbone"], {"tokens": batch["tokens"]})
        logits = feats @ p["head"]["W"] + p["head"]["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, batch["class_labels"][:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    freeze = {
        "backbone": jax.tree.map(
            lambda _: 0.0 if ft_strategy == "lp" else 1.0, params
        ),
        "head": jax.tree.map(
            lambda _: 0.0 if ft_strategy == "feat" else 1.0, head
        ),
    }

    @jax.jit
    def local_step(p, batch):
        grads = jax.grad(cls_loss)(p, batch)
        return jax.tree.map(lambda w, g, f: w - lr * g * f, p, grads, freeze)

    @jax.jit
    def evaluate(p):
        feats = model.extract_features(p["backbone"], {"tokens": test_tokens})
        logits = feats @ p["head"]["W"] + p["head"]["b"]
        return jnp.mean((jnp.argmax(logits, -1) == test_labels).astype(jnp.float32))

    np_rng = np.random.default_rng(3)
    for rnd in range(rounds):
        chosen = np_rng.choice(n_clients, size=clients_per_round, replace=False)
        deltas, weights = [], []
        for k in chosen:
            idx = parts[k]
            batch = {"tokens": ds.tokens[idx], "class_labels": ds.labels[idx]}
            local = local_step(full, batch)
            deltas.append(jax.tree.map(lambda a, b: a - b, local, full))
            weights.append(float(len(idx)))
        wsum = sum(weights)
        avg = jax.tree.map(
            lambda *ds_: sum(w * d for w, d in zip(weights, ds_)) / wsum, *deltas
        )
        full = jax.tree.map(lambda p, d: p + d, full, avg)
        if (rnd + 1) % 5 == 0 or rnd == rounds - 1:
            acc = float(evaluate(full))
            log["rounds"].append(rnd + 1)
            log["ft_acc"].append(acc)
            if verbose:
                print(f"[ft:{ft_strategy}] round {rnd+1:4d}  acc={acc:.4f}")
            if ckpt_dir:
                save_pytree(os.path.join(ckpt_dir, f"ckpt_{rnd+1}.npz"),
                            {"head": full["head"], "round": rnd + 1})
    return log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fed3r-mnv2-proxy-smoke")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--per-round", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--ft-strategy", default="feat", choices=["full", "lp", "feat"])
    ap.add_argument("--no-fed3r-init", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    run(
        args.arch, rounds=args.rounds, n_clients=args.clients,
        clients_per_round=args.per_round, seq_len=args.seq_len,
        ft_strategy=args.ft_strategy, use_fed3r_init=not args.no_fed3r_init,
        ckpt_dir=args.ckpt_dir,
    )


if __name__ == "__main__":
    main()
