"""Analytic MODEL_FLOPS (6·N·D family) for the roofline usefulness ratio."""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.configs.base import ModelConfig, ShapeConfig


def param_breakdown(cfg: ModelConfig, abstract_params: Any) -> Dict[str, float]:
    total = sum(float(l.size) for l in jax.tree.leaves(abstract_params))
    embed = cfg.padded_vocab * cfg.d_model
    lm_head = 0 if cfg.tie_embeddings else cfg.padded_vocab * cfg.d_model
    dec_pos = cfg.n_positions * cfg.d_model if cfg.arch_type == "audio" else 0
    backbone = total - embed - lm_head - dec_pos

    inactive = 0.0
    if cfg.arch_type == "moe":
        per_expert = 3 * cfg.d_model * cfg.d_expert  # swiglu expert
        inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return {
        "total": total,
        "backbone": backbone,
        "backbone_active": backbone - inactive,
        "embed": embed + lm_head + dec_pos,
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig, abstract_params: Any) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) + unembedding matmul."""
    pb = param_breakdown(cfg, abstract_params)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    head = mult * cfg.d_model * cfg.vocab_size * (
        tokens if shape.kind != "prefill" else shape.global_batch
    )  # prefill emits last-position logits only
    return mult * pb["backbone_active"] * tokens + head
