"""Multi-tenant head serving: per-client closed-form heads over a live stream.

The serving-side driver of the personalization engine
(:mod:`repro.federated.personalization`), composed with the streaming
arrival engine, in two interchangeable execution modes (``--engine``):

* ``lru`` — the synchronous per-burst path: a :class:`HeadCache` (LRU,
  keyed by client id) holds solved heads; a query burst is grouped by
  tenant, cache misses are packed into ONE
  :class:`repro.data.pipeline.PackedPersonalCohort` and solved in ONE
  batched dispatch, and tenants the server holds no data for are served
  the GLOBAL head (α = 0 ≡ ``factored_solution``).  Invalidation is a
  policy: ``strict`` dirty-marks the whole cache on every absorb (every
  head's global operands moved), ``segmented`` invalidates only tenants
  whose OWN statistics arrived — partial re-personalization: the next
  burst re-solves exactly those heads, resident heads tolerate global
  staleness until their tenant is touched.
* ``slots`` — the continuous-batching slot engine
  (:class:`repro.launch.serving_engine.ServingEngine`): S fixed
  device-resident head slots, absorb/solve/serve decomposed into one
  dispatch each, admission control and in-flight batching around them.
  This driver is then a thin compatibility shim producing the same
  report/log shape.

Query traffic is Zipf popularity-skewed by default
(:func:`repro.federated.arrivals.zipf_traffic` — the production
cross-device regime); ``--traffic uniform`` restores the flat draw.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_heads --waves 24 --segment 6 \
      --queries 48 --cache 32 --engine slots
"""
from __future__ import annotations

import argparse
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.data.pipeline import (
    FederatedDataset,
    make_federated_features,
    pack_personal_cohort,
)
from repro.federated.arrivals import pack_schedule, poisson_schedule, zipf_traffic
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.federated.telemetry import get_telemetry


class HeadCache:
    """LRU cache of per-tenant heads, versioned against the global stream.

    Two invalidation policies:

    * strict (``segmented=False``, the default): :meth:`advance` bumps the
      cache-wide version, dirty-marking EVERY live entry at once — any
      absorb moved the global (L, b) under every cached head.  O(1), but a
      single cold arrival invalidates the whole hot working set.
    * version-segmented (``segmented=True``): each entry is additionally
      stamped with its tenant's OWN statistics version, and
      ``advance(touched=[...])`` bumps only the touched tenants — an
      entry is stale iff its own tenant's stats changed since it was
      solved, so an absorb invalidates exactly the tenants it carried and
      the next burst re-solves ONLY those heads (partial
      re-personalization).  Untouched entries keep serving heads solved
      against the slightly older global state — the staleness the
      streaming engine's refresh policy already trades on.

    Eviction is least-recently-USED either way: every hit refreshes
    recency, so hot tenants survive cold sweeps.  Staleness is checked on
    access and stale entries are dropped then (lazy, never eager).
    """

    def __init__(self, capacity: int, *, segmented: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.segmented = segmented
        self.version = 0  # the global stream clock this cache is valid for
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0
        self.lru_evictions = 0
        # cid -> (W, global_version_at_solve, tenant_version_at_solve)
        self._entries: "OrderedDict[int, Tuple[jax.Array, int, int]]" = (
            OrderedDict()
        )
        self._tenant_versions: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def tenant_version(self, client_id: int) -> int:
        """The current stats version of one tenant (0 until first touched)."""
        return self._tenant_versions.get(int(client_id), 0)

    def advance(self, touched: Optional[Iterable[int]] = None) -> None:
        """The global state absorbed arrivals: bump the stream version and —
        under the segmented policy — the stats versions of the ``touched``
        tenants.  ``touched=None`` means the arrival set is unknown, which
        degrades to whole-cache invalidation in either policy."""
        self.version += 1
        if not self.segmented:
            return
        if touched is None:  # unknown arrivals: invalidate every live entry
            for cid in self._entries:
                self._tenant_versions[cid] = self.tenant_version(cid) + 1
        else:
            for cid in touched:
                cid = int(cid)
                self._tenant_versions[cid] = self.tenant_version(cid) + 1

    def _stale(self, client_id: int, entry: Tuple[jax.Array, int, int]) -> bool:
        _, global_v, tenant_v = entry
        if self.segmented:
            return tenant_v != self.tenant_version(client_id)
        return global_v != self.version

    def get(self, client_id: int) -> Optional[jax.Array]:
        entry = self._entries.get(client_id)
        if entry is None:
            self.misses += 1
            return None
        if self._stale(client_id, entry):
            del self._entries[client_id]  # lazily drop the dirty entry
            self.stale_evictions += 1
            self.misses += 1
            return None
        self._entries.move_to_end(client_id)
        self.hits += 1
        return entry[0]

    def put(self, client_id: int, W: jax.Array) -> None:
        self._entries[client_id] = (
            W, self.version, self.tenant_version(client_id)
        )
        self._entries.move_to_end(client_id)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.lru_evictions += 1


class HeadServer:
    """Streaming global state + LRU-cached personalized heads per tenant.

    ``dataset`` is the server's per-tenant data store (the statistics a
    tenant's head is personalized with); tenants outside it fall back to
    the global head.  ``cohort_round_to`` buckets the per-burst miss count
    so the batched solve retraces only per bucket, not per distinct count.
    ``invalidation`` selects the :class:`HeadCache` policy (``"strict"``
    dirty-sweeps everything per absorb; ``"segmented"`` invalidates only
    tenants whose own statistics arrived).
    """

    def __init__(
        self,
        stream: StreamingEngine,
        pers: PersonalizationEngine,
        dataset: FederatedDataset,
        *,
        cache_capacity: int = 256,
        cohort_round_to: int = 8,
        invalidation: str = "strict",
    ):
        if invalidation not in ("strict", "segmented"):
            raise ValueError(f"unknown invalidation policy: {invalidation!r}")
        self.stream = stream
        self.pers = pers
        self.dataset = dataset
        self.cache = HeadCache(
            cache_capacity, segmented=(invalidation == "segmented")
        )
        self.cohort_round_to = cohort_round_to
        # dataset-global sample capacity: every burst's cohort pads to the
        # same width, so the batched solve traces once per cohort bucket
        # (see pack_client_shards' max_n contract), not per miss set
        self.max_n = int(dataset.client_sizes().max())
        self.state = None  # StreamState, set by init()/absorb()
        self.global_queries = 0
        self.personalized_queries = 0

    def init(self, d: int) -> None:
        self.state = self.stream.init(d)

    def absorb(self, packed) -> None:
        """Fold an arrival segment (one dispatch) and dirty-mark the cache —
        every entry under the strict policy, only the arrived tenants
        under the segmented one."""
        self.state, _ = self.stream.absorb(self.state, packed)
        touched = np.unique(np.asarray(packed.client_ids))
        self.cache.advance(touched=touched[touched >= 0])

    def _solve_missing(self, missing: List[int]) -> Dict[int, jax.Array]:
        """Solve all cache misses of one burst in ONE batched dispatch."""
        clients = []
        for cid in missing:
            cd = self.dataset.client(cid)
            clients.append((np.asarray(cd.features), np.asarray(cd.labels)))
        pad = self.cohort_round_to
        cohort = -(-len(missing) // pad) * pad
        packed = pack_personal_cohort(
            clients, client_ids=missing, cohort_size=cohort, max_n=self.max_n
        )
        heads = self.pers.solve_heads(self.state.factored, packed)
        ids = np.asarray(heads.client_ids)
        out: Dict[int, jax.Array] = {}
        for slot, cid in enumerate(ids):
            if int(cid) >= 0:
                out[int(cid)] = heads.W[slot]
        return out

    def query(
        self,
        client_ids: Sequence[int],
        xs: np.ndarray,  # (Q, d) feature rows, one per query
    ) -> Tuple[jax.Array, dict]:
        """Answer a batched heterogeneous query burst with per-tenant heads.

        Returns (scores (Q, C), report).  Per burst: each unique tenant
        probes the cache ONCE, ALL misses with server-side data solve in
        one batched dispatch, unknown tenants get the global head, and the
        whole burst is answered by one batched matmul over the per-query
        heads.  Freshly solved heads serve this burst directly (LRU
        eviction of a just-inserted head cannot downgrade an in-flight
        query to the global mode).  The report counts per-mode traffic —
        the serving analogue of the staleness trace.
        """
        resolved: Dict[int, jax.Array] = {}
        wanted: List[int] = []
        for cid in client_ids:
            cid = int(cid)
            known = 0 <= cid < self.dataset.n_clients
            if not known or cid in resolved or cid in wanted:
                continue
            W = self.cache.get(cid)  # the burst's ONE probe of this tenant
            if W is None:
                wanted.append(cid)
            else:
                resolved[cid] = W
        fresh = self._solve_missing(wanted) if wanted else {}
        for cid, W in fresh.items():
            self.cache.put(cid, W)  # for future bursts; this burst serves
        resolved.update(fresh)  # from `resolved` even if LRU evicted it

        # stack each distinct head ONCE (row 0 = global) and gather per
        # query device-side: a burst repeating hot tenants moves U unique
        # heads, not Q copies, and the whole burst scores in one matmul
        rows: Dict[int, int] = {}
        uniq = [self.stream.classifier(self.state)]
        idx, modes = [], []
        for cid in client_ids:
            W = resolved.get(int(cid))
            if W is None:
                idx.append(0)
                modes.append("global")
                self.global_queries += 1
            else:
                row = rows.setdefault(int(cid), len(uniq))
                if row == len(uniq):
                    uniq.append(W)
                idx.append(row)
                modes.append("per-tenant")
                self.personalized_queries += 1
        scores = jnp.einsum(
            "qd,qdc->qc",
            jnp.asarray(np.asarray(xs), jnp.float32),
            jnp.stack(uniq)[jnp.asarray(idx, jnp.int32)],
        )
        report = {
            "queries": len(modes),
            "per_tenant": modes.count("per-tenant"),
            "global": modes.count("global"),
            "solved_now": len(fresh),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_version": self.cache.version,
            "modes": modes,
        }
        return scores, report


def _make_traffic(
    traffic: str,
    n_tenants: int,
    n_queries: int,
    zipf_exponent: float,
    seed: int,
) -> np.ndarray:
    """The demo's replayable query-traffic trace: tenant id per query."""
    if traffic == "zipf":
        return zipf_traffic(
            n_tenants, n_queries, exponent=zipf_exponent, seed=seed
        )
    if traffic == "uniform":
        rng = np.random.default_rng(seed)
        return rng.integers(0, n_tenants, size=n_queries).astype(np.int64)
    raise ValueError(f"unknown traffic model: {traffic!r}")


def serve_heads(
    n_waves: int = 24,
    segment: int = 6,
    rate: float = 4.0,
    queries_per_burst: int = 48,
    bursts_per_segment: int = 2,  # >1 ⇒ the cache can actually hit between absorbs
    cache_capacity: int = 32,
    n_clients: int = 64,
    d: int = 64,
    n_classes: int = 10,
    ridge_lambda: float = 1e-2,
    alpha_grid: Tuple[float, ...] = (0.0, 0.5, 1.0, 2.0, 4.0),
    engine: str = "lru",
    invalidation: str = "strict",
    traffic: str = "zipf",
    zipf_exponent: float = 1.1,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Arrival stream + per-tenant query bursts; returns the serving log.

    ``engine="lru"`` runs the synchronous per-burst :class:`HeadServer`;
    ``engine="slots"`` runs the continuous-batching slot engine behind the
    same loop and log shape (``cache_capacity`` then sizes the tenant
    slots; the pinned global slot is extra).
    """
    if engine not in ("lru", "slots"):
        raise ValueError(f"unknown serving engine: {engine!r}")
    fed, test = make_federated_features(
        seed=seed, n=8000, d=d, n_classes=n_classes, n_clients=n_clients,
        alpha=0.1, noise=7.0,
    )
    schedule = poisson_schedule(fed.n_clients, n_waves, rate, seed=seed)
    packed = pack_schedule(fed, schedule)

    if engine == "slots":
        from repro.launch.serving_engine import ServingConfig, ServingEngine

        server = ServingEngine(
            ServingConfig(
                n_classes=n_classes, ridge_lambda=ridge_lambda,
                n_slots=cache_capacity + 1,  # + the pinned global slot
                invalidation=(
                    "segmented" if invalidation == "segmented" else "strict"
                ),
                alpha_grid=alpha_grid,
            ),
            fed,
        )
    else:
        server = HeadServer(
            StreamingEngine(StreamConfig(
                n_classes=n_classes, ridge_lambda=ridge_lambda,
            )),
            PersonalizationEngine(PersonalizeConfig(
                n_classes=n_classes, alpha_grid=alpha_grid,
            )),
            fed,
            cache_capacity=cache_capacity,
            invalidation=invalidation,
        )
    server.init(d)

    n_bursts = -(-packed.n_waves // segment) * bursts_per_segment
    trace = _make_traffic(
        traffic, fed.n_clients, n_bursts * queries_per_burst,
        zipf_exponent, seed + 17,
    )
    rng = np.random.default_rng(seed + 17)
    log: dict = {
        "wave": [], "per_tenant": [], "global": [], "solved_now": [],
        "hit_rate": [], "acc_personal": [],
    }
    t0 = time.perf_counter()
    if verbose:
        print(f"engine={engine} invalidation={invalidation} traffic={traffic} "
              f"tenants={fed.n_clients} cache={cache_capacity} "
              f"waves={packed.n_waves} segment={segment} "
              f"alpha_grid={alpha_grid}")
        print("wave | mode (tenant/global) | solved | cum hit rate | "
              "acc on tenant-local queries")
    burst = 0
    for lo in range(0, packed.n_waves, segment):
        server.absorb(packed.slice_waves(lo, min(lo + segment, packed.n_waves)))
        for _ in range(bursts_per_segment):
            # a burst of tenant-attributed queries: each query is a sample
            # from the querying tenant's OWN distribution (the personalized
            # case); bursts after the first can hit the per-segment cache
            cids = trace[burst * queries_per_burst:(burst + 1) * queries_per_burst]
            burst += 1
            qx, qy = [], []
            for cid in cids:
                cd = fed.client(int(cid))
                i = int(rng.integers(0, cd.n))
                qx.append(cd.features[i])
                qy.append(cd.labels[i])
            scores, rep = server.query(cids, np.stack(qx))
            acc = float(jnp.mean(
                (jnp.argmax(scores, axis=-1) == jnp.asarray(np.asarray(qy))
                 ).astype(jnp.float32)
            ))
            if engine == "slots":
                hits, misses = server.hits, server.misses
            else:
                hits, misses = server.cache.hits, server.cache.misses
            hit_rate = hits / max(hits + misses, 1)
            log["wave"].append(int(server.state.wave))
            log["per_tenant"].append(rep["per_tenant"])
            log["global"].append(rep["global"])
            log["solved_now"].append(rep["solved_now"])
            log["hit_rate"].append(hit_rate)
            log["acc_personal"].append(acc)
            if verbose:
                print(f"{int(server.state.wave):4d} | {rep['per_tenant']:6d} /"
                      f"{rep['global']:6d} | {rep['solved_now']:6d} | "
                      f"{hit_rate:12.3f} | {acc:.4f}")
    acc_global = float(fed3r.accuracy(
        server.stream.classifier(server.state),
        jnp.asarray(test.features), jnp.asarray(test.labels),
    ))
    log["acc_global_test"] = acc_global
    if engine == "slots":
        log["stream_dispatches"] = server.absorb_dispatches
        log["personalize_dispatches"] = server.solve_dispatches
        log["serve_dispatches"] = server.serve_dispatches
        log["stage_s"] = dict(server.stage_s)
        log["cache"] = {
            "hits": server.hits, "misses": server.misses,
            "stale_evictions": 0,  # slots re-solve stale heads in place
            "lru_evictions": server.table.evictions,
        }
    else:
        log["stream_dispatches"] = server.stream.dispatches
        log["personalize_dispatches"] = server.pers.dispatches
        log["cache"] = {
            "hits": server.cache.hits, "misses": server.cache.misses,
            "stale_evictions": server.cache.stale_evictions,
            "lru_evictions": server.cache.lru_evictions,
        }
    log["wall_s"] = time.perf_counter() - t0
    get_telemetry().gauge(
        "driver_wall_seconds", driver="serve_heads", engine=engine
    ).set(log["wall_s"])
    if verbose:
        c = log["cache"]
        print(f"global-head test acc={acc_global:.4f}  "
              f"stream dispatches={log['stream_dispatches']}, "
              f"head-solve dispatches={log['personalize_dispatches']}")
        print(f"cache: {c['hits']} hits / {c['misses']} misses "
              f"({c['stale_evictions']} stale evictions on stream advance, "
              f"{c['lru_evictions']} evictions), {log['wall_s']:.2f}s")
    return log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=24)
    ap.add_argument("--segment", type=int, default=6)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--bursts", type=int, default=2,
                    help="query bursts per absorbed segment")
    ap.add_argument("--cache", type=int, default=32)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--ridge-lambda", type=float, default=1e-2)
    ap.add_argument("--engine", choices=("lru", "slots"), default="lru",
                    help="synchronous LRU path vs continuous-batching slots")
    ap.add_argument("--invalidation", choices=("strict", "segmented"),
                    default="strict",
                    help="absorb invalidates everything vs only arrived tenants")
    ap.add_argument("--traffic", choices=("zipf", "uniform"), default="zipf")
    ap.add_argument("--zipf-exponent", type=float, default=1.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve_heads(
        n_waves=args.waves, segment=args.segment, rate=args.rate,
        queries_per_burst=args.queries, bursts_per_segment=args.bursts,
        cache_capacity=args.cache,
        n_clients=args.clients, d=args.d, n_classes=args.classes,
        ridge_lambda=args.ridge_lambda, engine=args.engine,
        invalidation=args.invalidation, traffic=args.traffic,
        zipf_exponent=args.zipf_exponent, seed=args.seed,
    )


if __name__ == "__main__":
    main()
