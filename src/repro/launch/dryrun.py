import os
_N_DEV = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
# appended AFTER any inherited flags: XLA's duplicate-flag parsing is
# last-wins, so this is what makes the forced count override e.g. a CI
# job-level --xla_force_host_platform_device_count
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + f" --xla_force_host_platform_device_count={_N_DEV}"
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

The lines above MUST run before any jax import (jax locks the device
count on first init) — which is why this module must only ever be executed
as a script/module entry point, never imported by tests.  The simulated
host-device count defaults to the full multi-pod mesh (512) and can be
overridden with ``REPRO_DRYRUN_DEVICES=N`` for smaller scale-out dry runs
(the weak-scaling bench ``benchmarks/bench_scaleout.py`` drives the same
flag per worker subprocess at N ∈ {1, 4, 8}).

Per combination, TWO kinds of compile:

1. **Full model, scan-over-layers** — the deployment program.  Proves the
   sharding lowers and fits: ``memory_analysis()`` (per-device bytes) is
   recorded; this is the §Dry-run pass/fail artifact.
2. **Unrolled depth-1 / depth-2 variants** — exact per-layer roofline terms
   by the delta method (XLA's ``cost_analysis`` counts a while-loop body
   once, so the scanned program's numbers can't be used directly):

       total(L) = cost(L1) + (units − 1) · (cost(L2) − cost(L1))

   flops/bytes from ``cost_analysis`` (verified per-device on this backend),
   collective wire bytes parsed from the partitioned HLO.

Sharding/dtype policies (see sharding/specs.py for the fallback chains):
  * train:   fp32 params, FSDP ("data"-axis) sharding, microbatched grads;
  * prefill/decode: bf16 params; FSDP only if bf16 params > 8 GB per chip
    under 16-way tensor parallelism alone.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  python -m repro.launch.dryrun --all --multi-pod --skip-roofline
  python -m repro.launch.dryrun --arch qwen2-7b --shape prefill_32k --kind fed3r
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import fed3r
from repro.launch import hlo_analysis, steps
from repro.launch.flops import model_flops, param_breakdown
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    data_axes,
    make_production_mesh,
    n_chips,
)
from repro.launch.shapes import abstract_params, input_specs, variant_for
from repro.models import model as model_lib
from repro.sharding import compat
from repro.sharding.specs import batch_specs, cache_specs, param_specs, stats_specs

FED3R_N_CLASSES = 2028  # Landmarks-scale classifier head (paper Table 4)
FSDP_INFERENCE_THRESHOLD = 8e9  # bytes of bf16 params per chip under TP-only
FSDP_TRAIN_THRESHOLD = 12e9  # bytes of fp32 params+grads per chip under TP-only
MICROBATCH_ACT_BUDGET = 4e9  # target per-device activation bytes (train)
HBM_PER_CHIP = 16e9  # v5e


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _bf16_params(params_abs):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
        ),
        params_abs,
    )


def _depth_variants(cfg: ModelConfig) -> Tuple[ModelConfig, ModelConfig, int]:
    """(depth-1 cfg, depth-2 cfg, number of extrapolation units)."""
    if cfg.arch_type == "hybrid":
        p = len(cfg.block_pattern)
        rem = cfg.n_layers % p
        return (
            cfg.replace(n_layers=p + rem, scan_layers=False),
            cfg.replace(n_layers=2 * p + rem, scan_layers=False),
            cfg.n_superblocks,
        )
    if cfg.arch_type == "audio":
        return (
            cfg.replace(n_layers=1, n_encoder_layers=1, scan_layers=False),
            cfg.replace(n_layers=2, n_encoder_layers=2, scan_layers=False),
            cfg.n_layers,
        )
    return (
        cfg.replace(n_layers=1, scan_layers=False),
        cfg.replace(n_layers=2, scan_layers=False),
        cfg.n_layers,
    )


_ACT_FACTOR = {"dense": 6, "vlm": 6, "audio": 6, "moe": 12, "ssm": 14, "hybrid": 8}


def _pick_microbatches(cfg: ModelConfig, shape: ShapeConfig, da_size: int) -> int:
    if shape.kind != "train":
        return 1
    b_pd = max(shape.global_batch // da_size, 1)
    tokens_pd = shape.global_batch * shape.seq_len / da_size
    n_l = cfg.n_layers + cfg.n_encoder_layers
    act = n_l * tokens_pd * cfg.d_model * 2 * _ACT_FACTOR.get(cfg.arch_type, 6)
    m = 1
    while act / m > MICROBATCH_ACT_BUDGET and m < b_pd:
        m *= 2
    while b_pd % m != 0:
        m //= 2
    return max(m, 1)


def _build_jit(cfg, kind, shape, mesh, ax_sizes, da, *, num_microbatches=1):
    """Returns (jitted, abstract_args)."""
    is_train = kind == "train"
    params_abs = abstract_params(cfg)
    if not is_train:
        params_abs = _bf16_params(params_abs)
        tp_bytes = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(params_abs)
        ) / ax_sizes["model"]
        fsdp = tp_bytes > FSDP_INFERENCE_THRESHOLD
    else:
        # FSDP (params over "data" too) only when fp32 params + grad
        # accumulator exceed the TP-only budget — pure data-parallel grad
        # all-reduce is far cheaper than per-microbatch weight gathers.
        tp_bytes = sum(
            l.size * 4 for l in jax.tree.leaves(params_abs)
        ) / ax_sizes["model"]
        fsdp = 2 * tp_bytes > FSDP_TRAIN_THRESHOLD
    fsdp_axis = ("pod", "data") if "pod" in ax_sizes else "data"
    p_shard = _ns(
        mesh, param_specs(cfg, params_abs, ax_sizes, fsdp=fsdp, fsdp_axis=fsdp_axis)
    )
    specs = input_specs(cfg, shape)

    if kind == "train":
        fn = steps.make_train_step(
            cfg, lr=1e-2, num_microbatches=num_microbatches,
            param_specs=param_specs(
                cfg, params_abs, ax_sizes, fsdp=fsdp, fsdp_axis=fsdp_axis
            ),
        )
        b_shard = _ns(mesh, batch_specs(cfg, specs["batch"], da, ax_sizes))
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, b_shard),
            out_shardings=(p_shard, NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        return jitted, (params_abs, specs["batch"]), fsdp
    if kind == "prefill":
        cap = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        fn = steps.make_prefill_step(cfg, cache_capacity=cap)
        b_shard = _ns(mesh, batch_specs(cfg, specs["batch"], da, ax_sizes))
        cache_abs = jax.eval_shape(
            lambda: model_lib.make_cache(cfg, shape.global_batch, cap)
        )
        c_shard = _ns(mesh, cache_specs(cfg, cache_abs, da, ax_sizes))
        logits_shard = NamedSharding(
            mesh, P(da if shape.global_batch % _da_size(ax_sizes, da) == 0 else None,
                    "model" if cfg.vocab_size % ax_sizes["model"] == 0 else None)
        )
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )
        return jitted, (params_abs, specs["batch"]), fsdp
    if kind == "decode":
        fn = steps.make_decode_step(cfg)
        c_shard = _ns(mesh, cache_specs(cfg, specs["cache"], da, ax_sizes))
        bdiv = shape.global_batch % _da_size(ax_sizes, da) == 0
        tok_shard = NamedSharding(mesh, P(da if bdiv else None, None))
        pos_shard = NamedSharding(mesh, P())
        logits_shard = NamedSharding(
            mesh, P(da if bdiv else None,
                    "model" if cfg.vocab_size % ax_sizes["model"] == 0 else None)
        )
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(1,),
        )
        return jitted, (params_abs, specs["cache"], specs["token"], specs["pos"]), fsdp
    if kind == "fed3r":
        fn = steps.make_fed3r_stats_step(cfg, FED3R_N_CLASSES)
        pre = input_specs(cfg, dataclasses.replace(shape, kind="prefill"))
        batch = dict(pre["batch"])
        batch["class_labels"] = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        b_shard = _ns(mesh, batch_specs(cfg, batch, da, ax_sizes))
        s_abs = jax.eval_shape(lambda: fed3r.init_stats(cfg.d_feat, FED3R_N_CLASSES))
        s_shard = _ns(mesh, stats_specs(cfg.d_feat, ax_sizes))
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, s_shard, b_shard),
            out_shardings=s_shard,
            donate_argnums=(1,),
        )
        return jitted, (params_abs, s_abs, batch), fsdp
    raise ValueError(kind)


def _da_size(ax_sizes, da) -> int:
    s = 1
    for a in da:
        s *= ax_sizes[a]
    return s


def _compile_and_cost(cfg, kind, shape, mesh, ax_sizes, da, num_microbatches):
    """Compile one unrolled variant; return (flops_pd, bytes_pd, CollectiveStats)."""
    jitted, args, _ = _build_jit(
        cfg, kind, shape, mesh, ax_sizes, da, num_microbatches=num_microbatches
    )
    compiled = jitted.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), coll


def lower_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    kind_override: Optional[str] = None,
    mesh=None,
    skip_roofline: bool = False,
) -> Dict[str, Any]:
    """Lower + compile one combination; return the §Dry-run record."""
    t0 = time.time()
    cfg0 = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = variant_for(cfg0, shape)
    kind = kind_override or shape.kind
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "status": "skipped" if cfg is None else "pending",
    }
    if cfg is None:
        rec["skip_reason"] = "long_500k n/a for full-attn enc-dec (see DESIGN.md)"
        return rec
    if cfg.sliding_window and shape.name == "long_500k":
        rec["variant"] = f"sliding_window={cfg.sliding_window}"

    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    compat.set_mesh(mesh)  # ambient mesh: enables model-internal sharding hints
    da = data_axes(mesh)
    ax_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chips = n_chips(mesh)

    M = _pick_microbatches(cfg, shape, _da_size(ax_sizes, da))
    rec["num_microbatches"] = M
    rec["remat_block_size"] = cfg.remat_block_size

    # ---- 1) full-model compile: the deployment program ----------------------
    jitted, args, fsdp = _build_jit(
        cfg, kind, shape, mesh, ax_sizes, da, num_microbatches=M
    )
    rec["fsdp"] = bool(fsdp)
    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                rec[f] = int(v)
        per_dev = (
            rec.get("argument_size_in_bytes", 0)
            + rec.get("output_size_in_bytes", 0)
            + rec.get("temp_size_in_bytes", 0)
            - rec.get("alias_size_in_bytes", 0)
        )
        rec["per_device_bytes"] = per_dev
        rec["per_device_gb"] = round(per_dev / 1e9, 2)
        rec["fits_hbm"] = bool(per_dev <= HBM_PER_CHIP)

    census = hlo_analysis.collective_stats(compiled.as_text())
    rec["scanned_hlo_collectives"] = {k: int(v) for k, v in census.counts.items()}
    del compiled, lowered  # free compile memory

    # ---- 2) delta-method roofline (unrolled depth variants) -----------------
    if not skip_roofline:
        cfg1, cfg2, units = _depth_variants(cfg)
        f1, b1, c1 = _compile_and_cost(cfg1, kind, shape, mesh, ax_sizes, da, M)
        f2, b2, c2 = _compile_and_cost(cfg2, kind, shape, mesh, ax_sizes, da, M)
        dflops, dbytes = f2 - f1, b2 - b1
        dcoll = c2.minus(c1)
        # the microbatch loop body is also counted once by cost_analysis —
        # scale to the deployed M (epilogue overcount is negligible)
        flops_pd = (f1 + (units - 1) * dflops) * M
        bytes_pd = (b1 + (units - 1) * dbytes) * M
        coll = c1.plus_scaled(dcoll, units - 1).scaled(M)

        rt = hlo_analysis.roofline_terms(
            flops_pd, bytes_pd, coll.total_wire_bytes, chips,
            peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW,
        )
        rec["hlo_flops_global"] = rt.hlo_flops_global
        rec["hlo_bytes_global"] = rt.hlo_bytes_global
        rec["collective_wire_bytes_per_chip"] = coll.total_wire_bytes
        rec["collectives"] = {k: int(v) for k, v in coll.counts.items()}
        rec["collective_wire_by_kind"] = {k: float(v) for k, v in coll.wire_bytes.items()}
        rec["roofline"] = {
            "compute_s": rt.compute_s,
            "memory_s": rt.memory_s,
            "collective_s": rt.collective_s,
            "dominant": rt.dominant,
        }
        params_abs = abstract_params(cfg)
        mf = model_flops(cfg, shape, params_abs)
        rec["model_flops"] = mf
        rec["useful_flops_ratio"] = (
            mf / rt.hlo_flops_global if rt.hlo_flops_global else None
        )
        rec["params"] = param_breakdown(cfg, params_abs)

    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--kind", default=None, choices=[None, "fed3r"],
                    help="override the step kind (fed3r = statistics pass)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--all", action="store_true", help="arch=all shape=all")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="only the full compile (multi-pod pass)")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or args.arch == "all") else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape == "all") else [args.shape]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {mesh}", flush=True)
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            try:
                rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                                kind_override=args.kind, mesh=mesh,
                                skip_roofline=args.skip_roofline)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                    "kind": args.kind or INPUT_SHAPES[shape].kind,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
            status = rec["status"]
            n_ok += status == "ok"
            n_fail += status == "error"
            n_skip += status == "skipped"
            msg = f"[{status:7s}] {arch:24s} {shape:12s} {rec['mesh']:8s}"
            if status == "ok":
                msg += (
                    f" compile={rec['compile_s']:7.1f}s"
                    f" mem={rec.get('per_device_gb', -1):7.2f}GB"
                    f" fits={rec.get('fits_hbm')}"
                )
                if "roofline" in rec:
                    r = rec["roofline"]
                    msg += (
                        f" compute={r['compute_s']*1e3:9.3f}ms"
                        f" memory={r['memory_s']*1e3:9.3f}ms"
                        f" coll={r['collective_s']*1e3:9.3f}ms"
                        f" dom={r['dominant']}"
                    )
            elif status == "error":
                msg += f" {rec['error'][:140]}"
            print(msg, flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"done: ok={n_ok} failed={n_fail} skipped={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
