"""Step functions lowered onto the production mesh.

* ``train_step`` — one centralized SGD step (fwd + bwd + parameter
  update) with microbatching and mixed precision; the LM-pretraining
  shape.  Frozen-subtree masks multiply gradients by a 0/1 pytree.
* ``cls_per_example_loss`` — the classification objective of the FED3R+FT
  phase (backbone features → softmax head) in the per-example form the
  batched cohort round engine (:mod:`repro.federated.round_engine`)
  consumes: launch/train.py runs WHOLE FT rounds as one dispatch with the
  cohort dim sharded over the data axes, replacing the former ad-hoc
  per-client ``local_step`` loop here.
* ``prefill_step`` — forward + KV/state cache construction.
* ``decode_step`` — one token against the cache.
* ``fed3r_stats_step`` — the paper's statistics pass: backbone features →
  (A, b) accumulation.  Batch is sharded over the data axes, so the ZᵀZ
  contraction makes GSPMD emit exactly the hierarchical all-reduce that
  implements the paper's client→server aggregation.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import fed3r
from repro.core.random_features import RFFParams, rff_map
from repro.federated import engine as engine_lib
from repro.models import model as model_lib


def make_train_step(
    cfg: ModelConfig,
    lr: float = 1e-2,
    freeze: Optional[Any] = None,
    num_microbatches: int = 1,
    param_specs: Optional[Any] = None,
) -> Callable:
    """FL local SGD step with gradient accumulation and mixed precision.

    * ``num_microbatches`` splits the per-step batch into M sequential
      microbatches (lax.scan) — activation/remat memory scales 1/M while the
      SGD update stays mathematically identical (mean of microbatch grads).
    * Mixed precision: the fp32 master params are cast ONCE per step to a
      bf16 compute copy, constrained to the same (FSDP) sharding via
      ``param_specs`` — so every per-layer weight all-gather inside the scan
      moves bf16, not fp32 (2× collective wire; see EXPERIMENTS.md §Perf H2).
      Gradients are taken w.r.t. the bf16 copy (cotangent collectives also
      bf16) and applied to the fp32 master.
    """

    def to_bf16(params):
        pc = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params,
        )
        if param_specs is not None:
            pc = jax.lax.with_sharding_constraint(pc, param_specs)
        return pc

    def grads_of(pc, batch):
        return jax.value_and_grad(
            lambda pp, b: model_lib.lm_loss(cfg, pp, b)
        )(pc, batch)

    def train_step(params, batch):
        pc = to_bf16(params)
        if num_microbatches <= 1:
            loss, grads = grads_of(pc, batch)
        else:
            M = num_microbatches

            def split(a):
                assert a.shape[0] % M == 0, (a.shape, M)
                return a.reshape((M, a.shape[0] // M) + a.shape[1:])

            mb = jax.tree.map(split, batch)
            gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), pc)

            def body(acc, microbatch):
                loss, g = grads_of(pc, microbatch)
                return jax.tree.map(lambda a, x: (a + x).astype(a.dtype), acc, g), loss

            gsum, losses = jax.lax.scan(body, gz, mb)
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss = jnp.mean(losses)
        if freeze is not None:
            grads = jax.tree.map(lambda g, f: g * f, grads, freeze)
        params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return params, loss

    return train_step


def make_cls_per_example_loss(cfg: ModelConfig) -> Callable:
    """Per-example softmax-classification loss over backbone features.

    Params are ``{"backbone": ..., "head": {"W", "b"}}``; the batch is the
    round engine's ``{"x": tokens, "y": class labels, "mask": ...}`` dict.
    Returns ``(batch_size,)`` losses — masking/averaging happens inside the
    engine's ``local_update``, so padding rows contribute exactly nothing.
    """

    def per_example_loss(params, batch):
        feats = model_lib.extract_features(cfg, params["backbone"], {"tokens": batch["x"]})
        logits = feats @ params["head"]["W"] + params["head"]["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    return per_example_loss


def make_prefill_step(cfg: ModelConfig, cache_capacity: int) -> Callable:
    def prefill_step(params, batch):
        return model_lib.prefill(cfg, params, batch, cache_capacity)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        return model_lib.decode_step(cfg, params, cache, token, pos)

    return decode_step


def make_fed3r_stats_step(
    cfg: ModelConfig,
    n_classes: int,
    rff_params: Optional[RFFParams] = None,
    *,
    aggregation: str = "merge",
    mesh_axes: Tuple[str, ...] = (),
    use_kernel: bool = False,
) -> Callable:
    """(params, stats, batch{tokens..., class_labels[, mask]}) -> stats'.

    One statistics mini-round on the accumulation-engine core
    (:func:`repro.federated.engine.shard_stats`): extract φ over the
    (data-sharded) batch, optionally map through shared random features,
    accumulate A/b.  ``aggregation`` selects the engine's server backend:

    * ``"merge"`` (default) — the contraction over the batch dim is the
      paper's exact aggregation; under jit GSPMD lowers it to an all-reduce
      over ("pod", "data").
    * ``"psum"`` — explicit all-reduce over ``mesh_axes``, for use inside
      shard_map where the batch axes are manually partitioned.

    An optional per-sample ``batch["mask"]`` supports clients-per-shard
    packed batches (padding rows contribute exactly nothing).
    ``use_kernel`` defaults to False here even on TPU: under GSPMD jit the
    XLA contraction is what lowers to the hierarchical all-reduce; the
    Pallas kernel has no partitioning rule, so opt in only inside shard_map
    where the batch is already local.
    """

    def stats_step(params, stats: fed3r.Fed3RStats, batch) -> fed3r.Fed3RStats:
        feats = model_lib.extract_features(cfg, params, batch)
        if rff_params is not None:
            feats = rff_map(rff_params, feats)
        new = engine_lib.shard_stats(
            feats, batch["class_labels"], n_classes, batch.get("mask"),
            use_kernel=use_kernel,
        )
        new = engine_lib.aggregate(new, aggregation, mesh_axes)
        return fed3r.merge(stats, new)

    return stats_step
