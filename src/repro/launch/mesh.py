"""Production and host meshes — the device topologies the engines run over.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16); the
multi-pod deployment adds a leading "pod" axis over DCN (2 pods = 512
chips).  The distributed execution layer (:mod:`repro.federated.dist`)
shards the engines' batch-carrying axes over :func:`data_axes` — every
axis but "model" — and all-reduces the d² statistics hierarchically:
intra-pod over ICI first, then cross-pod over DCN (the two stages are
costed separately by ``repro.federated.costs.CostModel``).

Beyond two stages, :func:`make_tier_host_mesh` builds N-axis TIER meshes
(edge → region → cloud) for the generalized aggregation trees of
:mod:`repro.federated.tiers`: one mesh axis per tier, innermost axis =
leaf tier, each tier priced at its own bandwidth (``ICI_BW`` / ``DCN_BW``
/ ``WAN_BW``) by ``CostModel.tiered_allreduce``.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
first jax device query, while smoke tests must keep seeing 1 device.  The
host meshes (``make_host_mesh``) build the same axis layouts over however
many (possibly simulated) local devices exist, so tests and the weak-
scaling bench (``benchmarks/bench_scaleout.py``) exercise the exact
production code paths.
"""
from __future__ import annotations

from typing import Tuple

import jax

# Hardware constants (TPU v5e) used by the roofline analysis and cost model.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip effective for ring collectives)
DCN_BW = 12.5e9  # bytes/s per pod boundary (~100 Gbps cross-pod effective)
WAN_BW = 1.25e9  # bytes/s cross-region (~10 Gbps effective over WAN)

# Per-tier bandwidth lookup for aggregation trees: edge folds ride ICI,
# region crossings ride DCN, cloud crossings ride the WAN.
TIER_BANDWIDTHS = {"ici": ICI_BW, "dcn": DCN_BW, "wan": WAN_BW}

# Default axis names for N-tier host meshes, outermost (slowest) first.
# The leaf tier keeps the name "edge"; a 1-tier mesh degenerates to it.
_TIER_AXIS_NAMES = ("cloud", "region", "edge")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, *, pods: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local / dry runs).

    Mirrors the production axis layouts so host-device tests exercise the
    same code paths: ``pods=1`` builds ("data", "model"); ``pods>1`` adds
    the leading "pod" axis — ("pod", "data", "model") — over simulated
    host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    Raises ``ValueError`` (not a bare assert, which ``python -O`` strips)
    when the device count does not factor as pods × data × model_parallel.
    """
    n = len(jax.devices())
    if model_parallel < 1 or pods < 1:
        raise ValueError(
            f"model_parallel and pods must be >= 1, got {model_parallel}, {pods}"
        )
    if n % (model_parallel * pods) != 0:
        raise ValueError(
            f"{n} devices do not factor as pods={pods} × data × "
            f"model_parallel={model_parallel}"
        )
    data = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model_parallel), ("pod", "data", "model")
        )
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def make_tier_host_mesh(
    tier_shape: Tuple[int, ...],
    tier_names: Tuple[str, ...] = (),
    model_parallel: int = 1,
) -> jax.sharding.Mesh:
    """N-tier mesh over local devices: one axis per tier + "model".

    ``tier_shape`` lists tier sizes OUTERMOST FIRST (cloud → edge), so the
    trailing tier axis is the leaf/edge tier — the same outer-to-inner
    convention as ("pod", "data").  Default names for ≤3 tiers are drawn
    from ("cloud", "region", "edge") right-aligned; deeper trees must name
    their axes explicitly.  All tier axes are batch-carrying (returned by
    :func:`data_axes`), so the engines' packers and the aggregation trees
    of :mod:`repro.federated.tiers` see them uniformly.

    Raises ``ValueError`` when the device count does not factor as
    prod(tier_shape) × model_parallel, or when names/shape disagree.
    """
    if not tier_shape or any(s < 1 for s in tier_shape):
        raise ValueError(f"tier_shape must be non-empty positive ints, got {tier_shape}")
    if not tier_names:
        if len(tier_shape) > len(_TIER_AXIS_NAMES):
            raise ValueError(
                f"{len(tier_shape)} tiers need explicit tier_names "
                f"(defaults cover {len(_TIER_AXIS_NAMES)})"
            )
        tier_names = _TIER_AXIS_NAMES[len(_TIER_AXIS_NAMES) - len(tier_shape):]
    if len(tier_names) != len(tier_shape):
        raise ValueError(f"tier_names {tier_names} do not match tier_shape {tier_shape}")
    if "model" in tier_names:
        raise ValueError('"model" is reserved for the model-parallel axis')
    n = len(jax.devices())
    want = model_parallel
    for s in tier_shape:
        want *= s
    if n != want:
        raise ValueError(
            f"{n} devices do not factor as tiers {tier_shape} × "
            f"model_parallel={model_parallel}"
        )
    return jax.make_mesh(
        tuple(tier_shape) + (model_parallel,), tuple(tier_names) + ("model",)
    )


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_parallel_size(mesh: jax.sharding.Mesh) -> int:
    """Product of the batch-carrying axis sizes — the shard-count the
    packers pad the engines' leading axes to a multiple of."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in data_axes(mesh):
        n *= sizes[a]
    return n


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
