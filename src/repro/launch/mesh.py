"""Production and host meshes — the device topologies the engines run over.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16); the
multi-pod deployment adds a leading "pod" axis over DCN (2 pods = 512
chips).  The distributed execution layer (:mod:`repro.federated.dist`)
shards the engines' batch-carrying axes over :func:`data_axes` — every
axis but "model" — and all-reduces the d² statistics hierarchically:
intra-pod over ICI first, then cross-pod over DCN (the two stages are
costed separately by ``repro.federated.costs.CostModel``).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
first jax device query, while smoke tests must keep seeing 1 device.  The
host meshes (``make_host_mesh``) build the same axis layouts over however
many (possibly simulated) local devices exist, so tests and the weak-
scaling bench (``benchmarks/bench_scaleout.py``) exercise the exact
production code paths.
"""
from __future__ import annotations

from typing import Tuple

import jax

# Hardware constants (TPU v5e) used by the roofline analysis and cost model.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip effective for ring collectives)
DCN_BW = 12.5e9  # bytes/s per pod boundary (~100 Gbps cross-pod effective)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, *, pods: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local / dry runs).

    Mirrors the production axis layouts so host-device tests exercise the
    same code paths: ``pods=1`` builds ("data", "model"); ``pods>1`` adds
    the leading "pod" axis — ("pod", "data", "model") — over simulated
    host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

    Raises ``ValueError`` (not a bare assert, which ``python -O`` strips)
    when the device count does not factor as pods × data × model_parallel.
    """
    n = len(jax.devices())
    if model_parallel < 1 or pods < 1:
        raise ValueError(
            f"model_parallel and pods must be >= 1, got {model_parallel}, {pods}"
        )
    if n % (model_parallel * pods) != 0:
        raise ValueError(
            f"{n} devices do not factor as pods={pods} × data × "
            f"model_parallel={model_parallel}"
        )
    data = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model_parallel), ("pod", "data", "model")
        )
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_parallel_size(mesh: jax.sharding.Mesh) -> int:
    """Product of the batch-carrying axis sizes — the shard-count the
    packers pad the engines' leading axes to a multiple of."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in data_axes(mesh):
        n *= sizes[a]
    return n


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
