"""Production meshes.

Target: TPU v5e pods — 256 chips/pod arranged (data=16, model=16); the
multi-pod deployment adds a leading "pod" axis over DCN (2 pods = 512 chips).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the
first jax device query, while smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Tuple

import jax

# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip effective for ring collectives)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch dimension (everything but "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
