"""Render a telemetry snapshot (``telemetry_*.json``) for humans.

``benchmarks/run.py`` persists one :meth:`repro.federated.telemetry
.Telemetry.snapshot` per benchmark module (uploaded as a CI artifact);
this thin CLI turns a snapshot — or the live process-global registry of
an imported module — into a readable report: per-engine dispatch totals,
counters/gauges, span p50/p99/p999, and the tail of the flight-recorder
event ring.

Usage:
    PYTHONPATH=src python -m repro.launch.obs_report telemetry_serving.json
    PYTHONPATH=src python -m repro.launch.obs_report snap.json --events 50
    PYTHONPATH=src python -m repro.launch.obs_report snap.json --prometheus
    PYTHONPATH=src python -m repro.launch.obs_report snap.json --jsonl > ev.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.federated.telemetry import dispatch_summary


def _fmt_labels(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def _fmt_val(v) -> str:
    if v is None:
        return "-"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.6g}"


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024.0
    return f"{n:.1f}GiB"


def _render_tree(snapshot: dict) -> list:
    """The aggregation-tree block: one line per tier (leaf tier first),
    drawn from the ``tier_wire_bytes_total`` / ``tier_batches_total``
    counters the :class:`repro.federated.tiers.TieredAbsorber` meters at
    every boundary crossing."""
    per_tier: dict = {}
    for c in snapshot.get("counters", []):
        if c.get("name") not in ("tier_wire_bytes_total", "tier_batches_total"):
            continue
        lb = c.get("labels", {})
        key = (int(lb.get("level", 0)), str(lb.get("tier", "?")))
        row = per_tier.setdefault(key, {"wire": lb.get("wire", "fp32")})
        if c["name"] == "tier_wire_bytes_total":
            row["bytes"] = row.get("bytes", 0) + c["value"]
            if "wire" in lb:
                row["wire"] = lb["wire"]
        else:
            row["batches"] = row.get("batches", 0) + c["value"]
    if not per_tier:
        return []
    stale = {}
    for ev in snapshot.get("events", []):
        if ev.get("kind") == "tier_staleness_exceeded":
            t = str(ev.get("fields", {}).get("tier", "?"))
            stale[t] = stale.get(t, 0) + 1
    out = ["aggregation tree (leaf tier first):"]
    for i, ((level, tier), row) in enumerate(sorted(per_tier.items())):
        branch = "  " * level + ("└─ " if level else "")
        line = (
            f"  {branch}{tier:<10} wire={row['wire']:<5}"
            f" batches={_fmt_val(row.get('batches', 0)):>6}"
            f" bytes={_fmt_bytes(float(row.get('bytes', 0))):>10}"
        )
        if stale.get(tier):
            line += f"  staleness_exceeded={stale[tier]}"
        out.append(line)
    return out


def render(snapshot: dict, *, events: int = 20) -> str:
    """The human report for one snapshot dict."""
    out = []
    disp = dispatch_summary(snapshot)
    if disp:
        out.append("dispatches (host→device, per engine):")
        for eng, n in sorted(disp.items()):
            out.append(f"  {eng:<16} {n}")
    out.extend(_render_tree(snapshot))
    counters = [
        c for c in snapshot.get("counters", [])
        if c.get("name") != "engine_dispatches_total"
    ]
    if counters:
        out.append("counters:")
        for c in sorted(counters, key=lambda c: (c["name"], _fmt_labels(c["labels"]))):
            out.append(f"  {c['name']}{{{_fmt_labels(c['labels'])}}} = {_fmt_val(c['value'])}")
    gauges = snapshot.get("gauges", [])
    if gauges:
        out.append("gauges:")
        for g in sorted(gauges, key=lambda g: (g["name"], _fmt_labels(g["labels"]))):
            out.append(f"  {g['name']}{{{_fmt_labels(g['labels'])}}} = {_fmt_val(g['value'])}")
    hists = snapshot.get("histograms", [])
    if hists:
        out.append("spans / histograms (seconds):")
        out.append(f"  {'series':<48} {'n':>8} {'p50':>10} {'p99':>10} {'p999':>10}")
        for h in sorted(hists, key=lambda h: (h["name"], _fmt_labels(h["labels"]))):
            series = f"{h['name']}{{{_fmt_labels(h['labels'])}}}"
            out.append(
                f"  {series:<48} {h['count']:>8}"
                f" {_fmt_val(h['p50']):>10} {_fmt_val(h['p99']):>10}"
                f" {_fmt_val(h['p999']):>10}"
            )
    ring = snapshot.get("events", [])
    dropped = snapshot.get("events_dropped", 0)
    if ring or dropped:
        shown = ring[-events:] if events else []
        out.append(
            f"flight recorder: {len(ring)} events in ring"
            f" ({dropped} dropped), last {len(shown)}:"
        )
        for ev in shown:
            fields = ",".join(f"{k}={v}" for k, v in sorted(ev.get("fields", {}).items()))
            out.append(f"  #{ev.get('seq', '?'):<6} {ev.get('kind', '?'):<24} {fields}")
    return "\n".join(out) + "\n"


def _snapshot_prometheus(snapshot: dict) -> str:
    """Re-hydrate a snapshot into a Telemetry and expose it as Prometheus
    text (quantiles recompute from the persisted buckets)."""
    from repro.federated.telemetry import Telemetry

    t = Telemetry()
    for c in snapshot.get("counters", []):
        t.counter(c["name"], **c["labels"]).set(c["value"])
    for g in snapshot.get("gauges", []):
        t.gauge(g["name"], **g["labels"]).set(g["value"])
    for h in snapshot.get("histograms", []):
        cell = t.histogram(h["name"], **h["labels"])
        cell.counts = {int(k): int(v) for k, v in h.get("buckets", {}).items()}
        cell.zero_count = int(h.get("zero_count", 0))
        cell.count = int(h.get("count", 0))
        cell.sum = float(h.get("sum", 0.0))
    return t.prometheus()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", help="telemetry_*.json snapshot path ('-' for stdin)")
    ap.add_argument("--events", type=int, default=20,
                    help="how many trailing flight-recorder events to show")
    ap.add_argument("--prometheus", action="store_true",
                    help="emit Prometheus text exposition instead of the report")
    ap.add_argument("--jsonl", action="store_true",
                    help="emit the event ring as JSON-lines instead of the report")
    args = ap.parse_args(argv)

    if args.snapshot == "-":
        snapshot = json.load(sys.stdin)
    else:
        with open(args.snapshot) as f:
            snapshot = json.load(f)

    if args.prometheus:
        sys.stdout.write(_snapshot_prometheus(snapshot))
    elif args.jsonl:
        for ev in snapshot.get("events", []):
            sys.stdout.write(json.dumps(ev, sort_keys=True) + "\n")
    else:
        sys.stdout.write(render(snapshot, events=args.events))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
