"""Continuous-batching slot-based head-serving engine (absorb/solve/serve).

The serving counterpart of the four one-dispatch engines: where
``launch/serve_heads`` answers each query burst synchronously per tenant —
solve-on-miss inside the request path, whole-cache invalidation on every
absorb — this engine runs the JetStream/MaxText-decode shape
(prefill/insert/generate ≅ absorb/solve/serve) over S fixed
device-resident head slots (:class:`repro.federated.slots.SlotTable`):

* **absorb** — fold an arrival segment into the global factored state via
  the streaming engine (ONE dispatch per segment), bump the global stream
  version and the per-tenant versions of the clients whose OWN statistics
  arrived (version-segmented invalidation; ``invalidation="strict"``
  restores the dirty-sweep-everything policy for parity with the
  synchronous path);
* **solve** — fill-empty-slots: ALL pending cache-miss tenants of a tick
  (stale residents re-solve in place; new tenants claim free slots, then
  evict the coldest by recency/popularity) batch-solve in ONE dispatch —
  the personalization engine's grid-over-heads core plus a scatter into
  the donated ``(S, d, C)`` slot table and a refresh of the pinned global
  slot, all inside the same jitted program;
* **serve** — ONE dispatch answers every in-flight query against the
  resident table: a gather of per-query slot rows + one batched matmul.
  No per-tenant Python loop, no per-burst head stacking/transfer —
  dispatches per batch are O(1) in the tenant count by construction.

Around the stages: an admission-controlled request queue (bounded depth —
overflow is shed at enqueue; ``deadline_ticks`` sheds requests that waited
through too many ticks, the adaptive-dropout analogue for serving), and
in-flight batching of queries across tenants between solve ticks
(``max_batch`` caps a tick's serve width so traffic bursts spread over
ticks instead of unbounded batches).  Stage wall-times and dispatch
counters are tracked per stage, decode-microbenchmark style
(``benchmarks/bench_serving.py`` reports p50/p99 latency and sustained
QPS under Zipf traffic against the synchronous LRU path).

Observability (:mod:`repro.federated.telemetry`): every stage runs under
a span (``span_seconds{engine=serving, stage=tick/solve, ...}``), the
stage dispatch counters and hit/miss/shed tallies are homed in the
registry (``engine_dispatches_total{engine=serving, stage=...}``,
``serving_cache_*_total``, ``serving_shed_total{reason=...}``) behind
back-compat attributes, per-request latency feeds the log-bucketed
``serving_latency_seconds`` histogram, and overflow/deadline sheds land
in the flight recorder.  All timing is on the monotonic
``time.perf_counter`` clock — the wall clock steps backwards under NTP,
which can make p99 and deadline accounting go negative.

``launch/serve_heads``/``launch/serve_stream`` expose this engine behind
``--engine slots`` as thin compatibility drivers with unchanged reports.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.core.fed3r import Fed3RFactored
from repro.data.pipeline import pack_personal_cohort
from repro.federated.dist import donate_argnums
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.slots import SlotTable
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.federated.telemetry import Telemetry, get_telemetry


@dataclass(frozen=True)
class ServingConfig:
    """Static serving-engine configuration (trace-time constants).

    ``n_slots`` sizes the device-resident head table (slot 0 is pinned to
    the global head, so ``n_slots - 1`` tenants can be resident).
    ``queue_depth`` bounds the admission queue — enqueues beyond it are
    SHED, not buffered.  ``deadline_ticks`` (optional) sheds a queued
    request once it has waited through more than that many full ticks
    unserved; ``max_batch`` (optional) caps how many requests one tick
    serves, which is what makes waiting — and therefore deadlines —
    possible.  ``solve_bucket``/``serve_bucket`` round the solve-cohort
    and serve-batch widths up to fixed buckets so repeated ticks reuse one
    jit trace per bucket.  ``invalidation`` picks the staleness policy:
    ``"segmented"`` re-solves only tenants whose OWN statistics changed
    (resident heads tolerate global-state staleness until their tenant is
    touched; the pinned global slot refreshes every tick it is stale),
    ``"strict"`` dirty-marks every resident head on any absorb — the
    synchronous ``serve_heads`` semantics, kept for answer parity.
    """

    n_classes: int
    ridge_lambda: float = 1e-2
    n_slots: int = 64
    queue_depth: int = 4096
    deadline_ticks: Optional[int] = None
    max_batch: Optional[int] = None
    solve_bucket: int = 8
    serve_bucket: int = 32
    invalidation: str = "segmented"  # "segmented" | "strict"
    alpha_grid: Tuple[float, ...] = (0.0, 0.5, 1.0, 2.0, 4.0)
    normalize: bool = True
    selection: str = "error"
    use_kernel: Optional[bool] = None

    def __post_init__(self):
        if self.invalidation not in ("segmented", "strict"):
            raise ValueError(f"unknown invalidation policy: {self.invalidation!r}")
        if self.queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {self.queue_depth}")
        if self.deadline_ticks is not None and self.deadline_ticks < 0:
            raise ValueError(
                f"deadline_ticks must be >= 0, got {self.deadline_ticks}"
            )
        if self.max_batch is not None and self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.solve_bucket < 1 or self.serve_bucket < 1:
            raise ValueError("solve_bucket and serve_bucket must be >= 1")


class Request(NamedTuple):
    """One admitted query: tenant id, feature row, and its arrival stamps."""

    tenant: int
    x: np.ndarray  # (d,)
    tick: int  # ticks completed when the request was admitted
    t_enq: float  # monotonic perf_counter at admission (latency accounting)


class ServingEngine:
    """S-slot continuous-batching server over the streaming + personalization
    engines.

    ``dataset`` is the per-tenant statistics store (anything with the
    ``n_clients``/``client``/``client_sizes`` surface, e.g. a
    :class:`repro.data.pipeline.FederatedDataset` or a
    :class:`repro.federated.slots.TenantUniverse`); tenants outside
    ``range(dataset.n_clients)`` are served the pinned global head.
    """

    def __init__(
        self, cfg: ServingConfig, dataset, *, telemetry: Optional[Telemetry] = None
    ):
        self.cfg = cfg
        self.dataset = dataset
        self.telemetry = get_telemetry() if telemetry is None else telemetry
        self.stream = StreamingEngine(StreamConfig(
            n_classes=cfg.n_classes, ridge_lambda=cfg.ridge_lambda,
            normalize=cfg.normalize, use_kernel=cfg.use_kernel,
        ))
        self.pers = PersonalizationEngine(PersonalizeConfig(
            n_classes=cfg.n_classes, alpha_grid=cfg.alpha_grid,
            normalize=cfg.normalize, selection=cfg.selection,
            use_kernel=cfg.use_kernel,
        ))
        # every tick's cohort pads to the dataset-global sample capacity so
        # the solve stage traces once per cohort bucket (serve_heads' contract)
        self.max_n = int(dataset.client_sizes().max())
        self.state = None  # StreamState, set by init()
        self.table: Optional[SlotTable] = None
        self.queue: Deque[Request] = deque()
        self.ticks = 0
        self.global_version = 0
        self.tenant_versions: Dict[int, int] = {}
        # stage dispatch counters + wall-times (decode-microbenchmark style),
        # homed in the telemetry registry behind back-compat properties;
        # one labeled cell per engine instance keeps N servers independent
        t, inst = self.telemetry, self.telemetry.next_instance("serving")
        self._cells = {
            "absorb_dispatches": t.counter(
                "engine_dispatches_total", engine="serving", stage="absorb", inst=inst
            ),
            "solve_dispatches": t.counter(
                "engine_dispatches_total", engine="serving", stage="solve", inst=inst
            ),
            "serve_dispatches": t.counter(
                "engine_dispatches_total", engine="serving", stage="serve", inst=inst
            ),
            "hits": t.counter("serving_cache_hits_total", inst=inst),
            "misses": t.counter("serving_cache_misses_total", inst=inst),
            "shed_overflow": t.counter(
                "serving_shed_total", reason="overflow", inst=inst
            ),
            "shed_deadline": t.counter(
                "serving_shed_total", reason="deadline", inst=inst
            ),
            "slot_overflow": t.counter("serving_slot_overflow_total", inst=inst),
        }
        self._latency_hist = t.histogram("serving_latency_seconds", inst=inst)
        self.stage_s = {"absorb": 0.0, "solve": 0.0, "serve": 0.0}
        self._solve = jax.jit(
            self._solve_impl, donate_argnums=donate_argnums(True, (0,))
        )
        self._refresh_global = jax.jit(
            self._refresh_global_impl, donate_argnums=donate_argnums(True, (0,))
        )
        self._serve = jax.jit(self._serve_impl)

    # counters proxied onto their telemetry cells — `self.hits += 1` and the
    # benchmarks' reset-to-zero idiom keep working unchanged
    def _cell(name: str):  # noqa: N805 — descriptor factory, not a method
        def _get(self) -> int:
            return int(self._cells[name].value)

        def _set(self, value: int) -> None:
            self._cells[name].set(int(value))

        return property(_get, _set)

    absorb_dispatches = _cell("absorb_dispatches")
    solve_dispatches = _cell("solve_dispatches")
    serve_dispatches = _cell("serve_dispatches")
    hits = _cell("hits")  # fresh-resident tenant lookups
    misses = _cell("misses")  # tenant lookups that needed a solve
    shed_overflow = _cell("shed_overflow")
    shed_deadline = _cell("shed_deadline")
    slot_overflow = _cell("slot_overflow")  # tenants served global, no slot
    del _cell

    # ---- jitted stages ----------------------------------------------------

    def _solve_impl(self, heads, L, b, x, y, m, ho, slot_idx):
        """ONE dispatch: batch-solve the miss cohort (the personalization
        engine's in-dispatch α sweep), scatter the heads into their slots
        (padded cohort rows carry an out-of-range index and drop), and
        refresh the pinned global slot — the donated table never leaves
        the device."""
        W_k, alphas, _ = self.pers._heads_impl(L, b, x, y, m, ho)
        W_g = fed3r.factored_solution(
            Fed3RFactored(L=L, b=b), self.cfg.normalize
        )
        heads = heads.at[SlotTable.GLOBAL_SLOT].set(W_g)
        heads = heads.at[slot_idx].set(W_k, mode="drop")
        return heads, alphas

    def _refresh_global_impl(self, heads, L, b):
        """The no-miss tick's solve stage: refresh only the global slot."""
        W_g = fed3r.factored_solution(
            Fed3RFactored(L=L, b=b), self.cfg.normalize
        )
        return heads.at[SlotTable.GLOBAL_SLOT].set(W_g)

    def _serve_impl(self, heads, slot_idx, xs):
        """ONE dispatch answers the whole in-flight batch: gather each
        query's resident head row and contract — O(1) dispatches in the
        tenant count."""
        return jnp.einsum("qd,qdc->qc", xs, heads[slot_idx])

    # ---- host API ---------------------------------------------------------

    def init(self, d: int) -> None:
        self.state = self.stream.init(d)
        self.table = SlotTable(self.cfg.n_slots, d, self.cfg.n_classes)

    def absorb(self, packed, params=None):
        """Absorb stage: fold an arrival segment (one dispatch), advance the
        global version, and bump the per-tenant versions of the clients
        whose own statistics arrived."""
        t0 = time.perf_counter()
        with self.telemetry.span("absorb", engine="serving"):
            self.state, trace = self.stream.absorb(self.state, packed, params)
            jax.block_until_ready(self.state.L)
        self.stage_s["absorb"] += time.perf_counter() - t0
        self.absorb_dispatches += 1
        self.global_version += 1
        touched = np.unique(np.asarray(packed.client_ids))
        for t in touched[touched >= 0]:
            t = int(t)
            self.tenant_versions[t] = self.tenant_versions.get(t, 0) + 1
        return trace

    def _has_data(self, tenant: int) -> bool:
        return 0 <= tenant < self.dataset.n_clients

    def _fresh(self, slot: int) -> bool:
        """Is the resident head current under the invalidation policy?"""
        if self.cfg.invalidation == "strict":
            return int(self.table.global_version[slot]) == self.global_version
        tenant = int(self.table.tenant[slot])
        return int(self.table.tenant_version[slot]) == self.tenant_versions.get(
            tenant, 0
        )

    def enqueue(self, tenant_ids: Sequence[int], xs: np.ndarray) -> Tuple[int, int]:
        """Admission control: append to the bounded queue; overflow is shed.

        Returns ``(admitted, shed)``.
        """
        now = time.perf_counter()
        xs = np.asarray(xs)
        admitted = shed = 0
        for cid, x in zip(tenant_ids, xs):
            if len(self.queue) >= self.cfg.queue_depth:
                shed += 1
            else:
                self.queue.append(Request(int(cid), x, self.ticks, now))
                admitted += 1
        self.shed_overflow += shed
        if shed:
            self.telemetry.event(
                "request_shed", reason="overflow", shed=shed, tick=self.ticks
            )
        return admitted, shed

    def _dequeue(self) -> Tuple[List[Request], int]:
        """Take this tick's in-flight batch: deadline-shed the expired, then
        up to ``max_batch`` requests in arrival order."""
        batch: List[Request] = []
        shed = 0
        cap = self.cfg.max_batch or len(self.queue)
        while self.queue and len(batch) < cap:
            r = self.queue.popleft()
            waited = self.ticks - r.tick  # full ticks waited through
            if (
                self.cfg.deadline_ticks is not None
                and waited > self.cfg.deadline_ticks
            ):
                shed += 1
                continue
            batch.append(r)
        self.shed_deadline += shed
        if shed:
            self.telemetry.event(
                "request_shed", reason="deadline", shed=shed, tick=self.ticks
            )
        return batch, shed

    def tick(self) -> Tuple[Optional[jax.Array], dict]:
        """One solve+serve tick over the in-flight batch.

        Returns ``(scores, report)``: ``scores`` is ``(Q, C)`` aligned with
        ``report["tenants"]`` (the served requests in arrival order), or
        ``None`` when the tick served nothing.  The report carries the
        shed/eviction/mode accounting — the serving analogue of the
        staleness trace.
        """
        self.ticks += 1
        batch, shed = self._dequeue()
        report = {
            "queries": len(batch),
            "per_tenant": 0,
            "global": 0,
            "solved_now": 0,
            "shed": shed,
            "slot_overflow": 0,
            "evictions": self.table.evictions,
            "modes": [],
            "tenants": [r.tenant for r in batch],
            "latency_s": [],
        }
        if not batch:
            return None, report

        # -- solve stage: batch every pending miss into free slots ----------
        uniq: List[int] = []
        seen = set()
        for r in batch:
            if self._has_data(r.tenant) and r.tenant not in seen:
                seen.add(r.tenant)
                uniq.append(r.tenant)
        in_place: List[Tuple[int, int]] = []  # (tenant, its stale slot)
        need_slot: List[int] = []
        protect: List[int] = []
        for t in uniq:
            s = self.table.slot_of(t)
            if s is None:
                need_slot.append(t)
                self.misses += 1
            elif self._fresh(s):
                protect.append(s)
                self.hits += 1
            else:
                in_place.append((t, s))
                protect.append(s)
                self.misses += 1
        taken = self.table.take_slots(len(need_slot), protect=protect)
        placed = list(zip(need_slot, taken))
        overflow = need_slot[len(taken):]  # no slot: served global this tick
        self.slot_overflow += len(overflow)
        solved = in_place + placed

        t0 = time.perf_counter()
        span = self.telemetry.span("solve", engine="serving")
        span.__enter__()
        if solved:
            slot_map = {t: s for t, s in solved}
            clients = []
            for t, _ in solved:
                cd = self.dataset.client(t)
                clients.append((np.asarray(cd.features), np.asarray(cd.labels)))
            pad = self.cfg.solve_bucket
            packed = pack_personal_cohort(
                clients,
                client_ids=[t for t, _ in solved],
                cohort_size=-(-len(solved) // pad) * pad,
                max_n=self.max_n,
            )
            # cohort rows are canonically sorted; padded rows get an
            # out-of-range index so the scatter drops them
            slot_vec = np.asarray(
                [slot_map.get(int(c), self.table.n_slots)
                 for c in packed.client_ids],
                np.int32,
            )
            self.table.heads, _ = self._solve(
                self.table.heads,
                self.state.L,
                self.state.b,
                jnp.asarray(packed.inputs),
                jnp.asarray(packed.labels),
                jnp.asarray(packed.mask),
                jnp.asarray(packed.holdout),
                jnp.asarray(slot_vec),
            )
            self.solve_dispatches += 1
            self.table.assign(
                [s for _, s in solved],
                [t for t, _ in solved],
                [self.tenant_versions.get(t, 0) for t, _ in solved],
                self.global_version,
                self.ticks,
            )
        elif self.table.global_slot_version != self.global_version:
            self.table.heads = self._refresh_global(
                self.table.heads, self.state.L, self.state.b
            )
            self.solve_dispatches += 1
            self.table.global_slot_version = self.global_version
        jax.block_until_ready(self.table.heads)
        span.__exit__(None, None, None)
        self.stage_s["solve"] += time.perf_counter() - t0
        report["solved_now"] = len(solved)
        report["slot_overflow"] = len(overflow)

        # -- serve stage: one gather + batched matmul for the whole batch ---
        global_now = set(overflow)
        slot_idx = np.zeros((len(batch),), np.int32)
        for i, r in enumerate(batch):
            s = (
                self.table.slot_of(r.tenant)
                if self._has_data(r.tenant) and r.tenant not in global_now
                else None
            )
            if s is None:
                slot_idx[i] = SlotTable.GLOBAL_SLOT
                report["modes"].append("global")
            else:
                slot_idx[i] = s
                report["modes"].append("per-tenant")
        report["per_tenant"] = report["modes"].count("per-tenant")
        report["global"] = report["modes"].count("global")

        xs = np.stack([r.x for r in batch]).astype(np.float32)
        q = len(batch)
        bucket = -(-q // self.cfg.serve_bucket) * self.cfg.serve_bucket
        xs_pad = np.zeros((bucket,) + xs.shape[1:], np.float32)
        xs_pad[:q] = xs
        idx_pad = np.zeros((bucket,), np.int32)
        idx_pad[:q] = slot_idx
        t0 = time.perf_counter()
        with self.telemetry.span("serve", engine="serving"):
            scores = self._serve(
                self.table.heads, jnp.asarray(idx_pad), jnp.asarray(xs_pad)
            )[:q]
            jax.block_until_ready(scores)
        done = time.perf_counter()
        self.stage_s["serve"] += done - t0
        self.serve_dispatches += 1
        served_slots, counts = np.unique(slot_idx, return_counts=True)
        self.table.touch(served_slots.tolist(), counts.tolist(), self.ticks)
        report["latency_s"] = [done - r.t_enq for r in batch]
        if self.telemetry.enabled:
            observe = self._latency_hist.observe
            for lat in report["latency_s"]:
                observe(lat)
        report["evictions"] = self.table.evictions
        return scores, report

    def query(
        self, tenant_ids: Sequence[int], xs: np.ndarray
    ) -> Tuple[jax.Array, dict]:
        """Synchronous convenience: admit a burst and tick until it drains.

        The compatibility surface for the ``serve_heads``/``serve_stream``
        drivers (no ``max_batch``/deadline pressure ⇒ one tick).  Raises if
        admission control shed part of the burst — callers that want
        shedding semantics drive :meth:`enqueue`/:meth:`tick` directly.
        """
        admitted, shed = self.enqueue(tenant_ids, xs)
        if shed:
            raise RuntimeError(
                f"query burst overflowed the admission queue ({shed} shed); "
                f"use enqueue()/tick() for load-shedding traffic"
            )
        chunks, reports = [], []
        while admitted > 0:
            scores, rep = self.tick()
            if scores is None and not rep["shed"]:
                break
            if scores is not None:
                chunks.append(scores)
            admitted -= rep["queries"] + rep["shed"]
            reports.append(rep)
        scores = jnp.concatenate(chunks) if chunks else None
        if len(reports) == 1:
            return scores, reports[0]
        merged = {
            "queries": sum(r["queries"] for r in reports),
            "per_tenant": sum(r["per_tenant"] for r in reports),
            "global": sum(r["global"] for r in reports),
            "solved_now": sum(r["solved_now"] for r in reports),
            "shed": sum(r["shed"] for r in reports),
            "slot_overflow": sum(r["slot_overflow"] for r in reports),
            "evictions": reports[-1]["evictions"] if reports else 0,
            "modes": [m for r in reports for m in r["modes"]],
            "tenants": [t for r in reports for t in r["tenants"]],
            "latency_s": [s for r in reports for s in r["latency_s"]],
        }
        return scores, merged

    def classifier(self) -> jax.Array:
        """The streaming engine's served global classifier (driver compat)."""
        return self.stream.classifier(self.state)
