"""Client sampling strategies.

The paper (§4.3, Fig. 3, App. I) distinguishes:

* **without replacement** — FED3R's natural mode: every client is sampled
  exactly once; convergence is exact after ⌈K/κ⌉ rounds;
* **with replacement** — classical FL sampling; the paper's worst-case
  analysis connects rounds-to-coverage to the Batch Coupon Collector problem
  (Table 7), reproduced in benchmarks/bench_coupon.py.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class ClientSampler:
    def __init__(
        self,
        n_clients: int,
        per_round: int,
        *,
        replacement: bool = False,
        seed: int = 0,
    ):
        self.n_clients = n_clients
        self.per_round = per_round
        self.replacement = replacement
        self.rng = np.random.default_rng(seed)
        self._pool: List[int] = []
        self.seen: set = set()

    def sample(self) -> np.ndarray:
        if self.replacement:
            out = self.rng.choice(self.n_clients, size=self.per_round, replace=False)
        else:
            # epoch-style without replacement: refill+shuffle when exhausted
            while len(self._pool) < self.per_round:
                fresh = self.rng.permutation(self.n_clients).tolist()
                self._pool.extend(fresh)
            out = np.asarray(self._pool[: self.per_round])
            self._pool = self._pool[self.per_round :]
        self.seen.update(int(c) for c in out)
        return out

    @property
    def coverage(self) -> float:
        return len(self.seen) / self.n_clients

    def rounds_to_full_coverage(self) -> int:
        """⌈K/κ⌉ — FED3R's exact convergence horizon (no replacement)."""
        return -(-self.n_clients // self.per_round)
