"""Client sampling strategies.

The paper (§4.3, Fig. 3, App. I) distinguishes:

* **without replacement** — FED3R's natural mode: every client is sampled
  exactly once; convergence is exact after ⌈K/κ⌉ rounds;
* **with replacement** — classical FL sampling; the paper's worst-case
  analysis connects rounds-to-coverage to the Batch Coupon Collector problem
  (Table 7), reproduced in benchmarks/bench_coupon.py.

:func:`sample_round` is the STATELESS core: the cohort of round ``rnd`` is a
pure function of (n_clients, per_round, rnd, seed, replacement), so a
checkpoint-resumed run re-derives exactly the cohorts an uninterrupted run
would have drawn.  :class:`ClientSampler` wraps it with a round counter and
coverage bookkeeping for the driver loops.
"""
from __future__ import annotations

from typing import List

import numpy as np


def sample_round(
    n_clients: int,
    per_round: int,
    rnd: int,
    *,
    seed: int = 0,
    replacement: bool = False,
) -> np.ndarray:
    """The cohort of round ``rnd`` as a pure function of its arguments.

    With replacement: ``per_round`` iid draws (duplicates allowed, and
    ``per_round > n_clients`` is legal — the Batch-Coupon-Collector regime
    of §4.3/Table 7).  Without replacement: epoch-style — conceptually one
    infinite stream of per-epoch permutations, from which round ``rnd``
    takes positions ``[rnd·κ, (rnd+1)·κ)``; every client appears exactly
    once per epoch and each epoch's permutation is derived independently
    from (seed, epoch).
    """
    if replacement:
        rng = np.random.default_rng((seed, rnd, 0xC0))
        return rng.choice(n_clients, size=per_round, replace=True)
    start = rnd * per_round
    out: List[np.ndarray] = []
    for epoch in range(start // n_clients, (start + per_round - 1) // n_clients + 1):
        perm = np.random.default_rng((seed, epoch, 0xE0)).permutation(n_clients)
        lo = max(start - epoch * n_clients, 0)
        hi = min(start + per_round - epoch * n_clients, n_clients)
        out.append(perm[lo:hi])
    return np.concatenate(out).astype(np.int64)


class ClientSampler:
    def __init__(
        self,
        n_clients: int,
        per_round: int,
        *,
        replacement: bool = False,
        seed: int = 0,
    ):
        self.n_clients = n_clients
        self.per_round = per_round
        self.replacement = replacement
        self.seed = seed
        self.round = 0
        self.seen: set = set()

    def sample(self) -> np.ndarray:
        out = sample_round(
            self.n_clients, self.per_round, self.round,
            seed=self.seed, replacement=self.replacement,
        )
        self.round += 1
        self.seen.update(int(c) for c in out)
        return out

    @property
    def coverage(self) -> float:
        return len(self.seen) / self.n_clients

    def rounds_to_full_coverage(self) -> int:
        """⌈K/κ⌉ — FED3R's exact convergence horizon (no replacement)."""
        return -(-self.n_clients // self.per_round)
