"""Batched cohort round engine for gradient FL — the Fed3R+FT hot path.

The gradient-FL sibling of :mod:`repro.federated.engine`: where the
statistics engine folds a packed client selection into (A, b) in one
dispatch, this module runs an ENTIRE FedAvg-family round — K sampled
clients' local updates, weighted delta aggregation, the server optimizer
step, and the Scaffold control-variate scatter — inside ONE jitted
``round_step`` with donated server state:

* the cohort arrives as a :class:`repro.data.pipeline.PackedCohort`
  (stacked ``(cohort, n_steps, batch, ...)`` arrays with masks);
* ``local_update`` (the pure form from
  :mod:`repro.federated.algorithms`) is vmapped over the cohort dim;
* aggregation weights stay on device end to end — no ``float()`` host
  syncs, no Python-list delta sums (the round hot path is
  transfer-free, see ``tests/test_round_engine.py``);
* the Scaffold variates live in one stacked ``(n_clients, ...)`` table
  inside :class:`repro.federated.algorithms.ServerState`: gather by
  cohort ids on the way in, one ``.at[ids].set`` scatter on the way out;
* mesh mode (:mod:`repro.federated.dist`): under GSPMD jit the cohort dim
  is constrained over the ambient mesh's data axes
  (:func:`repro.sharding.hints.hint`) and the weighted-delta contraction
  lowers to the hierarchical all-reduce that IS the server aggregation
  (``aggregation="merge"``); with ``DistConfig(mesh=...)`` the dist layer
  wraps ``round_step`` in shard_map — the cohort axis split over the data
  axes, the weighted deltas all-reduced in two stages (intra-pod ICI,
  then cross-pod DCN), the server step replicated — still ONE dispatch.

K clients/round therefore cost 1 dispatch instead of K+1
(``benchmarks/bench_rounds.py``); :class:`ReferenceLoop` preserves the
seed-era per-client shape as the parity/benchmark baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.data.pipeline import PackedCohort
from repro.federated.algorithms import (
    FLAlgorithm,
    ServerState,
    make_local_update,
    scaffold_update,
    server_init,
    server_optimizer_step,
)
from repro.federated.dist import DistConfig, DistContext, DistDispatchMixin
from repro.sharding.hints import hint
from repro.sharding.specs import replicated


@dataclass(frozen=True)
class RoundConfig:
    """Static round-engine configuration (all trace-time constants)."""

    algo: FLAlgorithm
    client_lr: float
    server_lr: float = 1.0
    weight_decay: float = 0.0
    n_total_clients: int = 0  # sizes the Scaffold cvar table / 1/N update
    dist: DistConfig = field(default_factory=DistConfig)  # backend/mesh/donate


class RoundEngine(DistDispatchMixin):
    """One-dispatch federated rounds over packed cohorts.

    ``loss_fn(params, batch) -> (batch_size,)`` per-example losses;
    ``freeze`` is the 0/1 trainability mask pytree (FT / FT-LP / FT-FEAT).
    Both are closed over, so the jitted ``round_step`` is traced once per
    cohort shape and reused for every round.
    """

    def __init__(
        self,
        cfg: RoundConfig,
        loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
        freeze: Any,
    ):
        if cfg.dist.aggregation == "psum" and cfg.algo.uses_cvar:
            raise ValueError(
                "scaffold needs the global cohort for the cvar scatter; "
                "use aggregation='merge' (GSPMD) for mesh runs"
            )
        self.cfg = cfg
        self.freeze = freeze
        self._local = make_local_update(
            loss_fn, cfg.algo, lr=cfg.client_lr,
            weight_decay=cfg.weight_decay, jit=False,
        )
        self.dist = DistContext(cfg.dist, engine="rounds")
        # mesh mode: shard the cohort axis of the packed batches/ids over
        # the data axes; server state replicated in and (post all-reduce) out
        sharded = self.dist.data_spec()
        self._step = self.dist.jit(
            self.round_step,
            in_specs=(replicated(), sharded, sharded),
            out_specs=replicated(),
        )

    def init(self, params0: Any) -> ServerState:
        return server_init(
            self.cfg.algo, params0, n_clients=self.cfg.n_total_clients
        )

    # ---- pure core (also usable directly inside shard_map) ----------------

    def round_step(
        self,
        state: ServerState,
        batches: Dict[str, jax.Array],  # leaves (cohort, n_steps, B, ...)
        client_ids: jax.Array,  # (cohort,) int32, -1 = padded slot
    ) -> ServerState:
        """One full FL round as a pure ServerState transition."""
        algo = self.cfg.algo
        # constrain the cohort dim over the ambient mesh's data axes so the
        # vmapped local updates data-parallelize; exact no-op without a mesh
        batches = jax.tree.map(lambda a: hint(a, "batch"), batches)

        if algo.uses_cvar:
            safe = jnp.clip(client_ids, 0, self.cfg.n_total_clients - 1)
            c_client = jax.tree.map(lambda t: t[safe], state.cvars)
            res = jax.vmap(self._local, in_axes=(None, 0, None, None, 0))(
                state.params, batches, self.freeze, state.c_server, c_client
            )
        else:
            zeros = jax.tree.map(jnp.zeros_like, state.params)
            res = jax.vmap(self._local, in_axes=(None, 0, None, None, None))(
                state.params, batches, self.freeze, zeros, zeros
            )

        # weighted delta aggregation, entirely on device: padded cohort slots
        # have an all-zero mask, hence weight 0 and a zero delta
        w = res.n_samples  # (cohort,)
        weighted = jax.tree.map(
            lambda d: jnp.tensordot(w, d, axes=1), res.delta
        )
        wsum = jnp.sum(w)
        # identity under "merge"; the two-stage (ICI then DCN) all-reduce of
        # the local weighted deltas under "psum" — issued once, after the
        # vmapped local updates
        weighted, wsum = self.dist.all_reduce((weighted, wsum))
        wsum = jnp.maximum(wsum, 1.0)
        avg_delta = jax.tree.map(lambda d: d / wsum, weighted)

        state = server_optimizer_step(
            algo, state, avg_delta, server_lr=self.cfg.server_lr
        )

        if algo.uses_cvar:
            # padded slots produced new_c = c_k − c (not c_k): mask them out
            # of the 1/N sum; the scatter drops them via the safe-id trick
            valid = (client_ids >= 0).astype(jnp.float32)
            cvar_delta_sum = jax.tree.map(
                lambda new, old: jnp.tensordot(valid, new - old, axes=1),
                res.new_cvar, c_client,
            )
            state = scaffold_update(
                state, cvar_delta_sum, res.new_cvar, client_ids,
                n_total_clients=self.cfg.n_total_clients,
            )
        return state._replace(round=state.round + 1)

    # ---- host API ---------------------------------------------------------

    def step(self, state: ServerState, cohort: PackedCohort) -> ServerState:
        """Run one round over a packed cohort (ONE jitted dispatch)."""
        with self.dist.telemetry.span("round_step", engine="rounds"):
            self.dist.dispatch()
            batches = {k: jnp.asarray(v) for k, v in cohort.batches().items()}
            return self._step(state, batches, jnp.asarray(cohort.client_ids))


class ReferenceLoop:
    """The seed-era per-client round: K jitted local updates + host-side
    Python aggregation + one server dispatch (K+1 dispatches/round).

    Kept as the parity oracle for the engine (same ``local_update`` math,
    same pure server transition) and as the benchmark baseline the
    dispatch-reduction claim is measured against.  Mirrors the old
    ``Server.aggregate`` shape, including the per-client ``float()`` host
    syncs the engine removes.
    """

    def __init__(
        self,
        cfg: RoundConfig,
        loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
        freeze: Any,
    ):
        self.cfg = cfg
        self.freeze = freeze
        self._local = make_local_update(
            loss_fn, cfg.algo, lr=cfg.client_lr,
            weight_decay=cfg.weight_decay, jit=True,
        )
        self._server = jax.jit(
            lambda st, avg: server_optimizer_step(
                cfg.algo, st, avg, server_lr=cfg.server_lr
            )
        )
        self.dispatches = 0

    def init(self, params0: Any) -> ServerState:
        return server_init(
            self.cfg.algo, params0, n_clients=self.cfg.n_total_clients
        )

    def step(self, state: ServerState, cohort: PackedCohort) -> ServerState:
        algo = self.cfg.algo
        zeros = jax.tree.map(jnp.zeros_like, state.params)
        results, ids, cvar_olds = [], [], []
        for slot in range(cohort.cohort):
            cid = int(cohort.client_ids[slot])
            if cid < 0:
                continue
            batches = {
                k: jnp.asarray(v[slot]) for k, v in cohort.batches().items()
            }
            c_client = (
                jax.tree.map(lambda t: t[cid], state.cvars)
                if algo.uses_cvar else zeros
            )
            c_server = state.c_server if algo.uses_cvar else zeros
            res = self._local(
                state.params, batches, self.freeze, c_server, c_client
            )
            self.dispatches += 1
            results.append(res)
            ids.append(cid)
            cvar_olds.append(c_client)

        # host-side aggregation (the shape the engine replaces)
        weights = [float(r.n_samples) for r in results]
        wsum = max(sum(weights), 1.0)
        avg = jax.tree.map(
            lambda *ds: sum(wk * d for wk, d in zip(weights, ds)) / wsum,
            *[r.delta for r in results],
        )
        state = self._server(state, avg)
        self.dispatches += 1

        if algo.uses_cvar:
            cvar_delta_sum = jax.tree.map(
                lambda *cs: sum(cs),
                *[
                    jax.tree.map(lambda n, o: n - o, r.new_cvar, old)
                    for r, old in zip(results, cvar_olds)
                ],
            )
            c_server = jax.tree.map(
                lambda c, d: c + d / self.cfg.n_total_clients,
                state.c_server, cvar_delta_sum,
            )
            cvars = state.cvars
            for cid, r in zip(ids, results):
                cvars = jax.tree.map(
                    lambda t, n, i=cid: t.at[i].set(n), cvars, r.new_cvar
                )
            state = state._replace(c_server=c_server, cvars=cvars)
        return state._replace(round=state.round + 1)
