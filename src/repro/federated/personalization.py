"""Multi-tenant personalization engine — batched closed-form per-client heads.

The fourth engine of the family (batch statistics → rounds → streaming →
personalization): the global ridge head is immune to heterogeneity
precisely because it ignores per-client structure, but cross-device
serving wants PER-USER heads.  The closed form makes them nearly free —

    W_k = (A + α_k·A_k + λI)⁻¹ (b + α_k·b_k)

is a rank-n_k Cholesky update away from the shared factored state
(:class:`repro.core.fed3r.Fed3RFactored` carries L with L Lᵀ = A + λI), so
K personalized heads solve in ONE jitted dispatch instead of K re-solves:

* the cohort arrives as a :class:`repro.data.pipeline.PackedPersonalCohort`
  (padded ``(K, max_n, ...)`` arrays with masks + a per-client holdout
  split, canonical id order — bit-invariant to request order);
* the rank-n updates G_k = L Lᵀ + α_k·Z_kᵀZ_k batch through the
  grid-over-heads Pallas kernel (:func:`repro.kernels.batched_chol_gram`)
  on TPU and batched XLA GEMMs elsewhere, with α_k folded in by √α_k
  pre-scaling; the K refactorizations and 2K triangular solves are
  vmapped/batched XLA linalg;
* per-client α_k is selected INSIDE the same dispatch by a closed-form
  held-out score swept over a static α grid (vmap over grid × clients):
  each candidate head is solved from the client's train split and scored
  on its holdout split — 0/1 error of the served head by default, or the
  raw ridge residual — then the winning α_k refits on the client's full
  data;
* α = 0 reproduces the global :func:`repro.core.fed3r.factored_solution`
  BITWISE — the global factor L and rhs b are selected unchanged rather
  than recomputed, so a degenerate tenant (no data, or α grid pinned to 0)
  serves exactly the global classifier.

:class:`ReferencePersonalizedLoop` preserves the per-client shape — one
jitted global solve plus one jitted re-solve per client (K+1 dispatches
for a K-head cohort) — as the dispatch baseline and the parity oracle
(``benchmarks/bench_personalize.py``).  The multi-tenant serving layer
(LRU head cache over a live arrival stream) is
:mod:`repro.launch.serve_heads`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fed3r
from repro.core.fed3r import Fed3RFactored, Fed3RStats
from repro.data.pipeline import PackedPersonalCohort
from repro.federated.dist import (
    DistConfig,
    DistContext,
    DistDispatchMixin,
    resolve_use_kernel,
)
from repro.kernels import batched_chol_gram as batched_chol_gram_kernel
from repro.sharding.specs import replicated


@dataclass(frozen=True)
class PersonalizeConfig:
    """Static personalization-engine configuration (trace-time constants).

    ``alpha_grid`` is the candidate set the held-out sweep selects from;
    clients whose holdout split is empty (single-sample clients, or
    ``holdout_frac=0`` at pack time) fall back to ``alpha_grid[0]``, so
    put the conservative default (typically ``0.0`` = global head) first.

    ``dist`` is the shared distributed-execution config: with
    ``DistConfig(aggregation="psum", mesh=...)`` the dist layer shards the
    cohort axis over the mesh's data axes — each device solves only its
    K/N heads against the replicated (L, b) and the solved heads are
    gathered back (the cohort reduction is a gather, not a psum, since
    heads are per-tenant); pack with ``pack_personal_cohort(...,
    mesh=mesh)`` so the cohort divides.
    """

    n_classes: int
    alpha_grid: Tuple[float, ...] = (0.0, 0.25, 1.0, 4.0)
    normalize: bool = True  # per-class column normalization of served heads
    selection: str = "error"  # α score: "error" (0/1 held-out) | "sse" (ridge)
    use_kernel: Optional[bool] = None  # None → auto (Pallas on TPU, XLA else)
    dist: DistConfig = field(default_factory=DistConfig)  # mesh scale-out

    def __post_init__(self):
        if not self.alpha_grid:
            raise ValueError("alpha_grid must be non-empty")
        if any(a < 0.0 for a in self.alpha_grid):
            raise ValueError(f"alpha_grid must be >= 0, got {self.alpha_grid}")
        if self.selection not in ("error", "sse"):
            raise ValueError(f"unknown selection score: {self.selection!r}")


class PersonalizedHeads(NamedTuple):
    """The batched solve's output: K per-tenant heads + selection trace."""

    W: jax.Array  # (K, d, C) personalized classifiers (cohort order)
    alpha: jax.Array  # (K,) selected per-client interpolation weight
    score: jax.Array  # (K,) held-out ridge score at the selected α (0 if no sweep)
    client_ids: jax.Array  # (K,) int32 tenant ids, -1 = padded slot


class PersonalizationEngine(DistDispatchMixin):
    """K personalized heads over a shared factored state in ONE dispatch.

    ``solve_heads`` sweeps the α grid per client and refits; ``solve_at``
    skips the sweep and solves at caller-provided α_k (e.g. cached
    per-tenant values, or the reference-parity path).  Both are single
    jitted dispatches over the whole cohort.
    """

    def __init__(self, cfg: PersonalizeConfig):
        self.cfg = cfg
        self.dist = DistContext(cfg.dist, engine="personalization")
        # mesh mode: replicate the shared factored state, shard the cohort
        # axis of the packed client arrays, gather the per-tenant outputs
        # back along the same axis (no reduction: heads are per-client)
        sharded = self.dist.data_spec()
        common = (replicated(), replicated(), sharded, sharded, sharded, sharded)
        self._solve = self.dist.jit(
            self._heads_impl,
            in_specs=common,
            out_specs=(sharded, sharded, sharded),
            donate=False,  # (L, b) outlive the dispatch; nothing is carried
        )
        self._solve_at = self.dist.jit(
            self._heads_at_impl,
            in_specs=common,
            out_specs=sharded,
            donate=False,
        )

    # ---- pure core --------------------------------------------------------

    def _use_kernel(self) -> bool:
        return resolve_use_kernel(self.cfg.use_kernel)

    def _design(self, x, y, m):
        """Masked per-client designs: (K, N, d) features, (K, N, C) targets."""
        z = x.astype(jnp.float32) * m[..., None]
        yh = jax.nn.one_hot(y, self.cfg.n_classes, dtype=jnp.float32)
        return z, yh * m[..., None]

    def _batched_solve(self, L_use, rhs):
        """2K triangular solves, optionally normalized — the head refresh."""
        W = jax.vmap(
            lambda Lx, rx: jax.scipy.linalg.cho_solve((Lx, True), rx)
        )(L_use, rhs)
        if self.cfg.normalize:
            W = fed3r.normalize_columns(W, axis=1)
        return W

    def _refit(self, L, b, z, yh, alphas):
        """Batched rank-n refit at the selected α_k over full client data.

        α_k folds into the Gram bilinearly via √α_k pre-scaling, so the
        fused kernel stays scale-free.  α_k = 0 rows select a global head
        computed by :func:`repro.core.fed3r.factored_solution`'s exact ops
        (ONE unbatched solve — XLA's batched triangular solve lowers
        differently and would break the bitwise guarantee).
        """
        s = jnp.sqrt(alphas)[:, None, None]
        zs = z * s
        ys = yh * s
        if self._use_kernel():
            G, B = batched_chol_gram_kernel(L, zs, ys)
        else:
            G = L @ L.T + jnp.einsum("knd,kne->kde", zs, zs)
            B = jnp.einsum("knd,knc->kdc", zs, ys)
        Lk = jnp.linalg.cholesky(G)
        Wp = self._batched_solve(Lk, b[None] + B)
        Wg = fed3r.factored_solution(
            Fed3RFactored(L=L, b=b), self.cfg.normalize
        )
        return jnp.where(alphas[:, None, None] == 0.0, Wg[None], Wp)

    def _sweep(self, L, b, z_tr, yh_tr, z_ho, yh_ho, y, ho):
        """Closed-form α selection: grid × clients, one batched solve each.

        Candidate heads are solved from the TRAIN split only and scored on
        the HOLDOUT split (masks are already folded into the designs, so
        padded/train rows contribute exactly nothing):

        * ``"error"`` (default) — held-out misclassification count of the
          candidate head AS SERVED (normalized per config).  Robust: the
          raw ridge residual rewards prediction-magnitude growth, which
          biases toward large α on heavily shrunk global solutions even
          where decisions degrade.  Ties pick the FIRST grid entry, so an
          ascending grid starting at 0 degrades to the global head.
        * ``"sse"`` — the raw held-out ridge residual Σ_ho ‖Wᵀφ(x) − e_y‖²
          (the literal ridge objective; useful when scores, not decisions,
          are served).
        """
        grid = jnp.asarray(self.cfg.alpha_grid, jnp.float32)  # (G,)
        S = jnp.einsum("knd,kne->kde", z_tr, z_tr)  # (K, d, d)
        Bt = jnp.einsum("knd,knc->kdc", z_tr, yh_tr)  # (K, d, C)
        g = grid[:, None, None, None]
        Lg = jnp.linalg.cholesky(L @ L.T + g * S[None])  # (G, K, d, d)
        rhs = b + g * Bt[None]  # (G, K, d, C)
        W = jax.vmap(
            jax.vmap(lambda Lx, rx: jax.scipy.linalg.cho_solve((Lx, True), rx))
        )(Lg, rhs)
        if self.cfg.selection == "error":
            if self.cfg.normalize:
                W = fed3r.normalize_columns(W, axis=2)
            pick = jnp.argmax(
                jnp.einsum("knd,gkdc->gknc", z_ho, W), axis=-1
            )  # (G, K, N)
            score = jnp.sum(
                ho[None] * (pick != y[None]).astype(jnp.float32), axis=2
            )  # (G, K)
        else:
            resid = jnp.einsum("knd,gkdc->gknc", z_ho, W) - yh_ho[None]
            score = jnp.sum(resid**2, axis=(2, 3))  # (G, K)
        idx = jnp.argmin(score, axis=0)  # (K,) ties → first grid entry
        return grid[idx], jnp.take_along_axis(score, idx[None, :], axis=0)[0]

    def _heads_impl(self, L, b, x, y, m, ho) -> Tuple[jax.Array, ...]:
        z, yh = self._design(x, y, m)
        if len(self.cfg.alpha_grid) == 1:  # no sweep: α is pinned
            K = y.shape[0]
            alphas = jnp.full((K,), self.cfg.alpha_grid[0], jnp.float32)
            score = jnp.zeros((K,), jnp.float32)
        else:
            tr = (1.0 - ho)[..., None]  # holdout ⊆ mask, so z·tr is the train design
            alphas, score = self._sweep(
                L, b, z * tr, yh * tr, z * ho[..., None], yh * ho[..., None],
                y, ho,
            )
        return self._refit(L, b, z, yh, alphas), alphas, score

    def _heads_at_impl(self, L, b, x, y, m, alphas) -> jax.Array:
        z, yh = self._design(x, y, m)
        return self._refit(L, b, z, yh, alphas)

    # ---- host API ---------------------------------------------------------

    def solve_heads(
        self, state: Fed3RFactored, packed: PackedPersonalCohort
    ) -> PersonalizedHeads:
        """Sweep α and solve K personalized heads in ONE jitted dispatch."""
        with self.dist.telemetry.span("solve_heads", engine="personalization"):
            return self._solve_heads(state, packed)

    def _solve_heads(
        self, state: Fed3RFactored, packed: PackedPersonalCohort
    ) -> PersonalizedHeads:
        self.dist.dispatch()
        W, alphas, score = self._solve(
            state.L,
            state.b,
            jnp.asarray(packed.inputs),
            jnp.asarray(packed.labels),
            jnp.asarray(packed.mask),
            jnp.asarray(packed.holdout),
        )
        return PersonalizedHeads(
            W=W, alpha=alphas, score=score,
            client_ids=jnp.asarray(packed.client_ids),
        )

    def solve_at(
        self,
        state: Fed3RFactored,
        packed: PackedPersonalCohort,
        alphas: jax.Array,  # (K,) per-client weights, no selection sweep
    ) -> PersonalizedHeads:
        """Solve K heads at fixed per-client α_k in ONE jitted dispatch."""
        with self.dist.telemetry.span("solve_at", engine="personalization"):
            return self._solve_at_host(state, packed, alphas)

    def _solve_at_host(
        self,
        state: Fed3RFactored,
        packed: PackedPersonalCohort,
        alphas: jax.Array,
    ) -> PersonalizedHeads:
        self.dist.dispatch()
        a = jnp.asarray(alphas, jnp.float32)
        W = self._solve_at(
            state.L,
            state.b,
            jnp.asarray(packed.inputs),
            jnp.asarray(packed.labels),
            jnp.asarray(packed.mask),
            a,
        )
        return PersonalizedHeads(
            W=W, alpha=a, score=jnp.zeros_like(a),
            client_ids=jnp.asarray(packed.client_ids),
        )


class ReferencePersonalizedLoop:
    """The per-client shape: K+1 jitted dispatches for a K-head cohort.

    One global ``factored_solution`` (what a non-personalized server would
    serve) plus one per-client re-solve each — client statistics and the
    d×d refactorization re-dispatched per tenant.  Kept as the dispatch
    baseline and the numerical parity oracle the batched engine is measured
    against (``benchmarks/bench_personalize.py``).
    """

    def __init__(self, cfg: PersonalizeConfig):
        self.cfg = cfg
        self.dispatches = 0

        def one(L, b, x, y, m, a):
            stats = fed3r.client_stats(x, y, cfg.n_classes, m)
            return fed3r.personalized_solution(
                Fed3RFactored(L=L, b=b), stats, a, cfg.normalize
            )

        self._global = jax.jit(
            lambda L, b: fed3r.factored_solution(
                Fed3RFactored(L=L, b=b), cfg.normalize
            )
        )
        self._one = jax.jit(one)

    def solve_at(
        self,
        state: Fed3RFactored,
        packed: PackedPersonalCohort,
        alphas: jax.Array,  # (K,)
    ) -> Tuple[jax.Array, jax.Array]:
        """Returns (global W, stacked per-client heads (K, d, C))."""
        W_g = self._global(state.L, state.b)
        self.dispatches += 1
        heads = []
        for k in range(packed.cohort):
            heads.append(
                self._one(
                    state.L,
                    state.b,
                    jnp.asarray(packed.inputs[k]),
                    jnp.asarray(packed.labels[k]),
                    jnp.asarray(packed.mask[k]),
                    jnp.asarray(alphas[k], jnp.float32),
                )
            )
            self.dispatches += 1
        return W_g, jnp.stack(heads)


def cohort_stats(packed: PackedPersonalCohort, n_classes: int) -> Fed3RStats:
    """Fold the whole cohort's masked statistics — the secure-agg oracle.

    The sum of per-client (A_k, b_k, n_k) over the packed cohort: what the
    server's aggregate must equal whether uploads are masked (secure
    aggregation) or not, and a convenient parity anchor for tests.
    """
    K, N = packed.labels.shape
    feats = jnp.asarray(packed.inputs).reshape((K * N,) + packed.inputs.shape[2:])
    return fed3r.client_stats(
        feats,
        jnp.asarray(packed.labels).reshape(-1),
        n_classes,
        jnp.asarray(packed.mask).reshape(-1),
    )
