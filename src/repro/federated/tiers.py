"""Hierarchical N-tier aggregation trees — edge → region → cloud.

Fed3R's statistics are ORDER-INVARIANT additive sums (paper §4.3): any
reduction topology yields the same A/b, so topology is a free performance
variable.  This module generalizes
:func:`repro.federated.dist.two_stage_psum` (one psum per mesh axis,
innermost first) into an arbitrary N-tier reduction tree where every tier
owns

* a BATCHING WINDOW — ``fan_in`` child payloads fold in ONE fixed order
  per tier, so with fp32 wires the final ``W`` stays bitwise equal to the
  flat psum on the engines' grid-exact statistics;
* a WIRE FORMAT — the payload crosses each boundary compressed
  (:mod:`repro.federated.compress`) and is dequantized exactly ONCE per
  boundary through the fused dequantize-accumulate path (int8 on the slow
  WAN tier, fp32 on ICI);
* a STALENESS BUDGET — how many segments the tier's upward reduction may
  trail the newest arrival, riding the PR-8 async ring semantics (the
  budget is the depth of the pending-reduction ring).

Two execution forms share one :class:`AggregationTree`:

* :meth:`AggregationTree.psum` — inside ``shard_map``: one psum per
  MESH-TIER axis, leaf tier first, each crossing optionally compressed.
  ``DistConfig(tree=...)`` routes every engine's
  :meth:`repro.federated.dist.DistContext.all_reduce` through it; with
  fp32 wires the emitted program is the two-stage psum generalized to N
  axes (bitwise identical at N ≤ 2 by construction).
* :meth:`AggregationTree.fold_stacked` / :class:`TieredAbsorber` — the
  host-tier form: stacked child payloads fold tier by tier inside ONE
  jitted program, and the absorber OVERLAPS the upper-tier (DCN/WAN)
  reduction + refactorization of segment t with the lower-tier fold and
  feature extraction of segment t+1 (double-buffered donated accumulators:
  the upper program donates the carried state while the next segment's
  lower program is already on the async dispatch stream).

Every tier crossing is metered through the unified telemetry registry —
``tier_wire_bytes_total{tier=...}`` / ``tier_batches_total{tier=...}``
counters, ``tier_lower``/``tier_upper`` spans, an overlap-efficiency gauge,
and flight-recorder events (``tier_batch_flushed``,
``tier_staleness_exceeded``, ``tier_wire_fallback``) that
``repro.launch.obs_report`` renders as the tree.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.federated import compress
from repro.federated.compress import WireFormat
from repro.federated.costs import stats_wire_bytes
from repro.federated.dist import DistConfig, DistContext, donate_argnums
from repro.federated.engine import shard_stats
from repro.federated.telemetry import Telemetry
from repro.launch.mesh import ICI_BW

# tier boundaries carry arbitrary statistics pytrees, so only the
# per-matrix formats are valid tier wires (sketch is a client-uplink
# format for PSD second moments, not a generic boundary format)
TIER_WIRE_KINDS = ("fp32", "int8", "fp8")


@dataclass(frozen=True)
class TierSpec:
    """One tier of the aggregation tree.

    ``fan_in`` is the tier's batching window: how many child payloads fold
    into one parent payload (for a mesh tier, the axis size).  ``wire`` is
    the format each child crosses this boundary in; ``bandwidth`` prices
    the crossing (``CostModel.tiered_allreduce``); ``staleness`` is the
    tier's pending-reduction budget in segments (only the TOP tier's
    budget drives the :class:`TieredAbsorber` pipeline depth); ``axis``
    names the mesh axis when the tier is a collective stage (``None`` for
    host-level tiers).
    """

    name: str
    fan_in: int
    wire: WireFormat = field(default_factory=WireFormat)
    bandwidth: float = ICI_BW
    staleness: int = 0
    axis: Optional[str] = None

    def __post_init__(self):
        if self.fan_in < 1:
            raise ValueError(f"tier {self.name!r}: fan_in must be >= 1, got {self.fan_in}")
        if self.staleness < 0:
            raise ValueError(
                f"tier {self.name!r}: staleness must be >= 0, got {self.staleness}"
            )
        if self.bandwidth <= 0:
            raise ValueError(
                f"tier {self.name!r}: bandwidth must be > 0, got {self.bandwidth}"
            )
        if self.wire.kind not in TIER_WIRE_KINDS:
            raise ValueError(
                f"tier {self.name!r}: wire kind {self.wire.kind!r} is not a "
                f"tier-boundary format (expected one of {TIER_WIRE_KINDS})"
            )


def _wire_leaf(x: Any) -> bool:
    """Leaves the tier wire applies to: ≥2-D float matrices (the d² Gram
    and d·C class-sum payloads).  Scalars and 1-D sidecars (sample counts,
    class counts) stay exact fp32 — the same convention as the engines'
    uplink compression."""
    return jnp.ndim(x) >= 2 and jnp.issubdtype(jnp.result_type(x), jnp.floating)


def _roundtrip_nd(x: jax.Array, fmt: WireFormat, use_kernel: Optional[bool]) -> jax.Array:
    """Per-matrix wire roundtrip, vmapped over any leading stack axes."""
    if x.ndim == 2:
        return compress.matrix_roundtrip(x, fmt, use_kernel)
    return jax.vmap(lambda m: _roundtrip_nd(m, fmt, use_kernel))(x)


def _roundtrip_add_nd(
    acc: jax.Array, x: jax.Array, fmt: WireFormat, use_kernel: Optional[bool]
) -> jax.Array:
    """Fused dequantize-accumulate, vmapped over any leading stack axes."""
    if x.ndim == 2:
        return compress.matrix_roundtrip_add(acc, x, fmt, use_kernel)
    return jax.vmap(lambda a, m: _roundtrip_add_nd(a, m, fmt, use_kernel))(acc, x)


@dataclass(frozen=True)
class AggregationTree:
    """An N-tier reduction tree, LEAF TIER FIRST (edge → region → cloud).

    ``leaves`` child payloads enter the first tier; each tier folds
    ``fan_in`` children per group, so tier i receives
    ``prod(fan_in[i:])`` payloads per reduction.  The fp32 tree is an
    exact reassociation of the flat sum — bitwise equal on the engines'
    grid-exact statistics for ANY fan-in assignment and tier permutation.
    """

    tiers: Tuple[TierSpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers:
            raise ValueError("an aggregation tree needs at least one tier")
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"tier names must be unique, got {names}")
        axes = [t.axis for t in self.tiers if t.axis is not None]
        if len(set(axes)) != len(axes):
            raise ValueError(f"mesh-tier axes must be unique, got {axes}")

    @property
    def leaves(self) -> int:
        n = 1
        for t in self.tiers:
            n *= t.fan_in
        return n

    @property
    def axes(self) -> Tuple[str, ...]:
        """Mesh axes of the collective tiers, leaf tier first."""
        return tuple(t.axis for t in self.tiers if t.axis is not None)

    @property
    def lossy_wire(self) -> Optional[WireFormat]:
        """The coarsest-boundary lossy wire (topmost non-fp32 tier), or
        ``None`` for an all-fp32 (bit-exact) tree.  Engines use it to pick
        the PSD-guarded Cholesky when a tree crossing quantizes."""
        for t in reversed(self.tiers):
            if t.wire.kind != "fp32":
                return t.wire
        return None

    def resolved(self) -> "AggregationTree":
        """Tier wires resolved for this backend (fp8 → int8 fallback)."""
        return AggregationTree(
            tuple(
                TierSpec(
                    name=t.name,
                    fan_in=t.fan_in,
                    wire=t.wire.resolved(),
                    bandwidth=t.bandwidth,
                    staleness=t.staleness,
                    axis=t.axis,
                )
                for t in self.tiers
            )
        )

    def validate_mesh_axes(self, axis_names: Sequence[str]) -> None:
        """A mesh-routed tree must cover the resolved reduce axes exactly,
        leaf tier on the INNERMOST axis — the same order
        :func:`repro.federated.dist.two_stage_psum` reduces in, which is
        what makes the fp32 tree program identical to the two-stage one."""
        want = tuple(reversed(tuple(axis_names)))
        if self.axes != want:
            raise ValueError(
                f"tree mesh axes {self.axes} must equal the reversed reduce "
                f"axes {want} (leaf tier innermost)"
            )

    # ---- collective form (inside shard_map) --------------------------------

    def psum(self, payload: Any, use_kernel: Optional[bool] = None) -> Any:
        """N-tier hierarchical all-reduce: per collective tier, LEAF FIRST,
        optionally wire-compress each device's partial (dequantized exactly
        once at the boundary), then psum over the tier's axis.  Host-level
        tiers (``axis=None``) are skipped — they fold via
        :meth:`fold_stacked`.  With fp32 wires this is exactly
        ``two_stage_psum`` generalized to N axes."""
        for tier in self.tiers:
            if tier.axis is None:
                continue
            if tier.wire.kind != "fp32":
                payload = jax.tree.map(
                    lambda x, t=tier: _roundtrip_nd(x, t.wire, use_kernel)
                    if _wire_leaf(x)
                    else x,
                    payload,
                )
            payload = jax.tree.map(
                partial(jax.lax.psum, axis_name=tier.axis), payload
            )
        return payload

    # ---- host-tier form (stacked fixed-order folds) ------------------------

    def fold_stacked(
        self,
        payload: Any,
        tiers: Optional[Sequence[TierSpec]] = None,
        use_kernel: Optional[bool] = None,
    ) -> Any:
        """Fold stacked child payloads tier by tier, one FIXED-ORDER fold
        per tier (groups of ``fan_in`` along the leading axis, children
        accumulated left to right).  Lossy tiers cross every child through
        the fused dequantize-accumulate; fp32 tiers are a strict left fold
        (an exact reassociation of the flat sum).  Returns the stacked
        parents of the last folded tier."""
        for tier in self.tiers if tiers is None else tuple(tiers):
            k = tier.fan_in

            def fold_leaf(x, tier=tier, k=k):
                if x.shape[0] % k:
                    raise ValueError(
                        f"tier {tier.name!r}: {x.shape[0]} stacked children "
                        f"do not group by fan_in={k}"
                    )
                g = x.reshape((x.shape[0] // k, k) + x.shape[1:])
                lossy = tier.wire.kind != "fp32" and _wire_leaf(g[:, 0])
                if lossy:
                    acc = jnp.zeros_like(g[:, 0], dtype=jnp.float32)
                    for i in range(k):
                        acc = _roundtrip_add_nd(acc, g[:, i], tier.wire, use_kernel)
                    return acc
                acc = g[:, 0]
                for i in range(1, k):
                    acc = acc + g[:, i]
                return acc

            payload = jax.tree.map(fold_leaf, payload)
        return payload

    def reduce(self, payloads: Sequence[Any], use_kernel: Optional[bool] = None) -> Any:
        """Reduce exactly ``leaves`` child payload pytrees through the full
        tree (host-level convenience over :meth:`fold_stacked`)."""
        payloads = list(payloads)
        if len(payloads) != self.leaves:
            raise ValueError(
                f"tree with fan-ins {tuple(t.fan_in for t in self.tiers)} "
                f"reduces {self.leaves} leaf payloads, got {len(payloads)}"
            )
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
        folded = self.fold_stacked(stacked, use_kernel=use_kernel)
        return jax.tree.map(lambda x: x[0], folded)

    # ---- pricing ------------------------------------------------------------

    def as_cost_tiers(self) -> Tuple[dict, ...]:
        """The plain-data tier description ``CostModel.tiered_allreduce``
        prices (keeps :mod:`repro.federated.costs` jax-free)."""
        return tuple(
            {
                "name": t.name,
                "fan_in": t.fan_in,
                "wire": t.wire.kind,
                "bandwidth": t.bandwidth,
                "tile": t.wire.tile,
            }
            for t in self.tiers
        )


def two_stage_tree(axis_names: Sequence[str]) -> AggregationTree:
    """The fp32 tree equivalent of today's two-stage psum over
    ``axis_names`` (outermost first, as :class:`DistConfig` resolves them):
    routing ``DistConfig(tree=two_stage_tree(axes))`` is bitwise identical
    to routing without a tree."""
    names = tuple(axis_names)
    if not names:
        raise ValueError("two_stage_tree needs at least one mesh axis")
    return AggregationTree(
        tuple(TierSpec(name=ax, fan_in=1, axis=ax) for ax in reversed(names))
    )


def mesh_tree(
    mesh: jax.sharding.Mesh,
    wires: Optional[dict] = None,
    bandwidths: Optional[dict] = None,
) -> AggregationTree:
    """An N-tier tree over a tier mesh (:func:`repro.launch.mesh.
    make_tier_host_mesh`): one collective tier per batch-carrying axis,
    innermost (leaf/edge) first, fan-in = axis size.  ``wires`` /
    ``bandwidths`` map axis name → per-tier overrides."""
    from repro.launch.mesh import data_axes

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    wires = wires or {}
    bandwidths = bandwidths or {}
    tiers = []
    for ax in reversed(data_axes(mesh)):
        kwargs = {}
        if ax in wires:
            kwargs["wire"] = wires[ax]
        if ax in bandwidths:
            kwargs["bandwidth"] = bandwidths[ax]
        tiers.append(TierSpec(name=ax, fan_in=sizes[ax], axis=ax, **kwargs))
    return AggregationTree(tuple(tiers))


class TieredAbsorber:
    """Overlapped N-tier absorb pipeline over a streaming engine.

    Each SEGMENT is one batch of ``tree.leaves`` edge payload blocks —
    ``(leaves, N, ...)`` features/labels/mask.  The pipeline splits the
    work at the top-tier boundary into two jitted programs:

    * LOWER — feature extraction, per-leaf masked statistics, and every
      tier fold below the top (the fast intra-region legs);
    * UPPER — the top-tier (DCN/WAN) crossing, Gram refactorization and
      solve, donating the carried :class:`StreamState` (the double-buffered
      accumulator: while segment t's upper program runs, segment t+1's
      lower program is already on the dispatch stream).

    With ``overlap=True`` the upper reduction of segment t is issued AFTER
    the lower dispatch of segment t+1, so the slow top-tier leg overlaps
    the next segment's extraction; the top tier's ``staleness`` budget
    bounds how many segments the served classifier may trail (the PR-8
    ring semantics — exceeding the budget forces the oldest pending
    reduction and logs ``tier_staleness_exceeded``).  ``overlap=False``
    fuses both programs into ONE blocking dispatch per segment — the
    two-stage baseline generalized to N tiers, bitwise equal to the
    overlapped result and to ``engine.absorb_stats`` of the flat sum.
    """

    def __init__(
        self,
        engine: Any,  # StreamingEngine (duck-typed)
        tree: AggregationTree,
        *,
        overlap: bool = True,
        cost_model: Optional[Any] = None,
        telemetry: Optional[Telemetry] = None,
    ):
        if any(t.axis is not None for t in tree.tiers):
            raise ValueError(
                "TieredAbsorber folds host-level tiers; mesh tiers "
                "(axis=...) route through DistConfig(tree=...) instead"
            )
        if engine.cfg.dist.mesh is not None or engine.cfg.dist.aggregation != "merge":
            raise ValueError(
                "TieredAbsorber owns the reduction topology; give it a "
                "merge-backend engine without a dist-owned mesh"
            )
        if engine.wire.kind != "fp32":
            raise ValueError(
                "tier wires own the compression here; use an fp32 engine "
                "wire and put int8/fp8 on the tree's tiers"
            )
        self.engine = engine
        self.tree = tree.resolved()
        for before, after in zip(tree.tiers, self.tree.tiers):
            if before.wire.kind != after.wire.kind:
                tel = telemetry if telemetry is not None else engine.dist.telemetry
                tel.event(
                    "tier_wire_fallback",
                    tier=after.name,
                    requested=before.wire.kind,
                    using=after.wire.kind,
                )
        top = self.tree.tiers[-1]
        self.depth = top.staleness if overlap else 0
        if overlap and self.depth < 1:
            raise ValueError(
                "overlap needs a top-tier staleness budget >= 1 "
                "(the pending-reduction ring depth); got "
                f"staleness={top.staleness}"
            )
        self.dist = DistContext(
            DistConfig(),
            engine="tiers",
            telemetry=telemetry if telemetry is not None else engine.dist.telemetry,
        )
        self.telemetry = self.dist.telemetry
        self.cost_model = cost_model
        donate = donate_argnums(engine.cfg.dist.donate)
        self._lower_fn = jax.jit(self._lower_impl)
        self._upper_fn = jax.jit(self._upper_impl, donate_argnums=donate)
        self._blocking_fn = jax.jit(self._blocking_impl, donate_argnums=donate)
        self._pending: deque = deque()
        self._state = None
        self._segments = 0
        self._absorb_syncs = 0
        self._bytes_by_tier = {t.name: 0.0 for t in self.tree.tiers}

    # ---- jitted cores -------------------------------------------------------

    def _leaf_payload(self, feats, labels, mask, params):
        """Per-leaf masked statistics: feature extraction over the whole
        segment (the packed-flat idiom of the engines), then one vmapped
        fused stats GEMM per edge block."""
        eng = self.engine
        leaves = feats.shape[0]
        flat = feats.reshape((leaves * feats.shape[1],) + feats.shape[2:])
        if eng.feature_fn is not None:
            flat = eng.feature_fn(params, flat)
        if getattr(eng, "rff_params", None) is not None:
            from repro.core.random_features import rff_map

            flat = rff_map(eng.rff_params, flat)
        phi = flat.reshape((leaves, feats.shape[1], flat.shape[-1]))
        stats = jax.vmap(
            lambda x, y, m: shard_stats(
                x, y, eng.cfg.n_classes, m, use_kernel=eng.cfg.use_kernel
            )
        )(phi, labels, mask)
        return (stats.A, stats.b, stats.n.astype(jnp.float32))

    def _lower_impl(self, feats, labels, mask, params):
        payload = self._leaf_payload(feats, labels, mask, params)
        return self.tree.fold_stacked(
            payload, tiers=self.tree.tiers[:-1], use_kernel=self.engine.cfg.use_kernel
        )

    def _upper_impl(self, state, children):
        top = self.tree.tiers[-1]
        S, dB, nw = jax.tree.map(
            lambda x: x[0],
            self.tree.fold_stacked(
                children, tiers=(top,), use_kernel=self.engine.cfg.use_kernel
            ),
        )
        G = state.L @ state.L.T + S
        if top.wire.kind in ("int8", "fp8"):
            L = compress.psd_cholesky(G, compress.quant_spectral_bound(S, top.wire))
        else:
            L = jnp.linalg.cholesky(G)
        b = state.b + dB
        return state._replace(
            L=L,
            b=b,
            n=state.n + nw,
            W=self.engine._solve(L, b),
            wave=state.wave + 1,
            stale_waves=jnp.zeros((), jnp.int32),
            stale_samples=jnp.zeros((), jnp.float32),
        )

    def _blocking_impl(self, state, feats, labels, mask, params):
        return self._upper_impl(state, self._lower_impl(feats, labels, mask, params))

    # ---- host pipeline ------------------------------------------------------

    def reset(self, d: int) -> None:
        """(Re)initialize the carried state for feature dimension ``d``."""
        self._pending.clear()
        self._state = self.engine.init(d)
        self._segments = 0
        self._absorb_syncs = 0
        self._bytes_by_tier = {t.name: 0.0 for t in self.tree.tiers}

    def _account_tiers(self, tiers, entering: int) -> int:
        """Meter one segment's crossings for the given tiers: ``entering``
        payloads arrive at the first of them; each tier folds ``fan_in``
        children per batch.  Pure host-side integer math — zero jax."""
        d, C = self._state.L.shape[0], self.engine.cfg.n_classes
        level = {t.name: i for i, t in enumerate(self.tree.tiers)}
        for t in tiers:
            per_child = stats_wire_bytes(d, C, t.wire.kind, tile=t.wire.tile)
            nbytes = entering * per_child
            self._bytes_by_tier[t.name] += nbytes
            self.telemetry.counter(
                "tier_wire_bytes_total", tier=t.name, level=level[t.name],
                wire=t.wire.kind,
            ).inc(int(nbytes))
            self.telemetry.counter(
                "tier_batches_total", tier=t.name, level=level[t.name]
            ).inc(entering // t.fan_in)
            self.telemetry.event(
                "tier_batch_flushed",
                tier=t.name,
                children=entering,
                batches=entering // t.fan_in,
                wire=t.wire.kind,
            )
            entering //= t.fan_in
        return entering

    def _flush_one(self) -> None:
        children = self._pending.popleft()
        with self.telemetry.span("tier_upper", engine="tiers"):
            self.dist.dispatch()
            self._state = self._upper_fn(self._state, children)
        self._account_tiers((self.tree.tiers[-1],), self.tree.tiers[-1].fan_in)

    def absorb_segment(self, feats, labels, mask, params: Any = None) -> None:
        """Absorb one segment of ``tree.leaves`` edge blocks.

        Blocking mode (``overlap=False``): ONE fused dispatch, host-synced
        per segment.  Overlapped mode: the segment's LOWER program is
        dispatched immediately; its UPPER (top-tier) reduction is deferred
        onto the pending ring and issued once a newer segment is in flight
        (or at :meth:`drain`), never letting the ring exceed the top
        tier's staleness budget.
        """
        feats = jnp.asarray(feats)
        labels = jnp.asarray(labels)
        mask = jnp.asarray(mask)
        if feats.shape[0] != self.tree.leaves:
            raise ValueError(
                f"segment carries {feats.shape[0]} edge blocks; the tree "
                f"folds {self.tree.leaves}"
            )
        if self._state is None:
            if self.engine.feature_fn is not None:
                raise ValueError(
                    "feature_fn hides the feature dim; call reset(d) first"
                )
            self.reset(int(feats.shape[-1]))
        if self.depth == 0:
            with self.telemetry.span("tier_absorb", engine="tiers"):
                self.dist.dispatch()
                self._state = self._blocking_fn(
                    self._state, feats, labels, mask, params
                )
            jax.block_until_ready(self._state.W)
            self._absorb_syncs += 1
            self._segments += 1
            self._account_tiers(self.tree.tiers, self.tree.leaves)
            return
        with self.telemetry.span("tier_lower", engine="tiers"):
            self.dist.dispatch()
            children = self._lower_fn(feats, labels, mask, params)
        self._segments += 1
        self._account_tiers(self.tree.tiers[:-1], self.tree.leaves)
        self._pending.append(children)
        while len(self._pending) > self.depth:
            self.telemetry.event(
                "tier_staleness_exceeded",
                tier=self.tree.tiers[-1].name,
                pending=len(self._pending),
                budget=self.depth,
            )
            self._flush_one()

    def classifier(self):
        """The currently served W — trails the newest segment by at most
        the top tier's staleness budget."""
        if self._state is None:
            raise ValueError("no segments absorbed yet")
        return self._state.W

    def drain(self):
        """Retire every pending reduction, sync, and publish the gauges.

        ``tier_overlap_efficiency`` = 1 − host_syncs/segments over the
        absorb phase: 0.0 for the blocking path (one sync per segment),
        → 1.0 when every upper reduction overlapped a newer segment.
        With a ``cost_model``, ``tier_cost_model_drift`` compares metered
        tier bytes against ``CostModel.tiered_allreduce``'s prediction.
        """
        while self._pending:
            self._flush_one()
        jax.block_until_ready(self._state.W)
        if self._segments:
            eff = 1.0 - self._absorb_syncs / self._segments
            self.telemetry.gauge("tier_overlap_efficiency").set(eff)
        if self.cost_model is not None and self._segments:
            priced = self.cost_model.tiered_allreduce(self.tree.as_cost_tiers())
            model = priced["uplink_bytes_total"] * self._segments
            measured = sum(self._bytes_by_tier.values())
            if model > 0:
                self.telemetry.gauge("tier_cost_model_drift").set(measured / model)
        return self._state
