"""Unified distributed execution layer shared by the four engines.

Before this module, every engine (batch statistics, rounds, streaming,
personalization) carried its own copy of the same plumbing: the
``use_kernel`` auto-resolution, the ``donate_argnums`` backend policy, the
``merge|psum`` aggregation validation, a host-side dispatch counter, and —
for mesh runs — an externally-applied ``shard_map`` the caller had to
assemble by hand.  This module owns all of it:

* :func:`resolve_use_kernel` — ONE definition of the Pallas-vs-XLA auto
  rule (compiled Pallas on TPU; XLA GEMMs elsewhere).
* :func:`donate_argnums` — ONE definition of the donation policy (donate
  the carried state everywhere except CPU, where XLA ignores donation and
  warns).
* :class:`DistConfig` — the shared distributed-execution configuration the
  per-engine ``aggregation``/``mesh_axes``/``donate`` fields migrated
  into.  ``mesh=None`` keeps today's behavior (plain jit; ``"psum"`` mode
  is then for cores wrapped in an *external* shard_map).  ``mesh=Mesh``
  makes the layer own the scale-out: the engine core is wrapped in
  ``shard_map`` over the mesh, its batch-carrying leading axis sharded
  over the data axes (everything but ``"model"`` — on the multi-pod
  production mesh that is ``("pod", "data")``).
* :class:`DistContext` — the per-engine handle: dispatch counting,
  :meth:`DistContext.all_reduce` (identity under ``"merge"``; the
  TWO-STAGE psum under ``"psum"``), and :meth:`DistContext.jit` which
  builds the ``jit(shard_map(core))`` program from PartitionSpecs.
* :func:`dist_jit` — the functional core of :meth:`DistContext.jit`.
* :func:`two_stage_psum` — the hierarchical all-reduce: one psum per mesh
  axis, INNERMOST FIRST, so on a ``("pod", "data")`` mesh the d² statistics
  reduce over the fast intra-pod ICI before the small cross-pod DCN stage
  touches the wire (the tiered device/edge/cloud aggregation of the
  heterogeneous-FL systems literature, as collectives).  The per-stage
  bytes/latency are costed by ``repro.federated.costs.CostModel``
  (``two_stage_allreduce(..., wire=...)`` re-prices the moving payload
  under the compressed statistics formats; the engines feed their wire
  roundtrip into :meth:`DistContext.all_reduce` via ``wire_fn`` so the
  reduced payload actually IS the compressed one).

The two-stage psum is the N=2 point of a general family:
``DistConfig(tree=AggregationTree(...))`` (:mod:`repro.federated.tiers`)
routes :meth:`DistContext.all_reduce` through an N-TIER reduction tree —
one collective tier per mesh axis, leaf (edge) tier innermost, each tier
carrying its own wire format priced at its own bandwidth
(``CostModel.tiered_allreduce``).  An all-fp32 tree emits exactly the
two-stage program, so tree routing is bitwise backward compatible; the
engine's ``wire_fn`` stays the LEAF-side hook and is applied before the
first tier crossing.


Scheduling note: the engines place their all-reduce *after* the shard
scan wherever the algebra allows (batch statistics, rounds), so feature
extraction — the expensive leg of the scan — never serializes against
per-step collectives and XLA's async collectives overlap the reduction
with the epilogue.  The streaming engine's per-wave psum is inherently on
the critical path (wave t+1's factor depends on the reduced wave-t Gram);
its ``refresh_every`` policy bounds the solve cost instead.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax

from repro.federated.telemetry import Telemetry, get_telemetry
from repro.launch.mesh import data_axes, data_parallel_size
from repro.sharding.specs import data_parallel_spec


def resolve_use_kernel(use_kernel: Optional[bool]) -> bool:
    """Auto: compiled Pallas on TPU; XLA GEMMs elsewhere (interpret mode is
    for validation, not production CPU throughput)."""
    return jax.default_backend() == "tpu" if use_kernel is None else use_kernel


def donate_argnums(donate: bool, argnums: Tuple[int, ...] = (0,)) -> Tuple[int, ...]:
    """The shared donation policy: donate the carried state to the dispatch
    everywhere except CPU, where XLA ignores donation (and warns)."""
    return argnums if donate and jax.default_backend() != "cpu" else ()


def validate_backend(aggregation: str, axis_names: Tuple[str, ...]) -> None:
    """The merge|psum validation every engine used to re-implement."""
    if aggregation not in ("merge", "psum"):
        raise ValueError(f"unknown aggregation backend: {aggregation!r}")
    if aggregation == "psum" and not axis_names:
        raise ValueError("psum aggregation needs at least one mesh axis")


def _shard_map(fn: Callable, mesh, in_specs, out_specs) -> Callable:
    """Version-portable shard_map (``jax.shard_map`` when public, else the
    ``jax.experimental`` path), replication checking off: engine outputs are
    made replicated by explicit psums, not by tracked rep-sets, and the
    Pallas kernels inside the cores have no rep rules."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:  # older signature spells it check_rep
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as sm_experimental

    return sm_experimental(fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)


def shard_cohort(
    cohort: Tuple[int, ...], shard: int, n_shards: int
) -> Tuple[int, ...]:
    """Deterministic partition of a (possibly partial) cohort across shards.

    The psum-mode contract of the merge-on-arrival engine
    (:mod:`repro.federated.async_engine`): each shard scatters ONLY the
    uploads of the clients it owns — ``shard_cohort(cohort, i, n)`` for
    shard i — leaving every other slot an exact zero, and the retire
    all-reduce reassembles the full cohort sum.  Round-robin by sorted
    cohort position, so the partition is independent of arrival order,
    covers every client exactly once, and stays balanced even when the
    cohort is PARTIAL (fewer clients than slots: late joiners, demoted
    stragglers dropped by the health tracker).
    """
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range for {n_shards} shards")
    ordered = sorted(int(c) for c in cohort)
    return tuple(c for i, c in enumerate(ordered) if i % n_shards == shard)


def linear_shard_index(axis_names: Tuple[str, ...]):
    """The caller's linearized position over the given mesh axes (valid
    inside shard_map) — row-major in axis order, matching how a
    ``PartitionSpec`` with a tuple entry linearizes the axes.  The
    dist-owned async scatter uses it to find which slot block of the
    sharded ring this device owns."""
    idx = 0
    for ax in axis_names:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx


def two_stage_psum(tree: Any, axis_names: Tuple[str, ...]) -> Any:
    """Hierarchical all-reduce: one psum per axis, innermost (last) first.

    On the multi-pod mesh ``axis_names=("pod", "data")`` this reduces over
    the intra-pod ICI ring first and ships only the already-reduced d²
    statistics across the DCN — the two stages XLA can also schedule as
    separate async collectives.  For a single axis it is exactly one psum
    (bit-identical to the pre-refactor engines).
    """
    for ax in reversed(tuple(axis_names)):
        tree = jax.tree.map(partial(jax.lax.psum, axis_name=ax), tree)
    return tree


def dist_jit(
    fn: Callable,
    *,
    mesh: Optional[jax.sharding.Mesh] = None,
    in_specs: Any = None,
    out_specs: Any = None,
    donate: Tuple[int, ...] = (),
) -> Callable:
    """The one jit entry point of the engines.

    ``mesh=None``: plain ``jax.jit`` (single-process; the scan carry IS the
    aggregation).  ``mesh=Mesh``: ``jax.jit(shard_map(fn, mesh, in_specs,
    out_specs))`` — the engine core runs as one SPMD program per device
    over its shard of the batch-carrying axis, still ONE host dispatch.
    ``donate`` is already-resolved argnums (see :func:`donate_argnums`).
    """
    if mesh is not None:
        fn = _shard_map(fn, mesh, in_specs, out_specs)
    return jax.jit(fn, donate_argnums=tuple(donate))


@dataclass(frozen=True)
class DistConfig:
    """Shared distributed-execution configuration of the four engines.

    ``aggregation``:
      * ``"merge"`` — single-process: the associative scan/Python-level sum
        already produced the global result; ``mesh`` must be ``None``.
      * ``"psum"`` — distributed: local partials are all-reduced over the
        data axes.  With ``mesh=None`` the engine core must be wrapped in
        an EXTERNAL shard_map over ``mesh_axes`` (the pre-refactor
        contract, kept for composability).  With ``mesh=Mesh`` the dist
        layer owns the shard_map and the engine's host API transparently
        scales out.

    ``mesh_axes`` names the reduce axes explicitly; empty with a ``mesh``
    defaults to every non-``"model"`` axis of the mesh (``("pod", "data")``
    on the multi-pod production mesh).  ``donate`` is the donate-the-state
    policy (applied through :func:`donate_argnums`).

    ``tree`` routes :meth:`DistContext.all_reduce` through an N-tier
    :class:`repro.federated.tiers.AggregationTree` instead of the
    two-stage psum: one collective tier per reduce axis, LEAF TIER
    INNERMOST (the tree's axes must equal the reversed resolved axes), so
    an all-fp32 tree emits the identical program and stays bitwise
    backward compatible, while per-tier wire formats compress the slow
    upper crossings.  Requires ``"psum"``.
    """

    aggregation: str = "merge"  # "merge" | "psum"
    mesh_axes: Tuple[str, ...] = ()  # reduce axes ("psum"); () + mesh → data axes
    mesh: Optional[jax.sharding.Mesh] = None  # shard_map mesh (dist-owned scale-out)
    donate: bool = True  # donate the carried state to the dispatch
    tree: Optional[Any] = None  # N-tier AggregationTree (repro.federated.tiers)

    def __post_init__(self):
        if self.aggregation not in ("merge", "psum"):
            raise ValueError(f"unknown aggregation backend: {self.aggregation!r}")
        if self.aggregation == "merge" and self.mesh is not None:
            raise ValueError(
                "mesh-mode execution all-reduces device partials: use "
                "aggregation='psum' (merge is the single-process backend)"
            )
        axes = self.mesh_axes or (
            data_axes(self.mesh) if self.mesh is not None else ()
        )
        if self.aggregation == "psum" and not axes:
            raise ValueError("psum aggregation needs at least one mesh axis")
        if self.mesh is not None:
            unknown = set(axes) - set(self.mesh.axis_names)
            if unknown:
                raise ValueError(
                    f"mesh_axes {sorted(unknown)} not in mesh axes "
                    f"{self.mesh.axis_names}"
                )
        if self.tree is not None:
            if self.aggregation != "psum":
                raise ValueError(
                    "an aggregation tree routes the psum backend; merge "
                    "has no collective to tier"
                )
            # duck-typed (tiers.py imports this module); the tree's
            # collective tiers must cover the reduce axes leaf-innermost
            self.tree.validate_mesh_axes(axes)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        """The resolved reduce axes (explicit, or the mesh's data axes)."""
        if self.mesh_axes:
            return tuple(self.mesh_axes)
        return data_axes(self.mesh) if self.mesh is not None else ()

    @property
    def data_shards(self) -> int:
        """Data-parallel way count of the owned mesh (1 without a mesh)."""
        return 1 if self.mesh is None else data_parallel_size(self.mesh)

    @property
    def lossy_tier_wire(self) -> Optional[Any]:
        """The routed tree's coarsest lossy tier wire (``None`` when the
        reduction is bit-exact) — engines consult it to pick the
        PSD-guarded Cholesky when a tree crossing quantizes."""
        return None if self.tree is None else self.tree.lossy_wire


class DistContext:
    """Per-engine handle on the distributed execution layer.

    Owns the host→device dispatch counter every engine used to carry —
    now homed in the unified telemetry registry
    (:mod:`repro.federated.telemetry`) as the labeled series
    ``engine_dispatches_total{engine=<name>, inst=<n>}``, one counter
    cell per context so N same-type engines stay independently
    resettable — plus the aggregation backend (:meth:`all_reduce`) and
    program construction (:meth:`jit`).  Engines keep their
    ``.dispatches`` attribute as a property proxying this counter
    (:class:`DistDispatchMixin`), so benchmarks keep working unchanged;
    the CI regression gate reads the SAME cells back out of the
    ``telemetry_*.json`` snapshots, so the two can't diverge.
    """

    def __init__(
        self,
        cfg: DistConfig,
        *,
        engine: str = "engine",
        telemetry: Optional[Telemetry] = None,
    ):
        self.cfg = cfg
        # registry captured at construction (process-global by default,
        # injectable for tests/benches); spans/events ride the same handle
        self.telemetry = get_telemetry() if telemetry is None else telemetry
        inst = self.telemetry.next_instance(f"dist:{engine}")
        self._dispatches = self.telemetry.counter(
            "engine_dispatches_total", engine=engine, inst=inst
        )

    @property
    def dispatches(self) -> int:
        """Host→device dispatch count (a telemetry counter cell)."""
        return int(self._dispatches.value)

    @dispatches.setter
    def dispatches(self, value: int) -> None:
        self._dispatches.set(int(value))

    def dispatch(self) -> None:
        """Record one host→device dispatch (call at each host-API entry).

        A plain integer add on a telemetry Counter — zero device work."""
        self._dispatches.inc()

    def all_reduce(self, tree: Any, wire_fn: Optional[Callable[[Any], Any]] = None) -> Any:
        """The server aggregation behind one interface: identity under
        ``"merge"`` (the local fold IS the global sum); the two-stage psum
        over the resolved axes under ``"psum"`` (valid inside shard_map).

        ``wire_fn`` is the compressed-uplink hook
        (:mod:`repro.federated.compress`): the engines pass their
        wire-format roundtrip so each device's LOCAL partial crosses the
        ICI/DCN wire in the configured format — compressed on the way out,
        dequantized ONCE at the aggregation boundary before the psum sums
        the received payloads.  ``None`` (and the ``"merge"`` backend,
        whose uplink compression happens per client inside the engine
        fold) keeps the reduce bit-exact fp32.

        With ``cfg.tree`` set, the reduction runs the N-tier aggregation
        tree instead — ``wire_fn`` stays the LEAF-side hook (applied
        before the first tier crossing), then each collective tier
        compresses + psums in leaf-first order.  All-fp32 trees emit the
        identical two-stage program."""
        if self.cfg.aggregation == "merge":
            return tree
        if wire_fn is not None:
            tree = wire_fn(tree)
        if self.cfg.tree is not None:
            return self.cfg.tree.psum(tree)
        return two_stage_psum(tree, self.cfg.axis_names)

    def data_spec(self, axis: int = 0):
        """The in/out PartitionSpec of a batch-carrying array: dim ``axis``
        sharded over the data axes in mesh mode, ``None`` (don't-care —
        :meth:`jit` ignores specs) without a mesh.  The one spec idiom
        every engine's program construction uses."""
        if self.cfg.mesh is None:
            return None
        return data_parallel_spec(self.cfg.axis_names, axis)

    def jit(
        self,
        fn: Callable,
        *,
        in_specs: Any = None,
        out_specs: Any = None,
        donate: Optional[bool] = None,
        donate_argnums_: Tuple[int, ...] = (0,),
    ) -> Callable:
        """Build the engine's one-dispatch program (see :func:`dist_jit`).

        ``in_specs``/``out_specs`` are only consulted in mesh mode; the
        donation default comes from the config (``donate=False`` opts a
        non-carrying engine out).
        """
        want = self.cfg.donate if donate is None else donate
        return dist_jit(
            fn,
            mesh=self.cfg.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            donate=donate_argnums(want, donate_argnums_),
        )


class DistDispatchMixin:
    """The engines' public ``.dispatches`` counter, proxied onto the owned
    :class:`DistContext` (``self.dist``) — which in turn homes it in the
    telemetry registry as ``engine_dispatches_total`` — kept settable
    because the benchmarks reset it between timed sections."""

    dist: DistContext

    @property
    def dispatches(self) -> int:
        """Host→device dispatch count (owned by the dist context)."""
        return self.dist.dispatches

    @dispatches.setter
    def dispatches(self, value: int) -> None:
        self.dist.dispatches = int(value)
