"""Secure aggregation of FED3R statistics (paper Appendix B).

The paper notes that the server only ever needs the SUM of the clients'
(A_k, b_k), so Bonawitz et al.'s Secure Aggregation applies directly.  This
module implements the *masking algebra* of that protocol exactly (pairwise
additive masks that cancel in the aggregate), without the key-agreement
crypto (out of scope offline; the mask generation hook is where X25519-based
PRG seeds would plug in):

    client u sends  y_u = x_u + Σ_{v>u} m_{uv} − Σ_{v<u} m_{vu}
    Σ_u y_u = Σ_u x_u            (every mask appears with both signs)

Individual uploads are fully masked (marginally uniform given unknown
masks); the server learns nothing but the sum.  The psum/merge aggregation
paths accept masked statistics unchanged — demonstrating the paper's claim
that FED3R composes with secure aggregation *by construction*.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.fed3r import Fed3RStats


def _pair_mask(seed: int, u: int, v: int, like: Fed3RStats) -> Fed3RStats:
    """Deterministic pairwise mask m_{uv} (u < v) with x_u-shaped leaves."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), u), v)
    leaves, treedef = jax.tree.flatten(like)
    keys = jax.random.split(key, len(leaves))
    masked = [
        jax.random.normal(k, l.shape, jnp.float32) * 10.0 for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masked)


def mask_statistics(
    stats: Fed3RStats, client_id: int, cohort: Sequence[int], seed: int
) -> Fed3RStats:
    """Apply the pairwise masking a client performs before upload."""
    out = stats
    for v in cohort:
        if v == client_id:
            continue
        u, w = sorted((client_id, v))
        m = _pair_mask(seed, u, w, stats)
        sign = 1.0 if client_id == u else -1.0
        out = jax.tree.map(lambda a, b: a + sign * b, out, m)
    return out


def secure_aggregate(
    masked: List[Fed3RStats],
) -> Fed3RStats:
    """Server-side sum of masked uploads — masks cancel exactly."""
    total = masked[0]
    for s in masked[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, s)
    return total
