"""Secure aggregation of FED3R statistics (paper Appendix B).

The paper notes that the server only ever needs the SUM of the clients'
(A_k, b_k), so Bonawitz et al.'s Secure Aggregation applies directly.  This
module implements the *masking algebra* of that protocol exactly (pairwise
additive masks that cancel in the aggregate), without the key-agreement
crypto (out of scope offline; the mask generation hook is where X25519-based
PRG seeds would plug in):

    client u sends  y_u = x_u + Σ_{v>u} m_{uv} − Σ_{v<u} m_{vu}
    Σ_u y_u = Σ_u x_u            (every mask appears with both signs)

Individual uploads are fully masked (marginally uniform given unknown
masks); the server learns nothing but the sum.  The psum/merge aggregation
paths accept masked statistics unchanged — demonstrating the paper's claim
that FED3R composes with secure aggregation *by construction*.

Compressed-uplink interop (:mod:`repro.federated.compress`): the float
masking above assumes exact cancellation, which fp32 only gives because
addition of identical magnitudes is exact — but a QUANTIZED upload is an
integer payload, and the protocol-correct masking there is INTEGER masking
mod 2³²: uniform int32 masks added with two's-complement wraparound cancel
EXACTLY in the aggregate, bit for bit.  :func:`mask_quantized_payload` /
:func:`secure_aggregate_quantized` implement that ring arithmetic over the
shared-scale int8-valued payloads of
:func:`repro.federated.compress.cohort_quantize_int8`; the masked cohort
sum dequantizes to exactly the unmasked aggregate, so secure aggregation
survives wire compression with zero additional error.

Timeout tolerance (Bonawitz et al.'s unmasking round): when clients drop
AFTER masking, the survivors' sum retains the orphaned pairwise masks of
the dropped — :func:`recover_survivor_sum` /
:func:`recover_survivor_sum_quantized` reconstruct and cancel them, so a
dropped client never poisons the aggregate; the mod-2³² variant is
bit-exact and is what the asynchronous round engine's secure mode uses
(:mod:`repro.federated.async_engine`).
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.core.fed3r import Fed3RStats


def _pair_mask(seed: int, u: int, v: int, like: Fed3RStats) -> Fed3RStats:
    """Deterministic pairwise mask m_{uv} (u < v) with x_u-shaped leaves."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), u), v)
    leaves, treedef = jax.tree.flatten(like)
    keys = jax.random.split(key, len(leaves))
    masked = [
        jax.random.normal(k, l.shape, jnp.float32) * 10.0 for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masked)


def mask_statistics(
    stats: Fed3RStats, client_id: int, cohort: Sequence[int], seed: int
) -> Fed3RStats:
    """Apply the pairwise masking a client performs before upload."""
    out = stats
    for v in cohort:
        if v == client_id:
            continue
        u, w = sorted((client_id, v))
        m = _pair_mask(seed, u, w, stats)
        sign = 1.0 if client_id == u else -1.0
        out = jax.tree.map(lambda a, b: a + sign * b, out, m)
    return out


def secure_aggregate(
    masked: List[Fed3RStats],
) -> Fed3RStats:
    """Server-side sum of masked uploads — masks cancel exactly."""
    total = masked[0]
    for s in masked[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, s)
    return total


# ---------------------------------------------------------------------------
# Integer masking mod 2³² over quantized (compressed-uplink) payloads
# ---------------------------------------------------------------------------


def _pair_mask_int(seed: int, u: int, v: int, like: Any) -> Any:
    """Deterministic pairwise int32 mask m_{uv} (u < v), uniform over the
    full mod-2³² ring (random bits bitcast to int32)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), 2**20 + u), v
    )
    leaves, treedef = jax.tree.flatten(like)
    keys = jax.random.split(key, len(leaves))
    masks = [
        jax.lax.bitcast_convert_type(
            jax.random.bits(k, leaf.shape, jnp.uint32), jnp.int32
        )
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masks)


def mask_quantized_payload(
    payload: Any, client_id: int, cohort: Sequence[int], seed: int
) -> Any:
    """Pairwise integer masking of a quantized upload (int32 leaves).

    Identical protocol shape to :func:`mask_statistics`, but the masks add
    in the mod-2³² ring (XLA int32 addition wraps, two's complement), so
    the aggregate cancellation is EXACT — no fp rounding anywhere.
    """
    leaves = jax.tree.leaves(payload)
    if any(leaf.dtype != jnp.int32 for leaf in leaves):
        raise TypeError(
            "mask_quantized_payload masks int32 payloads (see "
            "repro.federated.compress.cohort_quantize_int8); got dtypes "
            f"{[str(leaf.dtype) for leaf in leaves]}"
        )
    out = payload
    for v in cohort:
        if v == client_id:
            continue
        u, w = sorted((client_id, v))
        m = _pair_mask_int(seed, u, w, payload)
        if client_id == u:
            out = jax.tree.map(lambda a, b: a + b, out, m)
        else:
            out = jax.tree.map(lambda a, b: a - b, out, m)
    return out


def secure_aggregate_quantized(masked: List[Any]) -> Any:
    """Mod-2³² sum of masked integer payloads — masks cancel bit-exactly.

    The true (unmasked) cohort sum of int8-valued entries is far inside
    int32 range, so after the masks cancel the wrapped sum IS the plain
    integer sum; dequantize it with the cohort's shared scales
    (:func:`repro.federated.compress.dequantize_int_sum`).
    """
    total = masked[0]
    for p in masked[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, p)
    return total


# ---------------------------------------------------------------------------
# Timeout-tolerant dropout recovery (Bonawitz et al. §unmasking round)
# ---------------------------------------------------------------------------
#
# When client j times out AFTER the cohort masked its uploads against j, the
# sum over the survivors S retains every pairwise mask that had exactly one
# endpoint in S and the other among the dropped D:
#
#     Σ_{u∈S} y_u = Σ_{u∈S} x_u + Σ_{u∈S, j∈D} sign(u, j)·m_{uj}
#
# (survivor–survivor masks appear with both signs and cancel; dropped–dropped
# masks never entered).  The protocol's unmasking round has the survivors
# reveal their pairwise PRG seeds with the dropped clients so the server can
# reconstruct and subtract that orphan total — here the seeds ARE the
# deterministic (seed, u, v) PRG inputs, so reconstruction is a direct
# re-derivation.  In the mod-2³² integer ring the subtraction cancels
# BIT-EXACTLY (two's-complement wraparound is a group); in float it cancels
# to fp tolerance only, which is why the engines' secure mode rides the
# quantized path (:mod:`repro.federated.async_engine`).


def _orphan_total(
    survivors: Sequence[int],
    dropped: Sequence[int],
    seed: int,
    like: Any,
    mask_fn,
) -> Any:
    """Σ over survivor–dropped pairs of the signed orphaned masks."""
    total = jax.tree.map(jnp.zeros_like, like)
    for u in survivors:
        for j in dropped:
            a, c = sorted((int(u), int(j)))
            m = mask_fn(seed, a, c, like)
            if u == a:
                total = jax.tree.map(lambda t, x: t + x, total, m)
            else:
                total = jax.tree.map(lambda t, x: t - x, total, m)
    return total


def dropout_mask_correction(
    survivors: Sequence[int], dropped: Sequence[int], seed: int, like: Fed3RStats
) -> Fed3RStats:
    """Float orphan-mask total stuck in the survivors' masked sum."""
    if set(survivors) & set(dropped):
        raise ValueError("survivors and dropped must be disjoint")
    return _orphan_total(survivors, dropped, seed, like, _pair_mask)


def recover_survivor_sum(
    masked_sum: Fed3RStats,
    survivors: Sequence[int],
    dropped: Sequence[int],
    seed: int,
) -> Fed3RStats:
    """Survivor aggregate after dropout: masked sum minus the orphan total.

    Float masks cancel to fp tolerance (the ~10× mask magnitude bounds the
    relative error near the fp32 epsilon); use the quantized variant when
    bit-exactness is required.
    """
    corr = dropout_mask_correction(survivors, dropped, seed, masked_sum)
    return jax.tree.map(lambda a, c: a - c, masked_sum, corr)


def dropout_mask_correction_quantized(
    survivors: Sequence[int], dropped: Sequence[int], seed: int, like: Any
) -> Any:
    """Integer orphan-mask total (int32 leaves, mod-2³² arithmetic)."""
    if set(survivors) & set(dropped):
        raise ValueError("survivors and dropped must be disjoint")
    leaves = jax.tree.leaves(like)
    if any(leaf.dtype != jnp.int32 for leaf in leaves):
        raise TypeError(
            "quantized dropout correction expects int32 payload leaves; got "
            f"{[str(leaf.dtype) for leaf in leaves]}"
        )
    return _orphan_total(survivors, dropped, seed, like, _pair_mask_int)


def recover_survivor_sum_quantized(
    masked_sum: Any,
    survivors: Sequence[int],
    dropped: Sequence[int],
    seed: int,
) -> Any:
    """Survivor aggregate after dropout in the mod-2³² ring — BIT-EXACT.

    The wrapped subtraction inverts the wrapped additions exactly (integer
    addition mod 2³² is a group), so the recovered sum equals the unmasked
    survivor sum bit for bit, for ANY 1..K-1 dropped clients — a dropped
    client can never poison the aggregate.
    """
    corr = dropout_mask_correction_quantized(survivors, dropped, seed, masked_sum)
    return jax.tree.map(lambda a, c: a - c, masked_sum, corr)
