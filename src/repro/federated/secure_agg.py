"""Secure aggregation of FED3R statistics (paper Appendix B).

The paper notes that the server only ever needs the SUM of the clients'
(A_k, b_k), so Bonawitz et al.'s Secure Aggregation applies directly.  This
module implements the *masking algebra* of that protocol exactly (pairwise
additive masks that cancel in the aggregate), without the key-agreement
crypto (out of scope offline; the mask generation hook is where X25519-based
PRG seeds would plug in):

    client u sends  y_u = x_u + Σ_{v>u} m_{uv} − Σ_{v<u} m_{vu}
    Σ_u y_u = Σ_u x_u            (every mask appears with both signs)

Individual uploads are fully masked (marginally uniform given unknown
masks); the server learns nothing but the sum.  The psum/merge aggregation
paths accept masked statistics unchanged — demonstrating the paper's claim
that FED3R composes with secure aggregation *by construction*.

Compressed-uplink interop (:mod:`repro.federated.compress`): the float
masking above assumes exact cancellation, which fp32 only gives because
addition of identical magnitudes is exact — but a QUANTIZED upload is an
integer payload, and the protocol-correct masking there is INTEGER masking
mod 2³²: uniform int32 masks added with two's-complement wraparound cancel
EXACTLY in the aggregate, bit for bit.  :func:`mask_quantized_payload` /
:func:`secure_aggregate_quantized` implement that ring arithmetic over the
shared-scale int8-valued payloads of
:func:`repro.federated.compress.cohort_quantize_int8`; the masked cohort
sum dequantizes to exactly the unmasked aggregate, so secure aggregation
survives wire compression with zero additional error.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.core.fed3r import Fed3RStats


def _pair_mask(seed: int, u: int, v: int, like: Fed3RStats) -> Fed3RStats:
    """Deterministic pairwise mask m_{uv} (u < v) with x_u-shaped leaves."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), u), v)
    leaves, treedef = jax.tree.flatten(like)
    keys = jax.random.split(key, len(leaves))
    masked = [
        jax.random.normal(k, l.shape, jnp.float32) * 10.0 for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masked)


def mask_statistics(
    stats: Fed3RStats, client_id: int, cohort: Sequence[int], seed: int
) -> Fed3RStats:
    """Apply the pairwise masking a client performs before upload."""
    out = stats
    for v in cohort:
        if v == client_id:
            continue
        u, w = sorted((client_id, v))
        m = _pair_mask(seed, u, w, stats)
        sign = 1.0 if client_id == u else -1.0
        out = jax.tree.map(lambda a, b: a + sign * b, out, m)
    return out


def secure_aggregate(
    masked: List[Fed3RStats],
) -> Fed3RStats:
    """Server-side sum of masked uploads — masks cancel exactly."""
    total = masked[0]
    for s in masked[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, s)
    return total


# ---------------------------------------------------------------------------
# Integer masking mod 2³² over quantized (compressed-uplink) payloads
# ---------------------------------------------------------------------------


def _pair_mask_int(seed: int, u: int, v: int, like: Any) -> Any:
    """Deterministic pairwise int32 mask m_{uv} (u < v), uniform over the
    full mod-2³² ring (random bits bitcast to int32)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), 2**20 + u), v
    )
    leaves, treedef = jax.tree.flatten(like)
    keys = jax.random.split(key, len(leaves))
    masks = [
        jax.lax.bitcast_convert_type(
            jax.random.bits(k, leaf.shape, jnp.uint32), jnp.int32
        )
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masks)


def mask_quantized_payload(
    payload: Any, client_id: int, cohort: Sequence[int], seed: int
) -> Any:
    """Pairwise integer masking of a quantized upload (int32 leaves).

    Identical protocol shape to :func:`mask_statistics`, but the masks add
    in the mod-2³² ring (XLA int32 addition wraps, two's complement), so
    the aggregate cancellation is EXACT — no fp rounding anywhere.
    """
    leaves = jax.tree.leaves(payload)
    if any(leaf.dtype != jnp.int32 for leaf in leaves):
        raise TypeError(
            "mask_quantized_payload masks int32 payloads (see "
            "repro.federated.compress.cohort_quantize_int8); got dtypes "
            f"{[str(leaf.dtype) for leaf in leaves]}"
        )
    out = payload
    for v in cohort:
        if v == client_id:
            continue
        u, w = sorted((client_id, v))
        m = _pair_mask_int(seed, u, w, payload)
        if client_id == u:
            out = jax.tree.map(lambda a, b: a + b, out, m)
        else:
            out = jax.tree.map(lambda a, b: a - b, out, m)
    return out


def secure_aggregate_quantized(masked: List[Any]) -> Any:
    """Mod-2³² sum of masked integer payloads — masks cancel bit-exactly.

    The true (unmasked) cohort sum of int8-valued entries is far inside
    int32 range, so after the masks cancel the wrapped sum IS the plain
    integer sum; dequantize it with the cohort's shared scales
    (:func:`repro.federated.compress.dequantize_int_sum`).
    """
    total = masked[0]
    for p in masked[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, p)
    return total
