"""Unified client-shard accumulation engine for FED3R statistics.

Every consumer of Eq. 5/6 — the simulator drivers
(:mod:`repro.federated.fed3r_driver`), the gradient-FL simulator and the
datacenter path (:mod:`repro.launch.steps` / ``launch/train.py``) — funnels
through this module instead of rolling its own padding + per-client
dispatch loop:

* :func:`shard_stats` — the fused masked (A, b, n) contraction for one
  padded sample block, dispatching to the Pallas kernel
  (:func:`repro.kernels.fed3r_stats`) on TPU (interpret mode in tests) and
  the XLA reference GEMMs elsewhere.
* :func:`aggregate` — the two server-aggregation backends behind one
  interface: ``"merge"`` (simulator: the scan carry IS the merged sum) and
  ``"psum"`` (mesh: the dist layer's two-stage all-reduce over the data
  axes inside shard_map).
* :class:`AccumulationEngine` — packed accumulation over a
  :class:`repro.data.pipeline.PackedClients`: ONE jitted ``lax.scan`` over
  shards (donated accumulator buffers), an inner scan folding the clients of
  each shard in canonical id order.  K sampled clients cost
  ⌈K/clients_per_shard⌉ scan steps inside a single dispatch, vs the K jit
  dispatches of the naive per-client loop.

Scale-out (:mod:`repro.federated.dist`): with ``DistConfig(mesh=...)`` the
same core runs as ONE shard_map dispatch over the mesh — the shard axis is
split over the data axes (pack with ``pack_client_shards(..., mesh=mesh)``
so it divides), each device scans only its local shards, and the final
A/b/class-count statistics are all-reduced hierarchically (intra-pod ICI,
then cross-pod DCN).  The all-reduce is issued once, AFTER the scan, so
feature extraction — the expensive leg — never serializes against
per-shard collectives.

Compressed uplink (:mod:`repro.federated.compress`): with
``EngineConfig(wire=WireFormat(kind="int8" | "fp8" | "sketch"))`` every
client's (A_k, b_k) crosses the wire quantized/sketched and folds into the
fp32 accumulator through the fused dequantize-accumulate kernel — same one
dispatch, ~4× (int8/fp8) fewer uplink bytes; ``"fp32"`` (default) keeps
the fold bitwise identical to the uncompressed engine.

Exactness: per-client blocks have identical padded shapes, and the
client fold is a strict left fold in sorted-id order regardless of how
clients land in shards — so A and b are *bit-identical* under client
reordering AND re-sharding (different ``clients_per_shard``), the paper's
§4.3 invariance made exact rather than approximate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import fed3r, ncm
from repro.core.fed3r import Fed3RStats
from repro.core.random_features import RFFParams, rff_map
from repro.data.pipeline import PackedClients
from repro.federated import compress
from repro.federated.compress import WireFormat
from repro.federated.dist import (
    DistConfig,
    DistContext,
    DistDispatchMixin,
    resolve_use_kernel,
    two_stage_psum,
    validate_backend,
)
from repro.kernels import fed3r_stats as fed3r_stats_kernel
from repro.sharding.hints import hint
from repro.sharding.specs import replicated


def _ab(z: jax.Array, y: jax.Array, use_kernel: Optional[bool]):
    """The (A, b) GEMM backend over masked design matrices."""
    if resolve_use_kernel(use_kernel):
        return fed3r_stats_kernel(z, y)
    return z.T @ z, z.T @ y


def shard_stats(
    features: jax.Array,  # (n, d) φ(x), any float dtype
    labels: jax.Array,  # (n,) int
    n_classes: int,
    mask: Optional[jax.Array] = None,  # (n,) 1.0 real / 0.0 padding
    *,
    use_kernel: Optional[bool] = None,
) -> Fed3RStats:
    """Fused masked statistics of one padded sample block (Eq. 5/6)."""
    z, y, n = fed3r.masked_design(features, labels, n_classes, mask)
    A, b = _ab(z, y, use_kernel)
    return Fed3RStats(A=A, b=b, n=n)


def aggregate(
    stats: Fed3RStats,
    backend: str = "merge",
    axis_names: Sequence[str] = (),
) -> Fed3RStats:
    """Server-aggregation backends behind one interface.

    ``"merge"``: the associative Python/scan-level sum already produced the
    global statistics — identity.  ``"psum"``: the mesh path; the dist
    layer's two-stage all-reduce over ``axis_names`` (valid inside
    shard_map only; one psum per axis, innermost first).
    """
    validate_backend(backend, tuple(axis_names))
    if backend == "merge":
        return stats
    return two_stage_psum(stats, tuple(axis_names))


class EngineStats(NamedTuple):
    """Engine accumulator: ridge statistics + per-class sample counts.

    ``class_counts`` rides along for free (one masked one-hot column sum per
    client) and makes the NCM baseline a byproduct of the same pass:
    ``NCMStats(sums=stats.b.T, counts=class_counts)``.
    """

    stats: Fed3RStats
    class_counts: jax.Array  # (C,) fp32


def engine_init(d: int, n_classes: int) -> EngineStats:
    return EngineStats(
        stats=fed3r.init_stats(d, n_classes),
        class_counts=jnp.zeros((n_classes,), jnp.float32),
    )


def to_ncm_stats(acc: EngineStats) -> ncm.NCMStats:
    """The FedNCM view of the accumulated statistics (sums = bᵀ)."""
    return ncm.NCMStats(sums=acc.stats.b.T, counts=acc.class_counts)


@dataclass(frozen=True)
class EngineConfig:
    n_classes: int
    use_kernel: Optional[bool] = None  # None → auto (Pallas on TPU, XLA else)
    dist: DistConfig = field(default_factory=DistConfig)  # backend/mesh/donate
    # statistics wire format (repro.federated.compress): each client's
    # (A_k, b_k) crosses the uplink compressed and lands in the fp32
    # accumulator through the fused dequantize-accumulate; "fp32" keeps
    # the fold bitwise identical to the uncompressed engine
    wire: WireFormat = field(default_factory=WireFormat)


class AccumulationEngine(DistDispatchMixin):
    """Packed client-shard accumulation of FED3R statistics.

    ``feature_fn(params, flat_inputs) -> (n, d)`` maps the packed raw inputs
    of one shard (tokens, images, precomputed features — flattened to
    ``(clients_per_shard·max_n, ...)``) to φ features *inside* the scan, so
    backbone extraction batches over whole shards.  ``None`` means inputs
    already are features.  ``rff_params`` fuses the FED3R-RF map into the
    same scan.
    """

    def __init__(
        self,
        cfg: EngineConfig,
        *,
        feature_fn: Optional[Callable[[Any, jax.Array], jax.Array]] = None,
        rff_params: Optional[RFFParams] = None,
    ):
        self.cfg = cfg
        self.feature_fn = feature_fn
        self.rff_params = rff_params
        self.wire = cfg.wire.resolved()  # fp8 → int8 fallback off-TPU
        self.dist = DistContext(cfg.dist, engine="accumulation")
        self._tree_reduce_cache: dict = {}  # AggregationTree → jitted reduce
        # mesh mode: shard the leading (n_shards) axis of the packed arrays
        # over the data axes; accumulator/params replicated; all-reduced
        # output replicated
        sharded = self.dist.data_spec()
        self._accumulate = self.dist.jit(
            self._accumulate_impl,
            in_specs=(replicated(), sharded, sharded, sharded, replicated()),
            out_specs=replicated(),
        )

    def init(self, d: int) -> EngineStats:
        return engine_init(d, self.cfg.n_classes)

    # ---- jitted core ------------------------------------------------------

    def _client_fold(self, acc: EngineStats, block) -> Tuple[EngineStats, None]:
        """Fold one client's padded block into the accumulator.

        With a compressed wire format the client's (A_k, b_k) is the wire
        payload: it quantizes client-side and lands in the fp32 accumulator
        through the fused dequantize-accumulate — per client, inside the
        scan, still one dispatch for the whole selection.  The tiny exact
        sidecars (n, class counts) stay fp32 on the wire.
        """
        feats, labels, mask = block
        z, y, n = fed3r.masked_design(feats, labels, self.cfg.n_classes, mask)
        A, b = _ab(z, y, self.cfg.use_kernel)
        if self.wire.kind == "fp32":
            stats = fed3r.merge(acc.stats, Fed3RStats(A=A, b=b, n=n))
        else:
            accA, accb = compress.roundtrip_add(
                acc.stats.A, acc.stats.b, A, b, self.wire, self.cfg.use_kernel
            )
            stats = Fed3RStats(A=accA, b=accb, n=acc.stats.n + n)
        return EngineStats(
            stats=stats,
            class_counts=acc.class_counts + jnp.sum(y, axis=0),
        ), None

    def _accumulate_impl(self, acc, inputs, labels, mask, params):
        def shard_body(carry, shard):
            x, y, m = shard  # (P, N, ...), (P, N), (P, N)
            flat = x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
            # constrain the shard batch over the ambient mesh's data axes so
            # feature extraction (the expensive leg) data-parallelizes when a
            # mesh is set; exact no-op otherwise
            flat = hint(flat, "batch")
            feats = flat if self.feature_fn is None else self.feature_fn(params, flat)
            if self.rff_params is not None:
                feats = rff_map(self.rff_params, feats)
            feats = feats.reshape(x.shape[:2] + feats.shape[1:])
            carry, _ = jax.lax.scan(self._client_fold, carry, (feats, y, m))
            return carry, None

        acc, _ = jax.lax.scan(shard_body, acc, (inputs, labels, mask))
        # ONE all-reduce, after the scan: the whole accumulator (A, b, n AND
        # the class counts) so every field is globally correct in mesh mode.
        # Under a compressed wire format each device's LOCAL partial crosses
        # the ICI/DCN wire compressed too (the edge→cloud hop of the uplink).
        return self.dist.all_reduce(acc, wire_fn=self._wire_fn())

    def _wire_fn(self):
        """The dist layer's compressed-payload hook (None under fp32)."""
        if self.wire.kind == "fp32":
            return None

        def roundtrip(acc: EngineStats) -> EngineStats:
            A, b = compress.wire_roundtrip(
                acc.stats.A, acc.stats.b, self.wire, self.cfg.use_kernel
            )
            return acc._replace(stats=acc.stats._replace(A=A, b=b))

        return roundtrip

    # ---- host API ---------------------------------------------------------

    def accumulate(
        self, acc: EngineStats, packed: PackedClients, params: Any = None
    ) -> EngineStats:
        """Fold a packed client selection into the accumulator (one dispatch)."""
        with self.dist.telemetry.span("accumulate", engine="accumulation"):
            self.dist.dispatch()
            return self._accumulate(
                acc,
                jnp.asarray(packed.inputs),
                jnp.asarray(packed.labels),
                jnp.asarray(packed.mask),
                params,
            )

    def reduce_payloads(self, payloads, tree) -> EngineStats:
        """The host-side tiered fold entry point: reduce ``tree.leaves``
        pre-computed :class:`EngineStats` payloads (edge aggregators'
        round outputs) through an N-tier
        :class:`repro.federated.tiers.AggregationTree` in ONE dispatch —
        one fixed-order fold per tier, each boundary crossed in the tier's
        wire format.  With fp32 wires the result is bitwise equal to
        ``fed3r.merge``-folding the payloads flat."""
        fn = self._tree_reduce_cache.get(tree)
        if fn is None:
            use_kernel = resolve_use_kernel(self.cfg.use_kernel)
            fn = jax.jit(lambda ps: tree.reduce(ps, use_kernel=use_kernel))
            self._tree_reduce_cache[tree] = fn
        with self.dist.telemetry.span("reduce_payloads", engine="accumulation"):
            self.dist.dispatch()
            return fn(list(payloads))
