"""Unified host-side telemetry: one registry for every engine's signals.

Before this module the repo proved its operational claims through eight
disconnected ad-hoc meters: ``DistContext.dispatches``, the serving
engine's hand-rolled ``stage_s`` wall-time dicts, shed/drop tallies,
``ClientHealth`` state flips, and wire-byte fields scattered through
``compress.py``.  This module is the single substrate they all report
through:

* **Counters / gauges** — labeled, plain-Python numeric cells.  Counters
  are monotone by convention but keep a ``set`` method because the
  benchmarks reset dispatch counts between timed sections
  (``engine.dispatches = 0`` still works through the back-compat property
  on :class:`repro.federated.dist.DistDispatchMixin`).
* **Histograms** — log-bucketed (HDR-style): bucket edges grow by
  2^(1/8) (~9% per bucket), so p50/p99/p999 at million-sample scale cost
  a few hundred integer cells instead of a stored sample list, and a
  reported quantile (the geometric bucket midpoint) is within half a
  bucket (≤ ~4.4% relative) of the true order statistic.
* **Spans** — nestable ``with telemetry.span("solve", engine="serving")``
  context managers on the *monotonic* ``time.perf_counter`` clock (the
  wall clock steps backwards under NTP).  Each exit records the stage
  duration into a ``span_seconds`` histogram labeled with the
  ``/``-joined stage path, so per-stage p50/p99 fall out for free.
* **Flight recorder** — a bounded ring (``collections.deque(maxlen=...)``)
  of structured events: client demoted/readmitted, request shed by
  overflow/deadline, staleness drop, chaos fault injected, fp8 fallback,
  secure-agg mask recovery.  Serialized as JSON-lines
  (:meth:`Telemetry.events_jsonl`) for offline replay of a failed chaos
  gate.
* **Exposition** — :meth:`Telemetry.snapshot` (JSON-native dict),
  :meth:`Telemetry.prometheus` (Prometheus text format), and parsers
  (:func:`events_from_jsonl`, :func:`parse_prometheus`) that round-trip
  under test.

The registry is process-global (:func:`get_telemetry`) but injectable:
:func:`set_telemetry` swaps the default (the bench harness installs a
fresh registry per benchmark), and every instrumented component accepts
an explicit ``telemetry=`` handle.

Hard contracts:

* **Zero device dispatches.**  Nothing in this module touches jax on a
  metric path — counters are integer adds, spans are two
  ``perf_counter`` calls, events are deque appends.  The only jax import
  is lazy, inside the optional :meth:`Telemetry.trace_window` profiler
  capture, which is inert unless ``profile_dir`` is set.
* **Near-free when disabled.**  ``Telemetry(enabled=False)`` turns
  spans into a shared no-op context manager and events/histogram
  convenience paths into early returns.  Counters still count — the
  dispatch contract the CI gate asserts is functional, not optional.
"""
from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

# Log-bucket geometry: 8 buckets per octave → edge ratio 2^(1/8) ≈ 1.0905.
# ~372 buckets cover 1 ns .. 10^5 s, so memory is bounded regardless of
# sample count and a bucket midpoint is within ~4.4% of any sample in it.
_BUCKETS_PER_OCTAVE = 8
_LOG_BASE = math.log(2.0) / _BUCKETS_PER_OCTAVE

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Labeled numeric total (int or float, e.g. wire bytes).

    Plain Python arithmetic — safe on hot paths.  ``set`` exists for the
    benchmarks' reset-between-timed-sections idiom.
    """

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        self.value += n

    def set(self, value: float) -> None:
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter({self.name}{self.labels}={self.value})"


class Gauge:
    """Labeled last-value cell (compression ratio, model drift, ...)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Gauge({self.name}{self.labels}={self.value})"


class Histogram:
    """Log-bucketed latency histogram (HDR-style, bounded memory).

    ``observe(v)`` drops v into bucket ``floor(log(v) / log(2^(1/8)))``
    (non-positive values land in a dedicated zero bucket); ``quantile(q)``
    walks the cumulative counts and returns the geometric midpoint of the
    selected bucket — within one bucket of the true order statistic.
    """

    __slots__ = ("name", "labels", "counts", "zero_count", "count", "sum", "min", "max")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self.counts: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v > 0.0:
            idx = math.floor(math.log(v) / _LOG_BASE)
            self.counts[idx] = self.counts.get(idx, 0) + 1
        else:
            self.zero_count += 1

    @staticmethod
    def bucket_of(v: float) -> int:
        """The bucket index a positive value lands in (tests use this to
        assert 'within one bucket' against raw-sample percentiles)."""
        return math.floor(math.log(float(v)) / _LOG_BASE)

    def quantile(self, q: float) -> float:
        """The q-quantile as the geometric midpoint of its bucket."""
        if self.count == 0:
            return math.nan
        target = max(1.0, q * self.count)
        seen = self.zero_count
        if seen >= target:
            return 0.0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen >= target:
                return math.exp((idx + 0.5) * _LOG_BASE)
        return self.max  # pragma: no cover - unreachable (counts sum to count)

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def p999(self) -> float:
        return self.quantile(0.999)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Histogram({self.name}{self.labels}, n={self.count})"


class _NullSpan:
    """Shared no-op span (disabled mode): enter/exit do nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: perf_counter at enter, histogram observe at exit.

    Nesting composes the stage path (``tick/solve``) from the per-thread
    span stack, so nested stages get their own histogram series.
    """

    __slots__ = ("_t", "_name", "_labels", "_path", "_t0")

    def __init__(self, t: "Telemetry", name: str, labels: Dict[str, Any]):
        self._t = t
        self._name = name
        self._labels = labels

    def __enter__(self) -> "_Span":
        stack = self._t._span_stack
        self._path = f"{stack[-1]}/{self._name}" if stack else self._name
        stack.append(self._path)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        dt = time.perf_counter() - self._t0
        stack = self._t._span_stack
        if stack and stack[-1] == self._path:
            stack.pop()
        self._t.histogram("span_seconds", stage=self._path, **self._labels).observe(dt)
        return False


class Telemetry:
    """The registry: labeled counters/gauges/histograms, spans, and the
    flight-recorder event ring.  Process-global by default
    (:func:`get_telemetry`) but plain to construct and inject."""

    def __init__(
        self,
        *,
        enabled: bool = True,
        ring: int = 4096,
        profile_dir: Optional[str] = None,
    ):
        self.enabled = enabled
        # jax.profiler trace-window target; None keeps trace_window a no-op
        self.profile_dir = profile_dir
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, _LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], Gauge] = {}
        self._hists: Dict[Tuple[str, _LabelKey], Histogram] = {}
        self._instances: Dict[str, int] = {}
        self._local = threading.local()
        self.events: deque = deque(maxlen=int(ring))
        self.events_dropped = 0
        self._seq = 0

    # ---- registry -------------------------------------------------------

    def counter(self, name: str, **labels: Any) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(
                    key, Counter(name, dict(sorted((k, str(v)) for k, v in labels.items())))
                )
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(
                    key, Gauge(name, dict(sorted((k, str(v)) for k, v in labels.items())))
                )
        return g

    def histogram(self, name: str, **labels: Any) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(
                    key, Histogram(name, dict(sorted((k, str(v)) for k, v in labels.items())))
                )
        return h

    def next_instance(self, kind: str) -> int:
        """Monotone per-kind instance ids, so N same-type engines own N
        distinct counter series (the benchmarks construct several serving
        engines and reset/read each one's dispatches independently)."""
        with self._lock:
            n = self._instances.get(kind, 0)
            self._instances[kind] = n + 1
            return n

    # ---- spans ----------------------------------------------------------

    @property
    def _span_stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **labels: Any):
        """Per-stage monotonic-clock span; a shared no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    # ---- flight recorder ------------------------------------------------

    def event(self, kind: str, **fields: Any) -> None:
        """Append one structured event to the bounded ring."""
        if not self.enabled:
            return
        ring = self.events
        if ring.maxlen is not None and len(ring) == ring.maxlen:
            self.events_dropped += 1
        self._seq += 1
        ring.append(
            {"seq": self._seq, "wall": time.time(), "kind": kind, "fields": fields}
        )

    def events_jsonl(self) -> str:
        """The event ring as JSON-lines (one event per line)."""
        return "\n".join(json.dumps(ev, sort_keys=True) for ev in self.events)

    # ---- optional profiler window --------------------------------------

    @contextmanager
    def trace_window(self, label: str = "trace") -> Iterator[None]:
        """Optional ``jax.profiler`` capture around a code window.

        Inert (and jax-import-free) unless the registry is enabled AND
        ``profile_dir`` is set — the flag-gated escape hatch for on-device
        stage attribution; host metrics never need it.
        """
        if not (self.enabled and self.profile_dir):
            yield
            return
        import jax  # lazy: the only jax touch in this module

        with jax.profiler.trace(self.profile_dir):
            yield

    # ---- exposition -----------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-native dict of every metric + the event ring.

        ``json.loads(json.dumps(snapshot()))`` is identity (round-trip
        under test); bucket keys are stringified for that reason.
        """
        return {
            "counters": [
                {"name": c.name, "labels": c.labels, "value": c.value}
                for c in self._counters.values()
            ],
            "gauges": [
                {"name": g.name, "labels": g.labels, "value": g.value}
                for g in self._gauges.values()
            ],
            "histograms": [
                {
                    "name": h.name,
                    "labels": h.labels,
                    "count": h.count,
                    "sum": h.sum,
                    "min": h.min if h.count else None,
                    "max": h.max if h.count else None,
                    "p50": None if h.count == 0 else h.p50,
                    "p99": None if h.count == 0 else h.p99,
                    "p999": None if h.count == 0 else h.p999,
                    "zero_count": h.zero_count,
                    "buckets": {str(k): v for k, v in sorted(h.counts.items())},
                }
                for h in self._hists.values()
            ],
            "events": list(self.events),
            "events_dropped": self.events_dropped,
        }

    def prometheus(self) -> str:
        """Prometheus text exposition (counters/gauges as-is; histograms as
        summary-style quantile series + ``_count``/``_sum``)."""
        lines: List[str] = []
        seen_type: set = set()

        def emit(name: str, labels: Dict[str, str], value: float, kind: str) -> None:
            if name not in seen_type:
                lines.append(f"# TYPE {name} {kind}")
                seen_type.add(name)
            if labels:
                body = ",".join(
                    f'{k}="{_escape_label(v)}"' for k, v in sorted(labels.items())
                )
                lines.append(f"{name}{{{body}}} {value}")
            else:
                lines.append(f"{name} {value}")

        for c in self._counters.values():
            emit(c.name, c.labels, c.value, "counter")
        for g in self._gauges.values():
            emit(g.name, g.labels, g.value, "gauge")
        for h in self._hists.values():
            emit(h.name + "_count", h.labels, h.count, "gauge")
            emit(h.name + "_sum", h.labels, h.sum, "gauge")
            for q, label in ((0.5, "0.5"), (0.99, "0.99"), (0.999, "0.999")):
                if h.count:
                    emit(h.name, {**h.labels, "quantile": label}, h.quantile(q), "summary")
        return "\n".join(lines) + "\n"

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another registry's :meth:`snapshot` into this one.

        The multi-process aggregation path: subprocess benchmark workers
        (``bench_scaleout.py``, ``bench_tiers.py`` — each pinned to its own
        simulated device count) snapshot their own registries and the
        parent merges them, so dispatch counters and flight-recorder
        events survive the process boundary and ``check_regression.py``
        reads scale-out dispatches from ``telemetry_*.json`` like every
        other bench.  Counters ADD, gauges take the incoming last-write,
        histograms merge bucket-wise (quantiles stay within one bucket of
        the union stream), events append in arrival order (ring bounds
        still apply).
        """
        for c in snap.get("counters", ()):
            self.counter(c["name"], **c.get("labels", {})).inc(c["value"])
        for g in snap.get("gauges", ()):
            self.gauge(g["name"], **g.get("labels", {})).set(g["value"])
        for rec in snap.get("histograms", ()):
            h = self.histogram(rec["name"], **rec.get("labels", {}))
            if not rec.get("count"):
                continue
            h.count += int(rec["count"])
            h.sum += float(rec["sum"])
            h.zero_count += int(rec.get("zero_count", 0))
            if rec.get("min") is not None:
                h.min = min(h.min, float(rec["min"]))
            if rec.get("max") is not None:
                h.max = max(h.max, float(rec["max"]))
            for idx, n in rec.get("buckets", {}).items():
                idx = int(idx)
                h.counts[idx] = h.counts.get(idx, 0) + int(n)
        for ev in snap.get("events", ()):
            self.event(ev.get("kind", "event"), **ev.get("fields", {}))
        self.events_dropped += int(snap.get("events_dropped", 0))

    def reset(self) -> None:
        """Zero every metric in place and clear the event ring (instances
        hold live references to their cells, so cells are zeroed, not
        discarded)."""
        for c in self._counters.values():
            c.value = 0
        for g in self._gauges.values():
            g.value = 0.0
        for h in self._hists.values():
            h.counts.clear()
            h.zero_count = 0
            h.count = 0
            h.sum = 0.0
            h.min = math.inf
            h.max = -math.inf
        self.events.clear()
        self.events_dropped = 0
        self._seq = 0


def _escape_label(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


# ---- parsers (round-trip counterparts of the expositions) ---------------


def events_from_jsonl(text: str) -> List[dict]:
    """Parse :meth:`Telemetry.events_jsonl` back into event dicts."""
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def parse_prometheus(text: str) -> Dict[Tuple[str, _LabelKey], float]:
    """Parse the text exposition back to ``{(name, label_key): value}``.

    Minimal by design (no escapes beyond :func:`_escape_label`'s, which
    our label values never trigger) — it exists so the exposition
    round-trips under test, not as a general Prometheus client.
    """
    out: Dict[Tuple[str, _LabelKey], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        metric, _, value = line.rpartition(" ")
        if "{" in metric:
            name, _, body = metric.partition("{")
            body = body.rstrip("}")
            labels = {}
            for part in body.split(","):
                if not part:
                    continue
                k, _, v = part.partition("=")
                labels[k] = v.strip('"')
            key = _label_key(labels)
        else:
            name, key = metric, ()
        out[(name, key)] = float(value)
    return out


def dispatch_summary(snapshot: dict) -> Dict[str, int]:
    """Per-engine host→device dispatch totals from a snapshot.

    Sums the per-instance ``engine_dispatches_total`` series by engine
    name — the exact numbers ``benchmarks/check_regression.py`` gates, so
    the CI gate and the telemetry layer cannot diverge.
    """
    out: Dict[str, int] = {}
    for c in snapshot.get("counters", []):
        if c.get("name") == "engine_dispatches_total":
            eng = c.get("labels", {}).get("engine", "engine")
            out[eng] = out.get(eng, 0) + int(c.get("value", 0))
    return out


# ---- the process-global default (injectable) ----------------------------

_GLOBAL = Telemetry()


def get_telemetry() -> Telemetry:
    """The process-global registry every component defaults to."""
    return _GLOBAL


def set_telemetry(t: Telemetry) -> Telemetry:
    """Swap the process-global registry; returns the previous one.

    Components capture the registry at CONSTRUCTION, so a swap scopes the
    instrumentation of everything built afterwards (the bench harness
    installs a fresh registry per benchmark module this way).
    """
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = t
    return prev
