"""Gradient-based FL algorithms: FedAvg, FedAvgM, FedProx, Scaffold (+ LP).

All four share one jitted ``local_update``:

* local SGD over padded client batches (padding batches are exact no-ops);
* optional proximal term (FedProx: + μ/2‖θ−θ_g‖²);
* optional Scaffold control-variate correction (g − c_k + c) and the
  Option-II variate update c_k' = c_k − c + (θ_g − θ_k)/(steps·lr);
* a ``freeze`` mask (pytree of 0/1) implementing the LP variants and the
  FED3R+FT strategies: FT (all 1), FT-LP (extractor 0), FT-FEAT (head 0).

Server side: weighted-average of client deltas, then a server optimizer
step (SGD; momentum > 0 gives FedAvgM, Hsu et al. 2019).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class LocalResult(NamedTuple):
    delta: Any  # θ_k − θ_g (masked by freeze)
    n_samples: jax.Array  # effective client size (aggregation weight)
    new_cvar: Any  # updated client control variate (scaffold) or None-like


@dataclass(frozen=True)
class FLAlgorithm:
    name: str
    uses_cvar: bool  # scaffold
    prox_mu: float
    server_momentum: float
    server_opt: str = "sgd"  # sgd | adam | yogi (Reddi et al. 2021)


def make_algorithm(
    name: str, *, prox_mu: float = 0.01, server_momentum: float = 0.9
) -> FLAlgorithm:
    name = name.lower()
    if name == "fedavg":
        return FLAlgorithm("fedavg", False, 0.0, 0.0)
    if name == "fedavgm":
        return FLAlgorithm("fedavgm", False, 0.0, server_momentum)
    if name == "fedprox":
        return FLAlgorithm("fedprox", False, prox_mu, 0.0)
    if name == "scaffold":
        return FLAlgorithm("scaffold", True, 0.0, 0.0)
    if name == "fedadam":
        return FLAlgorithm("fedadam", False, 0.0, 0.9, server_opt="adam")
    if name == "fedyogi":
        return FLAlgorithm("fedyogi", False, 0.0, 0.9, server_opt="yogi")
    raise ValueError(name)


# ---------------------------------------------------------------------------
# client local update
# ---------------------------------------------------------------------------


def make_local_update(
    loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
    algo: FLAlgorithm,
    *,
    lr: float,
    weight_decay: float = 0.0,
):
    """Build the jitted local-update fn.

    Batches arrive padded to a fixed shape: ``batches`` is a dict of arrays
    with leading dims (n_batches, batch_size, ...) plus ``mask``
    (n_batches, batch_size).  Empty padding batches contribute exactly zero.
    """

    def masked_loss(params, batch):
        per = loss_fn(params, batch)  # (batch_size,) per-example losses
        m = batch["mask"].astype(jnp.float32)
        return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)

    @functools.partial(jax.jit, static_argnames=())
    def local_update(global_params, batches, freeze, c_server, c_client):
        n_batches = jax.tree.leaves(batches)[0].shape[0]

        def step(params, batch):
            has = (jnp.sum(batch["mask"]) > 0).astype(jnp.float32)
            grads = jax.grad(masked_loss)(params, batch)
            if algo.prox_mu > 0.0:
                grads = jax.tree.map(
                    lambda g, p, p0: g + algo.prox_mu * (p - p0),
                    grads, params, global_params,
                )
            if weight_decay > 0.0:
                grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
            if algo.uses_cvar:
                grads = jax.tree.map(
                    lambda g, ck, cs: g - ck + cs, grads, c_client, c_server
                )
            # freeze mask + padding no-op
            params = jax.tree.map(
                lambda p, g, f: p - lr * has * f * g, params, grads, freeze
            )
            return params, None

        def body(params, batch):
            return step(params, batch)

        params, _ = jax.lax.scan(body, global_params, batches)

        delta = jax.tree.map(lambda p, p0, f: (p - p0) * f, params, global_params, freeze)
        n_eff = jnp.sum(batches["mask"])

        if algo.uses_cvar:
            # Scaffold Option II: c_k' = c_k − c + (θ_g − θ_k)/(steps·lr)
            steps = jnp.maximum(
                jnp.sum((jnp.sum(batches["mask"], axis=1) > 0).astype(jnp.float32)),
                1.0,
            )
            new_c = jax.tree.map(
                lambda ck, cs, dlt: ck - cs - dlt / (steps * lr),
                c_client, c_server, delta,
            )
        else:
            new_c = c_client
        return LocalResult(delta=delta, n_samples=n_eff, new_cvar=new_c)

    return local_update


# ---------------------------------------------------------------------------
# server aggregation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("server_momentum_on",))
def _server_step(params, weighted_deltas, weights_sum, momentum_buf, slr, smom,
                 server_momentum_on: bool):
    avg_delta = jax.tree.map(lambda d: d / weights_sum, weighted_deltas)
    if server_momentum_on:
        momentum_buf = jax.tree.map(
            lambda m, d: smom * m + d, momentum_buf, avg_delta
        )
        step = momentum_buf
    else:
        step = avg_delta
    params = jax.tree.map(lambda p, s: p + slr * s, params, step)
    return params, momentum_buf


@functools.partial(jax.jit, static_argnames=("yogi",))
def _adaptive_server_step(params, avg_delta, m, v, t, slr, yogi: bool,
                          b1=0.9, b2=0.99, eps=1e-3):
    """FedAdam / FedYogi (Reddi et al. 2021): adaptive server optimizer
    treating the aggregated client delta as a pseudo-gradient."""
    t = t + 1
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d, m, avg_delta)
    if yogi:
        v = jax.tree.map(
            lambda v_, d: v_ - (1 - b2) * jnp.square(d) * jnp.sign(v_ - jnp.square(d)),
            v, avg_delta,
        )
    else:
        v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) * jnp.square(d), v, avg_delta)
    params = jax.tree.map(
        lambda p, m_, v_: p + slr * m_ / (jnp.sqrt(jnp.maximum(v_, 0.0)) + eps),
        params, m, v,
    )
    return params, m, v, t


class Server:
    """FedAvg-family server: weighted delta aggregation + server optimizer."""

    def __init__(self, algo: FLAlgorithm, params, *, server_lr: float = 1.0):
        self.algo = algo
        self.params = params
        self.server_lr = server_lr
        self.momentum_buf = (
            jax.tree.map(jnp.zeros_like, params) if algo.server_momentum > 0 else None
        )
        self.c_server = (
            jax.tree.map(jnp.zeros_like, params) if algo.uses_cvar else None
        )
        self.adaptive = algo.server_opt in ("adam", "yogi")
        if self.adaptive:
            self.m = jax.tree.map(jnp.zeros_like, params)
            self.v = jax.tree.map(lambda p: jnp.full(p.shape, 1e-6), params)
            self.t = jnp.zeros((), jnp.int32)

    def aggregate(self, results, n_total_clients: Optional[int] = None,
                  cvar_deltas: Optional[list] = None):
        weights = jnp.asarray([float(r.n_samples) for r in results], jnp.float32)
        wsum = jnp.sum(weights)
        weighted = jax.tree.map(
            lambda *ds: sum(w * d for w, d in zip(weights, ds)), *[r.delta for r in results]
        )
        if self.adaptive:
            avg_delta = jax.tree.map(lambda d: d / wsum, weighted)
            self.params, self.m, self.v, self.t = _adaptive_server_step(
                self.params, avg_delta, self.m, self.v, self.t,
                jnp.asarray(self.server_lr, jnp.float32),
                self.algo.server_opt == "yogi",
            )
        else:
            mom = self.momentum_buf if self.momentum_buf is not None else jax.tree.map(
                jnp.zeros_like, self.params
            )
            self.params, mom = _server_step(
                self.params, weighted, wsum, mom,
                jnp.asarray(self.server_lr, jnp.float32),
                jnp.asarray(self.algo.server_momentum, jnp.float32),
                self.algo.server_momentum > 0,
            )
            if self.momentum_buf is not None:
                self.momentum_buf = mom

        if self.algo.uses_cvar and n_total_clients and cvar_deltas:
            # Scaffold: c ← c + (1/N)·Σ_k (c_k' − c_k)
            cd = jax.tree.map(lambda *cs: sum(cs), *cvar_deltas)
            self.c_server = jax.tree.map(
                lambda c, d: c + d / n_total_clients, self.c_server, cd
            )
        return self.params
