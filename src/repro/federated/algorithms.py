"""Gradient-based FL algorithms as PURE state transitions.

FedAvg, FedAvgM, FedProx, Scaffold, FedAdam, FedYogi share one pure
``local_update`` (built by :func:`make_local_update`):

* local SGD over padded client batches (padding batches are exact no-ops);
* optional proximal term (FedProx: + μ/2‖θ−θ_g‖²);
* optional Scaffold control-variate correction (g − c_k + c) and the
  Option-II variate update c_k' = c_k − c + (θ_g − θ_k)/(steps·lr);
* a ``freeze`` mask (pytree of 0/1) implementing the LP variants and the
  FED3R+FT strategies: FT (all 1), FT-LP (extractor 0), FT-FEAT (head 0).

The server is a :class:`ServerState` pytree (params, momentum buffer,
adaptive m/v/t, the Scaffold server variate, the STACKED per-client
variates, round index) advanced by pure functions — no Python-object
state, so the whole round (vmapped local updates + aggregation + server
optimizer step + cvar scatter) lowers into ONE jitted dispatch inside
:mod:`repro.federated.round_engine`, the state checkpoints through
:mod:`repro.checkpoint` as a plain pytree, and training is resumable at
any round boundary.

Server optimizers: weighted-average of client deltas, then SGD (momentum
> 0 gives FedAvgM, Hsu et al. 2019) or Adam/Yogi treating the aggregated
delta as a pseudo-gradient (Reddi et al. 2021).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp


class LocalResult(NamedTuple):
    delta: Any  # θ_k − θ_g (masked by freeze)
    n_samples: jax.Array  # effective client size (aggregation weight)
    new_cvar: Any  # updated client control variate (scaffold) or None-like


@dataclass(frozen=True)
class FLAlgorithm:
    name: str
    uses_cvar: bool  # scaffold
    prox_mu: float
    server_momentum: float
    server_opt: str = "sgd"  # sgd | adam | yogi (Reddi et al. 2021)

    @property
    def adaptive(self) -> bool:
        return self.server_opt in ("adam", "yogi")


def make_algorithm(
    name: str, *, prox_mu: float = 0.01, server_momentum: float = 0.9
) -> FLAlgorithm:
    name = name.lower()
    if name == "fedavg":
        return FLAlgorithm("fedavg", False, 0.0, 0.0)
    if name == "fedavgm":
        return FLAlgorithm("fedavgm", False, 0.0, server_momentum)
    if name == "fedprox":
        return FLAlgorithm("fedprox", False, prox_mu, 0.0)
    if name == "scaffold":
        return FLAlgorithm("scaffold", True, 0.0, 0.0)
    if name == "fedadam":
        return FLAlgorithm("fedadam", False, 0.0, 0.0, server_opt="adam")
    if name == "fedyogi":
        return FLAlgorithm("fedyogi", False, 0.0, 0.0, server_opt="yogi")
    raise ValueError(name)


# ---------------------------------------------------------------------------
# client local update
# ---------------------------------------------------------------------------


def make_local_update(
    loss_fn: Callable[[Any, Dict[str, jax.Array]], jax.Array],
    algo: FLAlgorithm,
    *,
    lr: float,
    weight_decay: float = 0.0,
    jit: bool = True,
):
    """Build the local-update fn (jitted unless ``jit=False``).

    Batches arrive padded to a fixed shape: ``batches`` is a dict of arrays
    with leading dims (n_batches, batch_size, ...) plus ``mask``
    (n_batches, batch_size).  Empty padding batches contribute exactly zero.

    The un-jitted form (``jit=False``) is what the round engine vmaps over
    the cohort dimension; the jitted form is the per-client reference path.
    """

    def masked_loss(params, batch):
        per = loss_fn(params, batch)  # (batch_size,) per-example losses
        m = batch["mask"].astype(jnp.float32)
        return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)

    def local_update(global_params, batches, freeze, c_server, c_client):
        def step(params, batch):
            has = (jnp.sum(batch["mask"]) > 0).astype(jnp.float32)
            grads = jax.grad(masked_loss)(params, batch)
            if algo.prox_mu > 0.0:
                grads = jax.tree.map(
                    lambda g, p, p0: g + algo.prox_mu * (p - p0),
                    grads, params, global_params,
                )
            if weight_decay > 0.0:
                grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
            if algo.uses_cvar:
                grads = jax.tree.map(
                    lambda g, ck, cs: g - ck + cs, grads, c_client, c_server
                )
            # freeze mask + padding no-op
            params = jax.tree.map(
                lambda p, g, f: p - lr * has * f * g, params, grads, freeze
            )
            return params, None

        params, _ = jax.lax.scan(step, global_params, batches)

        delta = jax.tree.map(lambda p, p0, f: (p - p0) * f, params, global_params, freeze)
        n_eff = jnp.sum(batches["mask"])

        if algo.uses_cvar:
            # Scaffold Option II: c_k' = c_k − c + (θ_g − θ_k)/(steps·lr)
            steps = jnp.maximum(
                jnp.sum((jnp.sum(batches["mask"], axis=1) > 0).astype(jnp.float32)),
                1.0,
            )
            new_c = jax.tree.map(
                lambda ck, cs, dlt: ck - cs - dlt / (steps * lr),
                c_client, c_server, delta,
            )
        else:
            new_c = c_client
        return LocalResult(delta=delta, n_samples=n_eff, new_cvar=new_c)

    return jax.jit(local_update) if jit else local_update


# ---------------------------------------------------------------------------
# server state + pure transitions
# ---------------------------------------------------------------------------


class ServerState(NamedTuple):
    """The complete FedAvg-family server as one checkpointable pytree.

    Unused slots are ``None`` (e.g. ``momentum`` for plain FedAvg,
    ``cvars`` for everything but Scaffold) so the structure stays minimal
    per algorithm while remaining a valid jit/donation target.
    """

    params: Any
    momentum: Any  # server momentum buffer (FedAvgM) or None
    opt_m: Any  # Adam/Yogi first moment or None
    opt_v: Any  # Adam/Yogi second moment or None
    opt_t: jax.Array  # () int32 adaptive step counter
    c_server: Any  # Scaffold server control variate or None
    cvars: Any  # STACKED (n_clients, ...) client variates or None
    round: jax.Array  # () int32 — rounds applied so far


def server_init(
    algo: FLAlgorithm, params0: Any, *, n_clients: int = 0
) -> ServerState:
    """Fresh server state.  ``n_clients`` sizes the stacked Scaffold
    variates (required iff ``algo.uses_cvar``).

    ``params0`` is COPIED: the state is a donation target (the round
    engine's dispatch consumes its buffers on accelerators), so it must
    own its arrays rather than alias caller-held ones.
    """
    if algo.uses_cvar and n_clients < 1:
        raise ValueError("scaffold needs n_clients to size the stacked cvars")
    zeros = lambda: jax.tree.map(jnp.zeros_like, params0)  # noqa: E731
    return ServerState(
        params=jax.tree.map(jnp.array, params0),
        momentum=zeros() if algo.server_momentum > 0 else None,
        opt_m=zeros() if algo.adaptive else None,
        opt_v=jax.tree.map(lambda p: jnp.full(p.shape, 1e-6), params0)
        if algo.adaptive else None,
        opt_t=jnp.zeros((), jnp.int32),
        c_server=zeros() if algo.uses_cvar else None,
        cvars=jax.tree.map(
            lambda p: jnp.zeros((n_clients,) + p.shape, p.dtype), params0
        ) if algo.uses_cvar else None,
        round=jnp.zeros((), jnp.int32),
    )


def server_state_from_tree(tree: Dict[str, Any]) -> ServerState:
    """Rewrap a checkpoint-restored dict (NamedTuples round-trip as dicts)."""
    return ServerState(**{f: tree[f] for f in ServerState._fields})


def server_optimizer_step(
    algo: FLAlgorithm,
    state: ServerState,
    avg_delta: Any,
    *,
    server_lr: float,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-3,
) -> ServerState:
    """Apply ONE server optimizer step to the weighted-average delta.

    Pure and trace-safe: called inside the round engine's single jitted
    dispatch, and by the per-client reference loop.  Does not touch the
    Scaffold fields or the round counter (see :func:`scaffold_update` /
    the engine for those).
    """
    slr = jnp.asarray(server_lr, jnp.float32)
    if algo.adaptive:
        t = state.opt_t + 1
        m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d, state.opt_m, avg_delta)
        if algo.server_opt == "yogi":
            v = jax.tree.map(
                lambda v_, d: v_ - (1 - b2) * jnp.square(d) * jnp.sign(v_ - jnp.square(d)),
                state.opt_v, avg_delta,
            )
        else:
            v = jax.tree.map(
                lambda v_, d: b2 * v_ + (1 - b2) * jnp.square(d), state.opt_v, avg_delta
            )
        params = jax.tree.map(
            lambda p, m_, v_: p + slr * m_ / (jnp.sqrt(jnp.maximum(v_, 0.0)) + eps),
            state.params, m, v,
        )
        return state._replace(params=params, opt_m=m, opt_v=v, opt_t=t)
    if algo.server_momentum > 0:
        momentum = jax.tree.map(
            lambda m_, d: algo.server_momentum * m_ + d, state.momentum, avg_delta
        )
        params = jax.tree.map(lambda p, s: p + slr * s, state.params, momentum)
        return state._replace(params=params, momentum=momentum)
    params = jax.tree.map(lambda p, d: p + slr * d, state.params, avg_delta)
    return state._replace(params=params)


def scaffold_update(
    state: ServerState,
    cvar_delta_sum: Any,  # Σ_k (c_k' − c_k), zeros on padded cohort slots
    new_cvars: Any,  # (cohort, ...) updated client variates
    client_ids: jax.Array,  # (cohort,) int32, −1 = padded slot
    *,
    n_total_clients: int,
) -> ServerState:
    """Scaffold server-side bookkeeping, pure and scatter-based.

    ``c ← c + (1/N)·Σ_k (c_k' − c_k)`` and the per-client variates are
    scattered back into the stacked ``(n_clients, ...)`` table in one
    ``.at[ids].set`` (padded slots target row ``n_total_clients`` and are
    dropped).
    """
    c_server = jax.tree.map(
        lambda c, d: c + d / n_total_clients, state.c_server, cvar_delta_sum
    )
    safe = jnp.where(client_ids >= 0, client_ids, n_total_clients)
    cvars = jax.tree.map(
        lambda table, new: table.at[safe].set(new, mode="drop"),
        state.cvars, new_cvars,
    )
    return state._replace(c_server=c_server, cvars=cvars)
