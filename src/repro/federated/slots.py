"""Slot-table state for the continuous-batching head-serving engine.

The serving engine (:mod:`repro.launch.serving_engine`) keeps S FIXED
device-resident head slots — the decode-style working set a
JetStream/MaxText generate loop keeps KV-cache pages in — and this module
owns that state:

* the DEVICE side is one ``(S, d, C)`` fp32 array of solved heads, donated
  through every solve tick so the table never round-trips the host;
* the HOST side is the control plane: which tenant occupies which slot, at
  which tenant/global version its head was solved, and the
  recency/popularity counters the eviction policy ranks.  It is plain
  numpy — admission control and victim selection cost no dispatches;
* slot 0 is PINNED to the global head (``factored_solution`` of the
  current stream state): every query whose tenant holds no server-side
  data — or whose head was shed by slot pressure — gathers slot 0, so the
  serve stage is always one dense gather + batched matmul with no
  fallback branch.

Eviction is coldest-first: free slots are taken before victims, and
victims rank by ``(last_used, hits)`` lexicographically — least-recently
served first, ties broken by lifetime popularity — so a Zipf-hot tenant
survives a sweep of one-shot cold tenants even when their recency is
newer.  :class:`TenantUniverse` maps a simulated millions-of-tenants id
space onto a base federation's client data for benchmark-scale traffic
(``benchmarks/bench_serving.py``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


class SlotTable:
    """S fixed head slots: a device ``(S, d, C)`` pytree + host metadata.

    ``heads`` is the only device-resident piece; everything else is the
    host control plane.  ``global_slot_version`` tracks the stream version
    the pinned slot-0 global head was solved at (``-1`` = never solved, so
    the first tick always refreshes it).
    """

    GLOBAL_SLOT = 0

    def __init__(self, n_slots: int, d: int, n_classes: int):
        if n_slots < 2:
            raise ValueError(
                f"n_slots must be >= 2 (slot 0 is the pinned global head), "
                f"got {n_slots}"
            )
        self.n_slots = n_slots
        self.heads = jnp.zeros((n_slots, d, n_classes), jnp.float32)
        self.tenant = np.full((n_slots,), -1, np.int64)  # -1 = empty slot
        self.tenant_version = np.zeros((n_slots,), np.int64)
        self.global_version = np.full((n_slots,), -1, np.int64)
        self.last_used = np.zeros((n_slots,), np.int64)
        self.hits = np.zeros((n_slots,), np.int64)
        self.global_slot_version = -1
        self.evictions = 0
        self._slot_of: Dict[int, int] = {}

    def __len__(self) -> int:
        """Number of occupied tenant slots (the pinned global slot excluded)."""
        return len(self._slot_of)

    def slot_of(self, tenant: int) -> Optional[int]:
        """The tenant's resident slot, or None."""
        return self._slot_of.get(int(tenant))

    def take_slots(self, n: int, protect: Sequence[int] = ()) -> List[int]:
        """Claim up to ``n`` slots for incoming heads: free slots first, then
        the coldest victims by ``(last_used, hits)``.

        ``protect`` lists slots that must not be evicted (tenants being
        served in the SAME tick — evicting them would downgrade an
        in-flight query to the global head).  May return fewer than ``n``
        when the table is protection-saturated; the engine serves the
        overflow tenants from the global slot and reports it.
        """
        keep = set(protect)
        keep.add(self.GLOBAL_SLOT)
        free = [s for s in range(self.n_slots)
                if self.tenant[s] < 0 and s not in keep]
        out = free[:n]
        need = n - len(out)
        if need > 0:
            occupied = [s for s in range(self.n_slots)
                        if self.tenant[s] >= 0 and s not in keep]
            occupied.sort(key=lambda s: (self.last_used[s], self.hits[s]))
            victims = occupied[:need]
            for s in victims:
                del self._slot_of[int(self.tenant[s])]
                self.tenant[s] = -1
                self.evictions += 1
            out.extend(victims)
        return out

    def assign(
        self,
        slots: Sequence[int],
        tenants: Sequence[int],
        tenant_versions: Sequence[int],
        global_version: int,
        tick: int,
    ) -> None:
        """Record freshly solved heads landing in ``slots`` (device scatter
        already happened inside the solve dispatch)."""
        for s, t, v in zip(slots, tenants, tenant_versions):
            old = int(self.tenant[s])
            if old >= 0 and old != int(t):
                del self._slot_of[old]
                self.evictions += 1
            self.tenant[s] = int(t)
            self.tenant_version[s] = int(v)
            self.global_version[s] = global_version
            self.last_used[s] = tick
            self.hits[s] = 0
            self._slot_of[int(t)] = int(s)
        self.global_slot_version = global_version

    def touch(self, slots: Sequence[int], counts: Sequence[int], tick: int) -> None:
        """Serve-stage recency/popularity update for the gathered slots."""
        for s, c in zip(slots, counts):
            self.last_used[s] = tick
            self.hits[s] += int(c)


class TenantUniverse:
    """A simulated huge tenant id space over a base federation's data.

    Tenant ``t`` is backed by base client ``t % base.n_clients`` — distinct
    tenant identities (distinct cache/slot entries, distinct versions)
    sharing a small pool of actual statistics, which is exactly what a
    serving benchmark needs to stress admission control and eviction at
    millions-of-tenants scale without millions of datasets.  Duck-types
    the :class:`repro.data.pipeline.FederatedDataset` surface the serving
    layers consume (``n_clients``/``client``/``client_sizes``).
    """

    def __init__(self, base, n_tenants: int):
        if n_tenants < base.n_clients:
            raise ValueError(
                f"n_tenants={n_tenants} < base federation size {base.n_clients}"
            )
        self.base = base
        self.n_tenants = int(n_tenants)

    @property
    def n_clients(self) -> int:
        return self.n_tenants

    @property
    def n_classes(self) -> int:
        return self.base.n_classes

    def client(self, k: int):
        return self.base.client(int(k) % self.base.n_clients)

    def client_sizes(self) -> np.ndarray:
        """The BASE sizes — the per-tenant sample-capacity envelope.

        Every tenant's data is one of the base clients', so the base array
        carries the same max/percentiles without materializing an
        ``n_tenants``-long copy; consumers (the serving layers) use it only
        to size the packed-cohort capacity.
        """
        return self.base.client_sizes()
