"""Communication & computation cost meters (paper Appendix D and E).

Exact re-implementation of the paper's cost accounting, parameterized by:
  b  — feature-extractor parameter count
  d  — feature dimensionality (c = d·C classifier size)
  C  — number of classes
  D  — random-feature count (FED3R-RF)
  F_phi / F_head — forward FLOPs per image of extractor / classifier head
  E  — local epochs, n_k — client dataset size, κ — clients per round

All communication figures are in *parameters per client per round*
(multiply by 4 for FP32 bytes, as the paper does); computation in FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

FP32_BYTES = 4

# wire formats of the compressed statistics uplink (repro.federated.compress)
WIRE_KINDS = ("fp32", "int8", "fp8", "sketch")


def stats_wire_bytes(
    d: int, C: int, kind: str = "fp32", tile: int = 128, rank: int = 16
) -> float:
    """Wire bytes of ONE (A_k, b_k) statistics upload under a wire format.

    The single pricing formula the cost model, the compression layer, and
    the accuracy-vs-bytes bench all share:

    * ``fp32``   — dense d² + d·C at 4 B/element (today's uplink).
    * ``int8`` / ``fp8`` — 1 B/element payload plus one fp32 absmax scale
      per (tile × tile) block of A and of b (the per-tile scale grid of
      :func:`repro.kernels.quantize_tiles`): → ~4× reduction.
    * ``sketch`` — A travels as its rank-r factor Z_k (r × d fp32, with
      A_k ≈ Z_kᵀZ_k); b stays dense fp32.  Wins over int8 when r ≪ d/4
      and C ≪ d (the b payload is incompressible here).
    """
    if kind not in WIRE_KINDS:
        raise ValueError(f"unknown wire kind: {kind!r} (expected one of {WIRE_KINDS})")
    if kind == "fp32":
        return float(d * d + d * C) * FP32_BYTES
    if kind in ("int8", "fp8"):
        dt = -(-d // tile)  # ⌈d/tile⌉
        ct = -(-C // tile)
        payload = float(d * d + d * C)  # 1 byte per element
        scales = float(dt * dt + dt * ct) * FP32_BYTES
        return payload + scales
    # sketch: rank-r fp32 factor of A + dense fp32 b
    return float(rank * d + d * C) * FP32_BYTES


@dataclass(frozen=True)
class CostModel:
    b: float  # extractor params
    d: int  # feature dim
    C: int  # classes
    D: int = 0  # random features (RF variant)
    F_phi: float = 332.9e6  # MobileNetV2 forward FLOPs / image (paper Table 5)
    E: int = 5  # local epochs (paper App. C)

    @property
    def head(self) -> float:
        return self.d * self.C

    @property
    def m(self) -> float:  # full model size
        return self.b + self.head

    @property
    def F_head(self) -> float:
        return self.d * self.C

    @property
    def F_M(self) -> float:
        return self.F_phi + self.F_head

    # --- communication per sampled client per round (params) ---------------

    def comm_per_client(self, algorithm: str) -> Dict[str, float]:
        a = algorithm.lower()
        if a in ("fedavg", "fedavgm"):
            return {"down": self.m, "up": self.m}
        if a == "scaffold":
            return {"down": 2 * self.m, "up": 2 * self.m}
        if a in ("fedavg-lp", "fedavgm-lp"):
            return {"down": self.head, "up": self.head}
        if a == "scaffold-lp":
            return {"down": 2 * self.head, "up": 2 * self.head}
        if a == "fed3r":
            return {"down": 0.0, "up": self.d**2 + self.d * self.C}
        if a == "fed3r-rf":
            assert self.D > 0
            return {"down": 0.0, "up": self.D**2 + self.D * self.C}
        if a == "fed3r+ft-feat":
            return {"down": self.b, "up": self.b}
        if a == "fed3r-personalized":
            # the ONE-TIME (A_k, b_k) upload the per-tenant closed form is
            # served from — the same statistics the client sent for the
            # global head, so the MARGINAL wire cost of personalizing on
            # top of fed3r is zero (this entry prices the shared upload,
            # not an extra one)
            return {"down": 0.0, "up": self.d**2 + self.d * self.C}
        if a == "personalized-ft":
            # the gradient-FL personalization baseline: a full model copy
            # pushed down and a fine-tuned one uploaded back, per tenant
            return {"down": self.m, "up": self.m}
        raise ValueError(algorithm)

    # --- computation per sampled client per round (FLOPs) ------------------

    def comp_per_client(self, algorithm: str, n_k: float) -> float:
        a = algorithm.lower()
        if a in ("fedavg", "fedavgm", "scaffold"):
            # forward + backward (B ≈ 2F) through the whole model
            return 3 * self.E * n_k * self.F_M
        if a in ("fedavg-lp", "fedavgm-lp", "scaffold-lp"):
            # full forward, backward only through the head
            return self.E * n_k * (self.F_phi + 3 * self.F_head)
        if a == "fed3r":
            # one extractor pass + A_k (symmetric: d(d+1)/2) + b_k (dC)
            return n_k * (self.F_phi + 0.5 * self.d * (self.d + 1) + self.d * self.C)
        if a == "fed3r-rf":
            assert self.D > 0
            rf_map = self.d * self.D  # Z·Ω
            return n_k * (
                self.F_phi + rf_map + 0.5 * self.D * (self.D + 1) + self.D * self.C
            )
        if a == "fed3r+ft-feat":
            return 3 * self.E * n_k * self.F_M
        if a == "fed3r-personalized":
            # MARGINAL cost on top of fed3r, and it is server-side: one
            # rank-n_k Gram update + d×d refactorization + two triangular
            # solves per head — no client compute at all
            return (
                n_k * 0.5 * self.d * (self.d + 1)
                + self.d**3 / 3.0
                + 2.0 * self.d**2 * self.C
            )
        if a == "personalized-ft":
            # per-tenant fine-tuning pass (forward + backward, E epochs)
            return 3 * self.E * n_k * self.F_M
        raise ValueError(algorithm)

    # --- cumulative curves (paper Fig. 2 middle/right) -----------------------

    def cumulative_comm_bytes(
        self, algorithm: str, n_rounds: int, clients_per_round: int
    ) -> np.ndarray:
        c = self.comm_per_client(algorithm)
        per_round = (c["down"] + c["up"]) * clients_per_round * FP32_BYTES
        return per_round * np.arange(1, n_rounds + 1, dtype=np.float64)

    def cumulative_comp_flops_per_client(
        self,
        algorithm: str,
        n_rounds: int,
        clients_per_round: int,
        n_clients: int,
        avg_n_k: float,
    ) -> np.ndarray:
        """Average cumulative FLOPs per client: T_t = T · t · κ/K (App. E)."""
        T = self.comp_per_client(algorithm, avg_n_k)
        t = np.arange(1, n_rounds + 1, dtype=np.float64)
        return T * t * clients_per_round / n_clients

    def fed3r_total_comm_bytes(self, n_clients: int, include_extractor_push: bool = False
                               ) -> float:
        """FED3R end-to-end: every client uploads its statistics exactly once."""
        up = (self.d**2 + self.d * self.C) * n_clients
        down = self.b * n_clients if include_extractor_push else 0.0
        return (up + down) * FP32_BYTES

    # --- multi-tenant personalized serving (repro.federated.personalization)

    def head_cache_bytes(self, n_tenants: int) -> float:
        """Serving-side memory for n cached per-tenant heads (d·C fp32 each).

        The LRU head cache (repro.launch.serve_heads) holds solved heads
        only — the capacity knob trades this memory against re-solve
        dispatches, so size it against the hot-tenant working set.
        """
        return n_tenants * self.head * FP32_BYTES

    def tenant_stats_bytes(self, n_tenants: int) -> float:
        """Server-side retained per-tenant statistics (A_k: d², b_k: d·C).

        What the server must keep per tenant to re-solve its head after
        every global stream advance; the d² second moment dominates, so
        compressed/quantized stats upload (ROADMAP) attacks this figure.
        """
        return n_tenants * (self.d**2 + self.d * self.C) * FP32_BYTES

    # --- compressed statistics uplink (repro.federated.compress) -----------

    def compressed_stats_bytes(
        self, kind: str, n_tenants: int = 1, tile: int = 128, rank: int = 16
    ) -> float:
        """Wire/retention bytes of n (A_k, b_k) uploads under a wire format.

        ``kind="fp32"`` reproduces :meth:`tenant_stats_bytes` exactly; the
        compressed kinds re-price the same payload as it actually crosses
        the uplink (int8/fp8 tiles + scale grid, or the rank-r sketch).
        """
        return n_tenants * stats_wire_bytes(self.d, self.C, kind, tile, rank)

    def wire_compression_ratio(
        self, kind: str, tile: int = 128, rank: int = 16
    ) -> float:
        """fp32 bytes over compressed bytes for one statistics upload."""
        return self.compressed_stats_bytes("fp32") / self.compressed_stats_bytes(
            kind, tile=tile, rank=rank
        )

    # --- continuous-batching slot serving (repro.launch.serving_engine) ----

    def slot_table_bytes(self, n_slots: int) -> float:
        """Device-resident slot-table memory at S slots (S·d·C fp32 heads).

        The slot engine's whole device footprint: a FIXED donated buffer
        sized by the hot working set, not the tenant universe — compare
        against :meth:`head_cache_bytes` at the full tenant count to see
        what the slots buy (a 1M-tenant head store vs a few thousand
        resident slots serving the same Zipf traffic).
        """
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        return n_slots * self.head * FP32_BYTES

    def slot_solve_flops(
        self, n_solved: float, avg_n_k: float, grid: int = 5
    ) -> float:
        """Solve-stage FLOPs for one tick batching ``n_solved`` cache misses.

        Per head: one rank-n_k symmetric update of the tenant's Gram
        contribution, then the α-grid sweep pays ``grid`` refactorizations
        (d³/3) + two triangular solves (2·d²·C) each — all inside ONE
        dispatch over the cohort, so this is the tick's arithmetic, not a
        per-tenant loop count.
        """
        per_head = (
            avg_n_k * 0.5 * self.d * (self.d + 1)
            + grid * (self.d**3 / 3.0 + 2.0 * self.d**2 * self.C)
        )
        return n_solved * per_head

    def serve_flops(self, n_queries: float) -> float:
        """Serve-stage FLOPs for one tick answering q queries.

        One gathered batched matvec: 2·d·C per query against its slot's
        head.  Orders of magnitude below :meth:`slot_solve_flops`, which
        is why the engine amortizes solves across ticks and serves hits
        from resident slots.
        """
        return 2.0 * n_queries * self.d * self.C

    def serving_qps_roofline(
        self,
        flops_per_s: float = 1.97e14,  # bf16 peak, TPU v5e chip
        hbm_bw: float = 8.1e11,  # bytes/s HBM, TPU v5e chip
    ) -> Dict[str, float]:
        """Sustained-QPS ceiling of the serve stage on one chip.

        Each query touches its gathered head (d·C), its feature row (d)
        and its score row (C) — at d·C fp32 bytes per 2·d·C FLOPs the
        arithmetic intensity is ~0.5 FLOP/byte, so the stage is
        MEMORY-BOUND on any accelerator: the roofline is HBM bandwidth
        over bytes-per-query, and batching queries per tick is how the
        engine actually reaches it (dispatch overhead amortized to O(1)
        per batch, not per query).
        """
        flops_q = self.serve_flops(1)
        bytes_q = FP32_BYTES * (self.head + self.d + self.C)
        compute_qps = flops_per_s / flops_q
        memory_qps = hbm_bw / bytes_q
        return {
            "flops_per_query": flops_q,
            "bytes_per_query": bytes_q,
            "compute_bound_qps": compute_qps,
            "memory_bound_qps": memory_qps,
            "qps": min(compute_qps, memory_qps),
            "bound": "memory" if memory_qps < compute_qps else "compute",
        }

    # --- two-stage statistics all-reduce (repro.federated.dist) ------------

    @property
    def stats_payload_bytes(self) -> float:
        """The per-device all-reduce payload of one statistics aggregation:
        the d² second moment + the d·C class sums, fp32 (the n scalar and
        class counts are noise)."""
        return (self.d**2 + self.d * self.C) * FP32_BYTES

    def two_stage_allreduce(
        self,
        data_parallel: int,
        n_pods: int = 1,
        *,
        ici_bw: float = 50e9,  # bytes/s per chip, intra-pod ring (TPU v5e ICI)
        dcn_bw: float = 12.5e9,  # bytes/s per pod boundary (cross-pod DCN)
        wire: str = "fp32",  # statistics wire format of the reduced payload
        tile: int = 128,
        rank: int = 16,
    ) -> Dict[str, float]:
        """Per-stage wire bytes and latency of the hierarchical all-reduce.

        The dist layer reduces the statistics in two stages — intra-pod
        over ICI across ``data_parallel`` chips, then cross-pod over DCN
        across ``n_pods`` pods (one psum per mesh axis, innermost first) —
        so each stage is costed with the ring all-reduce wire formula
        2·(n−1)/n · payload at its own bandwidth.  The DCN stage moves the
        ALREADY-REDUCED payload once per pod boundary, which is why the
        hierarchy wins: a flat all-reduce would drag every intra-pod hop
        across the slow cross-pod wire.

        ``wire`` re-prices the moving payload under a compressed statistics
        format (repro.federated.compress): each device's local partial
        crosses the wire as int8/fp8 tiles or a rank-r sketch instead of
        dense fp32, shrinking both stages by the format's compression
        ratio.  ``"fp32"`` reproduces the uncompressed figures exactly.
        """
        if data_parallel < 1 or n_pods < 1:
            raise ValueError(
                f"data_parallel and n_pods must be >= 1, got "
                f"{data_parallel}, {n_pods}"
            )
        payload = self.compressed_stats_bytes(wire, tile=tile, rank=rank)
        ici_bytes = 2.0 * (data_parallel - 1) / data_parallel * payload
        dcn_bytes = 2.0 * (n_pods - 1) / n_pods * payload
        ici_s = ici_bytes / ici_bw
        dcn_s = dcn_bytes / dcn_bw
        flat_n = data_parallel * n_pods  # flat all-reduce, DCN-bound
        flat_s = (2.0 * (flat_n - 1) / flat_n * payload) / (
            dcn_bw if n_pods > 1 else ici_bw
        )
        return {
            "payload_bytes": payload,
            "ici_bytes_per_chip": ici_bytes,
            "dcn_bytes_per_pod": dcn_bytes,
            "ici_s": ici_s,
            "dcn_s": dcn_s,
            "total_s": ici_s + dcn_s,
            "flat_allreduce_s": flat_s,
        }

    def tiered_allreduce(
        self,
        tiers,
        *,
        rank: int = 16,
    ) -> Dict[str, object]:
        """Per-tier wire bytes and latency of an N-tier aggregation tree.

        ``tiers`` is the plain-data description
        ``repro.federated.tiers.AggregationTree.as_cost_tiers()`` emits —
        a sequence of dicts with ``fan_in`` (participants reduced at the
        tier), ``wire`` (the tier's boundary format), ``bandwidth``
        (bytes/s of the tier's interconnect: ICI / DCN / WAN) and
        optionally ``name``/``tile`` — LEAF (edge) TIER FIRST.  Keeping
        the input jax-free lets this module price topologies without
        importing the tree implementation.

        Each tier is costed two ways:

        * ``ring_bytes`` / ``tier_s`` — the collective form: a ring
          all-reduce over ``fan_in`` participants at the tier's bandwidth
          (2·(n−1)/n · payload), the payload already shrunk to the tier's
          wire format.  ``total_s`` sums the stages; ``flat_allreduce_s``
          is the flat baseline dragging every hop across the SLOWEST
          tier's wire, and for two fp32 tiers ``total_s`` reproduces
          :meth:`two_stage_allreduce` exactly.
        * ``uplink_bytes`` — the host-tree form: ``prod(fan_in[i:])``
          child payloads cross INTO tier i per reduction, each at the
          tier's wire bytes.  This is the figure the
          :class:`repro.federated.tiers.TieredAbsorber` meters per
          segment, so measured-vs-model drift should sit at 1.0.
        """
        parsed = []
        for i, t in enumerate(tiers):
            name = str(t.get("name", f"tier{i}"))
            fan_in = int(t["fan_in"])
            wire = str(t.get("wire", "fp32"))
            bw = float(t.get("bandwidth", 50e9))
            tile = int(t.get("tile", 128))
            if fan_in < 1:
                raise ValueError(f"tier {name!r}: fan_in must be >= 1, got {fan_in}")
            if bw <= 0:
                raise ValueError(f"tier {name!r}: bandwidth must be > 0, got {bw}")
            parsed.append((name, fan_in, wire, bw, tile))
        if not parsed:
            raise ValueError("tiered_allreduce needs at least one tier")
        leaves = 1
        for _, fan_in, _, _, _ in parsed:
            leaves *= fan_in
        out_tiers = []
        total_s = 0.0
        uplink_total = 0.0
        entering = leaves
        slowest_bw = min(bw for _, _, _, bw, _ in parsed)
        for name, fan_in, wire, bw, tile in parsed:
            payload = self.compressed_stats_bytes(wire, tile=tile, rank=rank)
            ring_bytes = 2.0 * (fan_in - 1) / fan_in * payload
            tier_s = ring_bytes / bw
            uplink_bytes = entering * payload
            out_tiers.append(
                {
                    "name": name,
                    "fan_in": fan_in,
                    "wire": wire,
                    "bandwidth": bw,
                    "payload_bytes": payload,
                    "ring_bytes": ring_bytes,
                    "tier_s": tier_s,
                    "uplink_bytes": uplink_bytes,
                }
            )
            total_s += tier_s
            uplink_total += uplink_bytes
            entering //= fan_in
        # flat baseline: same (leaf-tier) payload, but every hop of the
        # single big ring crosses the slowest interconnect — consistent
        # with two_stage_allreduce's flat figure
        name0, _, wire0, _, tile0 = parsed[0]
        flat_payload = self.compressed_stats_bytes(wire0, tile=tile0, rank=rank)
        flat_s = (
            (2.0 * (leaves - 1) / leaves * flat_payload) / slowest_bw
            if leaves > 1
            else 0.0
        )
        return {
            "tiers": out_tiers,
            "n_tiers": len(out_tiers),
            "leaves": leaves,
            "total_s": total_s,
            "uplink_bytes_total": uplink_total,
            "flat_allreduce_s": flat_s,
            "speedup_vs_flat": flat_s / total_s if total_s > 0 else float("inf"),
        }

    # --- straggler-tail round pricing (repro.federated.async_engine) --------

    def straggler_tail(
        self,
        clients_per_round: int,
        straggler_frac: float,
        *,
        straggler_factor: float = 8.0,
        base_s: float = 0.3,
        deadline_s: Optional[float] = None,
    ) -> Dict[str, float]:
        """Expected round-completion time: synchronous barrier vs async close.

        The synchronous engines complete a round at the MAX of the cohort's
        upload latencies, so any sampled straggler (latency ≈
        ``straggler_factor × base_s``) stretches the whole round; with a
        straggler fraction p the probability a K-client round contains at
        least one is 1 − (1−p)^K — near-certain already at K = 16, p = 0.2.
        The asynchronous engine closes at ``deadline_s`` regardless (late
        uploads keep merging under the staleness bound), so its completion
        is min(deadline, tail).  The returned ``speedup`` is the analytic
        counterpart of the measured ``benchmarks/bench_async.py`` figure;
        wire bytes are unchanged (the same uploads move, just later), so
        this prices TIME, not bytes.
        """
        if clients_per_round < 1:
            raise ValueError(
                f"clients_per_round must be >= 1, got {clients_per_round}"
            )
        if not 0.0 <= straggler_frac <= 1.0:
            raise ValueError(
                f"straggler_frac must be in [0, 1], got {straggler_frac}"
            )
        p_tail = 1.0 - (1.0 - straggler_frac) ** clients_per_round
        tail_s = straggler_factor * base_s
        sync_s = p_tail * tail_s + (1.0 - p_tail) * base_s
        deadline = base_s if deadline_s is None else deadline_s
        async_s = min(deadline, sync_s)
        return {
            "p_straggler_round": p_tail,
            "sync_round_s": sync_s,
            "async_round_s": async_s,
            "speedup": sync_s / async_s if async_s > 0 else float("inf"),
        }

    def personalization_vs_model_push_ratio(self) -> float:
        """Wire cost of personalized-FT (a full model roundtrip per tenant,
        re-paid on every refresh) over the ONE-TIME stats upload the closed
        form reuses.  The closed form's marginal upload beyond fed3r is
        zero, so this ratio is its conservative lower bound — and it grows
        with every FT refresh while the closed form re-solves server-side
        for free."""
        closed = self.comm_per_client("fed3r-personalized")["up"]
        ft = sum(self.comm_per_client("personalized-ft").values())
        return ft / closed


# Paper-configured instances (Table 4/5): d=1280 (MobileNetV2 features).
LANDMARKS = CostModel(b=2.22e6, d=1280, C=2028, F_phi=332.9e6)
INATURALIST = CostModel(b=2.22e6, d=1280, C=1203, F_phi=332.9e6)
CIFAR100 = CostModel(b=2.22e6, d=1280, C=100, F_phi=332.9e6)


def speedup_table(
    cm: CostModel, target_rounds: Dict[str, float]
) -> Dict[str, float]:
    """Rounds-to-target speedups vs FED3R (paper §5.2 reports ×19.3–×82.4)."""
    base = target_rounds.get("fed3r") or target_rounds.get("fed3r-rf")
    return {k: v / base for k, v in target_rounds.items()}
