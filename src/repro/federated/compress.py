"""Compressed statistics uplink — quantized / sketched (A_k, b_k) wire formats.

Fed3R's wire cost is dominated by the d×d second moment every client
uploads (``costs.CostModel.tenant_stats_bytes``: ~17 TB per 1M tenants at
d = 1280).  This module makes that uplink LOSSY-TOLERANT: every (A_k, b_k)
statistics payload can travel as

* ``int8``  — per-tile absmax symmetric int8 (1 B/element + one fp32 scale
  per (tile × tile) block; ~4× fewer bytes), packed/unpacked by the fused
  Pallas kernels :func:`repro.kernels.quantize_tiles` /
  :func:`repro.kernels.dequant_accumulate` on TPU and their jnp oracles
  elsewhere;
* ``fp8``   — the same tiling algebra with a ``float8_e4m3fn`` payload
  (identical byte count to int8, coarser mantissa, wider per-tile dynamic
  range); falls back to int8 with a warning when the backend lacks fp8
  support (:func:`fp8_supported`), so CPU CI never hard-fails on dtype
  support;
* ``sketch`` — a rank-r factor Z_k (r × d) with A_k ≈ Z_kᵀZ_k (top-r
  eigenpairs — the optimal Frobenius rank-r approximation of the PSD
  second moment); the aggregator absorbs it through the same additive
  rank-n Gram algebra the streaming engine's Cholesky update uses, and b_k
  stays dense fp32.  Wins over int8 when r ≪ d/4 and C ≪ d.

``fp32`` is the identity format: its code path adds the raw arrays exactly
as the uncompressed engines did, so it stays BITWISE identical to them.

Error feedback: a lossy uplink hit repeatedly by the same client would
accumulate bias (deterministic rounding repeats the SAME error every
round, so it grows linearly).  The standard fix is a per-client residual
e_k carried between uploads: send Q(x + e_k), keep e_k ← (x + e_k) −
Q(x + e_k).  The aggregated sum over R uploads then telescopes to
Σ x_t − e_R — off by ONE quantization step regardless of R, instead of R
steps.  :func:`compress_stats_ef` is the jit-able algebra;
:class:`UplinkCompressor` is the host-side per-client residual store (the
deployment shape: one residual pytree per client, living where the client
lives) with wire-byte accounting priced by
:func:`repro.federated.costs.stats_wire_bytes`.

Engine integration (one dispatch preserved everywhere):

* :class:`repro.federated.engine.AccumulationEngine` folds each client's
  quantized payload into the fp32 accumulator INSIDE its scan via the
  fused dequantize-accumulate (``EngineConfig(wire=...)``);
* :class:`repro.federated.streaming_engine.StreamingEngine` compresses
  each wave's rank-n statistics before they touch the carried factor
  (``StreamConfig(wire=...)``);
* the dist layer's psum backends roundtrip each device's LOCAL partial
  through the wire before the all-reduce
  (``DistContext.all_reduce(..., wire_fn=...)``), so the ICI/DCN payload
  of every merge is the compressed statistics, dequantized once at the
  aggregation boundary.

Secure-aggregation interop (paper App. B): masked summation needs EXACT
arithmetic, which float payloads cannot give but integer payloads can —
:func:`cohort_quantize_int8` quantizes a whole cohort against SHARED
per-tile scales into int32 working precision, so pairwise masks added mod
2³² cancel exactly in the sum (:func:`repro.federated.secure_agg.
mask_quantized_payload`), and one shared-scale dequantization recovers
the cohort aggregate.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, replace
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.fed3r import Fed3RStats
from repro.federated.costs import WIRE_KINDS, stats_wire_bytes
from repro.federated.dist import resolve_use_kernel
from repro.federated.telemetry import get_telemetry
from repro.kernels import dequant_accumulate, quantize_tiles
from repro.kernels.quant import INT8_QMAX
from repro.kernels.ref import dequant_acc_ref, quantize_tiles_ref

FP8_QMAX = 448.0  # float8_e4m3fn max finite value


@functools.lru_cache(maxsize=1)
def fp8_supported() -> bool:
    """Can the current backend round-trip ``float8_e4m3fn``?"""
    if not hasattr(jnp, "float8_e4m3fn"):
        return False
    try:
        x = jnp.asarray([1.0, -2.5], jnp.float32)
        back = x.astype(jnp.float8_e4m3fn).astype(jnp.float32)
        jax.block_until_ready(back)
        return True
    except Exception:  # noqa: BLE001 — any dtype/lowering failure means "no"
        return False


@functools.lru_cache(maxsize=1)
def _warn_fp8_fallback() -> None:
    """Emit the fp8→int8 fallback warning once per process.

    The backend's fp8 support cannot change within a process
    (:func:`fp8_supported` is itself cached), so repeating the warning on
    every engine construction is pure noise; tests reset via
    ``_warn_fp8_fallback.cache_clear()``.
    """
    warnings.warn(
        "fp8 wire format is unsupported on backend "
        f"{jax.default_backend()!r}; falling back to int8 (identical "
        "wire bytes, round-to-nearest int mantissa)",
        RuntimeWarning,
        stacklevel=4,  # engine ctor → WireFormat.resolved → here
    )


@dataclass(frozen=True)
class WireFormat:
    """Static wire-format configuration of the statistics uplink.

    ``kind`` ∈ {"fp32", "int8", "fp8", "sketch"}; ``tile`` is the absmax
    granularity of the quantized kinds (one fp32 scale per tile × tile
    block); ``rank`` is the sketch rank r; ``error_feedback`` enables the
    per-client residual carry in :class:`UplinkCompressor` (the in-engine
    folds are single-shot per client and stateless by construction).
    Frozen + hashable, so it is a trace-time constant of the engines.
    """

    kind: str = "fp32"
    tile: int = 128
    rank: int = 16
    error_feedback: bool = True

    def __post_init__(self):
        if self.kind not in WIRE_KINDS:
            raise ValueError(
                f"unknown wire kind: {self.kind!r} (expected one of {WIRE_KINDS})"
            )
        if self.tile < 1:
            raise ValueError(f"tile must be >= 1, got {self.tile}")
        if self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")

    def resolved(self) -> "WireFormat":
        """The format actually used on this backend: fp8 degrades to int8
        (same byte count, finer mantissa) with a ONE-PER-PROCESS warning
        when the backend cannot represent ``float8_e4m3fn`` — tier-1 CPU CI
        never hard-fails on dtype support, and a deployment constructing
        hundreds of engines isn't drowned in identical warnings."""
        if self.kind == "fp8" and not fp8_supported():
            _warn_fp8_fallback()
            get_telemetry().event("fp8_fallback", backend=jax.default_backend())
            return replace(self, kind="int8")
        return self

    def wire_bytes(self, d: int, C: int) -> float:
        """Bytes one (A_k, b_k) upload costs under this format."""
        return stats_wire_bytes(d, C, self.kind, self.tile, self.rank)


# ---------------------------------------------------------------------------
# Pure quantization algebra (jit-able; fmt is a static trace-time constant)
# ---------------------------------------------------------------------------


def _quantize_int8(
    x: jax.Array, tile: int, use_kernel: Optional[bool]
) -> Tuple[jax.Array, jax.Array]:
    if resolve_use_kernel(use_kernel):
        return quantize_tiles(x, tile=tile)
    return quantize_tiles_ref(x, tile=tile)


def _dequant_add_int8(
    acc: jax.Array,
    q: jax.Array,
    scales: jax.Array,
    tile: int,
    use_kernel: Optional[bool],
) -> jax.Array:
    if resolve_use_kernel(use_kernel):
        return dequant_accumulate(acc, q, scales, tile=tile)
    return dequant_acc_ref(acc, q, scales, tile=tile)


def _fp8_roundtrip(x: jax.Array, tile: int) -> jax.Array:
    """Per-tile scaled fp8 quantize→dequantize (pure jnp; the payload byte
    count matches int8, so the Pallas tiling story is shared with it)."""
    M, N = x.shape
    xf = x.astype(jnp.float32)
    p0, p1 = (-M) % tile, (-N) % tile
    xp = jnp.pad(xf, ((0, p0), (0, p1))) if (p0 or p1) else xf
    Mt, Nt = xp.shape[0] // tile, xp.shape[1] // tile
    blocks = xp.reshape(Mt, tile, Nt, tile)
    absmax = jnp.max(jnp.abs(blocks), axis=(1, 3))
    scales = jnp.where(absmax > 0.0, absmax / FP8_QMAX, 1.0)[:, None, :, None]
    q = (blocks / scales).astype(jnp.float8_e4m3fn)
    back = q.astype(jnp.float32) * scales
    return back.reshape(xp.shape)[:M, :N]


def sketch_psd(A: jax.Array, rank: int) -> jax.Array:
    """Rank-r factor Z (r, d) of a PSD matrix with A ≈ ZᵀZ.

    Top-r eigenpairs of the symmetric A (the optimal Frobenius rank-r
    approximation); negative eigenvalues — fp noise around zero for a true
    second moment — clamp to 0 so ZᵀZ stays PSD.
    """
    w, V = jnp.linalg.eigh(A.astype(jnp.float32))  # ascending eigenvalues
    w_top = jnp.maximum(w[-rank:], 0.0)  # (r,)
    return (V[:, -rank:] * jnp.sqrt(w_top)[None, :]).T  # (r, d)


def unsketch(Z: jax.Array) -> jax.Array:
    """The aggregator's view of a sketched upload: A ≈ ZᵀZ — the same
    additive rank-n Gram form the Cholesky update kernel absorbs."""
    return Z.T @ Z


def wire_roundtrip(
    A: jax.Array,
    b: jax.Array,
    fmt: WireFormat,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Simulate the lossy uplink: the (Â, b̂) the aggregator receives.

    ``fp32`` returns the inputs UNTOUCHED (bitwise identity — not a
    recompute whose roundings could differ).  Under ``sketch`` only A is
    sketched; b stays dense fp32.
    """
    if fmt.kind == "fp32":
        return A, b
    if fmt.kind == "sketch":
        return unsketch(sketch_psd(A, fmt.rank)), b
    if fmt.kind == "fp8":
        return _fp8_roundtrip(A, fmt.tile), _fp8_roundtrip(b, fmt.tile)
    qA, sA = _quantize_int8(A, fmt.tile, use_kernel)
    qb, sb = _quantize_int8(b, fmt.tile, use_kernel)
    zA = jnp.zeros_like(A, jnp.float32)
    zb = jnp.zeros_like(b, jnp.float32)
    return (
        _dequant_add_int8(zA, qA, sA, fmt.tile, use_kernel),
        _dequant_add_int8(zb, qb, sb, fmt.tile, use_kernel),
    )


def roundtrip_add(
    accA: jax.Array,
    accb: jax.Array,
    A: jax.Array,
    b: jax.Array,
    fmt: WireFormat,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fold one compressed (A_k, b_k) upload into the fp32 accumulator.

    The aggregator-side merge primitive of the engines: under ``int8`` the
    payload lands through the FUSED dequantize-accumulate kernel — the
    dense dequantized intermediate never exists; under ``fp32`` this is
    exactly the uncompressed ``acc + A`` (bitwise identical to the
    pre-compression engines).
    """
    if fmt.kind == "fp32":
        return accA + A, accb + b
    if fmt.kind == "int8":
        qA, sA = _quantize_int8(A, fmt.tile, use_kernel)
        qb, sb = _quantize_int8(b, fmt.tile, use_kernel)
        return (
            _dequant_add_int8(accA, qA, sA, fmt.tile, use_kernel),
            _dequant_add_int8(accb, qb, sb, fmt.tile, use_kernel),
        )
    Ah, bh = wire_roundtrip(A, b, fmt, use_kernel)
    return accA + Ah, accb + bh


def matrix_roundtrip(
    x: jax.Array, fmt: WireFormat, use_kernel: Optional[bool] = None
) -> jax.Array:
    """Lossy wire roundtrip of ONE 2-D matrix (``fp32`` = bitwise identity).

    The per-leaf primitive of the N-tier aggregation tree
    (:mod:`repro.federated.tiers`): a tier boundary carries arbitrary
    statistics pytrees, so each matrix leaf crosses independently under the
    tier's format.  ``sketch`` is rejected — it is a client-uplink format
    for PSD second moments, not a generic tier wire.
    """
    if fmt.kind == "fp32":
        return x
    if fmt.kind == "fp8":
        return _fp8_roundtrip(x, fmt.tile)
    if fmt.kind == "int8":
        q, s = _quantize_int8(x, fmt.tile, use_kernel)
        return _dequant_add_int8(
            jnp.zeros_like(x, jnp.float32), q, s, fmt.tile, use_kernel
        )
    raise ValueError(
        f"wire kind {fmt.kind!r} is not a tier-boundary format "
        "(expected fp32 | int8 | fp8)"
    )


def matrix_roundtrip_add(
    acc: jax.Array,
    x: jax.Array,
    fmt: WireFormat,
    use_kernel: Optional[bool] = None,
) -> jax.Array:
    """Fold one matrix across a lossy tier boundary into an fp32 accumulator.

    ``int8`` lands through the FUSED dequantize-accumulate (the dense
    dequantized intermediate never exists); ``fp32`` is exactly ``acc + x``.
    """
    if fmt.kind == "fp32":
        return acc + x
    if fmt.kind == "int8":
        q, s = _quantize_int8(x, fmt.tile, use_kernel)
        return _dequant_add_int8(acc, q, s, fmt.tile, use_kernel)
    return acc + matrix_roundtrip(x, fmt, use_kernel)


def quant_spectral_bound(S: jax.Array, fmt: WireFormat) -> jax.Array:
    """Data-dependent bound on ‖E‖₂ of the quantization error E = Ŝ − S.

    Per-tile absmax quantization errs at most ``max_scale/2`` per entry
    (int8) or ``|S_ij|·2⁻⁴`` (fp8's 3-bit mantissa); the spectral norm of a
    dense d×d perturbation with entries bounded by δ concentrates near
    √d·δ.  Used to size the jitter of :func:`psd_cholesky` — ``sketch``
    and ``fp32`` introduce no indefiniteness (eigenvalue truncation keeps
    ZᵀZ PSD; fp32 is exact) and return 0.
    """
    if fmt.kind in ("fp32", "sketch"):
        return jnp.zeros((), jnp.float32)
    d = S.shape[0]
    per_entry = (
        jnp.max(jnp.abs(S)) / 16.0
        if fmt.kind == "fp8"
        else 0.5 * jnp.max(jnp.abs(S)) / INT8_QMAX
    )
    return jnp.sqrt(jnp.float32(d)) * per_entry


def psd_cholesky(G: jax.Array, bound: jax.Array) -> jax.Array:
    """Cholesky of a nominally-PSD matrix whose smallest eigenvalues may
    have been pushed negative by quantization noise.

    Tries the plain factorization first (the common case: a well-filled
    update keeps G positive definite and the answer is bit-identical to
    ``jnp.linalg.cholesky``); on NaN, retries with escalating diagonal
    jitter τ ∈ {1, 4, 16}·bound — a data-dependent ridge no larger than a
    few quantization steps, applied ONLY when the factorization actually
    failed.  Branch-free (``where`` chains), so it stays one fused program
    inside the engines' scans.
    """
    L = jnp.linalg.cholesky(G)
    eye = jnp.eye(G.shape[0], dtype=G.dtype)
    for mult in (1.0, 4.0, 16.0):
        retry = jnp.linalg.cholesky(G + (mult * bound) * eye)
        L = jnp.where(jnp.any(jnp.isnan(L)), retry, L)
    return L


# ---------------------------------------------------------------------------
# Error feedback — per-client residual carry across repeated participation
# ---------------------------------------------------------------------------


class EFState(NamedTuple):
    """Per-client error-feedback residuals (what the wire has not yet sent)."""

    eA: jax.Array  # (d, d) fp32
    eb: jax.Array  # (d, C) fp32


def ef_init(d: int, n_classes: int) -> EFState:
    return EFState(
        eA=jnp.zeros((d, d), jnp.float32),
        eb=jnp.zeros((d, n_classes), jnp.float32),
    )


def compress_stats_ef(
    A: jax.Array,
    b: jax.Array,
    ef: EFState,
    fmt: WireFormat,
    use_kernel: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, EFState]:
    """One error-compensated upload: send Q(x + e), carry e ← (x+e) − Q(x+e).

    Returns the aggregator's view (Â, b̂) and the new residual.  Under
    ``fp32`` the upload is exact and the residual stays zero (bitwise
    passthrough of A and b).
    """
    if fmt.kind == "fp32":
        return A, b, ef
    Ah, bh = wire_roundtrip(A + ef.eA, b + ef.eb, fmt, use_kernel)
    return Ah, bh, EFState(eA=A + ef.eA - Ah, eb=b + ef.eb - bh)


class UplinkCompressor:
    """Host-side per-client compressed uplink with error-feedback residuals.

    The deployment shape of the compression layer: each client owns one
    residual pytree that persists across its repeated participations, so
    the server-side accumulated A stays accurate no matter how many lossy
    uploads a client makes (the errors telescope instead of accumulating).
    ``upload`` is ONE jitted dispatch per call; ``bytes_sent`` /
    ``bytes_fp32`` price the wire under the configured format vs today's
    dense fp32 uplink — homed in the telemetry registry as
    ``wire_bytes_*_total`` counters, with a ``wire_cost_model_drift``
    gauge (bytes actually priced per upload over the ``cost_model``'s
    prediction) surfacing CostModel staleness the moment the wire formula
    and the analytic model disagree.
    """

    def __init__(
        self,
        fmt: WireFormat,
        use_kernel: Optional[bool] = None,
        *,
        cost_model=None,  # Optional[repro.federated.costs.CostModel]
        telemetry=None,
    ):
        self.fmt = fmt.resolved()
        self.use_kernel = use_kernel
        self.cost_model = cost_model
        self._residuals: Dict[int, EFState] = {}
        t = self.telemetry = get_telemetry() if telemetry is None else telemetry
        inst = t.next_instance("uplink")
        self._c_uploads = t.counter("wire_uploads_total", kind=self.fmt.kind, inst=inst)
        self._c_sent = t.counter("wire_bytes_sent_total", kind=self.fmt.kind, inst=inst)
        self._c_fp32 = t.counter("wire_bytes_fp32_total", kind=self.fmt.kind, inst=inst)
        self._g_ratio = t.gauge("wire_compression_ratio", kind=self.fmt.kind, inst=inst)
        self._g_drift = t.gauge("wire_cost_model_drift", kind=self.fmt.kind, inst=inst)
        self._fn = jax.jit(
            lambda A, b, eA, eb: compress_stats_ef(
                A, b, EFState(eA=eA, eb=eb), self.fmt, self.use_kernel
            )
        )

    # wire accounting proxied onto the telemetry cells (``+=`` keeps working)
    @property
    def uploads(self) -> int:
        return int(self._c_uploads.value)

    @uploads.setter
    def uploads(self, value: int) -> None:
        self._c_uploads.set(int(value))

    @property
    def bytes_sent(self) -> float:
        return float(self._c_sent.value)

    @bytes_sent.setter
    def bytes_sent(self, value: float) -> None:
        self._c_sent.set(float(value))

    @property
    def bytes_fp32(self) -> float:
        return float(self._c_fp32.value)

    @bytes_fp32.setter
    def bytes_fp32(self, value: float) -> None:
        self._c_fp32.set(float(value))

    def upload(self, client_id: int, stats: Fed3RStats) -> Fed3RStats:
        """Compress one client upload; returns the stats AS RECEIVED by the
        aggregator (dequantized), advancing the client's residual."""
        with self.telemetry.span("upload", engine="uplink"):
            d, C = stats.b.shape
            ef = self._residuals.get(client_id)
            if ef is None or not self.fmt.error_feedback:
                ef = ef_init(d, C)
            Ah, bh, new_ef = self._fn(stats.A, stats.b, ef.eA, ef.eb)
            if self.fmt.error_feedback:
                self._residuals[client_id] = new_ef
            sent = self.fmt.wire_bytes(d, C)
            self.uploads += 1
            self.bytes_sent += sent
            self.bytes_fp32 += stats_wire_bytes(d, C, "fp32")
            self._g_ratio.set(self.compression_ratio)
            if self.cost_model is not None:
                predicted = self.cost_model.compressed_stats_bytes(
                    self.fmt.kind, tile=self.fmt.tile, rank=self.fmt.rank
                )
                self._g_drift.set(sent / predicted if predicted else float("inf"))
            return Fed3RStats(A=Ah, b=bh, n=stats.n)

    @property
    def compression_ratio(self) -> float:
        """fp32 bytes over bytes actually sent (1.0 before any upload)."""
        return self.bytes_fp32 / self.bytes_sent if self.bytes_sent else 1.0


# ---------------------------------------------------------------------------
# Secure-aggregation interop — shared-scale integer payloads
# ---------------------------------------------------------------------------


class IntPayload(NamedTuple):
    """One client's shared-scale integer upload (int32 working precision so
    cohort sums and mod-2³² masks never saturate the int8 value range)."""

    qA: jax.Array  # (d, d) int32 — int8-valued entries
    qb: jax.Array  # (d, C) int32


def _shared_scales(xs: Sequence[jax.Array], tile: int, qmax: float) -> jax.Array:
    """Per-tile scales from the COHORT absmax (in deployment: a public
    per-tile bound agreed before upload, so no raw data leaks)."""
    M, N = xs[0].shape
    p0, p1 = (-M) % tile, (-N) % tile
    absmax = None
    for x in xs:
        xp = jnp.pad(x.astype(jnp.float32), ((0, p0), (0, p1))) if (p0 or p1) else x
        blocks = xp.astype(jnp.float32).reshape(
            xp.shape[0] // tile, tile, xp.shape[1] // tile, tile
        )
        am = jnp.max(jnp.abs(blocks), axis=(1, 3))
        absmax = am if absmax is None else jnp.maximum(absmax, am)
    return jnp.where(absmax > 0.0, absmax / qmax, 1.0)


def _quantize_shared(x: jax.Array, scales: jax.Array, tile: int, qmax: float) -> jax.Array:
    M, N = x.shape
    s = jnp.repeat(jnp.repeat(scales, tile, axis=0), tile, axis=1)[:M, :N]
    return jnp.clip(jnp.round(x.astype(jnp.float32) / s), -qmax, qmax).astype(jnp.int32)


def cohort_quantize_int8(
    stats: Sequence[Fed3RStats], tile: int = 128
) -> Tuple[List[IntPayload], jax.Array, jax.Array]:
    """Quantize a cohort's uploads against SHARED per-tile scales.

    Shared scales make the integer payloads ADDITIVE: Σ_k q_k dequantizes
    with one multiply to Σ_k Q(x_k) — the property masked (secure)
    aggregation needs, since the server only ever sees the masked integer
    sum.  Returns the per-client payloads and the (A, b) scale grids.
    """
    sA = _shared_scales([s.A for s in stats], tile, 127.0)
    sb = _shared_scales([s.b for s in stats], tile, 127.0)
    payloads = [
        IntPayload(
            qA=_quantize_shared(s.A, sA, tile, 127.0),
            qb=_quantize_shared(s.b, sb, tile, 127.0),
        )
        for s in stats
    ]
    return payloads, sA, sb


def dequantize_int_sum(
    q_sum: IntPayload, sA: jax.Array, sb: jax.Array, tile: int = 128
) -> Tuple[jax.Array, jax.Array]:
    """Shared-scale dequantization of an aggregated integer payload."""
    dA, dC = q_sum.qA.shape[0], q_sum.qb.shape[1]
    sAe = jnp.repeat(jnp.repeat(sA, tile, axis=0), tile, axis=1)[:dA, :dA]
    sbe = jnp.repeat(jnp.repeat(sb, tile, axis=0), tile, axis=1)[:dA, :dC]
    return q_sum.qA.astype(jnp.float32) * sAe, q_sum.qb.astype(jnp.float32) * sbe
