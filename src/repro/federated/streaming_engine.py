"""Streaming FED3R arrival engine — batched stable Woodbury + live serving.

The third engine of the triptych (batch statistics → rounds → streaming):
the paper's recursive-least-squares formulation (Eq. 3) and its §6 future
work — clients arriving over time with new data — promoted from a
per-arrival Python loop over the fp32-hazardous subtractive
``woodbury_update`` to a first-class arrival-driven runtime:

* the timeline arrives as a :class:`repro.data.pipeline.PackedArrivals`
  (padded ``(n_waves, clients_per_wave, max_n, ...)`` arrays with masks);
* ALL T waves fold through ONE jitted ``lax.scan`` with donated state —
  1 dispatch for the whole stream instead of the loop's T
  (``benchmarks/bench_streaming.py``);
* the carried state is the numerically stable FACTORED form
  (:class:`repro.core.fed3r.Fed3RFactored` semantics): the lower Cholesky
  factor L of A + λI, advanced per wave by the additive rank-n update
  L ← chol(L Lᵀ + ZᵀZ) — no subtraction, no fp32 cancellation — with the
  served classifier refreshed by two triangular solves;
* the rank-n update GEMMs dispatch to the fused Pallas kernel
  (:func:`repro.kernels.chol_gram`) on TPU and XLA GEMMs elsewhere,
  mirroring the statistics engine's backend split;
* live serving is a refresh POLICY inside the scan: ``refresh_every=1``
  is refresh-on-arrival, ``k > 1`` refreshes every k-th wave and the
  :class:`WaveTrace` reports the staleness metric (waves and samples
  absorbed since the served W was last solved) per wave;
* mesh mode (:mod:`repro.federated.dist`) mirrors ``engine.aggregate``:
  ``"merge"`` folds the whole wave locally; ``"psum"`` all-reduces each
  wave's rank-n statistics over the data axes (two stages on a pod mesh:
  intra-pod ICI, then cross-pod DCN) before the replicated
  refactorization.  With ``DistConfig(mesh=...)`` the dist layer owns the
  shard_map: the wave-WIDTH axis (concurrent arrivals) is split over the
  data axes — the wave axis itself is the scanned arrival clock — so pack
  with ``pack_arrival_waves(..., mesh=mesh)``.  Unlike the batch engine,
  the per-wave psum is inherently on the critical path (wave t+1's factor
  needs the reduced wave-t Gram); ``refresh_every`` bounds the solve cost.

Compressed uplink (:mod:`repro.federated.compress`): with
``StreamConfig(wire=WireFormat(kind="int8" | "fp8" | "sketch"))`` each
wave's rank-n statistics (S, Δb) cross the wire compressed — quantized
client-side, landed in the carried Gram through the fused dequantize-
accumulate (merge), or roundtripped per device partial before the psum —
still one dispatch per timeline; ``"fp32"`` keeps the scan bitwise
identical to today.

Exactness: each wave's clients are canonically packed (sorted by id), so
the folded state — and the final W — is bitwise invariant to the
presentation order of concurrent arrivals; across waves the stream order
IS the semantics.  :class:`ReferenceArrivalLoop` preserves the seed-era
per-arrival shape (one jitted subtractive Woodbury dispatch per wave) as
the dispatch baseline and the numerical foil.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fed3r
from repro.core.fed3r import Fed3RFactored
from repro.core.random_features import RFFParams, rff_map
from repro.data.pipeline import PackedArrivals
from repro.federated import compress
from repro.federated.compress import WireFormat
from repro.federated.dist import (
    DistConfig,
    DistContext,
    DistDispatchMixin,
    resolve_use_kernel,
)
from repro.kernels import chol_gram as chol_gram_kernel
from repro.kernels import fed3r_stats as fed3r_stats_kernel
from repro.sharding.hints import hint
from repro.sharding.specs import replicated


@dataclass(frozen=True)
class StreamConfig:
    """Static streaming-engine configuration (all trace-time constants)."""

    n_classes: int
    ridge_lambda: float
    refresh_every: int = 1  # 1 = refresh-on-arrival; k > 1 = every k-th wave
    normalize: bool = True  # per-class column normalization of the served W
    use_kernel: Optional[bool] = None  # None → auto (Pallas on TPU, XLA else)
    dist: DistConfig = field(default_factory=DistConfig)  # backend/mesh/donate
    # statistics wire format (repro.federated.compress): each wave's rank-n
    # (S, Δb) upload crosses the wire compressed before it touches the
    # carried factor; "fp32" keeps the scan bitwise identical to today
    wire: WireFormat = field(default_factory=WireFormat)


class StreamState(NamedTuple):
    """Donated scan carry: factored statistics + the live-served classifier."""

    L: jax.Array  # (d, d) fp32 lower Cholesky factor of A + λI
    b: jax.Array  # (d, C) fp32 class-conditional feature sums
    n: jax.Array  # () fp32 samples absorbed
    W: jax.Array  # (d, C) fp32 currently SERVED classifier
    wave: jax.Array  # () int32 waves absorbed (the arrival clock)
    stale_waves: jax.Array  # () int32 waves since W was last solved
    stale_samples: jax.Array  # () fp32 samples absorbed since W was last solved

    @property
    def factored(self) -> Fed3RFactored:
        """The core factored-state view (for factored_solution etc.)."""
        return Fed3RFactored(L=self.L, b=self.b)


class WaveTrace(NamedTuple):
    """Per-wave scan outputs, stacked over the absorbed timeline."""

    n_seen: jax.Array  # (T,) fp32 cumulative samples after each wave
    refreshed: jax.Array  # (T,) bool — did this wave re-solve W?
    stale_waves: jax.Array  # (T,) int32 staleness of the served W, in waves
    stale_samples: jax.Array  # (T,) fp32 staleness of the served W, in samples


class StreamingEngine(DistDispatchMixin):
    """One-dispatch streaming FED3R over packed arrival timelines.

    ``feature_fn(params, flat_inputs) -> (n, d)`` maps each wave's packed
    raw inputs (flattened to ``(clients_per_wave·max_n, ...)``) to φ
    features inside the scan; ``None`` means inputs already are features.
    ``rff_params`` fuses the FED3R-RF map the same way, mirroring
    :class:`repro.federated.engine.AccumulationEngine`.
    """

    def __init__(
        self,
        cfg: StreamConfig,
        *,
        feature_fn: Optional[Callable[[Any, jax.Array], jax.Array]] = None,
        rff_params: Optional[RFFParams] = None,
    ):
        if cfg.refresh_every < 1:
            raise ValueError(f"refresh_every must be >= 1, got {cfg.refresh_every}")
        self.cfg = cfg
        self.feature_fn = feature_fn
        self.rff_params = rff_params
        self.wire = cfg.wire.resolved()  # fp8 → int8 fallback off-TPU
        self.dist = DistContext(cfg.dist, engine="streaming")
        # a lossy tier in a routed aggregation tree quantizes the reduced
        # Gram exactly like a lossy engine wire — same PSD guard applies
        self._tree_wire = cfg.dist.lossy_tier_wire
        # mesh mode: shard the wave-WIDTH axis (dim 1; dim 0 is the scanned
        # arrival clock) over the data axes; state/params replicated
        sharded = self.dist.data_spec(axis=1)
        self._absorb = self.dist.jit(
            self.absorb_scan,
            in_specs=(replicated(), sharded, sharded, sharded, replicated()),
            out_specs=(replicated(), replicated()),
        )
        self._refresh = jax.jit(self._refresh_impl)
        # absorb_stats rejects dist-owned meshes (pre-reduced inputs would
        # broadcast-then-psum); plain jit keeps mesh-mode construction valid
        self._absorb_stats = jax.jit(self._absorb_stats_impl)

    def init(self, d: int) -> StreamState:
        fac = fed3r.init_factored(d, self.cfg.n_classes, self.cfg.ridge_lambda)
        return StreamState(
            L=fac.L,
            b=fac.b,
            n=jnp.zeros((), jnp.float32),
            W=jnp.zeros((d, self.cfg.n_classes), jnp.float32),
            wave=jnp.zeros((), jnp.int32),
            stale_waves=jnp.zeros((), jnp.int32),
            stale_samples=jnp.zeros((), jnp.float32),
        )

    # ---- pure core (also usable directly inside shard_map) ----------------

    def _use_kernel(self) -> bool:
        return resolve_use_kernel(self.cfg.use_kernel)

    def _wire_fn(self):
        """The dist layer's compressed-payload hook (None under fp32)."""
        if self.wire.kind == "fp32":
            return None

        def roundtrip(tree):
            S, dB, nw = tree
            S, dB = compress.wire_roundtrip(S, dB, self.wire, self.cfg.use_kernel)
            return (S, dB, nw)

        return roundtrip

    def _solve(self, L: jax.Array, b: jax.Array) -> jax.Array:
        """Two triangular solves against the carried factor (the refresh)."""
        return fed3r.factored_solution(
            Fed3RFactored(L=L, b=b), self.cfg.normalize
        )

    def _wave_body(self, state: StreamState, wave, params: Any) -> Tuple[StreamState, Any]:
        x, y, m = wave  # (P, N, ...), (P, N), (P, N)
        flat = x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
        # constrain the wave batch over the ambient mesh's data axes so
        # feature extraction data-parallelizes; exact no-op otherwise
        flat = hint(flat, "batch")
        feats = flat if self.feature_fn is None else self.feature_fn(params, flat)
        if self.rff_params is not None:
            feats = rff_map(self.rff_params, feats)
        z, yh, nw = fed3r.masked_design(
            feats, y.reshape(-1), self.cfg.n_classes, m.reshape(-1)
        )

        if self.cfg.dist.aggregation == "psum":
            # local rank-n statistics, all-reduced (two stages on a pod
            # mesh) before the replicated refactorization — the fused G
            # kernel would double-count L Lᵀ.  A compressed wire format
            # rides the dist hook: each device's partial (S, Δb) crosses
            # the ICI/DCN wire compressed, dequantized at the boundary.
            if self._use_kernel():
                S, dB = fed3r_stats_kernel(z, yh)
            else:
                S, dB = z.T @ z, z.T @ yh
            S_local = S
            S, dB, nw = self.dist.all_reduce((S, dB, nw), wire_fn=self._wire_fn())
            G = state.L @ state.L.T + S
            b = state.b + dB
        elif self.wire.kind != "fp32":
            # compressed uplink, merge backend: the wave's rank-n upload
            # (S, Δb) quantizes client-side and lands in the carried Gram /
            # class sums through the fused dequantize-accumulate — the
            # fused G kernel is bypassed because the wire sits between the
            # sample GEMMs and the factor reconstruction
            if self._use_kernel():
                S, dB = fed3r_stats_kernel(z, yh)
            else:
                S, dB = z.T @ z, z.T @ yh
            G, b = compress.roundtrip_add(
                state.L @ state.L.T, state.b, S, dB, self.wire, self.cfg.use_kernel
            )
            S_local = S
        elif self._use_kernel():
            G, dB = chol_gram_kernel(state.L, z, yh)
            b = state.b + dB
            S_local = None
        else:
            G = state.L @ state.L.T + z.T @ z
            dB = z.T @ yh
            b = state.b + dB
            S_local = None

        lossy = self.wire if self.wire.kind in ("int8", "fp8") else self._tree_wire
        if lossy is not None and S_local is not None:
            # quantization noise (engine wire OR a lossy tree tier) can push
            # the smallest eigenvalues of the received Ŝ negative on
            # rank-deficient waves (early stream, few samples ≪ d); factor
            # with data-dependent jitter — a ridge of a few quantization
            # steps, applied only when the plain Cholesky actually produced
            # NaN
            L = compress.psd_cholesky(
                G, compress.quant_spectral_bound(S_local, lossy)
            )
        else:
            L = jnp.linalg.cholesky(G)
        n = state.n + nw
        t = state.wave + 1

        refresh = (t % self.cfg.refresh_every) == 0
        W = jax.lax.cond(
            refresh, lambda: self._solve(L, b), lambda: state.W
        )
        stale_w = jnp.where(refresh, 0, state.stale_waves + 1).astype(jnp.int32)
        stale_n = jnp.where(refresh, 0.0, state.stale_samples + nw)
        out = (n, refresh, stale_w, stale_n)
        return StreamState(
            L=L, b=b, n=n, W=W, wave=t, stale_waves=stale_w, stale_samples=stale_n
        ), out

    def absorb_scan(
        self,
        state: StreamState,
        inputs: jax.Array,  # (T, P, N, ...)
        labels: jax.Array,  # (T, P, N)
        mask: jax.Array,  # (T, P, N)
        params: Any = None,  # feature_fn parameters (backbone weights)
    ) -> Tuple[StreamState, WaveTrace]:
        """Fold a whole arrival timeline — the jitted one-dispatch core."""

        def body(carry, wave):
            return self._wave_body(carry, wave, params)

        state, outs = jax.lax.scan(body, state, (inputs, labels, mask))
        return state, WaveTrace(*outs)

    def _absorb_stats_impl(
        self, state: StreamState, A: jax.Array, b: jax.Array, n: jax.Array
    ) -> StreamState:
        """Fold ALREADY-REDUCED statistics (ΣA_k, Σb_k, Σn_k) of one round.

        The round-level entry the asynchronous engine's retire shares
        (:meth:`repro.federated.async_engine.AsyncRoundEngine.retire_fold`):
        same all-reduce placement, same Gram reconstruction, same solve —
        under the ``merge`` backend and fp32 wire the two fold chains are
        BITWISE identical, which is what lets the async engine's drained W
        be cross-checked against a streaming replay of its retire sums.
        Always refreshes W (a retire is a serving point, not a wave).
        """
        S_A, S_b, S_n = self.dist.all_reduce((A, b, n), wire_fn=self._wire_fn())
        G = state.L @ state.L.T + S_A
        lossy = self.wire if self.wire.kind in ("int8", "fp8") else self._tree_wire
        if lossy is not None:
            L = compress.psd_cholesky(
                G, compress.quant_spectral_bound(S_A, lossy)
            )
        else:
            L = jnp.linalg.cholesky(G)
        b_new = state.b + S_b
        return StreamState(
            L=L,
            b=b_new,
            n=state.n + S_n,
            W=self._solve(L, b_new),
            wave=state.wave + 1,
            stale_waves=jnp.zeros((), jnp.int32),
            stale_samples=jnp.zeros((), jnp.float32),
        )

    def _refresh_impl(self, state: StreamState) -> StreamState:
        return state._replace(
            W=self._solve(state.L, state.b),
            stale_waves=jnp.zeros((), jnp.int32),
            stale_samples=jnp.zeros((), jnp.float32),
        )

    # ---- host API ---------------------------------------------------------

    def absorb(
        self, state: StreamState, packed: PackedArrivals, params: Any = None
    ) -> Tuple[StreamState, WaveTrace]:
        """Absorb T arrival waves in ONE jitted dispatch.

        Returns the advanced state (the served classifier is ``state.W``)
        and the per-wave :class:`WaveTrace`.
        """
        with self.dist.telemetry.span("absorb", engine="streaming"):
            self.dist.dispatch()
            return self._absorb(
                state,
                jnp.asarray(packed.inputs),
                jnp.asarray(packed.labels),
                jnp.asarray(packed.mask),
                params,
            )

    def absorb_stats(
        self, state: StreamState, A: jax.Array, b: jax.Array, n: jax.Array
    ) -> StreamState:
        """Fold one round's pre-reduced (ΣA_k, Σb_k, Σn_k) in ONE dispatch.

        The integration point for round-granular producers (the async
        engine's retires, a batch statistics engine's cohort sums): no
        packing, no per-sample features — the statistics land directly in
        the carried factor and W refreshes.  Under ``psum`` the arguments
        are each shard's LOCAL partials and the call belongs inside an
        external shard_map over the pure ``_absorb_stats_impl`` core; a
        dist-owned mesh would broadcast-then-psum (overcounting), so it is
        rejected here.
        """
        if self.cfg.dist.mesh is not None:
            raise ValueError(
                "absorb_stats takes pre-reduced statistics; under a "
                "dist-owned mesh use absorb(), or shard_map the "
                "_absorb_stats_impl core over per-device partials"
            )
        with self.dist.telemetry.span("absorb_stats", engine="streaming"):
            self.dist.dispatch()
            return self._absorb_stats(
                state, jnp.asarray(A), jnp.asarray(b),
                jnp.asarray(n, dtype=jnp.float32),
            )

    def tiered_absorber(self, tree, **kwargs):
        """The N-tier fold entry point: an overlapped
        :class:`repro.federated.tiers.TieredAbsorber` pipeline over this
        engine (host-level tree; upper-tier reductions of segment t overlap
        the lower folds of segment t+1).  Lazy import — tiers builds on
        this module."""
        from repro.federated.tiers import TieredAbsorber

        return TieredAbsorber(self, tree, **kwargs)

    def refresh(self, state: StreamState) -> StreamState:
        """Force a classifier re-solve now (e.g. before a query burst)."""
        with self.dist.telemetry.span("refresh", engine="streaming"):
            self.dist.dispatch()
            return self._refresh(state)

    def classifier(self, state: StreamState) -> jax.Array:
        """The currently SERVED classifier (possibly stale, by policy)."""
        return state.W


class ReferenceArrivalLoop:
    """The seed-era per-arrival path: one jitted subtractive Woodbury
    dispatch per wave (T dispatches for a T-wave stream).

    Kept as the dispatch-count baseline the streaming engine is measured
    against and as the numerical foil: at small λ its carried A⁻¹ cancels
    catastrophically in fp32 (``benchmarks/bench_streaming.py`` reports the
    divergence).  Padding rows are zero in the packed arrays, hence exact
    no-ops in the Woodbury algebra too.
    """

    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg
        self.dispatches = 0
        self._update = jax.jit(fed3r.woodbury_update)

    def init(self, d: int) -> fed3r.Fed3ROnline:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return fed3r.init_online(d, self.cfg.n_classes, self.cfg.ridge_lambda)

    def absorb(
        self, state: fed3r.Fed3ROnline, packed: PackedArrivals
    ) -> fed3r.Fed3ROnline:
        for t in range(packed.n_waves):
            x = packed.inputs[t]
            flat = x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
            state = self._update(
                state, jnp.asarray(flat), jnp.asarray(packed.labels[t].reshape(-1))
            )
            self.dispatches += 1
        return state

    def classifier(self, state: fed3r.Fed3ROnline) -> jax.Array:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return fed3r.online_solution(state, self.cfg.normalize)


def batch_equivalent(
    packed: PackedArrivals, cfg: StreamConfig
) -> Tuple[jax.Array, fed3r.Fed3RStats]:
    """The batch re-solve over the whole timeline — the parity oracle.

    Folds every wave's masked statistics with the batch path
    (init_stats/merge/solve) and returns (W, stats); the streaming engine's
    final refreshed W must match this to fp32 tolerance.
    """
    T, P, N = packed.mask.shape
    feats = jnp.asarray(packed.inputs).reshape((T * P * N,) + packed.inputs.shape[3:])
    stats = fed3r.client_stats(
        feats,
        jnp.asarray(packed.labels).reshape(-1),
        cfg.n_classes,
        jnp.asarray(packed.mask).reshape(-1),
    )
    return fed3r.solve(stats, cfg.ridge_lambda, cfg.normalize), stats
