"""FED3R / FED3R-RF / FedNCM / FED3R+FT round drivers (Algorithm 1 + §4.4).

These run on the simulator level (FederatedDataset of features, or a backbone
feature extractor).  The datacenter-scale statistics pass is in
launch/train.py (psum aggregation); both call the same repro.core functions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.core import calibration, fed3r, ncm
from repro.core.random_features import RFFParams, rff_init, rff_map
from repro.data.pipeline import FederatedDataset
from repro.federated.sampling import ClientSampler
from repro.federated.simulator import FLTask, run_federated


@dataclass
class Fed3RHistory:
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    clients_seen: List[int] = field(default_factory=list)
    wall_time: List[float] = field(default_factory=list)


def _default_extractor(x: np.ndarray) -> jax.Array:
    return jnp.asarray(x, jnp.float32)


def run_fed3r(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    f3_cfg: Fed3RConfig,
    fed_cfg: FederatedConfig,
    *,
    extractor: Optional[Callable[[np.ndarray], jax.Array]] = None,
    eval_every: int = 10,
    rff_params: Optional[RFFParams] = None,
) -> Tuple[jax.Array, fed3r.Fed3RStats, Fed3RHistory]:
    """FED3R (Algorithm 1).  Returns (W*, final stats, accuracy history).

    With ``f3_cfg.n_random_features > 0`` this is FED3R-RF: the server draws
    one shared (Ω, β) and every client maps its features before computing
    statistics.
    """
    extractor = extractor or _default_extractor
    C = dataset.n_classes
    d_raw = int(extractor(dataset.features[:1]).shape[-1])

    use_rf = f3_cfg.n_random_features > 0
    if use_rf and rff_params is None:
        rff_params = rff_init(
            jax.random.PRNGKey(fed_cfg.seed + 101), d_raw,
            f3_cfg.n_random_features, f3_cfg.rff_sigma,
        )
    d = f3_cfg.n_random_features if use_rf else d_raw

    def phi(x: np.ndarray) -> jax.Array:
        z = extractor(x)
        return rff_map(rff_params, z) if use_rf else z

    test_phi = phi(np.asarray(test_features))

    sampler = ClientSampler(
        dataset.n_clients, fed_cfg.clients_per_round,
        replacement=fed_cfg.sample_with_replacement, seed=fed_cfg.seed,
    )
    stats = fed3r.init_stats(d, C)
    client_stats_j = jax.jit(
        lambda f, y: fed3r.client_stats(f, y, C), static_argnums=()
    )

    hist = Fed3RHistory()
    n_rounds = fed_cfg.n_rounds or sampler.rounds_to_full_coverage()
    seen_once = set()
    t0 = time.time()
    for rnd in range(n_rounds):
        for k in sampler.sample():
            k = int(k)
            if not fed_cfg.sample_with_replacement and k in seen_once:
                continue  # statistics of a client are sent exactly once
            if fed_cfg.sample_with_replacement and k in seen_once:
                continue  # resampled client re-sends nothing (idempotent)
            seen_once.add(k)
            cd = dataset.client(k)
            stats = fed3r.merge(stats, client_stats_j(phi(cd.features), jnp.asarray(cd.labels)))
        if (rnd + 1) % eval_every == 0 or rnd == n_rounds - 1 or len(seen_once) == dataset.n_clients:
            W = fed3r.solve(stats, f3_cfg.ridge_lambda, f3_cfg.normalize_classifier)
            acc = float(fed3r.accuracy(W, test_phi, jnp.asarray(test_labels)))
            hist.rounds.append(rnd + 1)
            hist.accuracy.append(acc)
            hist.clients_seen.append(len(seen_once))
            hist.wall_time.append(time.time() - t0)
        if len(seen_once) == dataset.n_clients and not fed_cfg.sample_with_replacement:
            break  # exact convergence after ⌈K/κ⌉ rounds (paper §4.3)

    W = fed3r.solve(stats, f3_cfg.ridge_lambda, f3_cfg.normalize_classifier)
    return W, stats, hist


def run_fedncm(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    fed_cfg: FederatedConfig,
    *,
    extractor: Optional[Callable[[np.ndarray], jax.Array]] = None,
) -> Tuple[jax.Array, Fed3RHistory]:
    """FedNCM baseline (Legate et al. 2023a) — Table 1/6 comparison."""
    extractor = extractor or _default_extractor
    C = dataset.n_classes
    d = int(extractor(dataset.features[:1]).shape[-1])
    stats = ncm.init_stats(d, C)
    sampler = ClientSampler(dataset.n_clients, fed_cfg.clients_per_round, seed=fed_cfg.seed)
    hist = Fed3RHistory()
    for rnd in range(sampler.rounds_to_full_coverage()):
        for k in sampler.sample():
            cd = dataset.client(int(k))
            stats = ncm.merge(stats, ncm.client_stats(extractor(cd.features), jnp.asarray(cd.labels), C))
    W = ncm.solve(stats)
    acc = float(ncm.accuracy(W, extractor(np.asarray(test_features)), jnp.asarray(test_labels)))
    hist.rounds.append(sampler.rounds_to_full_coverage())
    hist.accuracy.append(acc)
    return W, hist


# ---------------------------------------------------------------------------
# FED3R + FT (paper §4.4): calibrated softmax init + gradient fine-tuning
# ---------------------------------------------------------------------------


def feature_finetune_task(
    d: int,
    n_classes: int,
    W_init: jax.Array,
    test_features: jax.Array,
    test_labels: jax.Array,
    *,
    strategy: str = "feat",  # full | lp | feat
) -> FLTask:
    """FT task with a trainable feature map M (init = I) + softmax head.

    logits = (x·M)·W + bias — the simulator-scale analogue of fine-tuning
    the extractor: FT trains (M, W), FT-LP trains W only, FT-FEAT trains M
    only with the FED3R classifier W kept fixed (the paper's most robust
    variant in cross-device settings).
    """
    params0 = {
        "M": jnp.eye(d, dtype=jnp.float32),
        "W": jnp.asarray(W_init, jnp.float32),
        "bias": jnp.zeros((n_classes,), jnp.float32),
    }

    def logits_fn(params, x):
        h = x.astype(jnp.float32) @ params["M"]
        return h @ params["W"] + params["bias"]

    def per_example_loss(params, batch):
        logits = logits_fn(params, batch["x"])
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    tf = jnp.asarray(test_features)
    tl = jnp.asarray(test_labels)

    @jax.jit
    def eval_fn(params):
        return jnp.mean((jnp.argmax(logits_fn(params, tf), -1) == tl).astype(jnp.float32))

    if strategy == "full":
        freeze = {"M": 1.0, "W": 1.0, "bias": 1.0}
    elif strategy == "lp":
        freeze = {"M": 0.0, "W": 1.0, "bias": 1.0}
    elif strategy == "feat":
        freeze = {"M": 1.0, "W": 0.0, "bias": 0.0}
    else:
        raise ValueError(strategy)
    return FLTask(params0=params0, per_example_loss=per_example_loss,
                  freeze=freeze, eval_fn=eval_fn)


def run_fed3r_ft(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    f3_cfg: Fed3RConfig,
    fed_cfg: FederatedConfig,
    *,
    strategy: Optional[str] = None,
    use_fed3r_init: bool = True,
    eval_every: int = 10,
) -> Tuple[Any, Dict[str, Any]]:
    """Two-stage FED3R+FT (paper §4.4 / Table 2).

    Stage 1: FED3R classifier (skipped if ``use_fed3r_init=False`` — the
    paper's "✗ init" ablation rows).  Temperature-calibrate the init.
    Stage 2: federated fine-tuning with the configured algorithm and the
    requested freeze strategy.
    """
    strategy = strategy or f3_cfg.ft_strategy
    C = dataset.n_classes
    d = dataset.features.shape[-1]

    info: Dict[str, Any] = {}
    if use_fed3r_init:
        W, stats, hist1 = run_fed3r(
            dataset, test_features, test_labels, f3_cfg, fed_cfg,
            eval_every=max(1, dataset.n_clients // fed_cfg.clients_per_round),
        )
        # calibrate on a subsample of training features (paper App. C)
        sample = jnp.asarray(dataset.features[: min(4096, len(dataset.labels))], jnp.float32)
        sample_y = jnp.asarray(dataset.labels[: min(4096, len(dataset.labels))])
        temp, ces = calibration.calibrate_temperature(fed3r.predict(W, sample), sample_y)
        W_init = calibration.fold_temperature(W, temp)
        info["fed3r_history"] = hist1
        info["temperature"] = float(temp)
        info["fed3r_rounds"] = hist1.rounds[-1] if hist1.rounds else 0
    else:
        W_init = 0.01 * jax.random.normal(jax.random.PRNGKey(fed_cfg.seed), (d, C))
        info["fed3r_rounds"] = 0

    task = feature_finetune_task(
        d, C, W_init, test_features, test_labels, strategy=strategy
    )
    params, hist2 = run_federated(task, dataset, fed_cfg, eval_every=eval_every)
    info["ft_history"] = hist2
    return params, info
