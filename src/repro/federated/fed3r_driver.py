"""FED3R / FED3R-RF / FedNCM / FED3R+FT round drivers (Algorithm 1 + §4.4).

These run on the simulator level (FederatedDataset of features, or a backbone
feature extractor).  The datacenter-scale statistics pass is in
launch/train.py (psum aggregation); both call the same repro.core functions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint
from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.core import calibration, fed3r, ncm
from repro.core.random_features import RFFParams, rff_init, rff_map
from repro.data.pipeline import FederatedDataset, pack_client_shards
from repro.federated.engine import (
    AccumulationEngine,
    EngineConfig,
    EngineStats,
    to_ncm_stats,
)
from repro.federated.sampling import ClientSampler
from repro.federated.simulator import FLTask, run_federated


@dataclass
class Fed3RHistory:
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    clients_seen: List[int] = field(default_factory=list)
    wall_time: List[float] = field(default_factory=list)


def _default_extractor(x: np.ndarray) -> jax.Array:
    return jnp.asarray(x, jnp.float32)


def _fresh_clients(sampled, seen: set) -> List[int]:
    """Statistics of a client are sent exactly once: a resampled or
    re-drawn client re-sends nothing (idempotent), in both sampling modes.
    With-replacement rounds can contain the same client TWICE, so the dedup
    runs draw by draw, not against the previous rounds only."""
    fresh = []
    for k in (int(k) for k in sampled):
        if k not in seen:
            seen.add(k)
            fresh.append(k)
    return fresh


def _accumulate_round(
    engine: AccumulationEngine,
    acc: EngineStats,
    dataset: FederatedDataset,
    fresh: List[int],
    extractor,
    clients_per_shard: int,
) -> EngineStats:
    """Pack this round's unseen clients and fold them in (one dispatch).

    The sample capacity is sized per call (bucketed by round_to=64) so tail
    rounds with few/small fresh clients don't pay the dataset-global maximum
    in padded FLOPs; each distinct bucket costs one jit trace.
    """
    clients = []
    for k in fresh:
        cd = dataset.client(k)
        clients.append((np.asarray(extractor(cd.features)), cd.labels))
    packed = pack_client_shards(
        clients, clients_per_shard, client_ids=fresh, round_to=64
    )
    return engine.accumulate(acc, packed)


def run_fed3r(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    f3_cfg: Fed3RConfig,
    fed_cfg: FederatedConfig,
    *,
    extractor: Optional[Callable[[np.ndarray], jax.Array]] = None,
    eval_every: int = 10,
    rff_params: Optional[RFFParams] = None,
) -> Tuple[jax.Array, fed3r.Fed3RStats, Fed3RHistory]:
    """FED3R (Algorithm 1).  Returns (W*, final stats, accuracy history).

    With ``f3_cfg.n_random_features > 0`` this is FED3R-RF: the server draws
    one shared (Ω, β) and every client maps its features before computing
    statistics.
    """
    extractor = extractor or _default_extractor
    C = dataset.n_classes
    d_raw = int(extractor(dataset.features[:1]).shape[-1])

    use_rf = f3_cfg.n_random_features > 0
    if use_rf and rff_params is None:
        rff_params = rff_init(
            jax.random.PRNGKey(fed_cfg.seed + 101), d_raw,
            f3_cfg.n_random_features, f3_cfg.rff_sigma,
        )
    d = f3_cfg.n_random_features if use_rf else d_raw

    def phi(x: np.ndarray) -> jax.Array:
        z = extractor(x)
        return rff_map(rff_params, z) if use_rf else z

    test_phi = phi(np.asarray(test_features))

    sampler = ClientSampler(
        dataset.n_clients, fed_cfg.clients_per_round,
        replacement=fed_cfg.sample_with_replacement, seed=fed_cfg.seed,
    )
    # One engine serves the whole run: the RFF map fuses into the packed
    # scan, so each round is a single dispatch over ⌈κ/clients_per_shard⌉
    # shard steps instead of κ per-client jit calls.
    engine = AccumulationEngine(
        EngineConfig(n_classes=C), rff_params=rff_params if use_rf else None,
    )
    acc = engine.init(d)
    clients_per_shard = min(fed_cfg.clients_per_round, dataset.n_clients)

    hist = Fed3RHistory()
    n_rounds = fed_cfg.n_rounds or sampler.rounds_to_full_coverage()
    seen_once: set = set()
    t0 = time.time()
    for rnd in range(n_rounds):
        fresh = _fresh_clients(sampler.sample(), seen_once)
        if fresh:
            acc = _accumulate_round(
                engine, acc, dataset, fresh, extractor, clients_per_shard
            )
        stats = acc.stats
        if (rnd + 1) % eval_every == 0 or rnd == n_rounds - 1 or len(seen_once) == dataset.n_clients:
            W = fed3r.solve(stats, f3_cfg.ridge_lambda, f3_cfg.normalize_classifier)
            test_acc = float(fed3r.accuracy(W, test_phi, jnp.asarray(test_labels)))
            hist.rounds.append(rnd + 1)
            hist.accuracy.append(test_acc)
            hist.clients_seen.append(len(seen_once))
            hist.wall_time.append(time.time() - t0)
        if len(seen_once) == dataset.n_clients and not fed_cfg.sample_with_replacement:
            break  # exact convergence after ⌈K/κ⌉ rounds (paper §4.3)

    stats = acc.stats
    W = fed3r.solve(stats, f3_cfg.ridge_lambda, f3_cfg.normalize_classifier)
    return W, stats, hist


def run_fedncm(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    fed_cfg: FederatedConfig,
    *,
    extractor: Optional[Callable[[np.ndarray], jax.Array]] = None,
) -> Tuple[jax.Array, Fed3RHistory]:
    """FedNCM baseline (Legate et al. 2023a) — Table 1/6 comparison.

    Runs on the same accumulation engine as FED3R: the NCM statistics
    (per-class sums + counts) are a projection of the engine accumulator
    (sums = bᵀ, counts = class_counts), so the baseline costs no second
    statistics pass.
    """
    extractor = extractor or _default_extractor
    C = dataset.n_classes
    d = int(extractor(dataset.features[:1]).shape[-1])
    engine = AccumulationEngine(EngineConfig(n_classes=C))
    acc = engine.init(d)
    sampler = ClientSampler(dataset.n_clients, fed_cfg.clients_per_round, seed=fed_cfg.seed)
    clients_per_shard = min(fed_cfg.clients_per_round, dataset.n_clients)
    seen: set = set()
    hist = Fed3RHistory()
    for rnd in range(sampler.rounds_to_full_coverage()):
        fresh = _fresh_clients(sampler.sample(), seen)
        if fresh:
            acc = _accumulate_round(
                engine, acc, dataset, fresh, extractor, clients_per_shard
            )
    W = ncm.solve(to_ncm_stats(acc))
    test_acc = float(ncm.accuracy(W, extractor(np.asarray(test_features)), jnp.asarray(test_labels)))
    hist.rounds.append(sampler.rounds_to_full_coverage())
    hist.accuracy.append(test_acc)
    return W, hist


# ---------------------------------------------------------------------------
# FED3R + FT (paper §4.4): calibrated softmax init + gradient fine-tuning
# ---------------------------------------------------------------------------


def feature_finetune_task(
    d: int,
    n_classes: int,
    W_init: jax.Array,
    test_features: jax.Array,
    test_labels: jax.Array,
    *,
    strategy: str = "feat",  # full | lp | feat
) -> FLTask:
    """FT task with a trainable feature map M (init = I) + softmax head.

    logits = (x·M)·W + bias — the simulator-scale analogue of fine-tuning
    the extractor: FT trains (M, W), FT-LP trains W only, FT-FEAT trains M
    only with the FED3R classifier W kept fixed (the paper's most robust
    variant in cross-device settings).
    """
    params0 = {
        "M": jnp.eye(d, dtype=jnp.float32),
        "W": jnp.asarray(W_init, jnp.float32),
        "bias": jnp.zeros((n_classes,), jnp.float32),
    }

    def logits_fn(params, x):
        h = x.astype(jnp.float32) @ params["M"]
        return h @ params["W"] + params["bias"]

    def per_example_loss(params, batch):
        logits = logits_fn(params, batch["x"])
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    tf = jnp.asarray(test_features)
    tl = jnp.asarray(test_labels)

    @jax.jit
    def eval_fn(params):
        return jnp.mean((jnp.argmax(logits_fn(params, tf), -1) == tl).astype(jnp.float32))

    if strategy == "full":
        freeze = {"M": 1.0, "W": 1.0, "bias": 1.0}
    elif strategy == "lp":
        freeze = {"M": 0.0, "W": 1.0, "bias": 1.0}
    elif strategy == "feat":
        freeze = {"M": 1.0, "W": 0.0, "bias": 0.0}
    else:
        raise ValueError(strategy)
    return FLTask(params0=params0, per_example_loss=per_example_loss,
                  freeze=freeze, eval_fn=eval_fn)


def run_fed3r_ft(
    dataset: FederatedDataset,
    test_features: jax.Array,
    test_labels: jax.Array,
    f3_cfg: Fed3RConfig,
    fed_cfg: FederatedConfig,
    *,
    strategy: Optional[str] = None,
    use_fed3r_init: bool = True,
    eval_every: int = 10,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
) -> Tuple[Any, Dict[str, Any]]:
    """Two-stage FED3R+FT (paper §4.4 / Table 2).

    Stage 1: FED3R classifier (skipped if ``use_fed3r_init=False`` — the
    paper's "✗ init" ablation rows).  Temperature-calibrate the init.
    Stage 2: federated fine-tuning with the configured algorithm and the
    requested freeze strategy, one jitted dispatch per round through the
    cohort round engine; ``ckpt_dir``/``resume`` snapshot and restore the
    FT phase's full ServerState at round granularity.
    """
    strategy = strategy or f3_cfg.ft_strategy
    C = dataset.n_classes
    d = dataset.features.shape[-1]

    # Resuming from a full FT-state snapshot makes stage 1 dead work: the
    # loaded ServerState overwrites whatever init it would produce.
    resuming = bool(ckpt_dir and resume and latest_checkpoint(ckpt_dir))

    info: Dict[str, Any] = {}
    if use_fed3r_init and not resuming:
        W, stats, hist1 = run_fed3r(
            dataset, test_features, test_labels, f3_cfg, fed_cfg,
            eval_every=max(1, dataset.n_clients // fed_cfg.clients_per_round),
        )
        # calibrate on a subsample of training features (paper App. C)
        sample = jnp.asarray(dataset.features[: min(4096, len(dataset.labels))], jnp.float32)
        sample_y = jnp.asarray(dataset.labels[: min(4096, len(dataset.labels))])
        temp, ces = calibration.calibrate_temperature(fed3r.predict(W, sample), sample_y)
        W_init = calibration.fold_temperature(W, temp)
        info["fed3r_history"] = hist1
        info["temperature"] = float(temp)
        info["fed3r_rounds"] = hist1.rounds[-1] if hist1.rounds else 0
    else:
        W_init = 0.01 * jax.random.normal(jax.random.PRNGKey(fed_cfg.seed), (d, C))
        info["fed3r_rounds"] = 0

    task = feature_finetune_task(
        d, C, W_init, test_features, test_labels, strategy=strategy
    )
    params, hist2 = run_federated(
        task, dataset, fed_cfg, eval_every=eval_every,
        ckpt_dir=ckpt_dir, resume=resume,
    )
    info["ft_history"] = hist2
    return params, info
