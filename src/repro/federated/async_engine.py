"""Asynchronous straggler-resilient FED3R round engine (merge-on-arrival).

The synchronous engines assume every packed client of a wave/cohort shows
up: one straggler stalls the whole dispatch.  Fed3R's headline property —
the (A_k, b_k) statistics sum is invariant to client sampling order (paper
§4.3) — makes that barrier unnecessary: a late client's contribution can
merge WHENEVER it arrives without biasing W.  This engine exploits exactly
that:

* **Merge-on-arrival.**  Each round owns K cohort *slots* inside a ring of
  ``staleness_rounds + 1`` donated device buffers; a client's statistics
  scatter into its (canonically ordered) slot the moment the upload lands.
  When a round *retires*, the slot axis reduces in one fixed canonical
  order and folds into the carried :class:`repro.core.fed3r.Fed3RFactored`
  state via the additive rank-n update L ← chol(L Lᵀ + ΣA).  Because slot
  contents are arrival-order independent (exactly-once per client, set
  semantics) and the reductions/folds run in round order, the final W is
  **bitwise identical** to the synchronous barrier engine whenever the
  same uploads are delivered — under arbitrary reordering, delay,
  duplication (deduped), and drop-with-retransmit.

* **Staleness bound.**  Round r accepts late uploads until round
  ``r + staleness_rounds`` closes; beyond that the upload is rejected
  (counted, never folded) — the bound on how stale a merged contribution
  can be.

* **Adaptive per-client timeout/dropout.**  :class:`ClientHealth` demotes
  a client after ``demote_after`` missed round deadlines; demoted clients
  are not sampled for ``cooldown`` rounds, then re-admitted on probation
  and fully restored by one on-time delivery — persistent stragglers stop
  stalling rounds, recovered clients rejoin (PAPERS.md: adaptive dropout,
  arXiv 2507.10430).

* **Timeout-tolerant secure aggregation.**  In ``secure=True`` mode the
  slots hold mod-2³² masked integer payloads
  (:func:`repro.federated.compress.cohort_quantize_int8` +
  :func:`repro.federated.secure_agg.mask_quantized_payload`); at retire
  the orphaned pairwise masks of clients that never arrived are
  reconstructed and cancelled
  (:func:`repro.federated.secure_agg.recover_survivor_sum_quantized`), so
  a dropped client never poisons the sum — the recovered aggregate equals
  the unmasked survivor sum bit for bit.

* **Distribution.**  ``dist.aggregation="psum"`` all-reduces the retire
  reduction's per-device partial cohort sums over the mesh axes (empty
  slots are exact no-op zeros), via
  :meth:`repro.federated.dist.DistContext.all_reduce` inside
  :meth:`AsyncRoundEngine.retire_fold`.  Two ways to run it: wrap the
  cores in an *external* ``shard_map`` where each shard scatters only the
  clients it owns (the pre-PR5 contract), or hand the layer a
  ``DistConfig(mesh=...)`` — the engine then builds its scatter/retire/
  live programs through :meth:`repro.federated.dist.DistContext.jit`
  itself, shards the slot ring's K axis over the data axes
  (``shard_cohort`` shard-major slot layout, so each device owns a
  contiguous local block), and masks the scatter so only the owning
  device writes.  Both reduce in the same canonical order, so W stays
  bitwise identical to the ``merge`` baseline; with
  ``DistConfig(tree=...)`` the retire all-reduce runs the N-tier
  aggregation tree (:mod:`repro.federated.tiers`).  ``merge`` keeps the
  all-reduce an identity (bitwise unchanged).

The fault model driving all of this lives in
:mod:`repro.federated.arrivals` (:class:`~repro.federated.arrivals.
ChaosSpec` seeded drop/duplicate/reorder/delay schedules);
``benchmarks/chaos_replay.py`` is the CI gate replaying eight of them and
failing on any W divergence, and ``benchmarks/bench_async.py`` measures
the round-completion speedup of closing at the deadline instead of
waiting for the straggler tail.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fed3r
from repro.core.fed3r import Fed3RFactored, Fed3RStats
from repro.federated import compress, secure_agg
from repro.federated.arrivals import ChaosSpec, UploadEvent, chaos_round_events
from repro.federated.compress import IntPayload, WireFormat
from repro.federated.dist import (
    DistConfig,
    DistContext,
    DistDispatchMixin,
    linear_shard_index,
    shard_cohort,
)
from repro.federated.telemetry import Telemetry, get_telemetry
from repro.sharding.specs import replicated


@dataclass(frozen=True)
class AsyncConfig:
    """Static configuration of the asynchronous round engine.

    ``cohort`` is the slot count K per round (rounds may carry fewer
    clients; empty slots are exact no-ops).  ``deadline`` is the sim-time
    round close; ``staleness_rounds`` bounds how many subsequent closes a
    late upload may trail before it is rejected.  ``synchronous=True`` is
    the barrier baseline: rounds close only when every cohort client has
    delivered (the engine the async path is asserted bitwise against).
    ``early_close`` lets an async round close as soon as its cohort is
    complete (before the deadline).  ``secure=True`` switches the slots to
    mod-2³² masked integer payloads with dropout mask recovery at retire.
    """

    n_classes: int
    ridge_lambda: float
    cohort: int
    deadline: float = 1.0
    staleness_rounds: int = 1
    demote_after: int = 2
    cooldown: int = 2
    synchronous: bool = False
    early_close: bool = True
    normalize: bool = True
    use_kernel: Optional[bool] = None
    dist: DistConfig = field(default_factory=DistConfig)
    wire: WireFormat = field(default_factory=WireFormat)
    secure: bool = False
    secure_seed: int = 0
    secure_tile: int = 128

    def __post_init__(self):
        if self.cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {self.cohort}")
        if self.deadline <= 0.0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.staleness_rounds < 0:
            raise ValueError(
                f"staleness_rounds must be >= 0, got {self.staleness_rounds}"
            )
        if self.demote_after < 1:
            raise ValueError(f"demote_after must be >= 1, got {self.demote_after}")
        if self.secure and self.wire.kind != "fp32":
            raise ValueError(
                "secure mode owns its quantization (shared-scale int8 payloads); "
                "configure secure_tile instead of wire"
            )


class ClientHealth:
    """Adaptive per-client timeout/dropout bookkeeping (host control plane).

    A client accrues one *miss* per round deadline it blows; at
    ``demote_after`` consecutive misses it is demoted — excluded from
    cohort sampling for ``cooldown`` rounds, then re-admitted on probation.
    One on-time delivery fully restores it (misses reset, demotion
    cleared): slow clients stop stalling rounds, recovered clients rejoin.

    Every transition lands in the telemetry flight recorder
    (``client_demoted`` with the probation round, ``client_readmitted``),
    so a failed chaos replay ships a replayable event log.
    """

    def __init__(
        self,
        demote_after: int = 2,
        cooldown: int = 2,
        telemetry: Optional[Telemetry] = None,
    ):
        self.demote_after = demote_after
        self.cooldown = cooldown
        self.misses: Dict[int, int] = {}
        self.demoted_at: Dict[int, int] = {}
        self.telemetry = get_telemetry() if telemetry is None else telemetry

    def on_time(self, client: int) -> None:
        """An on-time delivery: full recovery (re-admission on probation)."""
        self.misses[client] = 0
        if self.demoted_at.pop(client, None) is not None:
            self.telemetry.event("client_readmitted", client=int(client))

    def missed(self, client: int, round_id: int) -> None:
        """A blown round deadline; demote at the configured miss count."""
        self.misses[client] = self.misses.get(client, 0) + 1
        if self.misses[client] >= self.demote_after:
            if client not in self.demoted_at:
                self.telemetry.event(
                    "client_demoted",
                    client=int(client),
                    round=int(round_id),
                    misses=int(self.misses[client]),
                    probation_round=int(round_id) + self.cooldown,
                )
            self.demoted_at[client] = round_id

    def is_eligible(self, client: int, round_id: int) -> bool:
        """Sampled into cohorts?  Demoted clients sit out ``cooldown``
        rounds, then return on probation."""
        at = self.demoted_at.get(client)
        return at is None or round_id >= at + self.cooldown

    @property
    def demoted(self) -> Set[int]:
        return set(self.demoted_at)


class AsyncState(NamedTuple):
    """Donated device state: retired-global factored sums + the slot ring.

    ``A_slots``/``b_slots`` are ``(S, K, ...)`` with S =
    ``staleness_rounds + 1`` concurrently-open rounds (ring-indexed by
    ``round % S``) and K cohort slots each — fp32 statistics normally,
    mod-2³² masked int32 payloads in secure mode.
    """

    L: jax.Array  # (d, d) fp32 Cholesky factor of retired A + λI
    b: jax.Array  # (d, C) fp32 retired class-conditional sums
    n: jax.Array  # () fp32 retired sample count
    W: jax.Array  # (d, C) fp32 classifier solved at the last retire
    A_slots: jax.Array  # (S, K, d, d) fp32 | int32 (secure)
    b_slots: jax.Array  # (S, K, d, C) fp32 | int32 (secure)
    n_slots: jax.Array  # (S, K) fp32


@dataclass
class _RoundMeta:
    """Host-side per-round control record."""

    cohort: List[int]
    slot_of: Dict[int, int]
    start_t: float
    closed: bool = False
    close_t: Optional[float] = None
    arrived: Set[int] = field(default_factory=set)
    on_time: Set[int] = field(default_factory=set)
    scales: Optional[Tuple[jax.Array, jax.Array]] = None  # secure (sA, sb)


class AsyncRoundEngine(DistDispatchMixin):
    """Merge-on-arrival FED3R rounds with staleness, dropout, and chaos
    tolerance.  Device state is functional (passed through every method);
    round/cohort/health bookkeeping is the host control plane, matching
    the slot-serving engine's split.
    """

    def __init__(self, cfg: AsyncConfig):
        if cfg.secure and cfg.dist.aggregation == "psum":
            raise ValueError("secure mode and psum aggregation are exclusive")
        if cfg.dist.mesh is not None and cfg.cohort % cfg.dist.data_shards != 0:
            raise ValueError(
                f"dist-owned mesh shards the K={cfg.cohort} slot axis over "
                f"{cfg.dist.data_shards} data shards: K must divide evenly"
            )
        self.cfg = cfg
        self.wire = cfg.wire.resolved()
        self.dist = DistContext(cfg.dist, engine="async")
        self.telemetry = self.dist.telemetry
        self.health = ClientHealth(
            cfg.demote_after, cfg.cooldown, telemetry=self.telemetry
        )
        self._rounds: Dict[int, _RoundMeta] = {}
        self._next_begin = 0
        self._next_retire = 0
        # fault/robustness counters (the chaos report) — homed in the
        # telemetry registry, one labeled cell per engine instance
        inst = self.telemetry.next_instance("async")
        self._fault_counters = {
            k: self.telemetry.counter(f"async_{k}_total", inst=inst)
            for k in (
                "folded",
                "duplicates",
                "stale_rejected",
                "late_folds",
                "dropped_uploads",
            )
        }
        donate = self.dist.cfg.donate
        # dist-owned mesh: the slot ring's K axis shards over the data axes
        # (shard-major layout, see begin_round); the carried factored state,
        # the scalar ring/slot indices, and the replicated upload payloads
        # stay P().  Without a mesh the specs are ignored (plain jit).
        rep = replicated()
        slots = self.dist.data_spec(axis=1)
        state_specs = AsyncState(
            L=rep, b=rep, n=rep, W=rep,
            A_slots=slots, b_slots=slots, n_slots=slots,
        )
        self._scatter = self.dist.jit(
            self._scatter_impl, donate=donate,
            in_specs=(state_specs, rep, rep, rep, rep, rep),
            out_specs=state_specs,
        )
        self._retire = self.dist.jit(
            self._retire_impl, donate=donate,
            in_specs=(state_specs, rep), out_specs=state_specs,
        )
        # secure mode excludes psum (and so any mesh): only built off-mesh
        self._retire_secure = (
            None if cfg.dist.mesh is not None
            else self.dist.jit(self._retire_secure_impl, donate=donate)
        )
        self._live = self.dist.jit(
            self._live_impl, donate=False,
            in_specs=(state_specs,), out_specs=rep,
        )

    # fault/robustness counters proxied onto their telemetry cells (the
    # ``+=`` call sites and the chaos report keep working unchanged)
    def _fault_count(name: str):  # noqa: N805 — descriptor factory, not a method
        def _get(self) -> int:
            return int(self._fault_counters[name].value)

        def _set(self, value: int) -> None:
            self._fault_counters[name].set(int(value))

        return property(_get, _set)

    folded = _fault_count("folded")
    duplicates = _fault_count("duplicates")
    stale_rejected = _fault_count("stale_rejected")
    late_folds = _fault_count("late_folds")
    dropped_uploads = _fault_count("dropped_uploads")
    del _fault_count

    # ---- device programs ---------------------------------------------------

    @property
    def ring_size(self) -> int:
        return self.cfg.staleness_rounds + 1

    def init(self, d: int) -> AsyncState:
        S, K, C = self.ring_size, self.cfg.cohort, self.cfg.n_classes
        fac = fed3r.init_factored(d, C, self.cfg.ridge_lambda)
        slot_dtype = jnp.int32 if self.cfg.secure else jnp.float32
        return AsyncState(
            L=fac.L,
            b=fac.b,
            n=jnp.zeros((), jnp.float32),
            W=jnp.zeros((d, C), jnp.float32),
            A_slots=jnp.zeros((S, K, d, d), slot_dtype),
            b_slots=jnp.zeros((S, K, d, C), slot_dtype),
            n_slots=jnp.zeros((S, K), jnp.float32),
        )

    def _scatter_impl(self, state, ring, slot, A, b, n):
        """Set one client's payload into its round slot (exactly-once set
        semantics: dedup happens on the host before dispatch).  The wire
        format applies here — the upload lands as the aggregator received
        it; fp32 is the bitwise identity.

        Dist-owned mesh: the slot axis is sharded, so ``slot`` is a GLOBAL
        index and each device translates it into its local block — the
        owner writes the payload, every other device writes its current
        value back (a masked exact no-op)."""
        if not self.cfg.secure:
            A, b = compress.wire_roundtrip(A, b, self.wire, self.cfg.use_kernel)
        if self.cfg.dist.mesh is not None:
            k_local = self.cfg.cohort // self.cfg.dist.data_shards
            local = slot - linear_shard_index(self.cfg.dist.axis_names) * k_local
            ok = (local >= 0) & (local < k_local)
            slot = jnp.clip(local, 0, k_local - 1)
            A = jnp.where(ok, A, state.A_slots[ring, slot])
            b = jnp.where(ok, b, state.b_slots[ring, slot])
            n = jnp.where(ok, n, state.n_slots[ring, slot])
        return state._replace(
            A_slots=state.A_slots.at[ring, slot].set(A),
            b_slots=state.b_slots.at[ring, slot].set(b),
            n_slots=state.n_slots.at[ring, slot].set(n),
        )

    def retire_fold(self, L, b, n, S_A, S_b, S_n):
        """Fold one round's reduced statistics into the factored state.

        Pure; runs inside an external ``shard_map`` or the dist-owned mesh
        programs alike — under ``psum`` the per-device partial cohort sums
        all-reduce here (empty and remote slots are exact zeros; with
        ``DistConfig(tree=...)`` the reduction runs the N-tier aggregation
        tree), under ``merge`` the all-reduce is the identity, keeping the
        fold bitwise.
        """
        S_A, S_b, S_n = self.dist.all_reduce((S_A, S_b, S_n))
        G = L @ L.T + S_A
        if self.cfg.secure:
            # shared-scale int8-valued payloads: same error model as int8
            Lp = compress.psd_cholesky(
                G, compress.quant_spectral_bound(S_A, WireFormat(kind="int8"))
            )
        elif self.wire.kind in ("int8", "fp8"):
            Lp = compress.psd_cholesky(
                G, compress.quant_spectral_bound(S_A, self.wire)
            )
        else:
            Lp = jnp.linalg.cholesky(G)
        bp = b + S_b
        W = fed3r.factored_solution(Fed3RFactored(L=Lp, b=bp), self.cfg.normalize)
        return Lp, bp, n + S_n, W

    def _retire_impl(self, state, ring):
        """Canonical slot-axis reduction + fold + ring free, one dispatch."""
        S_A = jnp.sum(state.A_slots[ring], axis=0)
        S_b = jnp.sum(state.b_slots[ring], axis=0)
        S_n = jnp.sum(state.n_slots[ring], axis=0)
        L, b, n, W = self.retire_fold(state.L, state.b, state.n, S_A, S_b, S_n)
        return state._replace(
            L=L, b=b, n=n, W=W,
            A_slots=state.A_slots.at[ring].set(0),
            b_slots=state.b_slots.at[ring].set(0),
            n_slots=state.n_slots.at[ring].set(0.0),
        )

    def _retire_secure_impl(self, state, ring, corrA, corrb, sA, sb):
        """Secure retire: mod-2³² slot sum, orphan-mask cancellation for the
        clients that never arrived (bit-exact in the ring), shared-scale
        dequantization, then the same factored fold."""
        S_qA = jnp.sum(state.A_slots[ring], axis=0) - corrA  # wraps mod 2³²
        S_qb = jnp.sum(state.b_slots[ring], axis=0) - corrb
        S_A, S_b = compress.dequantize_int_sum(
            IntPayload(qA=S_qA, qb=S_qb), sA, sb, self.cfg.secure_tile
        )
        S_n = jnp.sum(state.n_slots[ring], axis=0)
        L, b, n, W = self.retire_fold(state.L, state.b, state.n, S_A, S_b, S_n)
        return state._replace(
            L=L, b=b, n=n, W=W,
            A_slots=state.A_slots.at[ring].set(0),
            b_slots=state.b_slots.at[ring].set(0),
            n_slots=state.n_slots.at[ring].set(0.0),
        )

    def _live_impl(self, state):
        """The live classifier: retired state + every OPEN partial cohort,
        solved without disturbing the carried factor (one dispatch)."""
        S_A = jnp.sum(state.A_slots, axis=(0, 1))
        S_b = jnp.sum(state.b_slots, axis=(0, 1))
        S_A, S_b = self.dist.all_reduce((S_A, S_b))
        G = state.L @ state.L.T + S_A
        if self.wire.kind in ("int8", "fp8"):
            L = compress.psd_cholesky(
                G, compress.quant_spectral_bound(S_A, self.wire)
            )
        else:
            L = jnp.linalg.cholesky(G)
        return fed3r.factored_solution(
            Fed3RFactored(L=L, b=state.b + S_b), self.cfg.normalize
        )

    # ---- host control plane ------------------------------------------------

    def begin_round(
        self,
        round_id: int,
        cohort: Sequence[int],
        start_t: float,
        scales: Optional[Tuple[jax.Array, jax.Array]] = None,
    ) -> None:
        """Open round ``round_id`` over ``cohort`` (canonical slot order =
        sorted client ids).  Rounds must begin contiguously and the ring
        slot must have retired (``deadline <= cadence`` guarantees it)."""
        if round_id != self._next_begin:
            raise ValueError(
                f"rounds begin contiguously: expected {self._next_begin}, "
                f"got {round_id}"
            )
        if round_id - self._next_retire >= self.ring_size:
            raise RuntimeError(
                f"ring overflow: round {round_id} needs the slot of round "
                f"{self._next_retire} which has not retired (raise "
                "staleness_rounds or the round cadence)"
            )
        ids = sorted(int(c) for c in cohort)
        if len(set(ids)) != len(ids):
            raise ValueError("cohort has duplicate client ids")
        if len(ids) > self.cfg.cohort:
            raise ValueError(
                f"cohort of {len(ids)} exceeds K={self.cfg.cohort} slots"
            )
        if self.cfg.secure and scales is None:
            raise ValueError("secure rounds need the shared (sA, sb) scales")
        if self.cfg.dist.mesh is not None:
            # shard-major slot layout: device s owns the contiguous local
            # block [s·K/dp, (s+1)·K/dp), filled with its round-robin
            # shard_cohort share — the same ownership partition as the
            # external-shard_map contract, reassembled by the retire psum
            dp = self.cfg.dist.data_shards
            k_local = self.cfg.cohort // dp
            slot_of = {
                c: s * k_local + j
                for s in range(dp)
                for j, c in enumerate(shard_cohort(ids, s, dp))
            }
        else:
            slot_of = {c: i for i, c in enumerate(ids)}
        self._rounds[round_id] = _RoundMeta(
            cohort=ids,
            slot_of=slot_of,
            start_t=start_t,
            scales=scales,
        )
        self._next_begin = round_id + 1

    def round_full(self, round_id: int) -> bool:
        meta = self._rounds.get(round_id)
        return meta is not None and len(meta.arrived) == len(meta.cohort)

    def deliver(
        self, state: AsyncState, ev: UploadEvent, payload, now: Optional[float] = None
    ) -> Tuple[AsyncState, str]:
        """Fold one upload the moment it lands.  Returns the advanced state
        and a status: ``folded`` (on time), ``late`` (after close, inside
        the staleness bound), ``duplicate`` (deduped, not re-folded), or
        ``stale`` (round already retired — rejected)."""
        r, c = ev.round_id, ev.client
        if r < self._next_retire:
            self.stale_rejected += 1
            self.telemetry.event("staleness_drop", client=int(c), round=int(r))
            return state, "stale"
        meta = self._rounds.get(r)
        if meta is None:
            raise ValueError(f"deliver for round {r} before begin_round")
        if c not in meta.slot_of:
            raise ValueError(f"client {c} is not in round {r}'s cohort")
        if c in meta.arrived:
            self.duplicates += 1
            return state, "duplicate"
        meta.arrived.add(c)
        ring = np.int32(r % self.ring_size)
        slot = np.int32(meta.slot_of[c])
        if self.cfg.secure:
            A, b = payload.qA, payload.qb
            n = getattr(payload, "n", jnp.zeros((), jnp.float32))
        else:
            A, b, n = payload.A, payload.b, payload.n
        with self.telemetry.span("fold", engine="async"):
            self.dist.dispatch()
            state = self._scatter(state, ring, slot, A, b, n)
        if meta.closed:
            self.late_folds += 1
            return state, "late"
        meta.on_time.add(c)
        self.health.on_time(c)
        self.folded += 1
        return state, "folded"

    def close_round(
        self, state: AsyncState, round_id: int, now: Optional[float] = None
    ) -> AsyncState:
        """Close a round (its deadline passed, or its cohort completed):
        record deadline misses, then retire every round whose staleness
        window has fully elapsed."""
        meta = self._rounds[round_id]
        if meta.closed:
            return state
        meta.closed = True
        meta.close_t = now
        for c in meta.cohort:
            if c not in meta.arrived:
                self.health.missed(c, round_id)
        return self._maybe_retire(state)

    def _maybe_retire(self, state: AsyncState) -> AsyncState:
        while self._next_retire < self._next_begin:
            r = self._next_retire
            watcher = self._rounds.get(r + self.cfg.staleness_rounds)
            if watcher is None or not watcher.closed:
                break  # staleness window still open; drain() forces it
            state = self._retire_round(state, r)
        return state

    def _retire_round(self, state: AsyncState, r: int) -> AsyncState:
        with self.telemetry.span("retire", engine="async"):
            meta = self._rounds[r]
            missing = [c for c in meta.cohort if c not in meta.arrived]
            self.dropped_uploads += len(missing)
            if missing:
                self.telemetry.event(
                    "upload_dropped", round=int(r), clients=[int(c) for c in missing]
                )
            ring = np.int32(r % self.ring_size)
            self.dist.dispatch()
            if self.cfg.secure:
                like = IntPayload(
                    qA=jnp.zeros(state.A_slots.shape[2:], jnp.int32),
                    qb=jnp.zeros(state.b_slots.shape[2:], jnp.int32),
                )
                survivors = sorted(meta.arrived)
                if missing:
                    corr = secure_agg.dropout_mask_correction_quantized(
                        survivors, missing, self.cfg.secure_seed + r, like
                    )
                    self.telemetry.event(
                        "secure_mask_recovery",
                        round=int(r),
                        missing=len(missing),
                        survivors=len(survivors),
                    )
                else:
                    corr = like
                sA, sb = meta.scales
                state = self._retire_secure(state, ring, corr.qA, corr.qb, sA, sb)
            else:
                state = self._retire(state, ring)
            self._next_retire = r + 1
            return state

    def drain(self, state: AsyncState) -> AsyncState:
        """Close every open round (in order) and retire everything."""
        for r in range(self._next_retire, self._next_begin):
            if not self._rounds[r].closed:
                state = self.close_round(state, r)
        while self._next_retire < self._next_begin:
            state = self._retire_round(state, self._next_retire)
        return state

    def live_classifier(self, state: AsyncState) -> jax.Array:
        """Serve NOW: retired sums + all open partial cohorts, one dispatch.
        Secure mode serves the last retired W — open slots are masked and
        unreadable by design."""
        if self.cfg.secure:
            return state.W
        self.dist.dispatch()
        return self._live(state)

    def classifier(self, state: AsyncState) -> jax.Array:
        """The classifier as of the last retire."""
        return state.W

    def report(self) -> dict:
        """The chaos/robustness counters plus per-round completion times."""
        completions = {
            r: (None if m.close_t is None else m.close_t - m.start_t)
            for r, m in sorted(self._rounds.items())
        }
        return {
            "folded": self.folded,
            "duplicates": self.duplicates,
            "late_folds": self.late_folds,
            "stale_rejected": self.stale_rejected,
            "dropped_uploads": self.dropped_uploads,
            "demoted": sorted(self.health.demoted),
            "completion": completions,
            "dispatches": self.dispatches,
        }


# ---------------------------------------------------------------------------
# Drivers — timeline execution under the async cadence vs the sync barrier
# ---------------------------------------------------------------------------


def run_chaos_timeline(
    engine: AsyncRoundEngine,
    state: AsyncState,
    cohorts: Sequence[Sequence[int]],
    events: Sequence[UploadEvent],
    payload_for: Callable[[int, int], object],
    *,
    interval: Optional[float] = None,
    scales_for: Optional[Callable[[int], Tuple[jax.Array, jax.Array]]] = None,
) -> Tuple[AsyncState, dict]:
    """Execute a (chaos-injected) upload timeline end to end.

    ``payload_for(client, round_id)`` supplies the upload the server
    receives (a :class:`~repro.core.fed3r.Fed3RStats`, or the masked
    :class:`~repro.federated.compress.IntPayload` in secure mode, with
    ``scales_for(round_id)`` providing the round's shared scales).

    Async engines run rounds on a fixed cadence (``interval``, default the
    deadline): round r begins at r·interval, closes at its deadline (or as
    soon as its cohort completes, if ``early_close``), and late uploads
    keep folding until the staleness bound retires the round.  The
    synchronous baseline (``cfg.synchronous``) instead BARRIERS: each
    round's completion is the straggler's arrival, and the next round
    starts only then — the makespan gap between the two is what
    ``benchmarks/bench_async.py`` prices.
    """
    cfg = engine.cfg
    interval = cfg.deadline if interval is None else interval
    if interval < cfg.deadline:
        raise ValueError("round cadence must be >= the deadline")
    per_round: Dict[int, List[UploadEvent]] = {}
    for ev in events:
        per_round.setdefault(ev.round_id, []).append(ev)

    def scales(r):
        return scales_for(r) if scales_for is not None else None

    if cfg.synchronous:
        t = 0.0
        completion: List[float] = []
        for r, cohort in enumerate(cohorts):
            engine.begin_round(r, cohort, t, scales=scales(r))
            evs = sorted(per_round.get(r, []), key=lambda e: (e.t, e.client, e.attempt))
            first: Dict[int, float] = {}
            for ev in evs:
                state, _ = engine.deliver(state, ev, payload_for(ev.client, r), now=t + ev.t)
                first.setdefault(ev.client, ev.t)
            comp = max(first.values(), default=0.0)
            state = engine.close_round(state, r, now=t + comp)
            completion.append(comp)
            t += comp
        state = engine.drain(state)
        rep = engine.report()
        rep["makespan"] = t
        rep["completion"] = completion
        return state, rep

    # at equal timestamps: deliveries first (a t == deadline upload is on
    # time), then closes (whose retires free ring slots), then begins
    counter = itertools.count()
    agenda: List[Tuple[float, int, int, str, object]] = []
    for r in range(len(cohorts)):
        start = r * interval
        heapq.heappush(agenda, (start, 2, next(counter), "begin", r))
        heapq.heappush(agenda, (start + cfg.deadline, 1, next(counter), "close", r))
        for ev in per_round.get(r, []):
            heapq.heappush(agenda, (start + ev.t, 0, next(counter), "ev", ev))
    completion_by_round: Dict[int, float] = {}
    while agenda:
        t, _, _, kind, x = heapq.heappop(agenda)
        if kind == "begin":
            engine.begin_round(x, cohorts[x], t, scales=scales(x))
        elif kind == "ev":
            state, status = engine.deliver(state, x, payload_for(x.client, x.round_id), now=t)
            r = x.round_id
            if (
                status == "folded"
                and cfg.early_close
                and engine.round_full(r)
                and not engine._rounds[r].closed
            ):
                state = engine.close_round(state, r, now=t)
                completion_by_round[r] = t - engine._rounds[r].start_t
        else:  # close (deadline)
            if not engine._rounds[x].closed:
                state = engine.close_round(state, x, now=t)
                completion_by_round[x] = cfg.deadline
    state = engine.drain(state)
    rep = engine.report()
    completion = [completion_by_round.get(r, cfg.deadline) for r in range(len(cohorts))]
    rep["completion"] = completion
    # the async makespan: the cadence carries R rounds, plus the final
    # round's close lag — stragglers never extend it
    rep["makespan"] = (len(cohorts) - 1) * interval + (
        completion[-1] if completion else 0.0
    )
    return state, rep


def run_adaptive_rounds(
    engine: AsyncRoundEngine,
    state: AsyncState,
    n_clients: int,
    per_round: int,
    n_rounds: int,
    latency: np.ndarray,
    spec: ChaosSpec,
    payload_for: Callable[[int, int], object],
    *,
    seed: int = 0,
    interval: Optional[float] = None,
) -> Tuple[AsyncState, dict]:
    """Adaptive-dropout rounds: cohorts are sampled per round from the
    clients the health tracker currently admits, so persistent stragglers
    stop being waited on after ``demote_after`` blown deadlines and
    re-enter on probation after ``cooldown`` — the steady-state cohort is
    straggler-free and rounds close at their natural (fast) completion.
    Fault events are generated per round with :func:`repro.federated.
    arrivals.chaos_round_events`, so a replay with the same seed is
    byte-identical.
    """
    cfg = engine.cfg
    if cfg.synchronous:
        raise ValueError("adaptive rounds are the async path; the sync "
                         "baseline replays fixed cohorts via run_chaos_timeline")
    interval = cfg.deadline if interval is None else interval
    counter = itertools.count()
    agenda: List[Tuple[float, int, int, str, object]] = []
    completion_by_round: Dict[int, float] = {}
    cohorts: List[List[int]] = []

    def flush(state, upto: float):
        while agenda and agenda[0][0] <= upto:
            t, _, _, kind, x = heapq.heappop(agenda)
            if kind == "ev":
                state, status = engine.deliver(
                    state, x, payload_for(x.client, x.round_id), now=t
                )
                r = x.round_id
                if (
                    status == "folded"
                    and cfg.early_close
                    and engine.round_full(r)
                    and not engine._rounds[r].closed
                ):
                    state = engine.close_round(state, r, now=t)
                    completion_by_round[r] = t - engine._rounds[r].start_t
            else:
                if not engine._rounds[x].closed:
                    state = engine.close_round(state, x, now=t)
                    completion_by_round[x] = cfg.deadline
        return state

    for r in range(n_rounds):
        start = r * interval
        state = flush(state, start)
        eligible = [c for c in range(n_clients) if engine.health.is_eligible(c, r)]
        rng = np.random.default_rng((seed, r, 0xADAF))
        take = min(per_round, len(eligible))
        cohort = sorted(
            int(eligible[i])
            for i in rng.choice(len(eligible), size=take, replace=False)
        )
        cohorts.append(cohort)
        engine.begin_round(r, cohort, start)
        heapq.heappush(agenda, (start + cfg.deadline, 1, next(counter), "close", r))
        for ev in chaos_round_events(cohort, latency, spec, r):
            heapq.heappush(agenda, (start + ev.t, 0, next(counter), "ev", ev))
    state = flush(state, float("inf"))
    state = engine.drain(state)
    rep = engine.report()
    completion = [completion_by_round.get(r, cfg.deadline) for r in range(n_rounds)]
    rep["completion"] = completion
    rep["cohorts"] = cohorts
    rep["makespan"] = (n_rounds - 1) * interval + (completion[-1] if completion else 0.0)
    return state, rep


def client_payloads(
    dataset, n_classes: int
) -> Dict[int, Fed3RStats]:
    """Precompute every client's (A_k, b_k, n_k) once (one jitted call per
    client; the upload the chaos timeline then delivers and re-delivers)."""
    stats_fn = jax.jit(fed3r.client_stats, static_argnums=(2,))
    out: Dict[int, Fed3RStats] = {}
    for k in range(dataset.n_clients):
        cd = dataset.client(k)
        out[k] = jax.tree.map(
            jax.block_until_ready,
            stats_fn(jnp.asarray(cd.features), jnp.asarray(cd.labels), n_classes),
        )
    return out
