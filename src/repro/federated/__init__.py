from repro.federated.algorithms import (  # noqa: F401
    FLAlgorithm,
    ServerState,
    make_algorithm,
    make_local_update,
    server_init,
    server_optimizer_step,
    server_state_from_tree,
)
from repro.federated.telemetry import (  # noqa: F401
    Telemetry,
    get_telemetry,
    set_telemetry,
)
from repro.federated.dist import (  # noqa: F401
    DistConfig,
    DistContext,
    dist_jit,
    two_stage_psum,
)
from repro.federated.engine import (  # noqa: F401
    AccumulationEngine,
    EngineConfig,
    EngineStats,
    aggregate,
    shard_stats,
)
from repro.federated.round_engine import (  # noqa: F401
    ReferenceLoop,
    RoundConfig,
    RoundEngine,
)
from repro.federated.async_engine import (  # noqa: F401
    AsyncConfig,
    AsyncRoundEngine,
    AsyncState,
    ClientHealth,
    run_adaptive_rounds,
    run_chaos_timeline,
)
from repro.federated.streaming_engine import (  # noqa: F401
    ReferenceArrivalLoop,
    StreamConfig,
    StreamState,
    StreamingEngine,
    WaveTrace,
)
from repro.federated.personalization import (  # noqa: F401
    PersonalizationEngine,
    PersonalizeConfig,
    PersonalizedHeads,
    ReferencePersonalizedLoop,
)
from repro.federated import arrivals  # noqa: F401
from repro.federated.sampling import ClientSampler, sample_round  # noqa: F401
from repro.federated.simulator import FLTask, run_federated  # noqa: F401
from repro.federated.fed3r_driver import (  # noqa: F401
    run_fed3r,
    run_fed3r_ft,
    run_fedncm,
)
from repro.federated import costs  # noqa: F401
