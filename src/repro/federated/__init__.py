from repro.federated.algorithms import FLAlgorithm, make_algorithm  # noqa: F401
from repro.federated.engine import (  # noqa: F401
    AccumulationEngine,
    EngineConfig,
    EngineStats,
    aggregate,
    shard_stats,
)
from repro.federated.sampling import ClientSampler  # noqa: F401
from repro.federated.simulator import FLTask, run_federated  # noqa: F401
from repro.federated.fed3r_driver import (  # noqa: F401
    run_fed3r,
    run_fed3r_ft,
    run_fedncm,
)
from repro.federated import costs  # noqa: F401
