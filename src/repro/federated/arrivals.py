"""Arrival-process simulator for streaming FED3R (§6 future work).

Generates the TIMELINE the streaming engine consumes: which clients arrive
at which wave.  Every schedule is a plain ``List[List[int]]`` (wave t →
client ids arriving at t; empty waves are legal and meaningful — the
serving clock still ticks), so schedules compose with any packer or
driver.  Three generators:

* :func:`poisson_schedule` — Poisson(rate) arrivals per wave from the
  not-yet-arrived pool (cross-device churn: each client arrives once);
* :func:`trace_schedule` — trace-driven: an explicit per-client arrival
  wave (replay of a production arrival log);
* :func:`skewed_schedule` — non-IID per-wave label skew: clients arrive
  roughly ordered by their dominant label (``skew`` interpolates between
  an IID shuffle and a strict label sort), the streaming analogue of the
  Dirichlet partition's pathological heterogeneity — early waves see only
  a few classes, so the served classifier's class coverage grows over
  time.

:func:`pack_schedule` materializes a schedule against a
:class:`repro.data.pipeline.FederatedDataset` into the engine's
:class:`repro.data.pipeline.PackedArrivals`.

The QUERY side of serving traffic lives here too: :func:`zipf_traffic`
draws seeded, replayable tenant-attributed query traces under the
bounded-Zipf popularity skew of the production cross-device regime — a
tiny head of hot tenants dominating a long cold tail — which is what the
slot-serving engine's cache/eviction policies are exercised against
(``benchmarks/bench_serving.py``, ``repro.launch.serve_heads``).

The UPLOAD side — what the network does to the statistics a client sends —
is the chaos-mode fault injector (:class:`ChaosSpec`,
:func:`chaos_round_events`, :func:`chaos_timeline`): seeded, replayable
drop/duplicate/reorder/delay schedules consumed by the asynchronous round
engine (:mod:`repro.federated.async_engine`) and replayed by the chaos CI
gate (``benchmarks/chaos_replay.py``).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.data.pipeline import (
    FederatedDataset,
    PackedArrivals,
    pack_arrival_waves,
)
from repro.federated.telemetry import get_telemetry

Schedule = List[List[int]]


def poisson_schedule(
    n_clients: int,
    n_waves: int,
    rate: float,
    *,
    seed: int = 0,
    drain: bool = True,
) -> Schedule:
    """Poisson(rate) client arrivals per wave, each client arriving once.

    Waves draw ``Poisson(rate)`` clients (capped by the remaining pool)
    from a seeded shuffle of the federation.  With ``drain`` the final
    wave absorbs any clients the process did not reach — the schedule is
    then a partition of ``range(n_clients)``; without it, stragglers
    simply never arrive (partial-participation streaming).
    """
    if n_waves < 1:
        raise ValueError(f"n_waves must be >= 1, got {n_waves}")
    rng = np.random.default_rng(seed)
    pool = rng.permutation(n_clients)
    waves: Schedule = []
    at = 0
    for _ in range(n_waves):
        k = min(int(rng.poisson(rate)), n_clients - at)
        waves.append([int(c) for c in pool[at : at + k]])
        at += k
    if drain and at < n_clients:
        waves[-1].extend(int(c) for c in pool[at:])
    return waves


def trace_schedule(
    arrival_wave: Sequence[int], n_waves: Optional[int] = None
) -> Schedule:
    """Trace-driven schedule: ``arrival_wave[k]`` is client k's wave index."""
    arr = np.asarray(arrival_wave, np.int64)
    if arr.size and arr.min() < 0:
        raise ValueError("arrival waves must be >= 0")
    T = int(arr.max()) + 1 if arr.size else 0
    if n_waves is not None:
        if T > n_waves:
            raise ValueError(f"trace spans {T} waves > n_waves={n_waves}")
        T = n_waves
    waves: Schedule = [[] for _ in range(T)]
    for k, t in enumerate(arr):
        waves[int(t)].append(k)
    return waves


def dominant_labels(dataset: FederatedDataset) -> np.ndarray:
    """Per-client dominant class — the skew key for label-skewed arrivals."""
    out = np.zeros((dataset.n_clients,), np.int64)
    for k in range(dataset.n_clients):
        labels = dataset.client(k).labels
        out[k] = (
            np.bincount(labels, minlength=dataset.n_classes).argmax()
            if len(labels) else 0
        )
    return out


def skewed_schedule(
    dominant: Sequence[int],
    n_waves: int,
    *,
    skew: float = 1.0,
    seed: int = 0,
) -> Schedule:
    """Label-skewed arrival order: clients stream in ≈ dominant-label order.

    ``skew=0`` is an IID shuffle, ``skew=1`` a strict sort by dominant
    label (each wave sees a narrow class slice); in between, each client's
    arrival key interpolates between uniform noise and its normalized
    label rank.  Clients are then chunked evenly into ``n_waves`` waves.
    """
    if not 0.0 <= skew <= 1.0:
        raise ValueError(f"skew must be in [0, 1], got {skew}")
    dom = np.asarray(dominant, np.float64)
    n = len(dom)
    rng = np.random.default_rng(seed)
    rank = dom / max(float(dom.max()), 1.0)
    key = (1.0 - skew) * rng.uniform(size=n) + skew * rank
    order = np.argsort(key, kind="stable")
    chunks = np.array_split(order, n_waves)
    return [[int(c) for c in chunk] for chunk in chunks]


def zipf_traffic(
    n_tenants: int,
    n_queries: int,
    *,
    exponent: float = 1.1,
    seed: int = 0,
    permute: bool = True,
) -> np.ndarray:
    """Seeded, replayable Zipf-skewed query traffic: ``(n_queries,)`` tenant ids.

    Tenant popularity follows a BOUNDED Zipf law over the ``n_tenants``
    universe — rank r drawn with probability ∝ r^(-exponent) — sampled by
    inverse-CDF so one call materializes the whole trace (no per-draw
    rejection, exact at any universe size).  With ``permute`` the
    popularity ranks are scattered over tenant ids by a seeded
    permutation, so "hot" tenants are not simply the low ids; without it
    tenant 0 is the hottest (convenient for assertions).  Same
    ``(n_tenants, n_queries, exponent, seed)`` ⇒ the identical trace, so
    benchmark runs replay byte-identical traffic.

    ``exponent`` ≈ 1.0–1.3 matches production cross-device skew: at 1.1
    over 1M tenants the top ~1% of tenants draw roughly half the queries.
    """
    if n_tenants < 1:
        raise ValueError(f"n_tenants must be >= 1, got {n_tenants}")
    if n_queries < 0:
        raise ValueError(f"n_queries must be >= 0, got {n_queries}")
    if exponent <= 0.0:
        raise ValueError(f"exponent must be > 0, got {exponent}")
    rng = np.random.default_rng(seed)
    weights = np.arange(1, n_tenants + 1, dtype=np.float64) ** -exponent
    cdf = np.cumsum(weights)
    cdf /= cdf[-1]
    ranks = np.searchsorted(cdf, rng.uniform(size=n_queries), side="right")
    if permute:
        ranks = rng.permutation(n_tenants)[ranks]
    return ranks.astype(np.int64)


# ---------------------------------------------------------------------------
# Chaos-mode fault injection — the UPLOAD side of the arrival process
# ---------------------------------------------------------------------------
#
# The generators above decide WHEN clients have data; the chaos injector
# decides what the network does to the resulting statistics uploads.  It
# produces the event timeline the asynchronous round engine
# (:mod:`repro.federated.async_engine`) consumes: seeded, replayable
# schedules that DROP uploads (forcing retransmits), DUPLICATE deliveries,
# REORDER concurrent arrivals, and DELAY stragglers — the four faults the
# chaos CI gate replays (`benchmarks/chaos_replay.py`) while asserting the
# folded classifier is bitwise unchanged versus the synchronous barrier.


class UploadEvent(NamedTuple):
    """One statistics-upload delivery, as the server observes it.

    ``t`` is the delivery time as an OFFSET from the round's start (so the
    same timeline replays under both the async cadence and the synchronous
    barrier's shifted round starts).  ``attempt`` counts the retransmits
    that preceded this copy (0 = the first send got through); duplicated
    deliveries share the attempt number of the copy they clone.
    """

    t: float
    round_id: int
    client: int
    attempt: int


@dataclass(frozen=True)
class ChaosSpec:
    """Seeded fault-injection knobs of one chaos schedule.

    Every probability is per-upload: ``drop`` loses the send (the client
    retransmits after ``rto``, re-flipping the coin, with the LAST of
    ``max_attempts`` always delivering — chaos perturbs timing, never the
    delivered set, so exact-once final states stay comparable);
    ``duplicate`` delivers a second identical copy within ``rto``;
    ``reorder`` jitters the delivery by up to ±``rto`` (swapping concurrent
    arrivals); ``delay`` multiplies the client's latency by
    ``delay_factor`` (the transient-straggler fault, distinct from the
    persistent per-client latency profile).
    """

    drop: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    delay: float = 0.0
    delay_factor: float = 8.0
    rto: float = 0.5
    max_attempts: int = 8
    seed: int = 0

    def __post_init__(self):
        for name in ("drop", "duplicate", "reorder", "delay"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be a probability, got {p}")
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")


def latency_profile(
    n_clients: int,
    straggler_frac: float,
    *,
    straggler_factor: float = 8.0,
    base: float = 0.3,
    jitter: float = 0.5,
    seed: int = 0,
) -> np.ndarray:
    """Per-client upload latencies with a persistent straggler tail.

    A seeded ``straggler_frac`` of the federation is ``straggler_factor``×
    slower than the ``base``-latency body (uniform ±``jitter`` spread) —
    the population the adaptive dropout policy demotes.
    """
    if not 0.0 <= straggler_frac <= 1.0:
        raise ValueError(f"straggler_frac must be in [0, 1], got {straggler_frac}")
    rng = np.random.default_rng((seed, 0x51))
    lat = base * (1.0 + jitter * rng.uniform(-1.0, 1.0, size=n_clients))
    n_slow = int(round(straggler_frac * n_clients))
    slow = rng.choice(n_clients, size=n_slow, replace=False)
    lat[slow] *= straggler_factor
    return lat.astype(np.float64)


def chaos_round_events(
    cohort: Sequence[int],
    latency: np.ndarray,
    spec: ChaosSpec,
    round_id: int,
) -> List[UploadEvent]:
    """The fault-injected delivery events of ONE round's cohort.

    Deterministic in ``(spec.seed, round_id, client)`` — re-generating a
    round replays byte-identical faults, which is what lets the chaos CI
    gate persist an offending schedule and replay it.  Each injected
    fault (delay, drop+retransmit, reorder, duplicate) is additionally
    recorded as a ``chaos_fault`` event in the telemetry flight recorder,
    so a failed replay ships an event log alongside the schedule JSON.
    """
    telemetry = get_telemetry()

    def fault(kind: str, c: int, **fields) -> None:
        telemetry.event(
            "chaos_fault", fault=kind, client=int(c), round=int(round_id), **fields
        )

    events: List[UploadEvent] = []
    for c in cohort:
        rng = np.random.default_rng((spec.seed, round_id, int(c), 0xC4A0))
        base = float(latency[int(c)])
        if rng.random() < spec.delay:
            base *= spec.delay_factor
            fault("delay", c, factor=spec.delay_factor)
        attempt = 0
        while attempt < spec.max_attempts - 1 and rng.random() < spec.drop:
            attempt += 1  # this copy was lost; retransmit after rto
        if attempt:
            fault("drop", c, retransmits=attempt)
        t = base + attempt * spec.rto
        if rng.random() < spec.reorder:
            t = max(1e-6, t + rng.uniform(-spec.rto, spec.rto))
            fault("reorder", c)
        events.append(UploadEvent(t=t, round_id=round_id, client=int(c), attempt=attempt))
        if rng.random() < spec.duplicate:
            fault("duplicate", c)
            events.append(
                UploadEvent(
                    t=t + rng.uniform(1e-6, spec.rto),
                    round_id=round_id,
                    client=int(c),
                    attempt=attempt,
                )
            )
    events.sort(key=lambda e: (e.t, e.client, e.attempt))
    return events


def chaos_timeline(
    cohorts: Sequence[Sequence[int]],
    latency: np.ndarray,
    spec: ChaosSpec,
) -> List[UploadEvent]:
    """The full fault-injected timeline over a pre-drawn cohort sequence."""
    out: List[UploadEvent] = []
    for r, cohort in enumerate(cohorts):
        out.extend(chaos_round_events(cohort, latency, spec, r))
    return out


def timeline_to_json(
    cohorts: Sequence[Sequence[int]],
    latency: np.ndarray,
    spec: ChaosSpec,
    events: Sequence[UploadEvent],
) -> str:
    """Serialize a chaos schedule for artifact upload / offline replay."""
    return json.dumps(
        {
            "spec": asdict(spec),
            "cohorts": [[int(c) for c in cohort] for cohort in cohorts],
            "latency": [float(x) for x in np.asarray(latency)],
            "events": [[float(e.t), e.round_id, e.client, e.attempt] for e in events],
        },
        indent=2,
    )


def timeline_from_json(blob: str) -> Dict[str, object]:
    """Rehydrate a chaos schedule persisted by :func:`timeline_to_json`."""
    obj = json.loads(blob)
    return {
        "spec": ChaosSpec(**obj["spec"]),
        "cohorts": [[int(c) for c in cohort] for cohort in obj["cohorts"]],
        "latency": np.asarray(obj["latency"], np.float64),
        "events": [
            UploadEvent(t=float(t), round_id=int(r), client=int(c), attempt=int(a))
            for t, r, c, a in obj["events"]
        ],
    }


def pack_schedule(
    dataset: FederatedDataset,
    schedule: Schedule,
    *,
    extractor: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    clients_per_wave: Optional[int] = None,
    max_n: Optional[int] = None,
    round_to: int = 8,
) -> PackedArrivals:
    """Materialize a schedule into the engine's :class:`PackedArrivals`.

    ``extractor`` optionally maps raw client inputs to features on the
    host (pass ``feature_fn`` to the engine instead to fuse a backbone
    into the scan).  ``max_n`` defaults to the DATASET-global maximum
    client size so repeated streams over the same federation share one
    jit trace.
    """
    if max_n is None:
        max_n = int(max(dataset.client_sizes(), default=1))
    waves = []
    ids = []
    for wave in schedule:
        packed_wave = []
        for k in wave:
            cd = dataset.client(k)
            x = np.asarray(extractor(cd.features)) if extractor else cd.features
            packed_wave.append((x, cd.labels))
        waves.append(packed_wave)
        ids.append(list(wave))
    return pack_arrival_waves(
        waves,
        client_ids=ids,
        clients_per_wave=clients_per_wave,
        max_n=max_n,
        round_to=round_to,
    )
