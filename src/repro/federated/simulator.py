"""Federated round-loop simulator.

Runs any :mod:`repro.federated.algorithms` algorithm over a
:class:`repro.data.pipeline.FederatedDataset`.  Client data is padded to a
global (n_batches, batch_size) shape so one jitted ``local_update`` serves
every client without retracing.  Designed for CPU-scale experiments
(linear heads or reduced backbones); the datacenter path lives in
launch/train.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig
from repro.data.pipeline import FederatedDataset, pack_client_batches
from repro.federated.algorithms import Server, make_algorithm, make_local_update
from repro.federated.sampling import ClientSampler


class FLTask(NamedTuple):
    """A federated optimization problem.

    ``per_example_loss(params, batch) -> (batch_size,)`` losses;
    ``batch`` = {"x": ..., "y": ..., "mask": ...}.
    ``freeze``: pytree of {1.0: trainable, 0.0: frozen} matching params.
    """

    params0: Any
    per_example_loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    freeze: Any
    eval_fn: Optional[Callable[[Any], float]] = None


@dataclass
class FLHistory:
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    coverage: List[float] = field(default_factory=list)
    wall_time: List[float] = field(default_factory=list)

    def as_dict(self) -> Dict[str, list]:
        return {
            "rounds": self.rounds,
            "accuracy": self.accuracy,
            "coverage": self.coverage,
            "wall_time": self.wall_time,
        }


def run_federated(
    task: FLTask,
    dataset: FederatedDataset,
    cfg: FederatedConfig,
    *,
    eval_every: int = 10,
    verbose: bool = False,
) -> tuple:
    """Run cfg.n_rounds of federated training. Returns (params, FLHistory)."""
    algo = make_algorithm(
        cfg.algorithm, prox_mu=cfg.prox_mu, server_momentum=cfg.server_momentum
    )
    local_update = make_local_update(
        task.per_example_loss, algo, lr=cfg.client_lr,
        weight_decay=cfg.client_weight_decay,
    )
    server = Server(algo, task.params0, server_lr=cfg.server_lr)
    sampler = ClientSampler(
        dataset.n_clients, cfg.clients_per_round,
        replacement=cfg.sample_with_replacement, seed=cfg.seed,
    )

    max_nk = int(dataset.client_sizes().max())
    n_batches = -(-max_nk // cfg.local_batch_size)
    np_rng = np.random.default_rng(cfg.seed + 7)

    zeros_like_params = jax.tree.map(jnp.zeros_like, task.params0)
    cvars: Dict[int, Any] = {}

    hist = FLHistory()
    t0 = time.time()
    for rnd in range(cfg.n_rounds):
        chosen = sampler.sample()
        results, cvar_deltas = [], []
        for k in chosen:
            cd = dataset.client(int(k))
            batches = pack_client_batches(
                cd.features, cd.labels, cfg.local_batch_size, n_batches,
                cfg.local_epochs, np_rng,
            )
            batches = {kk: jnp.asarray(v) for kk, v in batches.items()}
            c_client = cvars.get(int(k), zeros_like_params) if algo.uses_cvar else zeros_like_params
            c_server = server.c_server if algo.uses_cvar else zeros_like_params
            res = local_update(server.params, batches, task.freeze, c_server, c_client)
            results.append(res)
            if algo.uses_cvar:
                cvar_deltas.append(
                    jax.tree.map(lambda n, o: n - o, res.new_cvar, c_client)
                )
                cvars[int(k)] = res.new_cvar
        server.aggregate(results, n_total_clients=dataset.n_clients,
                         cvar_deltas=cvar_deltas or None)

        if task.eval_fn is not None and ((rnd + 1) % eval_every == 0 or rnd == cfg.n_rounds - 1):
            acc = float(task.eval_fn(server.params))
            hist.rounds.append(rnd + 1)
            hist.accuracy.append(acc)
            hist.coverage.append(sampler.coverage)
            hist.wall_time.append(time.time() - t0)
            if verbose:
                print(f"round {rnd+1:5d}  acc={acc:.4f}  coverage={sampler.coverage:.2f}")
    return server.params, hist


# ---------------------------------------------------------------------------
# linear softmax-head task over fixed features (LP baselines of the paper)
# ---------------------------------------------------------------------------


def linear_head_task(
    d: int,
    n_classes: int,
    test_features: jax.Array,
    test_labels: jax.Array,
    *,
    W_init: Optional[jax.Array] = None,
    rng: Optional[jax.Array] = None,
) -> FLTask:
    """FedAvg-LP etc.: train only a linear softmax head on frozen features."""
    if W_init is None:
        key = rng if rng is not None else jax.random.PRNGKey(0)
        W_init = 0.01 * jax.random.normal(key, (d, n_classes), jnp.float32)
    params0 = {"W": jnp.asarray(W_init, jnp.float32),
               "bias": jnp.zeros((n_classes,), jnp.float32)}

    def per_example_loss(params, batch):
        logits = batch["x"].astype(jnp.float32) @ params["W"] + params["bias"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return lse - picked

    @jax.jit
    def eval_fn(params):
        logits = test_features.astype(jnp.float32) @ params["W"] + params["bias"]
        return jnp.mean((jnp.argmax(logits, -1) == test_labels).astype(jnp.float32))

    freeze = jax.tree.map(lambda _: 1.0, params0)
    return FLTask(params0=params0, per_example_loss=per_example_loss,
                  freeze=freeze, eval_fn=eval_fn)
