"""Federated round-loop simulator on the batched cohort round engine.

Runs any :mod:`repro.federated.algorithms` algorithm over a
:class:`repro.data.pipeline.FederatedDataset`.  Each round, the sampled
cohort is packed into stacked ``(cohort, n_steps, batch)`` arrays
(:func:`repro.data.pipeline.pack_cohort_batches`) and the WHOLE round —
vmapped local updates, on-device weighted aggregation, server optimizer
step, Scaffold cvar scatter — executes as ONE jitted dispatch through
:class:`repro.federated.round_engine.RoundEngine` (K+1 dispatches/round
in the seed-era per-client loop).

Rounds are resumable: cohorts and epoch shuffles are pure functions of
(seed, round, client id), and the full :class:`ServerState` checkpoints
through :mod:`repro.checkpoint`, so a run stopped at any round boundary
and restarted with ``resume=True`` reproduces the uninterrupted run
exactly.  Designed for CPU-scale experiments (linear heads or reduced
backbones); the datacenter path lives in launch/train.py.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_checkpoint, load_pytree, save_pytree
from repro.configs.base import FederatedConfig
from repro.data.pipeline import FederatedDataset, pack_cohort_batches
from repro.federated.algorithms import make_algorithm, server_state_from_tree
from repro.federated.round_engine import RoundConfig, RoundEngine
from repro.federated.sampling import sample_round


class FLTask(NamedTuple):
    """A federated optimization problem.

    ``per_example_loss(params, batch) -> (batch_size,)`` losses;
    ``batch`` = {"x": ..., "y": ..., "mask": ...}.
    ``freeze``: pytree of {1.0: trainable, 0.0: frozen} matching params.
    """

    params0: Any
    per_example_loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    freeze: Any
    eval_fn: Optional[Callable[[Any], float]] = None


@dataclass
class FLHistory:
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    coverage: List[float] = field(default_factory=list)
    wall_time: List[float] = field(default_factory=list)

    def as_dict(self) -> Dict[str, list]:
        return {
            "rounds": self.rounds,
            "accuracy": self.accuracy,
            "coverage": self.coverage,
            "wall_time": self.wall_time,
        }


def make_round_engine(
    task: FLTask, dataset: FederatedDataset, cfg: FederatedConfig
) -> RoundEngine:
    """The simulator's engine: merge aggregation on the ambient mesh."""
    algo = make_algorithm(
        cfg.algorithm, prox_mu=cfg.prox_mu, server_momentum=cfg.server_momentum
    )
    return RoundEngine(
        RoundConfig(
            algo=algo,
            client_lr=cfg.client_lr,
            server_lr=cfg.server_lr,
            weight_decay=cfg.client_weight_decay,
            n_total_clients=dataset.n_clients,
        ),
        task.per_example_loss,
        task.freeze,
    )


def pack_round(
    dataset: FederatedDataset,
    cfg: FederatedConfig,
    rnd: int,
    n_batches: int,
    mesh=None,
):
    """The packed cohort of round ``rnd`` — a pure function of (cfg, rnd).

    Sampling and the per-client epoch shuffles both derive from
    (cfg.seed, rnd, client id), which is what makes stop/resume exact.
    ``mesh`` pads the cohort axis to the mesh's data-parallel size for
    dist-layer (shard_map) rounds — padded slots are exact no-ops.
    """
    chosen = sample_round(
        dataset.n_clients, cfg.clients_per_round, rnd,
        seed=cfg.seed, replacement=cfg.sample_with_replacement,
    )
    clients = [
        (dataset.client(int(k)).features, dataset.client(int(k)).labels)
        for k in chosen
    ]
    return chosen, pack_cohort_batches(
        clients, cfg.local_batch_size, n_batches, cfg.local_epochs,
        client_ids=chosen, seed=(cfg.seed + 7, rnd), mesh=mesh,
    )


def run_federated(
    task: FLTask,
    dataset: FederatedDataset,
    cfg: FederatedConfig,
    *,
    eval_every: int = 10,
    verbose: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every: Optional[int] = None,
    resume: bool = False,
) -> tuple:
    """Run cfg.n_rounds of federated training. Returns (params, FLHistory).

    With ``ckpt_dir`` the full :class:`ServerState` (params, momentum,
    adaptive m/v/t, stacked cvars, round index) is snapshotted every
    ``ckpt_every`` rounds (default: ``eval_every``); ``resume=True`` picks
    up from the latest snapshot and reproduces the uninterrupted run.
    """
    engine = make_round_engine(task, dataset, cfg)
    state, start_round = None, 0
    if resume and ckpt_dir:
        path = latest_checkpoint(ckpt_dir)
        if path is not None:
            state = server_state_from_tree(load_pytree(path))
            start_round = int(state.round)
    if state is None:
        state = engine.init(task.params0)

    max_nk = int(dataset.client_sizes().max())
    n_batches = -(-max_nk // cfg.local_batch_size)

    seen: set = set()
    for rnd in range(start_round):  # replay coverage of resumed rounds
        seen.update(
            int(k) for k in sample_round(
                dataset.n_clients, cfg.clients_per_round, rnd,
                seed=cfg.seed, replacement=cfg.sample_with_replacement,
            )
        )

    hist = FLHistory()
    t0 = time.time()
    for rnd in range(start_round, cfg.n_rounds):
        chosen, cohort = pack_round(dataset, cfg, rnd, n_batches)
        seen.update(int(k) for k in chosen)
        state = engine.step(state, cohort)

        if ckpt_dir and (
            (rnd + 1) % (ckpt_every or eval_every) == 0 or rnd == cfg.n_rounds - 1
        ):
            save_pytree(os.path.join(ckpt_dir, f"ckpt_{rnd + 1}.npz"), state)

        if task.eval_fn is not None and ((rnd + 1) % eval_every == 0 or rnd == cfg.n_rounds - 1):
            acc = float(task.eval_fn(state.params))
            hist.rounds.append(rnd + 1)
            hist.accuracy.append(acc)
            hist.coverage.append(len(seen) / dataset.n_clients)
            hist.wall_time.append(time.time() - t0)
            if verbose:
                print(f"round {rnd+1:5d}  acc={acc:.4f}  coverage={len(seen)/dataset.n_clients:.2f}")
    return state.params, hist


# ---------------------------------------------------------------------------
# linear softmax-head task over fixed features (LP baselines of the paper)
# ---------------------------------------------------------------------------


def linear_head_task(
    d: int,
    n_classes: int,
    test_features: jax.Array,
    test_labels: jax.Array,
    *,
    W_init: Optional[jax.Array] = None,
    rng: Optional[jax.Array] = None,
) -> FLTask:
    """FedAvg-LP etc.: train only a linear softmax head on frozen features."""
    if W_init is None:
        key = rng if rng is not None else jax.random.PRNGKey(0)
        W_init = 0.01 * jax.random.normal(key, (d, n_classes), jnp.float32)
    params0 = {"W": jnp.asarray(W_init, jnp.float32),
               "bias": jnp.zeros((n_classes,), jnp.float32)}

    def per_example_loss(params, batch):
        logits = batch["x"].astype(jnp.float32) @ params["W"] + params["bias"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return lse - picked

    @jax.jit
    def eval_fn(params):
        logits = test_features.astype(jnp.float32) @ params["W"] + params["bias"]
        return jnp.mean((jnp.argmax(logits, -1) == test_labels).astype(jnp.float32))

    freeze = jax.tree.map(lambda _: 1.0, params0)
    return FLTask(params0=params0, per_example_loss=per_example_loss,
                  freeze=freeze, eval_fn=eval_fn)
