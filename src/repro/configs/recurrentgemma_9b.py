"""RecurrentGemma 9B — Griffin hybrid: RG-LRU recurrent blocks + local attention.

Source: [arXiv:2402.19427]: 38 layers, d_model=4096, 16 heads (MQA kv=1),
d_ff=12288, vocab=256000, block pattern (rec, rec, attn) — i.e. local
attention every third layer — local window 2048, lru_width=4096.

38 = 12 x (rec, rec, attn) + 2 remainder rec layers: the stack scans over 12
homogeneous super-blocks and unrolls the 2 remainder layers (see
models/transformer.py).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        block_pattern=("rec", "rec", "attn"),
        lru_width=4096,
        local_window=2048,
        mlp_type="geglu",
        norm_type="rmsnorm",
        tie_embeddings=True,
        rope_theta=10_000.0,
        source="arXiv:2402.19427",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="recurrentgemma-9b-smoke",
        n_layers=3,
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        lru_width=128,
        local_window=32,
        vocab_size=512,
    )
)
