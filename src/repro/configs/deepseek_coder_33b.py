"""DeepSeek-Coder 33B — llama-architecture dense decoder.

Source: [arXiv:2401.14196]: 62 layers, d_model=7168, 56 heads (GQA kv=8),
d_ff=19200, vocab=32256, SwiGLU, RMSNorm, untied, rope theta 100000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-coder-33b",
        arch_type="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=19200,
        vocab_size=32_256,
        qkv_bias=False,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=False,
        rope_theta=100_000.0,
        source="arXiv:2401.14196",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="deepseek-coder-33b-smoke",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
    )
)
