"""Minitron 8B — width-pruned Nemotron-4 15B dense decoder.

Source: [arXiv:2407.14679]: 32 layers, d_model=4096, 32 heads (GQA kv=8),
d_ff=16384, vocab=256000.  Nemotron family uses squared-ReLU (non-gated)
MLPs; we model that with the non-gated ``gelu`` MLP type, LayerNorm-1p ≈
layernorm, untied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="minitron-8b",
        arch_type="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256_000,
        qkv_bias=False,
        mlp_type="gelu",
        norm_type="layernorm",
        tie_embeddings=False,
        rope_theta=10_000.0,
        source="arXiv:2407.14679",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="minitron-8b-smoke",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
    )
)
