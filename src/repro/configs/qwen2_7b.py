"""Qwen2 7B — dense GQA decoder with QKV bias.

Source: [arXiv:2407.10671]: 28 layers, d_model=3584, 28 heads (GQA kv=4),
d_ff=18944, vocab=152064, QKV bias, SwiGLU, RMSNorm, untied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-7b",
        arch_type="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        qkv_bias=True,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=False,
        rope_theta=1_000_000.0,
        source="arXiv:2407.10671",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="qwen2-7b-smoke",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
    )
)
