"""Whisper large-v3 — encoder-decoder audio model (transformer backbone only).

Source: [arXiv:2212.04356]: 32 encoder + 32 decoder layers, d_model=1280,
20 heads (MHA: kv=20), d_ff=5120, vocab=51866, GELU MLP, LayerNorm,
learned decoder positions, sinusoidal encoder positions.

The mel-spectrogram + conv1d feature frontend is a STUB per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings of shape
(B, 1500, d_model) directly to the encoder stack.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-large-v3",
        arch_type="audio",
        n_layers=32,  # decoder layers
        n_encoder_layers=32,
        is_encoder_decoder=True,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51_866,
        n_audio_frames=1500,
        qkv_bias=True,
        mlp_type="gelu",
        norm_type="layernorm",
        tie_embeddings=True,
        source="arXiv:2212.04356",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="whisper-large-v3-smoke",
        n_layers=2,
        n_encoder_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_audio_frames=32,
    )
)
