"""DeepSeekMoE 16B — fine-grained MoE: 2 shared + 64 routed experts, top-6.

Source: [arXiv:2401.06066]: 28 layers, d_model=2048, 16 heads (MHA: kv=16),
per-expert FFN hidden 1408, vocab=102400.  Every layer is MoE (the public
model keeps layer 0 dense; the assignment pins d_ff=1408 so we treat all
layers uniformly as MoE with 2 always-on shared experts of the same size).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-moe-16b",
        arch_type="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102_400,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_expert=1408,
        capacity_factor=1.25,
        router_aux_coef=0.01,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=False,
        rope_theta=10_000.0,
        source="arXiv:2401.06066",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="deepseek-moe-16b-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=96,
        d_expert=96,
        n_experts=4,
        n_shared_experts=1,
        top_k=2,
        vocab_size=512,
    )
)
