"""Qwen2-VL 2B — VLM decoder with M-RoPE and dynamic-resolution ViT frontend.

Source: [arXiv:2409.12191]: 28 layers, d_model=1536, 12 heads (GQA kv=2),
d_ff=8960, vocab=151936, QKV bias, M-RoPE rotary sections (t,h,w)=(16,24,24)
over the 64 rotary half-dims (head_dim=128).

The ViT/merger vision frontend is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed patch embeddings of shape
(B, n_patches, d_model) which the decoder consumes prepended to the text
tokens, with 3-D (temporal, height, width) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        n_patches=256,  # stub: 16x16 patch grid per image
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        source="arXiv:2409.12191",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="qwen2-vl-2b-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mrope_sections=(4, 6, 6),
        n_patches=16,  # 4x4 grid
    )
)
