"""Command R+ 104B — dense GQA decoder, parallel attn+FFN blocks, no bias.

Source: [hf:CohereForAI/c4ai-command-r-v01] (scaled per assignment):
64 layers, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000.
Cohere models use LayerNorm, tied embeddings, and the parallel-block
formulation x + attn(norm(x)) + mlp(norm(x)).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="command-r-plus-104b",
        arch_type="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab_size=256_000,
        qkv_bias=False,
        mlp_type="swiglu",
        norm_type="layernorm",
        tie_embeddings=True,
        parallel_block=True,
        rope_theta=8_000_000.0,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="command-r-plus-104b-smoke",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
    )
)
