"""Paper-scale proxy backbone for FED3R experiments.

The paper uses an ImageNet-pretrained MobileNetV2 whose feature space is
d=1280.  Offline we cannot ship MobileNetV2 weights, so the FED3R-family
benchmarks use either (a) raw synthetic feature vectors of d=1280 (data-level
φ) or (b) this small dense transformer with d_model=1280 as a stand-in
extractor for the end-to-end FED3R+FT drivers.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="fed3r-mnv2-proxy",
        arch_type="dense",
        n_layers=6,
        d_model=1280,
        n_heads=10,
        n_kv_heads=10,
        head_dim=128,
        d_ff=3072,
        vocab_size=8192,
        mlp_type="gelu",
        norm_type="layernorm",
        tie_embeddings=True,
        source="paper proxy (MobileNetV2 feature dim d=1280)",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="fed3r-mnv2-proxy-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
)
