"""Llama-4 Scout 17B-A16E — MoE decoder, 16 experts top-1 + shared expert.

Source: [hf:meta-llama/Llama-4-Scout-17B-16E]: 48 layers, d_model=5120,
40 heads (GQA kv=8), expert FFN hidden 8192, vocab=202048, MoE 16 experts
top-1 with one always-on shared expert per layer (early-fusion multimodal
in the public model; text backbone per the assignment).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=16,
        n_shared_experts=1,
        top_k=1,
        d_expert=8192,
        capacity_factor=1.25,
        router_aux_coef=0.01,
        mlp_type="swiglu",
        norm_type="rmsnorm",
        tie_embeddings=False,
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="llama4-scout-17b-a16e-smoke",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        d_expert=128,
        n_experts=4,
        n_shared_experts=1,
        top_k=1,
        vocab_size=512,
    )
)
