"""Configuration system for the repro framework.

Every assigned architecture is described by a single frozen ``ModelConfig``
dataclass.  Configs are plain data — no jax imports happen at config time so
that importing a config module never touches device state (required by the
dry-run contract: ``XLA_FLAGS`` must be set before the first jax import).

``input_specs`` (in :mod:`repro.launch.shapes`) consumes these configs to build
``jax.ShapeDtypeStruct`` stand-ins for every step function input.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Complete architectural description of one backbone.

    The same dataclass covers all six architecture families (dense / moe /
    ssm / hybrid / vlm / audio); family-specific fields default to inert
    values so that dense configs stay small.
    """

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""  # citation for the config numbers

    # --- attention ---------------------------------------------------------
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    parallel_block: bool = False  # Command-R style parallel attn+FFN
    attn_logit_softcap: Optional[float] = None

    # --- MLP ---------------------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = True

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # per-expert FFN hidden size (d_ff used for shared/dense)
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    moe_every: int = 1  # MoE layer every N layers (1 = all layers MoE)

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (RecurrentGemma / Griffin) ----------------------------------
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 2048

    # --- VLM (Qwen2-VL) ------------------------------------------------------
    mrope_sections: Tuple[int, ...] = ()  # rotary dim split (t, h, w)
    n_patches: int = 0  # stub image tokens prepended per example

    # --- audio enc-dec (Whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 0  # stub conv-frontend output frames
    n_positions: int = 32_768  # learned-position table size (enc-dec decoder)

    # --- numerics / structure -----------------------------------------------
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    # Block remat: checkpoint only every Nth layer boundary; the backward
    # pass recomputes within a block.  Cuts saved-activation memory ~N×
    # for deep/wide models (command-r: 64 × 100MB saves -> 8 × 100MB).
    remat_block_size: int = 1
    # Sequence parallelism: shard the residual stream's seq dim over the TP
    # axis (Korthikanti et al.).  Opt-in: helps wide models whose per-layer
    # remat saves dominate; hurts row-parallel-fallback archs.
    sequence_parallel: bool = False
    scan_layers: bool = True
    attn_impl: str = "xla"  # xla | flash (pallas)

    # int8 KV cache (symmetric per-token-per-head scales): 2× decode-memory
    # reduction for cache-resident serving (EXPERIMENTS.md §Perf).
    kv_cache_quant: bool = False

    # --- FED3R feature head ---------------------------------------------------
    feature_pooling: str = "mean"  # mean | last
    feature_dim: Optional[int] = None  # defaults to d_model

    # Embedding/classifier tables are padded to a multiple of this so the
    # vocab dim shards evenly on any power-of-two mesh axis (standard
    # practice; padded logit columns are masked to -inf in unembed_apply).
    vocab_pad_to: int = 128

    # ------------------------------------------------------------------ utils
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_feat(self) -> int:
        return self.feature_dim if self.feature_dim is not None else self.d_model

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def pattern_for(self, n_layers: int) -> Tuple[str, ...]:
        """Expand ``block_pattern`` to an explicit per-layer type list."""
        if not self.block_pattern:
            base = {"ssm": "ssm"}.get(self.arch_type, "attn")
            return tuple(base for _ in range(n_layers))
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Number of homogeneous scan "super blocks" and unrolled remainder layers
    # for hybrid patterns (scan requires homogeneous carry structure).
    @property
    def n_superblocks(self) -> int:
        if not self.block_pattern:
            return self.n_layers
        return self.n_layers // len(self.block_pattern)

    @property
    def n_remainder_layers(self) -> int:
        if not self.block_pattern:
            return 0
        return self.n_layers % len(self.block_pattern)

    def validate(self) -> None:
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"), self.arch_type
        if self.arch_type != "ssm":
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA requires n_heads % n_kv_heads == 0"
        if self.arch_type == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.arch_type == "ssm":
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_headdim == 0
        if self.arch_type == "hybrid":
            assert self.lru_width > 0 and self.block_pattern
        if self.arch_type == "audio":
            assert self.is_encoder_decoder and self.n_audio_frames > 0
        if self.arch_type == "vlm":
            assert self.mrope_sections and self.n_patches > 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# FED3R configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Fed3RConfig:
    """Hyper-parameters of the paper's technique (Sections 4.1-4.4)."""

    ridge_lambda: float = 0.01  # Tikhonov λ (paper App. C: λ = 0.01)
    n_classes: int = 1000
    normalize_classifier: bool = True  # W*_c <- W*_c / ||W*_c||
    # Random features (FED3R-RF): 0 disables the RFF map.
    n_random_features: int = 0
    rff_sigma: float = 1000.0  # paper App. C: σ = 1000 (RBF)
    # FT phase
    softmax_temperature: float = 0.1  # paper App. C / Fig. 7
    ft_strategy: str = "feat"  # full | lp | feat
    stats_dtype: str = "float32"

    @property
    def stats_dim(self) -> int:
        """Dimensionality of the RR statistics space (d or D)."""
        return self.n_random_features if self.n_random_features > 0 else 0


# ---------------------------------------------------------------------------
# Federated-simulation configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FederatedConfig:
    n_clients: int = 100
    clients_per_round: int = 10
    n_rounds: int = 50
    local_epochs: int = 1
    local_batch_size: int = 50
    client_lr: float = 0.1
    client_weight_decay: float = 4e-5
    server_lr: float = 1.0
    server_momentum: float = 0.0
    algorithm: str = "fedavg"  # fedavg | fedavgm | fedprox | scaffold
    prox_mu: float = 0.01
    sample_with_replacement: bool = False
    dirichlet_alpha: float = 0.0  # 0 => one-class-per-client (most heterogeneous)
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Lazy-import the per-arch modules on first lookup.
        from repro import configs as _c  # noqa: F401

        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    from repro import configs as _c

    _c.load_all()
    return sorted(_REGISTRY)
