"""Mamba2 1.3B — attention-free state-space model with SSD.

Source: [arXiv:2405.21060]: 48 layers, d_model=2048, ssm_state=128,
vocab=50280.  d_inner = 2*d_model = 4096, headdim=64 -> 64 SSD heads,
ngroups=1, causal conv width 4, chunked SSD scan (chunk=256).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-1.3b",
        arch_type="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=1,  # unused for ssm
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=256,
        ssm_conv=4,
        ssm_ngroups=1,
        norm_type="rmsnorm",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
)

REDUCED = register(
    CONFIG.replace(
        name="mamba2-1.3b-smoke",
        n_layers=2,
        d_model=128,
        ssm_state=16,
        ssm_headdim=32,
        ssm_chunk=32,
        vocab_size=512,
    )
)
