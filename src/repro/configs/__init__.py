"""Architecture configs assigned to this paper (public-literature pool).

Each module defines ``CONFIG`` (the exact assigned configuration, with source
citation) and ``REDUCED`` (a smoke-test variant of the same family: ≤2-3
layers, d_model ≤ 512, ≤4 experts) registered as ``<name>-smoke``.
"""
from repro.configs.base import (  # noqa: F401
    Fed3RConfig,
    FederatedConfig,
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    register,
)

ARCH_MODULES = [
    "command_r_plus_104b",
    "minitron_8b",
    "deepseek_moe_16b",
    "qwen2_vl_2b",
    "mamba2_1_3b",
    "recurrentgemma_9b",
    "qwen2_7b",
    "deepseek_coder_33b",
    "llama4_scout_17b_a16e",
    "whisper_large_v3",
    "fed3r_mnv2_proxy",
]

ASSIGNED_ARCHS = [
    "command-r-plus-104b",
    "minitron-8b",
    "deepseek-moe-16b",
    "qwen2-vl-2b",
    "mamba2-1.3b",
    "recurrentgemma-9b",
    "qwen2-7b",
    "deepseek-coder-33b",
    "llama4-scout-17b-a16e",
    "whisper-large-v3",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    import importlib

    for m in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
