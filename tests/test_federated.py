"""Federated runtime: algorithms, sampling, FED3R drivers, cost meters."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import Fed3RConfig, FederatedConfig
from repro.core import fed3r
from repro.data import make_federated_features
from repro.data.partition import dirichlet_partition, quantity_skew_sizes
from repro.federated import costs, run_fed3r, run_fed3r_ft, run_fedncm
from repro.federated.sampling import ClientSampler
from repro.federated.simulator import linear_head_task, run_federated

N_CLIENTS, C, D = 20, 6, 32


@pytest.fixture(scope="module")
def fed_data():
    return make_federated_features(
        seed=0, n=1500, d=D, n_classes=C, n_clients=N_CLIENTS, alpha=0.0, noise=1.5
    )


def _fc(**kw):
    base = dict(
        n_clients=N_CLIENTS, clients_per_round=5, n_rounds=20, local_epochs=1,
        local_batch_size=16, client_lr=0.1, algorithm="fedavg", seed=0,
    )
    base.update(kw)
    return FederatedConfig(**base)


def test_fed3r_converges_in_k_over_kappa_rounds(fed_data):
    """Paper §4.3: exactly ⌈K/κ⌉ rounds to the final solution."""
    fed, test = fed_data
    f3 = Fed3RConfig(n_classes=C)
    W, stats, hist = run_fed3r(fed, test.features, test.labels, f3, _fc(), eval_every=1)
    assert hist.rounds[-1] == -(-N_CLIENTS // 5)  # ⌈20/5⌉ = 4
    assert hist.clients_seen[-1] == N_CLIENTS
    # and the solution equals the centralized one
    cen = fed3r.solve(
        fed3r.client_stats(jnp.asarray(fed.features), jnp.asarray(fed.labels), C),
        f3.ridge_lambda,
    )
    np.testing.assert_allclose(np.asarray(W), np.asarray(cen), rtol=1e-4, atol=1e-4)


def test_fed3r_split_invariance_via_driver(fed_data):
    """Fig. 1: different federated splits converge to identical accuracy."""
    fed, test = fed_data
    f3 = Fed3RConfig(n_classes=C)
    accs = []
    for n_cl, alpha in [(10, 0.0), (40, 0.0), (20, 100.0)]:
        fed2 = fed.repartition(np.random.default_rng(7), n_cl, alpha)
        W, _, h = run_fed3r(
            fed2, test.features, test.labels, f3,
            _fc(n_clients=n_cl), eval_every=1000,
        )
        accs.append(h.accuracy[-1])
    assert max(accs) - min(accs) < 1e-6


def test_fed3r_resampled_client_sends_exactly_once(fed_data):
    """Regression for the seen-once dedup (formerly two identical branches):
    with-replacement sampling re-draws clients, but each client's statistics
    enter the sum exactly once — stats equal the centralized pass and ``n``
    counts every sample once."""
    fed, test = fed_data
    f3 = Fed3RConfig(n_classes=C)
    cfg = _fc(sample_with_replacement=True, n_rounds=60)
    W, stats, hist = run_fed3r(fed, test.features, test.labels, f3, cfg)
    assert hist.clients_seen[-1] == N_CLIENTS  # coupon collector finished
    cen = fed3r.client_stats(jnp.asarray(fed.features), jnp.asarray(fed.labels), C)
    np.testing.assert_allclose(np.asarray(stats.A), np.asarray(cen.A),
                               rtol=1e-4, atol=1e-4)
    assert float(stats.n) == len(fed.labels)


def test_fed3r_beats_fedncm(fed_data):
    fed, test = fed_data
    f3 = Fed3RConfig(n_classes=C)
    W, _, h3 = run_fed3r(fed, test.features, test.labels, f3, _fc())
    _, hn = run_fedncm(fed, test.features, test.labels, _fc())
    assert h3.accuracy[-1] >= hn.accuracy[-1] - 0.02


@pytest.mark.parametrize("algorithm", ["fedavg", "fedavgm", "fedprox", "scaffold"])
def test_gradient_fl_learns(fed_data, algorithm):
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    cfg = _fc(algorithm=algorithm, n_rounds=15,
              server_momentum=0.9 if algorithm == "fedavgm" else 0.0)
    params, hist = run_federated(task, fed, cfg, eval_every=5)
    assert hist.accuracy[-1] > 1.5 / C  # clearly better than chance


@pytest.mark.parametrize("algorithm", ["fedadam", "fedyogi"])
def test_adaptive_server_optimizers_learn(fed_data, algorithm):
    """FedAdam / FedYogi (Reddi et al. 2021) as FT-phase server optimizers."""
    fed, test = fed_data
    task = linear_head_task(D, C, test.features, test.labels)
    cfg = _fc(algorithm=algorithm, n_rounds=15, server_lr=0.01)
    params, hist = run_federated(task, fed, cfg, eval_every=5)
    assert hist.accuracy[-1] > 1.5 / C


def test_ft_feat_keeps_classifier_fixed(fed_data):
    fed, test = fed_data
    f3 = Fed3RConfig(n_classes=C, ft_strategy="feat")
    params, info = run_fed3r_ft(
        fed, test.features, test.labels, f3, _fc(n_rounds=5), strategy="feat",
    )
    # classifier must equal the calibrated FED3R init exactly (frozen)
    hist1 = info["fed3r_history"]
    assert hist1.accuracy[-1] > 0
    W_init_norm = float(jnp.linalg.norm(params["W"]))
    assert W_init_norm > 0  # present
    grid = (3.0, 1.0, 0.3, 0.1, 0.03, 0.01)
    assert min(abs(info["temperature"] - t) for t in grid) < 1e-5


def test_sampler_without_replacement_covers_all():
    s = ClientSampler(17, 5, replacement=False, seed=0)
    seen = set()
    for _ in range(s.rounds_to_full_coverage()):
        seen.update(int(c) for c in s.sample())
    assert len(seen) == 17


def test_sampler_with_replacement_coupon_collector():
    s = ClientSampler(50, 10, replacement=True, seed=0)
    rounds = 0
    while s.coverage < 1.0 and rounds < 500:
        s.sample()
        rounds += 1
    assert rounds > 50 / 10  # strictly more rounds than ⌈K/κ⌉


# ---------------------------------------------------------------------------
# cost meters (paper App. D/E)
# ---------------------------------------------------------------------------


def test_cost_formulas_match_paper_structure():
    cm = costs.CostModel(b=2.22e6, d=1280, C=2028)
    assert cm.comm_per_client("fedavg")["up"] == cm.b + cm.d * cm.C
    assert cm.comm_per_client("scaffold")["up"] == 2 * (cm.b + cm.d * cm.C)
    assert cm.comm_per_client("fedavg-lp")["up"] == cm.d * cm.C
    assert cm.comm_per_client("fed3r")["up"] == cm.d**2 + cm.d * cm.C
    assert cm.comm_per_client("fed3r")["down"] == 0.0
    # computation: FedAvg = 3·E·n_k·F_M (App. E)
    assert cm.comp_per_client("fedavg", 100) == 3 * cm.E * 100 * cm.F_M
    fed3r_comp = cm.comp_per_client("fed3r", 100)
    assert fed3r_comp == 100 * (cm.F_phi + 0.5 * cm.d * (cm.d + 1) + cm.d * cm.C)


def test_fed3r_two_orders_of_magnitude_cheaper():
    """§5.2: at paper scale, FED3R total compute ≪ gradient FL compute."""
    cm = costs.INATURALIST
    # gradient FL: 5000 rounds (paper's iNaturalist budget)
    grad = cm.comp_per_client("fedavg", 13.0) * 5000 * 10 / 9275
    f3 = cm.comp_per_client("fed3r", 13.0)  # each client works exactly once
    assert grad / f3 > 25  # orders-of-magnitude regime


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------


def test_dirichlet_alpha0_single_class_per_client():
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_partition(rng, labels, 20, alpha=0.0)
    for p in parts:
        assert len(np.unique(labels[p])) == 1
    assert sum(len(p) for p in parts) == len(labels)


def test_dirichlet_alpha_large_is_roughly_uniform():
    rng = np.random.default_rng(0)
    labels = np.repeat(np.arange(5), 200)
    parts = dirichlet_partition(rng, labels, 10, alpha=1000.0)
    for p in parts:
        counts = np.bincount(labels[p], minlength=5)
        assert counts.min() > 0  # every class present


def test_quantity_skew_sizes_sum():
    rng = np.random.default_rng(0)
    sizes = quantity_skew_sizes(rng, 1000, 30, sigma=1.5)
    assert sizes.sum() == 1000
    assert sizes.min() >= 1
