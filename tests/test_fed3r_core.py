"""FED3R core: the paper's exact claims, tested exactly.

Section 4.3 properties:
  * immunity to statistical heterogeneity == invariance to the data split;
  * invariance to client sampling order;
  * federated solution == centralized solution;
plus solve correctness against the normal equations and the class-norm step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibration, fed3r, ncm
from repro.data.synthetic import make_feature_dataset

D, C, N = 24, 7, 400


@pytest.fixture(scope="module")
def data():
    ds = make_feature_dataset(jax.random.PRNGKey(0), N, D, C, noise=1.0)
    return np.asarray(ds.features), np.asarray(ds.labels)


def _centralized(feats, labels, lam=0.01):
    stats = fed3r.client_stats(jnp.asarray(feats), jnp.asarray(labels), C)
    return fed3r.solve(stats, lam)


def test_solve_matches_normal_equations(data):
    feats, labels = data
    lam = 0.37
    stats = fed3r.client_stats(jnp.asarray(feats), jnp.asarray(labels), C)
    W = fed3r.solve(stats, lam, normalize=False)
    Z = feats.astype(np.float64)
    Y = np.eye(C)[labels]
    W_np = np.linalg.solve(Z.T @ Z + lam * np.eye(D), Z.T @ Y)
    np.testing.assert_allclose(np.asarray(W), W_np, rtol=2e-4, atol=2e-4)


def test_split_invariance(data):
    """Eq. (5)/(6): any partition of D gives the same A, b, W*."""
    feats, labels = data
    W_cen = _centralized(feats, labels)
    rng = np.random.default_rng(1)
    for trial in range(3):
        order = rng.permutation(N)
        cuts = np.sort(rng.choice(np.arange(1, N), size=5, replace=False))
        parts = np.split(order, cuts)
        stats = [
            fed3r.client_stats(jnp.asarray(feats[p]), jnp.asarray(labels[p]), C)
            for p in parts
        ]
        W_fed = fed3r.solve(fed3r.merge(*stats), 0.01)
        np.testing.assert_allclose(np.asarray(W_fed), np.asarray(W_cen),
                                   rtol=1e-4, atol=1e-4)


def test_sampling_order_invariance(data):
    feats, labels = data
    parts = np.array_split(np.arange(N), 8)
    stats = [
        fed3r.client_stats(jnp.asarray(feats[p]), jnp.asarray(labels[p]), C)
        for p in parts
    ]
    W1 = fed3r.solve(fed3r.merge(*stats), 0.01)
    W2 = fed3r.solve(fed3r.merge(*stats[::-1]), 0.01)
    rng = np.random.default_rng(2)
    shuffled = [stats[i] for i in rng.permutation(len(stats))]
    W3 = fed3r.solve(fed3r.merge(*shuffled), 0.01)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W3), rtol=1e-5, atol=1e-5)


def test_masked_client_stats_exact(data):
    """Padding masks keep the statistics exact (clients-per-shard batching)."""
    feats, labels = data
    z = jnp.asarray(feats[:64])
    y = jnp.asarray(labels[:64])
    full = fed3r.client_stats(z[:40], y[:40], C)
    mask = jnp.arange(64) < 40
    padded = fed3r.client_stats(z, y, C, mask=mask)
    np.testing.assert_allclose(np.asarray(full.A), np.asarray(padded.A), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(full.b), np.asarray(padded.b), rtol=1e-6)
    assert float(padded.n) == 40.0


def test_class_normalization():
    W = jnp.asarray(np.random.default_rng(0).normal(size=(D, C)))
    stats = fed3r.Fed3RStats(
        A=jnp.eye(D), b=W, n=jnp.asarray(1.0)
    )
    Wn = fed3r.solve(stats, 0.0 + 1e-9, normalize=True)
    norms = jnp.linalg.norm(Wn, axis=0)
    np.testing.assert_allclose(np.asarray(norms), np.ones(C), rtol=1e-5)


def test_accuracy_perfect_on_separable():
    ds = make_feature_dataset(jax.random.PRNGKey(3), 500, 16, 5,
                              noise=0.1, class_scale=5.0)
    stats = fed3r.client_stats(ds.features, ds.labels, 5)
    W = fed3r.solve(stats, 0.01)
    assert float(fed3r.accuracy(W, ds.features, ds.labels)) > 0.99


def test_ncm_stats_and_solve(data):
    feats, labels = data
    stats = ncm.client_stats(jnp.asarray(feats), jnp.asarray(labels), C)
    parts = np.array_split(np.arange(N), 5)
    merged = ncm.merge(*[
        ncm.client_stats(jnp.asarray(feats[p]), jnp.asarray(labels[p]), C)
        for p in parts
    ])
    np.testing.assert_allclose(np.asarray(stats.sums), np.asarray(merged.sums),
                               rtol=1e-5, atol=1e-5)
    W = ncm.solve(stats)
    assert W.shape == (D, C)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(W, axis=0)), np.ones(C), rtol=1e-5
    )


def test_temperature_calibration_prefers_sharp():
    """RR scores are small-scale; the best temperature should be < 1."""
    ds = make_feature_dataset(jax.random.PRNGKey(4), 600, 32, 10,
                              noise=0.5, class_scale=4.0)
    stats = fed3r.client_stats(ds.features, ds.labels, 10)
    W = fed3r.solve(stats, 0.01)
    scores = fed3r.predict(W, ds.features)
    temp, ces = calibration.calibrate_temperature(scores, ds.labels)
    assert float(temp) < 1.0
    assert ces.shape[0] == len(calibration.DEFAULT_TEMPERATURES)


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_online_woodbury_matches_batch_well_conditioned():
    """DEPRECATED RLS path: exact on well-conditioned scales (fed3r.py caveat)."""
    ds = make_feature_dataset(jax.random.PRNGKey(5), 200, 12, 4, noise=1.0,
                              class_scale=1.0)
    lam = 1.0
    stats = fed3r.client_stats(ds.features, ds.labels, 4)
    W_batch = fed3r.solve(stats, lam, normalize=False)
    st = fed3r.init_online(12, 4, lam)
    for part in np.array_split(np.arange(200), 4):
        st = fed3r.woodbury_update(st, ds.features[part], ds.labels[part])
    W_onl = fed3r.online_solution(st, normalize=False)
    np.testing.assert_allclose(np.asarray(W_onl), np.asarray(W_batch),
                               rtol=5e-3, atol=5e-3)
