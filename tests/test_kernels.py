"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles.

Per the kernel contract: each kernel is swept over shapes (including
non-tile-aligned ones that exercise padding) and dtypes, asserting allclose
against the pure-jnp oracle.  Kernels run in interpret mode on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fed3r_stats, flash_attention, rff_transform
from repro.kernels import ref


@pytest.mark.parametrize("n,d,C", [(64, 32, 5), (300, 200, 37), (513, 129, 10), (1024, 256, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed3r_stats_kernel(n, d, C, dtype, rng):
    Z = jax.random.normal(rng, (n, d), dtype)
    Y = jax.nn.one_hot(jax.random.randint(rng, (n,), 0, C), C)
    A, b = fed3r_stats(Z, Y)
    Ar, br = ref.fed3r_stats_ref(Z, Y)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(A), np.asarray(Ar), rtol=tol, atol=tol * n)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=tol, atol=tol * n)
    assert A.dtype == jnp.float32  # fp32 accumulation regardless of input


@pytest.mark.parametrize("n,d,D", [(64, 32, 64), (200, 100, 257), (130, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rff_kernel(n, d, D, dtype, rng):
    Z = jax.random.normal(rng, (n, d), dtype)
    om = jax.random.normal(jax.random.fold_in(rng, 1), (d, D), jnp.float32) / 3.0
    be = jax.random.uniform(jax.random.fold_in(rng, 2), (D,), maxval=2 * np.pi)
    R = rff_transform(Z, om, be)
    Rr = ref.rff_ref(Z, om, be)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(R), np.asarray(Rr), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 2, 2, 32),   # MHA
    (2, 256, 4, 2, 64),   # GQA
    (1, 384, 8, 1, 16),   # MQA, 3 tiles
])
@pytest.mark.parametrize("window", [None, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, S, H, KV, hd, window, dtype, rng):
    q = jax.random.normal(rng, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=True, window=window)
    orf = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(orf, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_matches_model_attention(rng):
    """Kernel vs the framework's XLA attention path (same contract)."""
    from repro.models.attention import multihead_attention

    B, S, H, KV, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(rng, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    xla_out = multihead_attention(q, k, v, pos, pos)
    ker_out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(xla_out), np.asarray(ker_out), rtol=2e-4, atol=2e-4
    )


def test_fed3r_stats_kernel_feeds_solver(rng):
    """End-to-end: kernel statistics → ridge solve → same classifier."""
    from repro.core import fed3r as f3

    Z = jax.random.normal(rng, (256, 64))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (256,), 0, 10)
    Y = jax.nn.one_hot(labels, 10)
    A, b = fed3r_stats(Z, Y)
    W_kernel = f3.solve(f3.Fed3RStats(A=A, b=b, n=jnp.asarray(256.0)), 0.01)
    W_ref = f3.solve(f3.client_stats(Z, labels, 10), 0.01)
    np.testing.assert_allclose(np.asarray(W_kernel), np.asarray(W_ref),
                               rtol=1e-3, atol=1e-3)
