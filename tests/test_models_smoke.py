"""Per-architecture smoke tests: reduced variant, forward + one train step.

Deliverable (f): every assigned architecture instantiates (≤2-3 layers,
d_model ≤ 512, ≤4 experts), runs a forward and a train step on CPU, and
produces finite outputs of the right shape.
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.steps import make_train_step
from repro.models import build_model

SMOKE = [a + "-smoke" for a in ASSIGNED_ARCHS] + ["fed3r-mnv2-proxy-smoke"]


@pytest.mark.parametrize("name", SMOKE)
def test_forward_and_train_step(name, rng):
    cfg = get_config(name)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = make_batch(cfg, rng, B, S)

    # forward / loss
    loss = model.loss(params, batch)
    assert jnp.isfinite(loss), name
    assert loss.shape == ()

    # features (the FED3R φ)
    feats = model.extract_features(params, batch)
    assert feats.shape == (B, cfg.d_feat)
    assert bool(jnp.all(jnp.isfinite(feats)))

    # one SGD train step moves the loss
    step = jax.jit(make_train_step(cfg, lr=0.05))
    params2, loss1 = step(params, batch)
    _, loss2 = step(params2, batch)
    assert jnp.isfinite(loss2)
    assert float(loss2) < float(loss1) + 0.5  # no blow-up


@pytest.mark.parametrize("name", ["qwen2-7b-smoke", "deepseek-moe-16b-smoke"])
def test_microbatched_train_step_matches_plain(name, rng):
    """Gradient accumulation is mathematically the same step (bf16 tol)."""
    cfg = get_config(name).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, 4, 16)
    s1 = jax.jit(make_train_step(cfg, lr=0.1, num_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, lr=0.1, num_microbatches=4))
    p1, l1 = s1(params, batch)
    p4, l4 = s4(params, batch)
    # MoE routing depends on batch composition; dense archs should be close
    if cfg.arch_type != "moe":
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
        assert max(jax.tree.leaves(d)) < 5e-2


@pytest.mark.parametrize("name", ["qwen2-7b-smoke"])
def test_freeze_mask(name, rng):
    cfg = get_config(name)
    model = build_model(cfg)
    params = model.init(rng)
    batch = make_batch(cfg, rng, 2, 16)
    freeze = jax.tree.map(lambda _: 0.0, params)  # everything frozen
    step = jax.jit(make_train_step(cfg, lr=0.5, freeze=freeze))
    p2, _ = step(params, batch)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params, p2)
    assert all(jax.tree.leaves(same))
