"""Sharding-rule unit tests (no devices needed — abstract trees only).

Verifies, for EVERY assigned architecture, that param/batch/cache specs:
  * always produce evenly-divisible shardings (the jit input contract);
  * shard the big tables (embeddings, experts, FFN) rather than replicate;
  * follow the documented fallback chains for indivisible head counts.
"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.shapes import abstract_params, input_specs, variant_for
from repro.configs.base import INPUT_SHAPES
from repro.sharding.specs import batch_specs, cache_specs, param_specs

AX = {"model": 16, "data": 16, "pod": 2}


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        s = 1
        for a in entry:
            s *= AX[a]
        return s
    return AX[entry]


def _check_divisible(tree, specs):
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            assert dim % _axis_size(entry) == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, AX, fsdp=fsdp)
    _check_divisible(params, specs)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_embedding_is_sharded_not_replicated(arch):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, AX)
    emb_spec = specs["embed"]["embedding"]
    assert tuple(emb_spec) != (), f"{arch}: embedding replicated"


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "llama4-scout-17b-a16e"])
def test_moe_experts_expert_parallel(arch):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, AX)
    wg = specs["layers"]["moe"]["w_gate"]
    assert tuple(wg)[1] == "model", "experts must shard on the E axis"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_batch_and_cache_specs_divisible(arch, shape_name):
    cfg = variant_for(get_config(arch), INPUT_SHAPES[shape_name])
    if cfg is None:
        pytest.skip("documented long_500k skip")
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    da = ("data",)
    if "batch" in specs:
        _check_divisible(specs["batch"], batch_specs(cfg, specs["batch"], da, AX))
    if "cache" in specs:
        _check_divisible(specs["cache"], cache_specs(cfg, specs["cache"], da, AX))


def test_qwen2_head_fallback_row_parallel():
    """28 heads don't divide 16 → wq falls back to sharding d_model."""
    cfg = get_config("qwen2-7b")
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, AX)
    wq = tuple(specs["layers"]["attn"]["wq"])  # (L, d, H, hd)
    assert wq[2] != "model" and wq[1] == "model"


def test_command_r_heads_shard_on_model():
    """96 q-heads divide 16 → primary head sharding is used."""
    cfg = get_config("command-r-plus-104b")
    params = abstract_params(cfg)
    specs = param_specs(cfg, params, AX)
    wq = tuple(specs["layers"]["attn"]["wq"])
    assert wq[2] == "model"
