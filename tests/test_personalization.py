"""Personalization engine: parity, α=0 bitwise, invariance, serving, secure-agg.

The engine's contract (federated/personalization.py):
  * K personalized heads solve in ONE jitted dispatch, matching the
    per-client re-solve loop (K+1 dispatches) to fp32 tolerance at the
    same α_k;
  * an α of exactly 0 reproduces the global ``factored_solution``
    BITWISE — engine, core API, and padded cohort slots alike;
  * the packed cohort (and hence the batched head solve) is BIT-identical
    under permutation of the request order (canonical packing);
  * the grid-over-heads Pallas kernel matches its pure-jnp oracle;
  * α selection happens inside the dispatch via the held-out ridge score;
  * secure aggregation composes: masked per-client uploads still sum to
    the unmasked cohort statistics, so the global base state — and every
    head derived from it — is unchanged;
  * the serving layer's LRU head cache evicts by recency, dirty-marks on
    stream advance, and answers per-tenant vs global by data availability.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.data.pipeline import pack_personal_cohort
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
    ReferencePersonalizedLoop,
    cohort_stats,
)
from repro.federated.secure_agg import mask_statistics, secure_aggregate
from repro.kernels import batched_chol_gram
from repro.kernels.ref import batched_chol_gram_ref
from repro.launch.serve_heads import HeadCache

D, C, LAM = 24, 6, 1e-2


def _make_clients(seed, K, lo=20, hi=60, d=D, n_classes=C):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(K):
        n = int(rng.integers(lo, hi))
        out.append((
            rng.normal(size=(n, d)).astype(np.float32),
            rng.integers(0, n_classes, size=n).astype(np.int32),
        ))
    return out


def _state_from(packed, lam=LAM):
    stats = cohort_stats(packed, C)
    L = jnp.linalg.cholesky(stats.A + lam * jnp.eye(D, dtype=jnp.float32))
    return fed3r.Fed3RFactored(L=L, b=stats.b)


def _cfg(**kw):
    base = dict(n_classes=C, alpha_grid=(0.0, 0.5, 1.0, 2.0))
    base.update(kw)
    return PersonalizeConfig(**base)


# ---------------------------------------------------------------------------
# packer
# ---------------------------------------------------------------------------


def test_personal_cohort_packer_shapes_masks_holdout():
    clients = _make_clients(0, 5)
    p = pack_personal_cohort(clients, cohort_size=8, holdout_frac=0.25)
    sizes = [len(y) for _, y in clients]
    assert p.cohort == 8
    assert p.n_clients == 5
    assert p.n_samples == sum(sizes)
    assert p.inputs.shape[1] % 8 == 0 and p.inputs.shape[1] >= max(sizes)
    # empty slots: -1 ids, all-zero masks
    assert (p.client_ids == -1).sum() == 3
    assert p.mask[p.client_ids == -1].sum() == 0.0
    # holdout ⊆ mask, roughly the requested fraction, never sample 0
    assert np.all(p.holdout <= p.mask)
    assert p.holdout[:, 0].sum() == 0.0
    for k in range(5):
        n_k = sizes[k] if p.client_ids[k] == k else int(p.mask[k].sum())
        got = int(p.holdout[k].sum())
        assert got == len(np.arange(3, n_k, 4))


def test_personal_cohort_packer_validates():
    clients = _make_clients(1, 3)
    with pytest.raises(ValueError):
        pack_personal_cohort(clients, cohort_size=2)
    with pytest.raises(ValueError):
        pack_personal_cohort(clients, holdout_frac=1.0)
    with pytest.raises(ValueError):
        pack_personal_cohort(clients, max_n=2)
    with pytest.raises(ValueError):
        pack_personal_cohort([])


def test_personal_cohort_packer_canonical_order():
    clients = _make_clients(2, 6)
    ids = list(range(6))
    p1 = pack_personal_cohort(clients, client_ids=ids)
    perm = [3, 0, 5, 1, 4, 2]
    p2 = pack_personal_cohort(
        [clients[i] for i in perm], client_ids=[ids[i] for i in perm]
    )
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)


def test_tiny_client_keeps_a_train_sample():
    clients = [(np.ones((1, D), np.float32), np.zeros((1,), np.int32))]
    p = pack_personal_cohort(clients, holdout_frac=0.5)
    assert p.holdout.sum() == 0.0  # n_k < 2: never hold out the only sample
    # n_k >= 2 but below the stride still holds out exactly ONE sample
    # (its last), so small tenants are swept rather than pinned to grid[0]
    clients = [(np.ones((3, D), np.float32), np.zeros((3,), np.int32))]
    p = pack_personal_cohort(clients, holdout_frac=0.25)  # stride 4 > 3
    assert p.holdout[0].sum() == 1.0
    assert p.holdout[0, 2] == 1.0 and p.holdout[0, 0] == 0.0


# ---------------------------------------------------------------------------
# batched grid-over-heads kernel (Pallas, interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n,d,C_", [(3, 30, 16, 3), (2, 129, 65, 7), (4, 7, 24, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_chol_gram_kernel_matches_oracle(K, n, d, C_, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    A = jax.random.normal(k1, (d, d), jnp.float32)
    L = jnp.linalg.cholesky(A @ A.T + jnp.eye(d))
    Z = jax.random.normal(k2, (K, n, d), dtype)
    Y = jax.nn.one_hot(jax.random.randint(k3, (K, n), 0, C_), C_, dtype=dtype)
    G, B = batched_chol_gram(L, Z, Y)
    Gr, Br = batched_chol_gram_ref(L, Z, Y)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr), rtol=tol, atol=tol * n)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Br), rtol=tol, atol=tol * n)
    assert G.shape == (K, d, d) and B.shape == (K, d, C_)
    assert G.dtype == jnp.float32


def test_batched_chol_gram_kernel_handles_empty_cohort_batch():
    L = jnp.linalg.cholesky(2.0 * jnp.eye(16))
    G, B = batched_chol_gram(L, jnp.zeros((3, 0, 16)), jnp.zeros((3, 0, 4)))
    np.testing.assert_allclose(
        np.asarray(G), np.broadcast_to(2.0 * np.eye(16), (3, 16, 16)), atol=1e-6
    )
    assert not np.asarray(B).any()


def test_engine_kernel_path_matches_xla_path():
    packed = pack_personal_cohort(_make_clients(3, 6))
    state = _state_from(packed)
    xla = PersonalizationEngine(_cfg(use_kernel=False))
    ker = PersonalizationEngine(_cfg(use_kernel=True))
    h1 = xla.solve_heads(state, packed)
    h2 = ker.solve_heads(state, packed)
    np.testing.assert_array_equal(np.asarray(h1.alpha), np.asarray(h2.alpha))
    np.testing.assert_allclose(np.asarray(h1.W), np.asarray(h2.W),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# α = 0 ⇒ the global factored_solution, bitwise
# ---------------------------------------------------------------------------


def test_alpha_zero_engine_is_factored_solution_bitwise():
    packed = pack_personal_cohort(_make_clients(4, 5), cohort_size=8)
    state = _state_from(packed)
    eng = PersonalizationEngine(_cfg(alpha_grid=(0.0,)))
    heads = eng.solve_heads(state, packed)
    W_g = np.asarray(fed3r.factored_solution(state))
    assert eng.dispatches == 1
    # every head — real AND padded slots — is exactly the global solve
    for k in range(packed.cohort):
        np.testing.assert_array_equal(np.asarray(heads.W[k]), W_g)


def test_alpha_zero_core_api_is_factored_solution_bitwise():
    packed = pack_personal_cohort(_make_clients(5, 3))
    state = _state_from(packed)
    cs = fed3r.client_stats(
        jnp.asarray(packed.inputs[1]), jnp.asarray(packed.labels[1]), C,
        jnp.asarray(packed.mask[1]),
    )
    W0 = fed3r.personalized_solution(state, cs, 0.0)
    np.testing.assert_array_equal(
        np.asarray(W0), np.asarray(fed3r.factored_solution(state))
    )
    # and with α > 0 it visibly moves off the global head
    W1 = fed3r.personalized_solution(state, cs, 4.0)
    assert float(jnp.max(jnp.abs(W1 - W0))) > 1e-4


def test_alpha_zero_rows_of_mixed_cohort_are_bitwise_global():
    packed = pack_personal_cohort(_make_clients(6, 6))
    state = _state_from(packed)
    eng = PersonalizationEngine(_cfg())
    alphas = jnp.asarray([0.0, 2.0, 0.0, 1.0, 0.0, 0.5])
    heads = eng.solve_at(state, packed, alphas)
    W_g = np.asarray(fed3r.factored_solution(state))
    for k, a in enumerate(np.asarray(alphas)):
        if a == 0.0:
            np.testing.assert_array_equal(np.asarray(heads.W[k]), W_g)
        else:
            assert float(np.max(np.abs(np.asarray(heads.W[k]) - W_g))) > 1e-5


def test_batched_personalized_solution_matches_per_client():
    packed = pack_personal_cohort(_make_clients(7, 4))
    state = _state_from(packed)
    A_k, b_k = [], []
    for k in range(4):
        cs = fed3r.client_stats(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(packed.mask[k]),
        )
        A_k.append(cs.A)
        b_k.append(cs.b)
    alphas = jnp.asarray([0.0, 1.0, 2.0, 0.5])
    W = fed3r.batched_personalized_solution(
        state, jnp.stack(A_k), jnp.stack(b_k), alphas
    )
    for k in range(4):
        cs = fed3r.Fed3RStats(A=A_k[k], b=b_k[k], n=jnp.zeros(()))
        np.testing.assert_allclose(
            np.asarray(W[k]),
            np.asarray(fed3r.personalized_solution(state, cs, alphas[k])),
            rtol=1e-5, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# engine vs per-client reference loop (dispatch shape + parity)
# ---------------------------------------------------------------------------


def test_engine_matches_reference_loop_at_same_alphas():
    packed = pack_personal_cohort(_make_clients(8, 8, lo=30, hi=70))
    state = _state_from(packed)
    cfg = _cfg()
    eng = PersonalizationEngine(cfg)
    heads = eng.solve_heads(state, packed)
    ref = ReferencePersonalizedLoop(cfg)
    _, W_ref = ref.solve_at(state, packed, np.asarray(heads.alpha))
    assert eng.dispatches == 1
    assert ref.dispatches == packed.cohort + 1  # K re-solves + the global head
    err = float(jnp.max(jnp.abs(heads.W - W_ref)))
    assert err <= 1e-5, f"engine drifted from per-client re-solves: {err:.2e}"


def test_cohort_permutation_bit_invariance_of_batched_solve():
    clients = _make_clients(9, 7)
    ids = list(range(7))
    perm = [4, 1, 6, 0, 2, 5, 3]
    state = _state_from(pack_personal_cohort(clients, client_ids=ids))
    eng = PersonalizationEngine(_cfg())
    h1 = eng.solve_heads(state, pack_personal_cohort(clients, client_ids=ids))
    h2 = eng.solve_heads(state, pack_personal_cohort(
        [clients[i] for i in perm], client_ids=[ids[i] for i in perm]
    ))
    np.testing.assert_array_equal(np.asarray(h1.client_ids), np.asarray(h2.client_ids))
    np.testing.assert_array_equal(np.asarray(h1.alpha), np.asarray(h2.alpha))
    np.testing.assert_array_equal(np.asarray(h1.W), np.asarray(h2.W))


def test_alpha_selection_minimizes_heldout_error():
    """The default sweep must pick the grid argmin of the held-out 0/1
    error of the SERVED (normalized) candidate head — verified against a
    by-hand sweep outside the engine."""
    clients = _make_clients(10, 5, lo=40, hi=80)
    packed = pack_personal_cohort(clients, holdout_frac=0.25)
    state = _state_from(packed)
    grid = (0.0, 0.5, 1.0, 2.0, 4.0)
    eng = PersonalizationEngine(_cfg(alpha_grid=grid, selection="error"))
    heads = eng.solve_heads(state, packed)
    for k in range(packed.cohort):
        m = packed.mask[k]
        ho = packed.holdout[k]
        tr = m * (1.0 - ho)
        z_tr, y_tr, _ = fed3r.masked_design(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(tr),
        )
        z_ho, _, _ = fed3r.masked_design(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(ho),
        )
        errs = []
        for a in grid:
            G = state.L @ state.L.T + a * (z_tr.T @ z_tr)
            W = jax.scipy.linalg.cho_solve(
                (jnp.linalg.cholesky(G), True), state.b + a * (z_tr.T @ y_tr)
            )
            W = W / jnp.maximum(jnp.linalg.norm(W, axis=0, keepdims=True), 1e-12)
            pick = jnp.argmax(z_ho @ W, axis=-1)
            errs.append(float(jnp.sum(
                jnp.asarray(ho) * (pick != jnp.asarray(packed.labels[k]))
            )))
        assert float(heads.alpha[k]) == grid[int(np.argmin(errs))]
        assert float(heads.score[k]) == pytest.approx(min(errs))


def test_alpha_selection_minimizes_heldout_sse():
    """selection="sse" picks the grid argmin of the raw held-out ridge
    residual — verified against a by-hand sweep outside the engine."""
    clients = _make_clients(10, 5, lo=40, hi=80)
    packed = pack_personal_cohort(clients, holdout_frac=0.25)
    state = _state_from(packed)
    grid = (0.0, 0.5, 1.0, 2.0, 4.0)
    eng = PersonalizationEngine(_cfg(alpha_grid=grid, selection="sse"))
    heads = eng.solve_heads(state, packed)
    for k in range(packed.cohort):
        m = packed.mask[k]
        ho = packed.holdout[k]
        tr = m * (1.0 - ho)
        z_tr, y_tr, _ = fed3r.masked_design(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(tr),
        )
        z_ho, y_ho, _ = fed3r.masked_design(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(ho),
        )
        scores = []
        for a in grid:
            G = state.L @ state.L.T + a * (z_tr.T @ z_tr)
            W = jax.scipy.linalg.cho_solve(
                (jnp.linalg.cholesky(G), True), state.b + a * (z_tr.T @ y_tr)
            )
            scores.append(float(jnp.sum((z_ho @ W - y_ho) ** 2)))
        assert float(heads.alpha[k]) == grid[int(np.argmin(scores))]
        assert float(heads.score[k]) == pytest.approx(min(scores), rel=1e-4)


def test_config_validation():
    with pytest.raises(ValueError):
        PersonalizeConfig(n_classes=C, alpha_grid=())
    with pytest.raises(ValueError):
        PersonalizeConfig(n_classes=C, alpha_grid=(0.0, -1.0))
    with pytest.raises(ValueError):
        PersonalizeConfig(n_classes=C, selection="accuracy")


def test_personalization_recovers_tenant_concept_drift():
    """Tenants whose label concepts DISAGREE with the federation (per-tenant
    label swaps — user-specific tastes) must get large-α personalized heads
    that beat the global average-of-concepts head on their own data, while
    aligned tenants may keep α = 0 (the bitwise global head)."""
    from repro.data.pipeline import make_federated_features

    fed, _ = make_federated_features(
        seed=11, n=4000, d=D, n_classes=C, n_clients=10, alpha=0.3, noise=2.0
    )
    clients, eval_xy = [], []
    for k in range(fed.n_clients):
        cd = fed.client(k)
        labels = np.asarray(cd.labels)
        if k % 2 == 1:  # every other tenant swaps two class labels
            rng = np.random.default_rng((11, k))
            i, j = rng.choice(C, size=2, replace=False)
            perm = np.arange(C)
            perm[[i, j]] = perm[[j, i]]
            labels = perm[labels]
        half = max(cd.n // 2, 1)
        clients.append((cd.features[:half], labels[:half]))
        eval_xy.append((cd.features[half:], labels[half:]))
    packed = pack_personal_cohort(clients, client_ids=list(range(fed.n_clients)))
    stats = cohort_stats(packed, C)
    L = jnp.linalg.cholesky(stats.A + LAM * jnp.eye(D, dtype=jnp.float32))
    state = fed3r.Fed3RFactored(L=L, b=stats.b)
    eng = PersonalizationEngine(_cfg(alpha_grid=(0.0, 1.0, 4.0, 16.0, 64.0)))
    heads = eng.solve_heads(state, packed)
    W_g = fed3r.factored_solution(state)
    acc_p, acc_g = [], []
    for k, (x, y) in enumerate(eval_xy):
        if len(y) == 0:
            continue
        x, y = jnp.asarray(x), jnp.asarray(np.asarray(y))
        acc_p.append(float(fed3r.accuracy(heads.W[k], x, y)))
        acc_g.append(float(fed3r.accuracy(W_g, x, y)))
    assert np.mean(acc_p) > np.mean(acc_g) + 0.05


# ---------------------------------------------------------------------------
# secure aggregation interop: masked per-client uploads, unmasked cohort sum
# ---------------------------------------------------------------------------


def test_masked_client_stats_sum_to_unmasked_cohort():
    clients = _make_clients(12, 5)
    packed = pack_personal_cohort(clients, client_ids=list(range(5)))
    per_client = [
        fed3r.client_stats(
            jnp.asarray(packed.inputs[k]), jnp.asarray(packed.labels[k]), C,
            jnp.asarray(packed.mask[k]),
        )
        for k in range(5)
    ]
    cohort = list(range(5))
    masked = [
        mask_statistics(s, k, cohort, seed=7) for k, s in enumerate(per_client)
    ]
    # individual uploads are actually masked...
    assert float(jnp.max(jnp.abs(masked[0].A - per_client[0].A))) > 1.0
    # ...but the server's sum is the exact unmasked cohort statistics
    agg = secure_aggregate(masked)
    plain = cohort_stats(packed, C)
    np.testing.assert_allclose(np.asarray(agg.A), np.asarray(plain.A),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(agg.b), np.asarray(plain.b),
                               rtol=1e-5, atol=1e-3)
    # and the personalized heads built on the secure-agg base agree
    lam_eye = LAM * jnp.eye(D, dtype=jnp.float32)
    st_plain = fed3r.Fed3RFactored(
        L=jnp.linalg.cholesky(plain.A + lam_eye), b=plain.b
    )
    st_agg = fed3r.Fed3RFactored(
        L=jnp.linalg.cholesky(agg.A + lam_eye), b=agg.b
    )
    eng = PersonalizationEngine(_cfg())
    alphas = jnp.ones((packed.cohort,))
    h1 = eng.solve_at(st_plain, packed, alphas)
    h2 = eng.solve_at(st_agg, packed, alphas)
    np.testing.assert_allclose(np.asarray(h1.W), np.asarray(h2.W),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# serving layer: LRU head cache + per-tenant vs global query modes
# ---------------------------------------------------------------------------


def test_head_cache_lru_eviction_and_counters():
    cache = HeadCache(capacity=2)
    W = jnp.zeros((D, C))
    assert cache.get(1) is None  # miss
    cache.put(1, W)
    cache.put(2, W)
    assert cache.get(1) is not None  # hit refreshes recency of 1
    cache.put(3, W)  # evicts 2 (LRU), not 1
    assert cache.get(2) is None
    assert cache.get(1) is not None and cache.get(3) is not None
    assert cache.lru_evictions == 1
    assert cache.hits == 3 and cache.misses == 2


def test_head_cache_dirty_marking_on_stream_advance():
    cache = HeadCache(capacity=4)
    cache.put(1, jnp.zeros((D, C)))
    assert cache.get(1) is not None
    cache.advance()  # the global state moved: every cached head is stale
    assert cache.get(1) is None
    assert cache.stale_evictions == 1
    cache.put(1, jnp.ones((D, C)))  # re-solved against the new version
    assert cache.get(1) is not None


def test_head_server_batched_query_modes_and_single_dispatch():
    from repro.data.pipeline import make_federated_features
    from repro.federated.streaming_engine import StreamConfig, StreamingEngine
    from repro.federated.arrivals import pack_schedule, poisson_schedule
    from repro.launch.serve_heads import HeadServer

    fed, _ = make_federated_features(
        seed=13, n=900, d=D, n_classes=C, n_clients=8, alpha=0.3, noise=2.0
    )
    server = HeadServer(
        StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM)),
        PersonalizationEngine(_cfg()),
        fed,
        cache_capacity=4,
        cohort_round_to=4,
    )
    server.init(D)
    packed = pack_schedule(fed, poisson_schedule(fed.n_clients, 4, 3.0, seed=0))
    server.absorb(packed)
    assert server.cache.version == 1

    # burst: 3 known tenants (one repeated) + 1 unknown tenant id
    cids = [0, 3, 0, 999]
    xs = np.stack([fed.client(0).features[0], fed.client(3).features[0],
                   fed.client(0).features[1], fed.client(3).features[1]])
    scores, rep = server.query(cids, xs)
    assert scores.shape == (4, C)
    assert rep["modes"] == ["per-tenant", "per-tenant", "per-tenant", "global"]
    assert rep["solved_now"] == 2  # tenants {0, 3}, ONE batched dispatch
    assert server.pers.dispatches == 1
    # second burst on the same tenants: pure cache hits, no new dispatch
    _, rep2 = server.query(cids, xs)
    assert rep2["solved_now"] == 0
    assert server.pers.dispatches == 1
    # the stream advances ⇒ cached heads dirty ⇒ the next burst re-solves
    server.absorb(packed)
    _, rep3 = server.query(cids, xs)
    assert rep3["solved_now"] == 2
    assert server.pers.dispatches == 2
    assert server.cache.stale_evictions >= 2
