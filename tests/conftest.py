import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU.
# The multi-pod dry-run sets its own flags as a separate process.


def make_batch(cfg, rng, B=2, S=16, with_labels=True):
    """Batch dict matching the model contract for any arch family."""
    r1, r2 = jax.random.split(rng)
    batch = {"tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(r2, (B, S), 0, cfg.vocab_size)
    if cfg.arch_type == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            r1, (B, cfg.n_patches, cfg.d_model)
        )
    if cfg.arch_type == "audio":
        batch["audio_frames"] = 0.1 * jax.random.normal(
            r1, (B, cfg.n_audio_frames, cfg.d_model)
        )
    return batch


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
