"""Prefill + decode must reproduce the full forward, per architecture family.

Covers the KV ring buffer, Mamba2 state recurrence, RG-LRU state, whisper
self+cross caches, VLM M-RoPE positions, and sliding-window semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_config
from repro.models import build_model
from repro.models.model import forward

FAMS = [
    "qwen2-7b-smoke",           # dense GQA
    "command-r-plus-104b-smoke",  # parallel-block dense
    "deepseek-moe-16b-smoke",   # MoE (no-drop capacity for exactness)
    "mamba2-1.3b-smoke",        # SSM
    "recurrentgemma-9b-smoke",  # hybrid
    "qwen2-vl-2b-smoke",        # VLM
    "whisper-large-v3-smoke",   # enc-dec
]


@pytest.mark.parametrize("name", FAMS)
def test_prefill_decode_matches_full_forward(name, rng):
    extra = {"capacity_factor": 8.0} if "moe" in name else {}
    cfg = get_config(name).replace(dtype="float32", **extra)
    model = build_model(cfg)
    params = model.init(rng)
    B, S, T = 2, 16, 4
    toks = jax.random.randint(rng, (B, S + T), 0, cfg.vocab_size)
    batch = make_batch(cfg, rng, B, S, with_labels=False)
    batch["tokens"] = toks[:, :S]
    off = cfg.n_patches if cfg.arch_type == "vlm" else 0

    logits_pre, cache = model.prefill(params, batch, cache_capacity=off + S + T)
    dec = []
    for i in range(T):
        lg, cache = model.decode_step(
            params, cache, toks[:, S + i : S + i + 1], jnp.int32(off + S + i)
        )
        dec.append(lg)

    fb = dict(batch)
    fb["tokens"] = toks
    ref = forward(cfg, params, fb, mode="train").logits
    if cfg.arch_type == "vlm":
        ref = ref[:, cfg.n_patches :, :]

    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(ref[:, S - 1, :]), rtol=2e-4, atol=2e-4
    )
    for i in range(T):
        np.testing.assert_allclose(
            np.asarray(dec[i]), np.asarray(ref[:, S + i, :]), rtol=2e-4, atol=2e-4
        )


def test_sliding_window_decode_matches_windowed_forward(rng):
    """Ring-buffer decode with capacity=window == full windowed attention."""
    cfg = get_config("qwen2-7b-smoke").replace(dtype="float32", sliding_window=8)
    model = build_model(cfg)
    params = model.init(rng)
    B, S, T = 2, 24, 6
    toks = jax.random.randint(rng, (B, S + T), 0, cfg.vocab_size)

    logits_pre, cache = model.prefill(
        params, {"tokens": toks[:, :S]}, cache_capacity=S + T
    )
    # capacity is clamped to the window inside forward/make_cache
    dec = []
    for i in range(T):
        lg, cache = model.decode_step(
            params, cache, toks[:, S + i : S + i + 1], jnp.int32(S + i)
        )
        dec.append(lg)

    ref = forward(cfg, params, {"tokens": toks}, mode="train").logits
    for i in range(T):
        np.testing.assert_allclose(
            np.asarray(dec[i]), np.asarray(ref[:, S + i, :]), rtol=2e-4, atol=2e-4
        )
