"""Asynchronous round engine: chaos parity, staleness, dropout, secure agg.

The engine's contract (federated/async_engine.py):
  * merge-on-arrival is bitwise-equivalent to the synchronous barrier for
    exact-once delivery, under every chaos fault type (drop-with-
    retransmit, duplication, reordering, transient delay) — statistics
    sums are order-invariant (paper §4.3) and the slot/retire design
    keeps the fp32 operand sequence identical;
  * uploads landing after the staleness window retire are rejected
    ("stale"), duplicates are deduped without re-folding;
  * ClientHealth demotes persistent stragglers after ``demote_after``
    blown deadlines and re-admits them after ``cooldown`` rounds;
  * secure mode: masked mod-2³² integer slots with orphan-mask recovery —
    the retired W with 1..K-1 dropped clients is BITWISE the W of a
    survivor-only cohort with unmasked payloads (same shared scales);
  * the retire fold is the same algebra as the streaming engine's
    ``absorb_stats`` round-granular entry.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.federated import secure_agg
from repro.federated.arrivals import (
    ChaosSpec,
    UploadEvent,
    chaos_timeline,
    latency_profile,
    timeline_from_json,
    timeline_to_json,
)
from repro.federated.async_engine import (
    AsyncConfig,
    AsyncRoundEngine,
    ClientHealth,
    run_adaptive_rounds,
    run_chaos_timeline,
)
from repro.federated.compress import WireFormat, cohort_quantize_int8
from repro.federated.costs import CostModel
from repro.federated.dist import shard_cohort
from repro.federated.streaming_engine import StreamConfig, StreamingEngine

D, C = 16, 4
N_CLIENTS = 10
COHORT = 4
LAMBDA = 1e-2


def _payloads(seed=0, n_clients=N_CLIENTS, d=D, lo=20, hi=40):
    rng = np.random.default_rng(seed)
    out = {}
    for k in range(n_clients):
        n = int(rng.integers(lo, hi))
        x = rng.normal(size=(n, d)).astype(np.float32)
        y = rng.integers(0, C, size=n).astype(np.int32)
        out[k] = fed3r.client_stats(jnp.asarray(x), jnp.asarray(y), C)
    return out


def _cohorts(n_rounds, seed=0, n_clients=N_CLIENTS, k=COHORT):
    return [
        sorted(
            np.random.default_rng((seed, r))
            .choice(n_clients, size=k, replace=False)
            .tolist()
        )
        for r in range(n_rounds)
    ]


def _engine(synchronous=False, **kw):
    kw.setdefault("staleness_rounds", 3)
    kw.setdefault("early_close", False)
    kw.setdefault("demote_after", 10_000)
    return AsyncRoundEngine(AsyncConfig(
        n_classes=C, ridge_lambda=LAMBDA, cohort=COHORT,
        deadline=1.0, synchronous=synchronous, **kw,
    ))


FAULTS = {
    "drop": ChaosSpec(drop=0.5, rto=0.1, max_attempts=6, seed=3),
    "duplicate": ChaosSpec(duplicate=0.6, seed=3),
    "reorder": ChaosSpec(reorder=0.9, rto=0.2, seed=3),
    "delay": ChaosSpec(delay=0.5, delay_factor=2.0, seed=3),
    "all": ChaosSpec(drop=0.3, duplicate=0.3, reorder=0.5, delay=0.2,
                     delay_factor=2.0, rto=0.1, max_attempts=6, seed=3),
}


@pytest.mark.parametrize("fault", sorted(FAULTS))
def test_chaos_parity_bitwise_per_fault_type(fault):
    payloads = _payloads()
    cohorts = _cohorts(5)
    latency = latency_profile(N_CLIENTS, 0.2, straggler_factor=3.0,
                              base=0.3, jitter=0.5, seed=1)
    events = chaos_timeline(cohorts, latency, FAULTS[fault])

    def pf(c, r):
        return payloads[c]

    ea = _engine(synchronous=False)
    sa, ra = run_chaos_timeline(ea, ea.init(D), cohorts, events, pf)
    es = _engine(synchronous=True)
    ss, _ = run_chaos_timeline(es, es.init(D), cohorts, events, pf)

    assert ra["dropped_uploads"] == 0, "chaos tail escaped the staleness window"
    np.testing.assert_array_equal(np.asarray(sa.W), np.asarray(ss.W))
    np.testing.assert_array_equal(np.asarray(sa.L), np.asarray(ss.L))
    if fault in ("duplicate", "all"):
        assert ra["duplicates"] > 0  # dedup actually exercised


def test_stale_upload_rejected_and_never_folds():
    payloads = _payloads()
    eng = _engine(staleness_rounds=0)
    state = eng.init(D)
    eng.begin_round(0, [0, 1], 0.0)
    state, s = eng.deliver(state, UploadEvent(0.1, 0, 0, 0), payloads[0])
    assert s == "folded"
    state = eng.close_round(state, 0, now=1.0)  # staleness 0: retires at once
    W_before = np.asarray(state.W)
    state, s = eng.deliver(state, UploadEvent(1.5, 0, 1, 0), payloads[1])
    assert s == "stale"
    assert eng.stale_rejected == 1
    np.testing.assert_array_equal(np.asarray(state.W), W_before)


def test_duplicate_deduped_state_unchanged():
    payloads = _payloads()
    eng = _engine()
    state = eng.init(D)
    eng.begin_round(0, [0, 1], 0.0)
    state, _ = eng.deliver(state, UploadEvent(0.1, 0, 0, 0), payloads[0])
    snap = np.asarray(state.A_slots)
    state, s = eng.deliver(state, UploadEvent(0.2, 0, 0, 1), payloads[0])
    assert s == "duplicate"
    assert eng.duplicates == 1
    np.testing.assert_array_equal(np.asarray(state.A_slots), snap)


def test_late_fold_inside_staleness_window_counts():
    payloads = _payloads()
    eng = _engine(staleness_rounds=2)
    state = eng.init(D)
    eng.begin_round(0, [0, 1], 0.0)
    state, _ = eng.deliver(state, UploadEvent(0.1, 0, 0, 0), payloads[0])
    state = eng.close_round(state, 0, now=1.0)
    state, s = eng.deliver(state, UploadEvent(1.5, 0, 1, 0), payloads[1])
    assert s == "late"
    assert eng.late_folds == 1
    state = eng.drain(state)
    # both uploads made it into the retired sums
    assert float(state.n) == pytest.approx(
        float(payloads[0].n) + float(payloads[1].n)
    )


def test_client_health_demotes_and_readmits():
    h = ClientHealth(demote_after=2, cooldown=3)
    h.missed(7, 0)
    assert h.is_eligible(7, 1)
    h.missed(7, 1)
    assert 7 in h.demoted
    assert not h.is_eligible(7, 2)
    assert not h.is_eligible(7, 3)
    assert h.is_eligible(7, 4)  # cooldown elapsed: probation
    h.on_time(7)
    assert 7 not in h.demoted
    assert h.is_eligible(7, 5)


def test_adaptive_rounds_demote_persistent_straggler():
    payloads = _payloads()
    latency = latency_profile(N_CLIENTS, 0.0, base=0.2, jitter=0.2, seed=2)
    latency[3] = 50.0  # client 3 never makes any deadline
    eng = AsyncRoundEngine(AsyncConfig(
        n_classes=C, ridge_lambda=LAMBDA, cohort=N_CLIENTS,
        deadline=1.0, staleness_rounds=2, demote_after=2, cooldown=100,
    ))
    _, rep = run_adaptive_rounds(
        eng, eng.init(D), N_CLIENTS, N_CLIENTS, 8, latency,
        ChaosSpec(seed=0), lambda c, r: payloads[c], seed=5,
    )
    assert 3 in rep["demoted"]
    # once demoted, client 3 stops being sampled
    demoted_from = next(
        r for r, cohort in enumerate(rep["cohorts"]) if 3 not in cohort
    )
    for cohort in rep["cohorts"][demoted_from:]:
        assert 3 not in cohort


def test_live_classifier_tracks_open_rounds():
    payloads = _payloads()
    eng = _engine(staleness_rounds=2)
    state = eng.init(D)
    eng.begin_round(0, [0, 1], 0.0)
    state, _ = eng.deliver(state, UploadEvent(0.1, 0, 0, 0), payloads[0])
    state, _ = eng.deliver(state, UploadEvent(0.2, 0, 1, 0), payloads[1])
    live = np.asarray(eng.live_classifier(state))
    # the open round has not retired; the carried classifier is still empty
    assert not np.array_equal(live, np.asarray(state.W))
    state = eng.drain(state)
    np.testing.assert_allclose(live, np.asarray(state.W), rtol=1e-5, atol=1e-6)


def test_retire_matches_streaming_absorb_stats():
    payloads = _payloads()
    cohort = [0, 1, 2, 3]
    eng = _engine(staleness_rounds=0)
    state = eng.init(D)
    eng.begin_round(0, cohort, 0.0)
    for i, c in enumerate(cohort):
        state, _ = eng.deliver(state, UploadEvent(0.1 * i, 0, c, 0), payloads[c])
    state = eng.close_round(state, 0, now=1.0)

    se = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAMBDA))
    ss = se.init(D)
    S_A = jnp.sum(jnp.stack([payloads[c].A for c in cohort]), axis=0)
    S_b = jnp.sum(jnp.stack([payloads[c].b for c in cohort]), axis=0)
    S_n = jnp.sum(jnp.stack([payloads[c].n for c in cohort]), axis=0)
    ss = se.absorb_stats(ss, S_A, S_b, S_n)

    np.testing.assert_allclose(
        np.asarray(state.W), np.asarray(ss.W), rtol=1e-6, atol=1e-7
    )
    assert float(state.n) == pytest.approx(float(ss.n))


# ---------------------------------------------------------------------------
# Secure aggregation under dropout
# ---------------------------------------------------------------------------


def _secure_round(cohort, payloads_masked, scales, deliver_clients, seed=0):
    eng = AsyncRoundEngine(AsyncConfig(
        n_classes=C, ridge_lambda=LAMBDA, cohort=len(cohort), deadline=1.0,
        staleness_rounds=0, secure=True, secure_seed=seed,
    ))
    state = eng.init(D)
    eng.begin_round(0, cohort, 0.0, scales=scales)
    for i, c in enumerate(deliver_clients):
        state, s = eng.deliver(
            state, UploadEvent(0.1 * i, 0, c, 0), payloads_masked[c]
        )
        assert s == "folded"
    state = eng.close_round(state, 0, now=1.0)
    return eng, state


@pytest.mark.parametrize("n_drop", [1, 2, 3])
def test_secure_dropout_recovery_bitwise(n_drop):
    """Masked round with 1..K-1 dropped clients == survivor-only round with
    UNMASKED payloads and the same shared scales, bit for bit."""
    stats = _payloads(seed=4)
    cohort = [0, 1, 2, 3]
    q, sA, sb = cohort_quantize_int8([stats[c] for c in cohort])
    dropped = cohort[:n_drop]
    survivors = cohort[n_drop:]
    seed = 11

    masked = {
        c: secure_agg.mask_quantized_payload(q[i], c, cohort, seed)
        for i, c in enumerate(cohort)
    }
    _, s_drop = _secure_round(cohort, masked, (sA, sb), survivors, seed=seed)

    unmasked = {c: q[cohort.index(c)] for c in survivors}
    _, s_base = _secure_round(survivors, unmasked, (sA, sb), survivors, seed=seed)

    np.testing.assert_array_equal(np.asarray(s_drop.W), np.asarray(s_base.W))
    np.testing.assert_array_equal(np.asarray(s_drop.L), np.asarray(s_base.L))


def test_secure_live_classifier_serves_last_retired_w():
    stats = _payloads(seed=4)
    cohort = [0, 1]
    q, sA, sb = cohort_quantize_int8([stats[c] for c in cohort])
    masked = {
        c: secure_agg.mask_quantized_payload(q[i], c, cohort, 0)
        for i, c in enumerate(cohort)
    }
    eng, state = _secure_round(cohort, masked, (sA, sb), cohort)
    # open slots are masked garbage by design; live serving returns state.W
    np.testing.assert_array_equal(
        np.asarray(eng.live_classifier(state)), np.asarray(state.W)
    )


def test_recover_survivor_sum_quantized_host_bitwise():
    stats = _payloads(seed=6)
    cohort = [0, 1, 2, 3, 4]
    q, _, _ = cohort_quantize_int8([stats[c] for c in cohort])
    survivors, dropped = cohort[:3], cohort[3:]
    seed = 9
    masked_sum = secure_agg.secure_aggregate_quantized([
        secure_agg.mask_quantized_payload(q[i], c, cohort, seed)
        for i, c in enumerate(cohort) if c in survivors
    ])
    rec = secure_agg.recover_survivor_sum_quantized(
        masked_sum, survivors, dropped, seed
    )
    plain = secure_agg.secure_aggregate_quantized(
        [q[cohort.index(c)] for c in survivors]
    )
    np.testing.assert_array_equal(np.asarray(rec.qA), np.asarray(plain.qA))
    np.testing.assert_array_equal(np.asarray(rec.qb), np.asarray(plain.qb))


def test_recover_survivor_sum_float_tolerance():
    stats = _payloads(seed=6)
    cohort = [0, 1, 2]
    survivors, dropped = cohort[:2], cohort[2:]
    seed = 9
    masked = [
        secure_agg.mask_statistics(stats[c], c, cohort, seed) for c in survivors
    ]
    rec = secure_agg.recover_survivor_sum(
        secure_agg.secure_aggregate(masked), survivors, dropped, seed
    )
    plain_A = sum(np.asarray(stats[c].A) for c in survivors)
    np.testing.assert_allclose(np.asarray(rec.A), plain_A, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# Control-plane errors, serialization, satellites
# ---------------------------------------------------------------------------


def test_begin_round_contiguity_and_overflow():
    eng = _engine(staleness_rounds=1)  # ring of 2
    eng.init(D)
    with pytest.raises(ValueError, match="contiguously"):
        eng.begin_round(1, [0], 0.0)
    eng.begin_round(0, [0], 0.0)
    eng.begin_round(1, [1], 1.0)
    with pytest.raises(RuntimeError, match="ring overflow"):
        eng.begin_round(2, [2], 2.0)
    with pytest.raises(ValueError, match="duplicate"):
        _engine().begin_round(0, [3, 3], 0.0)


def test_deliver_unknown_round_or_client_raises():
    payloads = _payloads()
    eng = _engine()
    state = eng.init(D)
    with pytest.raises(ValueError, match="before begin_round"):
        eng.deliver(state, UploadEvent(0.1, 0, 0, 0), payloads[0])
    eng.begin_round(0, [0, 1], 0.0)
    with pytest.raises(ValueError, match="cohort"):
        eng.deliver(state, UploadEvent(0.1, 0, 9, 0), payloads[9])


def test_timeline_json_roundtrip():
    cohorts = _cohorts(3)
    latency = latency_profile(N_CLIENTS, 0.2, seed=0)
    spec = ChaosSpec(drop=0.3, duplicate=0.2, reorder=0.4, seed=7)
    events = chaos_timeline(cohorts, latency, spec)
    sched = timeline_from_json(timeline_to_json(cohorts, latency, spec, events))
    assert sched["spec"] == spec
    assert sched["cohorts"] == [list(c) for c in cohorts]
    np.testing.assert_allclose(sched["latency"], latency)
    assert sched["events"] == list(events)


def test_straggler_tail_pricing():
    cm = CostModel(b=2.22e6, d=D, C=C)
    out = cm.straggler_tail(16, 0.2, straggler_factor=8.0, base_s=0.3,
                            deadline_s=1.0)
    assert 0.0 < out["p_straggler_round"] <= 1.0
    assert out["async_round_s"] <= out["sync_round_s"]
    assert out["speedup"] >= 1.5  # the bench_async regime
    flat = cm.straggler_tail(16, 0.0, straggler_factor=8.0, base_s=0.3)
    assert flat["speedup"] == pytest.approx(1.0)


def test_shard_cohort_partitions_round_robin():
    cohort = [9, 2, 5, 7, 1]
    parts = [shard_cohort(cohort, s, 3) for s in range(3)]
    joined = sorted(c for p in parts for c in p)
    assert joined == sorted(cohort)
    assert all(len(set(p)) == len(p) for p in parts)
    with pytest.raises(ValueError):
        shard_cohort(cohort, 3, 3)


def test_config_validation():
    with pytest.raises(ValueError, match="cohort"):
        AsyncConfig(n_classes=C, ridge_lambda=LAMBDA, cohort=0)
    with pytest.raises(ValueError, match="deadline"):
        AsyncConfig(n_classes=C, ridge_lambda=LAMBDA, cohort=1, deadline=0.0)
    with pytest.raises(ValueError, match="secure"):
        AsyncConfig(n_classes=C, ridge_lambda=LAMBDA, cohort=1, secure=True,
                    wire=WireFormat(kind="int8"))
