"""CI benchmark-regression gate: the seeded baselines pass, doctored fail.

The gate's contract (benchmarks/check_regression.py): comparing a BENCH
result dict against its committed baseline passes when every gated metric
honors its rule, and fails loudly when dispatch counts grow, speedups
collapse, numerics drift, invariance flags flip, wall-times blow up, or
the smoke config silently changes.
"""
import copy
import json
import pathlib
import subprocess
import sys

import pytest

from benchmarks.check_regression import compare, flatten

REPO = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = REPO / "benchmarks" / "baselines"


@pytest.fixture(scope="module")
def baselines():
    out = {}
    for path in sorted(BASELINE_DIR.glob("BENCH_*.json")):
        with open(path) as f:
            out[path.name] = json.load(f)
    return out


def test_baselines_are_seeded(baselines):
    assert {"BENCH_engine.json", "BENCH_rounds.json", "BENCH_streaming.json"} <= (
        set(baselines)
    )


def test_seeded_baselines_pass_against_themselves(baselines):
    for name, base in baselines.items():
        assert compare(base, base) == [], name


def test_flatten_nests_dotted_paths():
    flat = flatten({"a": 1, "b": {"c": 2.0, "d": {"e": True}}})
    assert flat == {"a": 1, "b.c": 2.0, "b.d.e": True}


def test_doctored_dispatch_count_fails(baselines):
    cur = copy.deepcopy(baselines["BENCH_streaming.json"])
    cur["engine_dispatches"] = cur["engine_dispatches"] + 5
    bad = compare(cur, baselines["BENCH_streaming.json"])
    assert any("dispatch" in v for v in bad)


def test_doctored_speedup_fails(baselines):
    base = baselines["BENCH_rounds.json"]
    cur = copy.deepcopy(base)
    cur["speedup"] = base["speedup"] / 100.0
    assert any("speedup" in v for v in compare(cur, base))
    # within tolerance: CI noise does not fail the gate
    cur["speedup"] = base["speedup"] * 0.5
    assert compare(cur, base, speedup_tol=0.25) == []


def test_doctored_numerics_fail(baselines):
    base = baselines["BENCH_streaming.json"]
    cur = copy.deepcopy(base)
    cur["factored_err"] = 0.5
    assert any("factored_err" in v for v in compare(cur, base))
    # fp jitter under the absolute floor passes
    cur["factored_err"] = 5e-5
    assert compare(cur, base) == []


def test_doctored_invariance_flag_fails(baselines):
    base = baselines["BENCH_engine.json"]
    cur = copy.deepcopy(base)
    cur["bit_identical_perm"] = False
    assert any("bit_identical_perm" in v for v in compare(cur, base))


def test_doctored_walltime_blowup_fails(baselines):
    base = baselines["BENCH_streaming.json"]
    cur = copy.deepcopy(base)
    cur["engine_s_per_stream"] = base["engine_s_per_stream"] * 100.0
    assert any("engine_s_per_stream" in v for v in compare(cur, base))
    cur["engine_s_per_stream"] = base["engine_s_per_stream"] * 2.0
    assert compare(cur, base) == []  # loose tolerance: timing noise passes


def test_changed_smoke_config_fails(baselines):
    base = baselines["BENCH_streaming.json"]
    cur = copy.deepcopy(base)
    cur["waves"] = base["waves"] * 2
    assert any("waves" in v for v in compare(cur, base))


def test_missing_metric_fails(baselines):
    base = baselines["BENCH_rounds.json"]
    cur = copy.deepcopy(base)
    del cur["engine_dispatches_per_round"]
    assert any("missing" in v for v in compare(cur, base))


def test_cli_passes_on_baselines_and_fails_on_doctored(tmp_path, baselines):
    script = REPO / "benchmarks" / "check_regression.py"
    ok = subprocess.run(
        [sys.executable, str(script),
         "--baseline-dir", str(BASELINE_DIR), "--current-dir", str(BASELINE_DIR)],
        capture_output=True, text=True,
    )
    assert ok.returncode == 0, ok.stderr
    for name, base in baselines.items():
        doc = copy.deepcopy(base)
        for key in doc:
            if "dispatch" in key and "reference" not in key and (
                "naive" not in key
            ):
                doc[key] = int(doc[key]) + 7
        with open(tmp_path / name, "w") as f:
            json.dump(doc, f)
    bad = subprocess.run(
        [sys.executable, str(script),
         "--baseline-dir", str(BASELINE_DIR), "--current-dir", str(tmp_path)],
        capture_output=True, text=True,
    )
    assert bad.returncode == 1
    assert "REGRESSIONS" in bad.stderr
