"""Beyond-paper extensions: int8 KV cache, secure aggregation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import fed3r
from repro.federated.secure_agg import mask_statistics, secure_aggregate
from repro.models import build_model


def test_int8_kv_cache_decode_close_to_fp(rng):
    """Quantized-cache decode tracks the fp cache within int8 tolerance."""
    cfg = get_config("qwen2-7b-smoke").replace(dtype="float32")
    cfg_q = cfg.replace(kv_cache_quant=True)
    model, model_q = build_model(cfg), build_model(cfg_q)
    params = model.init(rng)
    B, S, T = 2, 16, 4
    toks = jax.random.randint(rng, (B, S + T), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}

    lg, cache = model.prefill(params, batch, cache_capacity=S + T)
    lgq, cache_q = model_q.prefill(params, batch, cache_capacity=S + T)
    assert cache_q["k"].dtype == jnp.int8
    # cache bytes halve (int8 + fp32 scale/hd vs bf16)
    for i in range(T):
        lg, cache = model.decode_step(params, cache, toks[:, S+i:S+i+1], jnp.int32(S+i))
        lgq, cache_q = model_q.decode_step(params, cache_q, toks[:, S+i:S+i+1], jnp.int32(S+i))
        # logits close in ranking: top-1 agreement + bounded error
        err = float(jnp.mean(jnp.abs(lg - lgq)))
        assert err < 0.05, err
        agree = float(jnp.mean((jnp.argmax(lg, -1) == jnp.argmax(lgq, -1)).astype(jnp.float32)))
        assert agree >= 0.5


def test_int8_cache_memory_halves():
    cfg = get_config("qwen2-7b")
    from repro.models.model import make_cache

    fp = jax.eval_shape(lambda: make_cache(cfg, 4, 1024))
    q = jax.eval_shape(lambda: make_cache(cfg.replace(kv_cache_quant=True), 4, 1024))
    bytes_fp = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(fp))
    bytes_q = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(q))
    assert bytes_q < 0.6 * bytes_fp  # int8 + per-token scales ≈ 0.53×


def test_secure_aggregation_masks_cancel(rng):
    """App. B: server recovers the exact sum; single uploads are masked."""
    d, C = 8, 3
    cohort = [0, 1, 2, 3, 4]
    feats = jax.random.normal(rng, (50, d))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (50,), 0, C)
    parts = np.array_split(np.arange(50), len(cohort))
    stats = [
        fed3r.client_stats(feats[p], labels[p], C) for p in parts
    ]
    masked = [
        mask_statistics(s, u, cohort, seed=42) for u, s in zip(cohort, stats)
    ]
    # each masked upload differs substantially from the raw statistics
    for s, m in zip(stats, masked):
        assert float(jnp.max(jnp.abs(m.A - s.A))) > 1.0
    agg = secure_aggregate(masked)
    ref = fed3r.merge(*stats)
    np.testing.assert_allclose(np.asarray(agg.A), np.asarray(ref.A), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(agg.b), np.asarray(ref.b), rtol=1e-4, atol=1e-3)
    # and the solve on securely-aggregated stats matches
    W1 = fed3r.solve(agg, 0.01)
    W2 = fed3r.solve(ref, 0.01)
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W2), rtol=1e-3, atol=1e-3)
