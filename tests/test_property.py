"""Hypothesis property tests for the system's invariants.

The paper's central claim — exact aggregation — is an algebraic property
amenable to property-based testing: for ANY partition, ANY order, ANY
merge tree shape, the statistics (and hence W*) are identical.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fed3r, ncm
from repro.federated.costs import CostModel

D, C = 8, 4
_RNG = np.random.default_rng(0)
_FEATS = _RNG.normal(size=(120, D)).astype(np.float32)
_LABELS = _RNG.integers(0, C, size=120).astype(np.int32)


def _stats(idx):
    return fed3r.client_stats(jnp.asarray(_FEATS[idx]), jnp.asarray(_LABELS[idx]), C)


@st.composite
def partitions(draw):
    n = len(_LABELS)
    k = draw(st.integers(min_value=1, max_value=10))
    cuts = sorted(draw(
        st.lists(st.integers(1, n - 1), min_size=k - 1, max_size=k - 1, unique=True)
    ))
    perm = draw(st.permutations(list(range(n))))
    return np.split(np.asarray(perm), cuts)


@settings(max_examples=25, deadline=None)
@given(partitions())
def test_fed3r_partition_invariance(parts):
    merged = fed3r.merge(*[_stats(p) for p in parts if len(p)])
    ref = _stats(np.arange(len(_LABELS)))
    np.testing.assert_allclose(np.asarray(merged.A), np.asarray(ref.A),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(merged.b), np.asarray(ref.b),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.permutations(list(range(6))))
def test_fed3r_merge_order_invariance(order):
    parts = np.array_split(np.arange(len(_LABELS)), 6)
    stats = [_stats(p) for p in parts]
    a = fed3r.merge(*stats)
    b = fed3r.merge(*[stats[i] for i in order])
    np.testing.assert_allclose(np.asarray(a.A), np.asarray(b.A), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a.b), np.asarray(b.b), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(0, 100))
def test_fed3r_merge_associativity(k, seed):
    """merge(merge(a,b),c) == merge(a,merge(b,c)) — the psum-tree freedom."""
    parts = np.array_split(np.arange(len(_LABELS)), k)
    stats = [_stats(p) for p in parts]
    rng = np.random.default_rng(seed)
    # random binary merge tree vs flat merge
    pool = list(stats)
    while len(pool) > 1:
        i, j = sorted(rng.choice(len(pool), size=2, replace=False))
        b = pool.pop(j)
        a = pool.pop(i)
        pool.append(fed3r.merge(a, b))
    flat = fed3r.merge(*stats)
    np.testing.assert_allclose(np.asarray(pool[0].A), np.asarray(flat.A),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(partitions())
def test_ncm_partition_invariance(parts):
    merged = ncm.merge(*[
        ncm.client_stats(jnp.asarray(_FEATS[p]), jnp.asarray(_LABELS[p]), C)
        for p in parts if len(p)
    ])
    ref = ncm.client_stats(jnp.asarray(_FEATS), jnp.asarray(_LABELS), C)
    np.testing.assert_allclose(np.asarray(merged.sums), np.asarray(ref.sums),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(merged.counts), np.asarray(ref.counts))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 512), st.integers(2, 5000))
def test_cost_model_fed3r_cheaper_upstream_than_full_model(d, C_, b_scale):
    """App. D: FED3R upstream (d²+dC) vs FedAvg (b+dC) — for realistic
    extractor sizes (b ≫ d²) FED3R uploads less."""
    cm = CostModel(b=float(d * d * b_scale), d=d, C=C_)
    fed3r_up = cm.comm_per_client("fed3r")["up"]
    fedavg_up = cm.comm_per_client("fedavg")["up"]
    assert fed3r_up < fedavg_up or b_scale <= 1


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20))
def test_cost_model_cumulative_monotone(rounds):
    cm = CostModel(b=2.2e6, d=64, C=10)
    for alg in ("fedavg", "scaffold", "fedavg-lp", "fed3r"):
        curve = cm.cumulative_comm_bytes(alg, rounds, 10)
        assert len(curve) == rounds
        assert np.all(np.diff(curve) >= 0) if rounds > 1 else True
