"""Layer-level unit tests: attention math, MoE, SSD, RG-LRU, conv, rope."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_rope,
    causal_conv1d_apply,
    causal_conv1d_init,
    causal_conv1d_step,
    mrope_angles,
    rope_angles,
)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def test_chunked_attention_matches_direct(rng):
    B, S, H, KV, hd = 2, 4096, 4, 2, 16  # S > 2*Q_CHUNK triggers chunking
    q = jax.random.normal(rng, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attn.multihead_attention(q, k, v, pos, pos)
    # direct path (small-S branch) on slices: compare a few query rows
    qg = q.reshape(B, S, KV, H // KV, hd)
    ref = attn._scores_softmax_values(qg, k, v, pos, pos, None, False)
    ref = ref.reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_sliding_window_mask(rng):
    B, S, H, hd, W = 1, 64, 2, 8, 8
    q = jax.random.normal(rng, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out_w = attn.multihead_attention(q, k, v, pos, pos, window=W)
    # position S-1 should ignore keys < S-W: build explicit reference
    scores = jnp.einsum("bshd,bkhd->bhsk", q, k) / jnp.sqrt(hd)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - W)
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, -1)
    ref = jnp.einsum("bhsk,bkhd->bshd", probs.astype(q.dtype), v)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_ring_cache_update_and_positions():
    cfg = get_config("qwen2-7b-smoke")
    cache = attn.init_cache(cfg, batch=2, capacity=4, dtype=jnp.float32)
    KV, hd = cfg.n_kv_heads, cfg.hd
    for p in range(6):  # wraps around twice
        k = jnp.full((2, 1, KV, hd), float(p))
        cache = attn.cache_decode_update(cache, k, k, jnp.int32(p))
    # slots hold positions 2..5 (last 4)
    assert sorted(np.asarray(cache["pos"]).tolist()) == [2, 3, 4, 5]
    slot_of_5 = 5 % 4
    assert float(cache["k"][0, slot_of_5, 0, 0]) == 5.0


def test_rope_preserves_norm_and_relativity(rng):
    S, hd = 16, 32
    x = jax.random.normal(rng, (1, S, 2, hd))
    ang = rope_angles(jnp.arange(S), hd, 10_000.0)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-5,
    )
    # relative property: <q_i, k_j> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (1, 1, 1, hd))
    def dot_at(i, j):
        qi = apply_rope(q, rope_angles(jnp.asarray([i]), hd, 10_000.0))
        kj = apply_rope(k, rope_angles(jnp.asarray([j]), hd, 10_000.0))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4


def test_mrope_sections_sum():
    ang = mrope_angles(jnp.zeros((3, 8), jnp.int32), 32, 1e4, (4, 6, 6))
    assert ang.shape == (8, 16)
    with pytest.raises(AssertionError):
        mrope_angles(jnp.zeros((3, 8), jnp.int32), 32, 1e4, (4, 6, 5))


# ---------------------------------------------------------------------------
# causal conv
# ---------------------------------------------------------------------------


def test_causal_conv_step_matches_sequence(rng):
    C, W, S, B = 6, 4, 10, 2
    p = causal_conv1d_init(rng, C, W)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, C))
    y_seq = causal_conv1d_apply(p, x)
    state = jnp.zeros((B, W - 1, C))
    for t in range(S):
        state, y_t = causal_conv1d_step(p, state, x[:, t, :])
        np.testing.assert_allclose(
            np.asarray(y_t), np.asarray(y_seq[:, t, :]), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------


def _naive_ssd(x, a, Bm, Cm):
    """Direct recurrence oracle: h_t = exp(a_t)·h_{t-1} + B_t x_tᵀ ; y=C·h."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        h = h * np.exp(a[:, t])[:, :, None, None] + np.einsum(
            "bhn,bhp->bhpn", Bm[:, t], x[:, t]
        )
        ys.append(np.einsum("bhpn,bhn->bhp", h, Cm[:, t]))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(chunk, rng):
    B, S, H, P, N = 2, 16, 3, 4, 5
    x = np.asarray(jax.random.normal(rng, (B, S, H, P)))
    a = -np.abs(np.asarray(jax.random.normal(jax.random.fold_in(rng, 1), (B, S, H)))) * 0.5
    Bm = np.asarray(jax.random.normal(jax.random.fold_in(rng, 2), (B, S, H, N)))
    Cm = np.asarray(jax.random.normal(jax.random.fold_in(rng, 3), (B, S, H, N)))
    y_ref, h_ref = _naive_ssd(x, a, Bm, Cm)
    y, h = ssm_mod.ssd_chunked(
        jnp.asarray(x), jnp.asarray(a), jnp.asarray(Bm), jnp.asarray(Cm), chunk
    )
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_ssm_decode_matches_sequence(rng):
    cfg = get_config("mamba2-1.3b-smoke").replace(dtype="float32")
    p = ssm_mod.ssm_init(rng, cfg)
    B, S = 2, 12
    u = 0.5 * jax.random.normal(jax.random.fold_in(rng, 1), (B, S, cfg.d_model))
    y_seq, cache = ssm_mod.ssm_apply(cfg, p, u, build_cache=True)
    # continue for 3 more steps and compare against longer sequence
    u_ext = 0.5 * jax.random.normal(jax.random.fold_in(rng, 2), (B, 3, cfg.d_model))
    u_full = jnp.concatenate([u, u_ext], axis=1)
    y_full, _ = ssm_mod.ssm_apply(cfg, p, u_full)
    for t in range(3):
        y_t, cache = ssm_mod.ssm_decode_step(cfg, p, u_ext[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(y_t[:, 0]), np.asarray(y_full[:, S + t]), rtol=2e-4, atol=2e-4
        )


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def test_rglru_scan_matches_loop(rng):
    cfg = get_config("recurrentgemma-9b-smoke").replace(dtype="float32")
    p = rglru_mod.rglru_init(rng, cfg)
    B, S = 2, 9
    x = 0.5 * jax.random.normal(jax.random.fold_in(rng, 1), (B, S, cfg.d_model))
    y_seq, cache = rglru_mod.rglru_apply(cfg, p, x, build_cache=True)
    # decode continuation equals longer-sequence slice
    x_ext = 0.5 * jax.random.normal(jax.random.fold_in(rng, 2), (B, 2, cfg.d_model))
    y_full, _ = rglru_mod.rglru_apply(cfg, p, jnp.concatenate([x, x_ext], 1))
    for t in range(2):
        y_t, cache = rglru_mod.rglru_decode_step(cfg, p, x_ext[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(y_t[:, 0]), np.asarray(y_full[:, S + t]), rtol=2e-4, atol=2e-4
        )


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def test_moe_no_drop_matches_dense_sum(rng):
    """With no dropping, scatter-dispatch == dense per-expert compute."""
    cfg = get_config("deepseek-moe-16b-smoke").replace(
        dtype="float32", capacity_factor=16.0, n_shared_experts=0
    )
    p = moe_mod.moe_init(rng, cfg)
    B, S = 2, 8
    x = 0.3 * jax.random.normal(jax.random.fold_in(rng, 1), (B, S, cfg.d_model))
    y, aux = moe_mod.moe_apply(cfg, p, x)

    # dense reference: compute every expert on every token, weight by top-k
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_idx = jax.lax.top_k(probs, cfg.top_k)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_gate"]))
    u = jnp.einsum("td,edf->tef", xf, p["w_up"])
    all_out = jnp.einsum("tef,efd->ted", g * u, p["w_down"])
    ref = jnp.zeros_like(xf)
    for kk in range(cfg.top_k):
        sel = jnp.take_along_axis(all_out, top_idx[:, kk][:, None, None], axis=1)[:, 0]
        ref = ref + sel * top_p[:, kk][:, None]
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(ref), rtol=2e-4, atol=2e-4
    )
    assert float(aux) > 0.0


def test_moe_shared_expert_fusion(rng):
    """Sum of S separate swiglu experts == one fused wide swiglu."""
    d, f = 16, 8
    k1, k2 = jax.random.split(rng)
    Wg = jax.random.normal(k1, (2, d, f))
    Wu = jax.random.normal(k2, (2, d, f))
    Wd = jax.random.normal(jax.random.fold_in(rng, 3), (2, f, d))
    x = jax.random.normal(jax.random.fold_in(rng, 4), (5, d))
    sep = sum(
        (jax.nn.silu(x @ Wg[i]) * (x @ Wu[i])) @ Wd[i] for i in range(2)
    )
    fused_g = jnp.concatenate([Wg[0], Wg[1]], axis=1)
    fused_u = jnp.concatenate([Wu[0], Wu[1]], axis=1)
    fused_d = jnp.concatenate([Wd[0], Wd[1]], axis=0)
    fused = (jax.nn.silu(x @ fused_g) * (x @ fused_u)) @ fused_d
    np.testing.assert_allclose(np.asarray(sep), np.asarray(fused), rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_tokens(rng):
    cfg = get_config("deepseek-moe-16b-smoke").replace(
        dtype="float32", capacity_factor=0.1
    )
    p = moe_mod.moe_init(rng, cfg)
    x = jax.random.normal(rng, (2, 16, cfg.d_model))
    y, _ = moe_mod.moe_apply(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
