"""Random features, RR probe, checkpointing, optimizers, schedules."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_checkpoint, load_pytree, save_pytree
from repro.core import fed3r
from repro.core.probe import probe_quality
from repro.core.random_features import rbf_kernel, rff_init, rff_map
from repro.data.synthetic import make_feature_dataset
from repro.optim import adamw_init, adamw_update, apply_updates, sgd_init, sgd_update
from repro.optim.schedules import cosine_decay, warmup_cosine


def test_rff_approximates_rbf_kernel(rng):
    """Fig. 8 mechanism: more features → better kernel approximation."""
    d, sigma = 16, 2.0
    z = jax.random.normal(rng, (64, d))
    K = rbf_kernel(z, z, sigma)
    errs = []
    for D in (64, 512, 4096):
        p = rff_init(jax.random.PRNGKey(1), d, D, sigma)
        phi = rff_map(p, z)
        errs.append(float(jnp.mean(jnp.abs(phi @ phi.T - K))))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.03


def test_rff_helps_on_nonlinear_data(rng):
    """FED3R-RF > FED3R when classes aren't linearly separable (§4.2)."""
    ds = make_feature_dataset(rng, 6000, 16, 6, nonlinear=True, noise=0.1,
                              class_scale=1.0)
    tr, te = 4800, 6000
    f_tr, y_tr = ds.features[:tr], ds.labels[:tr]
    f_te, y_te = ds.features[tr:te], ds.labels[tr:te]

    W_lin = fed3r.solve(fed3r.client_stats(f_tr, y_tr, 6), 1.0)
    acc_lin = float(fed3r.accuracy(W_lin, f_te, y_te))

    p = rff_init(jax.random.PRNGKey(2), 16, 1024, sigma=5.0)
    W_rf = fed3r.solve(fed3r.client_stats(rff_map(p, f_tr), y_tr, 6), 1.0)
    acc_rf = float(fed3r.accuracy(W_rf, rff_map(p, f_te), y_te))
    assert acc_rf > acc_lin + 0.2, (acc_lin, acc_rf)


def test_probe_ranks_feature_quality(rng):
    """§5.4: the RR probe scores clean features above noisy ones."""
    ds = make_feature_dataset(rng, 2000, 24, 8, noise=0.3)
    noisy = ds.features + 10.0 * jax.random.normal(jax.random.PRNGKey(9), ds.features.shape)
    tr = 1600
    good = probe_quality(ds.features[:tr], ds.labels[:tr],
                         ds.features[tr:], ds.labels[tr:], 8)
    bad = probe_quality(noisy[:tr], ds.labels[:tr], noisy[tr:], ds.labels[tr:], 8)
    assert float(good.accuracy) > float(bad.accuracy) + 0.05


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "params": {"w": jax.random.normal(rng, (4, 5)), "b": jnp.zeros(5)},
        "opt": {"mu": [jnp.ones(3), jnp.zeros((2, 2))], "t": jnp.asarray(7)},
        "meta": {"none_leaf": None, "tup": (jnp.ones(2), jnp.zeros(1))},
    }
    path = os.path.join(tmp_path, "ckpt_3.npz")
    save_pytree(path, tree)
    back = load_pytree(path)
    np.testing.assert_allclose(np.asarray(tree["params"]["w"]), back["params"]["w"])
    assert isinstance(back["opt"]["mu"], list) and len(back["opt"]["mu"]) == 2
    assert isinstance(back["meta"]["tup"], tuple)
    assert back["meta"]["none_leaf"] is None
    assert int(back["opt"]["t"]) == 7
    assert latest_checkpoint(str(tmp_path)) == path


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------


def test_sgd_momentum_accumulates():
    params = {"w": jnp.ones(3)}
    grads = {"w": jnp.ones(3)}
    state = sgd_init(params, momentum=0.9)
    u1, state = sgd_update(grads, state, params, 0.1, momentum=0.9)
    u2, state = sgd_update(grads, state, params, 0.1, momentum=0.9)
    assert float(jnp.abs(u2["w"][0])) > float(jnp.abs(u1["w"][0]))


def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        updates, state = adamw_update(grads, state, params, 0.1)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_schedules_shapes():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) < 0.2
    assert abs(float(s(10)) - 1.0) < 1e-5
    assert float(s(99)) < 0.5
    cd = cosine_decay(2.0, 50)
    assert abs(float(cd(0)) - 2.0) < 1e-5
