"""Continuous-batching slot-serving engine: parity, eviction, admission.

The serving acceptance surface (ISSUE 6):

* strict-mode slot serving matches the synchronous ``serve_heads`` path —
  BITWISE on global-mode queries, <= 1e-5 on personalized ones (same
  cohort packing, same in-dispatch alpha sweep);
* the slot table evicts coldest-first, readmits evicted tenants with a
  fresh solve, and never evicts a slot protected by an in-flight query;
* admission control sheds at enqueue beyond ``queue_depth`` and sheds
  queued requests past ``deadline_ticks``, with every request accounted;
* each stage costs ONE dispatch per tick regardless of batch composition;
* version-segmented invalidation re-solves ONLY tenants whose own
  statistics arrived (both in the :class:`HeadCache` policy and the slot
  engine), where the strict policy re-solves the whole working set.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import make_federated_features
from repro.federated.arrivals import pack_schedule, poisson_schedule
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.slots import SlotTable, TenantUniverse
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.launch.serve_heads import HeadCache, HeadServer
from repro.launch.serving_engine import ServingConfig, ServingEngine

D, C, LAM = 16, 5, 1e-2
ALPHA_GRID = (0.0, 0.5, 1.0, 2.0, 4.0)


def _fed(seed=1, n_clients=8):
    fed, _ = make_federated_features(
        seed=seed, n=600, d=D, n_classes=C, n_clients=n_clients,
        alpha=0.3, noise=2.0,
    )
    return fed


def _packed(fed, seed=0, waves=4):
    return pack_schedule(fed, poisson_schedule(fed.n_clients, waves, 3.0, seed=seed))


def _engine(fed, **kw):
    cfg = dict(
        n_classes=C, ridge_lambda=LAM, n_slots=6, solve_bucket=4,
        serve_bucket=8, alpha_grid=ALPHA_GRID,
    )
    cfg.update(kw)
    eng = ServingEngine(ServingConfig(**cfg), fed)
    eng.init(D)
    return eng


def _lru(fed, capacity=4, invalidation="strict"):
    srv = HeadServer(
        StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM)),
        PersonalizationEngine(PersonalizeConfig(
            n_classes=C, alpha_grid=ALPHA_GRID,
        )),
        fed,
        cache_capacity=capacity,
        cohort_round_to=4,
        invalidation=invalidation,
    )
    srv.init(D)
    return srv


def _burst(fed, cids):
    return np.stack([
        fed.client(c % fed.n_clients).features[i] for i, c in enumerate(cids)
    ])


# ---------------------------------------------------------------------------
# slot-table state
# ---------------------------------------------------------------------------


def test_slot_table_rejects_degenerate_sizes():
    with pytest.raises(ValueError):
        SlotTable(1, D, C)  # no room for a tenant next to the pinned slot


def test_slot_table_free_first_then_coldest_eviction():
    t = SlotTable(5, D, C)
    # fill the three tenant slots behind the pinned global slot
    s = t.take_slots(3)
    assert s == [1, 2, 3] and t.evictions == 0
    t.assign(s, [10, 11, 12], [0, 0, 0], global_version=1, tick=1)
    assert len(t) == 3 and t.slot_of(11) == 2
    # one free slot left; ask for two: free slot 4 first, then the coldest
    t.touch([1], [5], tick=3)  # tenant 10 is hot and recent
    t.touch([2], [1], tick=2)  # tenant 11 lukewarm
    got = t.take_slots(2)  # slot 3 (tenant 12, never served) is coldest
    assert got == [4, 3]
    assert t.evictions == 1 and t.slot_of(12) is None
    assert t.slot_of(10) == 1  # the hot tenant survived


def test_slot_table_protected_slots_survive_saturation():
    t = SlotTable(4, D, C)
    s = t.take_slots(3)
    t.assign(s, [7, 8, 9], [0, 0, 0], global_version=1, tick=1)
    got = t.take_slots(3, protect=[1, 2])  # only slot 3 is evictable
    assert got == [3]
    assert t.slot_of(7) == 1 and t.slot_of(8) == 2


def test_tenant_universe_aliases_base_clients():
    fed = _fed()
    uni = TenantUniverse(fed, 1_000_000)
    assert uni.n_clients == 1_000_000
    k = 777_777
    base = fed.client(k % fed.n_clients)
    np.testing.assert_array_equal(uni.client(k).features, base.features)
    assert int(uni.client_sizes().max()) == int(fed.client_sizes().max())
    with pytest.raises(ValueError):
        TenantUniverse(fed, fed.n_clients - 1)


# ---------------------------------------------------------------------------
# version-segmented invalidation (cache policy + partial re-personalization)
# ---------------------------------------------------------------------------


def test_head_cache_segmented_invalidates_only_touched_tenants():
    cache = HeadCache(capacity=4, segmented=True)
    W = jnp.zeros((D, C))
    cache.put(1, W)
    cache.put(2, W)
    cache.advance(touched=[1])  # only tenant 1's own statistics moved
    assert cache.get(1) is None  # stale: its stats version advanced
    assert cache.get(2) is not None  # untouched resident survives
    assert cache.stale_evictions == 1
    # unknown arrival set degrades to whole-cache invalidation
    cache.put(1, W)
    cache.advance(touched=None)
    assert cache.get(1) is None and cache.get(2) is None


def test_head_cache_strict_still_sweeps_everything():
    cache = HeadCache(capacity=4, segmented=False)
    cache.put(1, jnp.zeros((D, C)))
    cache.put(2, jnp.zeros((D, C)))
    cache.advance(touched=[1])  # strict ignores the touched set
    assert cache.get(1) is None and cache.get(2) is None


def test_head_server_partial_repersonalization():
    fed = _fed()
    srv = _lru(fed, capacity=8, invalidation="segmented")
    packed = _packed(fed)
    srv.absorb(packed)
    cids = [0, 1, 2, 3]
    xs = _burst(fed, cids)
    _, rep = srv.query(cids, xs)
    assert rep["solved_now"] == 4
    # an absorb whose arrivals touch ONLY client 2
    wave = pack_schedule(fed, [[2]])
    srv.absorb(wave)
    _, rep2 = srv.query(cids, xs)
    assert rep2["solved_now"] == 1  # partial re-personalization: just 2
    assert srv.cache.stale_evictions == 1
    # the strict server re-solves the whole working set on the same event
    strict = _lru(fed, capacity=8, invalidation="strict")
    strict.absorb(packed)
    strict.query(cids, xs)
    strict.absorb(wave)
    _, rep3 = strict.query(cids, xs)
    assert rep3["solved_now"] == 4


def test_slot_engine_segmented_resolves_only_touched_tenants():
    fed = _fed()
    eng = _engine(fed, invalidation="segmented")
    strict = _engine(fed, invalidation="strict")
    packed = _packed(fed)
    cids = [0, 1, 2, 3]
    xs = _burst(fed, cids)
    for e in (eng, strict):
        e.absorb(packed)
        _, rep = e.query(cids, xs)
        assert rep["solved_now"] == 4
        e.absorb(pack_schedule(fed, [[2]]))  # touches only client 2
    _, rep_seg = eng.query(cids, xs)
    _, rep_strict = strict.query(cids, xs)
    assert rep_seg["solved_now"] == 1
    assert rep_strict["solved_now"] == 4


# ---------------------------------------------------------------------------
# answer parity with the synchronous server
# ---------------------------------------------------------------------------


def test_slot_engine_matches_synchronous_server():
    fed = _fed()
    eng = _engine(fed, invalidation="strict")
    srv = _lru(fed, capacity=5)
    packed = _packed(fed)
    eng.absorb(packed)
    srv.absorb(packed)
    cids = [0, 3, 0, 999]  # repeat + an unknown tenant
    xs = _burst(fed, cids)
    for _ in range(2):  # second burst exercises the hit path on both
        s1, r1 = eng.query(cids, xs)
        s2, r2 = srv.query(cids, xs)
        assert r1["modes"] == r2["modes"] == [
            "per-tenant", "per-tenant", "per-tenant", "global",
        ]
        # personalized rows: same cohort packing + same alpha sweep => bitwise
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # and the engine's served global head IS the synchronous classifier
    assert np.array_equal(np.asarray(eng.classifier()),
                          np.asarray(srv.stream.classifier(srv.state)))


def test_slot_engine_personalized_parity_after_stream_advance():
    fed = _fed()
    eng = _engine(fed, invalidation="strict")
    srv = _lru(fed, capacity=5)
    cids = [1, 4, 6]
    xs = _burst(fed, cids)
    for seed in (0, 1):  # absorb -> query -> absorb -> query
        packed = _packed(fed, seed=seed, waves=2)
        eng.absorb(packed)
        srv.absorb(packed)
        s1, _ = eng.query(cids, xs)
        s2, _ = srv.query(cids, xs)
        err = float(np.max(np.abs(np.asarray(s1) - np.asarray(s2))))
        assert err <= 1e-5


# ---------------------------------------------------------------------------
# slot lifecycle: eviction / readmission round-trip
# ---------------------------------------------------------------------------


def test_slot_engine_eviction_readmission_roundtrip():
    fed = _fed()
    eng = _engine(fed, n_slots=3)  # 2 tenant slots only
    eng.absorb(_packed(fed))
    xs0 = _burst(fed, [0])
    s_first, rep = eng.query([0], xs0)
    assert rep["solved_now"] == 1 and eng.table.slot_of(0) is not None
    # flood with other tenants until tenant 0 is evicted
    _, rep2 = eng.query([1, 2], _burst(fed, [1, 2]))
    assert eng.table.slot_of(0) is None  # evicted (coldest of the three)
    assert eng.table.evictions >= 1
    # readmission: a fresh solve into a reclaimed slot, same answer (the
    # stream state never moved, so the re-solved head is bitwise the same)
    s_again, rep3 = eng.query([0], xs0)
    assert rep3["solved_now"] == 1
    assert eng.table.slot_of(0) is not None
    np.testing.assert_array_equal(np.asarray(s_first), np.asarray(s_again))


def test_slot_engine_overflow_serves_global_and_reports():
    fed = _fed()
    eng = _engine(fed, n_slots=3)  # 2 tenant slots vs 4 distinct tenants
    eng.absorb(_packed(fed))
    cids = [0, 1, 2, 3]
    scores, rep = eng.query(cids, _burst(fed, cids))
    assert rep["slot_overflow"] == 2
    assert rep["modes"].count("per-tenant") == 2
    assert rep["modes"].count("global") == 2
    assert scores.shape == (4, C)
    # the overflowed queries were answered with the pinned global head
    g = [i for i, m in enumerate(rep["modes"]) if m == "global"]
    W_g = eng.classifier()
    expect = np.asarray(_burst(fed, cids))[g] @ np.asarray(W_g)
    np.testing.assert_allclose(np.asarray(scores)[g], expect, rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch budget + admission control
# ---------------------------------------------------------------------------


def test_slot_engine_one_dispatch_per_stage():
    fed = _fed()
    eng = _engine(fed)
    eng.absorb(_packed(fed))
    assert eng.absorb_dispatches == 1
    cids = [0, 1, 2, 0, 1, 3]
    eng.query(cids, _burst(fed, cids))
    assert eng.solve_dispatches == 1  # whole miss cohort in one dispatch
    assert eng.serve_dispatches == 1  # whole burst in one dispatch
    # all-hit burst: no solve work at all, still one serve dispatch
    eng.query(cids, _burst(fed, cids))
    assert eng.solve_dispatches == 1
    assert eng.serve_dispatches == 2


def test_slot_engine_queue_overflow_sheds_at_enqueue():
    fed = _fed()
    eng = _engine(fed, queue_depth=4)
    eng.absorb(_packed(fed))
    cids = [0, 1, 2, 3, 4, 5]
    admitted, shed = eng.enqueue(cids, _burst(fed, cids))
    assert (admitted, shed) == (4, 2)
    assert eng.shed_overflow == 2
    scores, rep = eng.tick()
    assert rep["queries"] == 4 and scores.shape == (4, C)
    with pytest.raises(RuntimeError):  # query() refuses silently-shed bursts
        eng.query(cids, _burst(fed, cids))


def test_slot_engine_deadline_sheds_stale_requests():
    fed = _fed()
    eng = _engine(fed, queue_depth=64, max_batch=2, deadline_ticks=1)
    eng.absorb(_packed(fed))
    cids = [0, 1, 2, 3, 4, 5]
    admitted, shed = eng.enqueue(cids, _burst(fed, cids))
    assert (admitted, shed) == (6, 0)
    served = 0
    sheds = 0
    while eng.queue:
        _, rep = eng.tick()
        served += rep["queries"]
        sheds += rep["shed"]
    # tick 1 serves 2 (waited 1), tick 2 serves 2 (waited 2 > 1? no: the
    # deadline compares full ticks waited; admitted at tick 0, popped at
    # tick 2 => waited 2 > 1 => shed)
    assert served + sheds == 6
    assert sheds == eng.shed_deadline > 0
    assert eng.ticks >= 2


def test_slot_engine_latency_accounting_covers_every_served_request():
    fed = _fed()
    eng = _engine(fed, max_batch=3)
    eng.absorb(_packed(fed))
    cids = [0, 1, 2, 3, 4]
    eng.enqueue(cids, _burst(fed, cids))
    reports = []
    while eng.queue:
        _, rep = eng.tick()
        reports.append(rep)
    assert [r["queries"] for r in reports] == [3, 2]
    for rep in reports:
        assert len(rep["latency_s"]) == rep["queries"]
        assert all(t >= 0.0 for t in rep["latency_s"])
    # in-flight batching across tenants: the first tick mixed 3 tenants
    assert reports[0]["tenants"] == [0, 1, 2]
    assert reports[1]["tenants"] == [3, 4]
