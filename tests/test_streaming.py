"""Streaming arrival engine: parity, stability, invariance, policy, purity.

The engine's contract (federated/streaming_engine.py):
  * T waves fold in ONE jitted dispatch, and the factored-form final W
    matches the batch ``solve`` in fp32 at λ ≤ 1e-2 — the regime where the
    legacy subtractive Woodbury path visibly diverges;
  * the packed timeline (and hence the folded state and final W) is
    BIT-identical under permutation of a wave's concurrent arrivals
    (canonical within-wave packing);
  * ``"psum"`` aggregation inside shard_map == the local ``"merge"`` fold;
  * the arrival hot path performs NO host transfers after warmup
    (regression guard for the per-arrival host loop it replaced);
  * the refresh policy: ``refresh_every=k`` re-solves W on every k-th
    wave only, with the staleness metric counting waves/samples since;
  * the factored core state is the stable path and the subtractive
    ``Fed3ROnline`` path is deprecated (warning) — ``online_solution``
    routes factored states through the triangular solves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.data.pipeline import PackedArrivals, pack_arrival_waves
from repro.federated.arrivals import (
    dominant_labels,
    pack_schedule,
    poisson_schedule,
    skewed_schedule,
    trace_schedule,
)
from repro.federated.streaming_engine import (
    ReferenceArrivalLoop,
    StreamConfig,
    StreamingEngine,
    batch_equivalent,
)
from repro.kernels import chol_gram
from repro.kernels.ref import chol_gram_ref

D, C = 24, 6


def _make_stream(seed, n_waves, lo=8, hi=40, max_clients=3, d=D, n_classes=C):
    rng = np.random.default_rng(seed)
    waves = []
    for _ in range(n_waves):
        wave = []
        for _ in range(int(rng.integers(0, max_clients + 1))):
            n = int(rng.integers(lo, hi))
            wave.append((
                rng.normal(size=(n, d)).astype(np.float32),
                rng.integers(0, n_classes, size=n).astype(np.int32),
            ))
        waves.append(wave)
    if all(not w for w in waves):
        waves[0].append((
            rng.normal(size=(lo, d)).astype(np.float32),
            rng.integers(0, n_classes, size=lo).astype(np.int32),
        ))
    return waves


def _cfg(**kw):
    base = dict(n_classes=C, ridge_lambda=1e-2)
    base.update(kw)
    return StreamConfig(**base)


# ---------------------------------------------------------------------------
# packer
# ---------------------------------------------------------------------------


def test_arrival_packer_shapes_masks_and_clock():
    waves = _make_stream(0, 6)
    p = pack_arrival_waves(waves)
    widths = [len(w) for w in waves]
    sizes = [len(y) for w in waves for _, y in w]
    assert p.n_waves == 6
    assert p.clients_per_wave == max(widths)
    assert p.inputs.shape[2] % 8 == 0 and p.inputs.shape[2] >= max(sizes)
    assert p.n_clients == sum(widths)
    assert p.n_samples == sum(sizes)
    # empty waves / empty slots are all-padding: -1 ids, zero mask
    for t, w in enumerate(waves):
        assert (p.client_ids[t] >= 0).sum() == len(w)
        assert p.mask[t][p.client_ids[t] < 0].sum() == 0.0


def test_arrival_packer_canonical_within_wave():
    waves = _make_stream(1, 4, max_clients=4)
    ids = []
    nxt = 0
    for w in waves:
        ids.append(list(range(nxt, nxt + len(w))))
        nxt += len(w)
    p1 = pack_arrival_waves(waves, client_ids=ids)
    rng = np.random.default_rng(2)
    shuffled, sh_ids = [], []
    for w, wi in zip(waves, ids):
        perm = rng.permutation(len(w))
        shuffled.append([w[i] for i in perm])
        sh_ids.append([wi[i] for i in perm])
    p2 = pack_arrival_waves(shuffled, client_ids=sh_ids)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)


def test_arrival_packer_validates():
    waves = _make_stream(3, 3, max_clients=2)
    with pytest.raises(ValueError):
        pack_arrival_waves([])
    with pytest.raises(ValueError):
        pack_arrival_waves(waves, clients_per_wave=1)
    with pytest.raises(ValueError):
        pack_arrival_waves(waves, max_n=2)
    with pytest.raises(ValueError):
        pack_arrival_waves([[], []])  # no clients in any wave


# ---------------------------------------------------------------------------
# chol_gram kernel (Pallas, interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,n,C_", [(16, 30, 3), (65, 129, 7), (24, 7, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chol_gram_kernel_matches_oracle(d, n, C_, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    A = jax.random.normal(k1, (d, d), jnp.float32)
    L = jnp.linalg.cholesky(A @ A.T + jnp.eye(d))
    Z = jax.random.normal(k2, (n, d), dtype)
    Y = jax.nn.one_hot(jax.random.randint(k3, (n,), 0, C_), C_, dtype=dtype)
    G, B = chol_gram(L, Z, Y)
    Gr, Br = chol_gram_ref(L, Z, Y)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(G), np.asarray(Gr), rtol=tol, atol=tol * n)
    np.testing.assert_allclose(np.asarray(B), np.asarray(Br), rtol=tol, atol=tol * n)
    assert G.dtype == jnp.float32


def test_chol_gram_kernel_handles_empty_arrival_batch():
    """Regression: a 0-row Z must degrade to the pure refactorization."""
    L = jnp.linalg.cholesky(2.0 * jnp.eye(16))
    G, B = chol_gram(L, jnp.zeros((0, 16)), jnp.zeros((0, 4)))
    np.testing.assert_allclose(np.asarray(G), 2.0 * np.eye(16), atol=1e-6)
    assert not np.asarray(B).any()


def test_engine_kernel_path_matches_xla_path():
    packed = pack_arrival_waves(_make_stream(4, 5))
    xla = StreamingEngine(_cfg(use_kernel=False))
    ker = StreamingEngine(_cfg(use_kernel=True))
    s1, _ = xla.absorb(xla.init(D), packed)
    s2, _ = ker.absorb(ker.init(D), packed)
    np.testing.assert_allclose(np.asarray(s1.W), np.asarray(s2.W),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1.L), np.asarray(s2.L),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# parity with the batch solve where the legacy path diverges (fp32, small λ)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lam", [1e-2, 1e-3])
def test_streaming_matches_batch_solve_at_small_lambda(lam):
    waves = _make_stream(5, 16, lo=40, hi=80, max_clients=3)
    packed = pack_arrival_waves(waves)
    cfg = _cfg(ridge_lambda=lam)
    eng = StreamingEngine(cfg)
    state, _ = eng.absorb(eng.init(D), packed)
    W_batch, stats = batch_equivalent(packed, cfg)
    assert eng.dispatches == 1  # the whole T-wave stream in one dispatch
    err = float(jnp.max(jnp.abs(state.W - W_batch)))
    assert err <= 1e-4, f"factored engine drifted: {err:.2e}"
    assert float(state.n) == float(stats.n) == packed.n_samples


def test_legacy_woodbury_visibly_diverges_where_engine_holds():
    """The fix under test: same stream, λ=1e-2, fp32 — the subtractive
    path's error is orders of magnitude above the factored engine's."""
    packed = pack_arrival_waves(_make_stream(6, 16, lo=40, hi=80))
    cfg = _cfg()
    eng = StreamingEngine(cfg)
    state, _ = eng.absorb(eng.init(D), packed)
    legacy = ReferenceArrivalLoop(cfg)
    W_legacy = legacy.classifier(legacy.absorb(legacy.init(D), packed))
    W_batch, _ = batch_equivalent(packed, cfg)
    err_fac = float(jnp.max(jnp.abs(state.W - W_batch)))
    err_leg = float(jnp.max(jnp.abs(W_legacy - W_batch)))
    assert legacy.dispatches == packed.n_waves  # the T-dispatch shape
    assert err_fac <= 1e-4
    assert err_leg > 10 * max(err_fac, 1e-7), (
        f"expected visible legacy divergence, got {err_leg:.2e}"
    )


def test_streaming_is_chunk_invariant():
    """Absorbing the stream in segments == absorbing it in one dispatch."""
    packed = pack_arrival_waves(_make_stream(7, 9))
    eng = StreamingEngine(_cfg())
    whole, _ = eng.absorb(eng.init(D), packed)
    state = eng.init(D)
    for lo in (0, 3, 6):
        state, _ = eng.absorb(state, packed.slice_waves(lo, lo + 3))
    assert int(state.wave) == int(whole.wave) == 9
    np.testing.assert_array_equal(np.asarray(whole.L), np.asarray(state.L))
    np.testing.assert_array_equal(np.asarray(whole.W), np.asarray(state.W))


# ---------------------------------------------------------------------------
# arrival-order bit-invariance of the final W
# ---------------------------------------------------------------------------


def test_final_w_bit_invariant_under_concurrent_arrival_permutation():
    waves = _make_stream(8, 6, max_clients=4)
    ids = []
    nxt = 0
    for w in waves:
        ids.append(list(range(nxt, nxt + len(w))))
        nxt += len(w)
    rng = np.random.default_rng(9)
    shuffled, sh_ids = [], []
    for w, wi in zip(waves, ids):
        perm = rng.permutation(len(w))
        shuffled.append([w[i] for i in perm])
        sh_ids.append([wi[i] for i in perm])
    eng = StreamingEngine(_cfg())
    s1, _ = eng.absorb(eng.init(D), pack_arrival_waves(waves, client_ids=ids))
    s2, _ = eng.absorb(
        eng.init(D), pack_arrival_waves(shuffled, client_ids=sh_ids)
    )
    # canonical within-wave packing ⇒ bit-identical state and served W
    np.testing.assert_array_equal(np.asarray(s1.L), np.asarray(s2.L))
    np.testing.assert_array_equal(np.asarray(s1.b), np.asarray(s2.b))
    np.testing.assert_array_equal(np.asarray(s1.W), np.asarray(s2.W))


# ---------------------------------------------------------------------------
# refresh policy + staleness metric
# ---------------------------------------------------------------------------


def test_refresh_policy_and_staleness_trace():
    packed = pack_arrival_waves(_make_stream(10, 8, max_clients=2))
    eng = StreamingEngine(_cfg(refresh_every=3))
    state, trace = eng.absorb(eng.init(D), packed)
    np.testing.assert_array_equal(
        np.asarray(trace.refreshed),
        np.array([False, False, True] * 2 + [False, False]),
    )
    np.testing.assert_array_equal(
        np.asarray(trace.stale_waves), np.array([1, 2, 0, 1, 2, 0, 1, 2])
    )
    # samples-staleness re-accumulates between refreshes
    per_wave = packed.mask.sum(axis=(1, 2))
    assert float(trace.stale_samples[1]) == pytest.approx(per_wave[:2].sum())
    assert float(trace.stale_samples[2]) == 0.0
    # the served W is the wave-6 solve, NOT the final statistics' solve
    W_at_6, _ = batch_equivalent(
        PackedArrivals(*[a[:6] for a in packed]), _cfg()
    )
    np.testing.assert_allclose(np.asarray(state.W), np.asarray(W_at_6),
                               rtol=1e-5, atol=1e-5)
    refreshed = eng.refresh(state)
    W_final, _ = batch_equivalent(packed, _cfg())
    np.testing.assert_allclose(np.asarray(refreshed.W), np.asarray(W_final),
                               rtol=1e-5, atol=1e-5)
    assert int(refreshed.stale_waves) == 0


def test_refresh_on_arrival_never_stale():
    packed = pack_arrival_waves(_make_stream(11, 5))
    eng = StreamingEngine(_cfg(refresh_every=1))
    _, trace = eng.absorb(eng.init(D), packed)
    assert np.asarray(trace.refreshed).all()
    assert not np.asarray(trace.stale_waves).any()
    assert not np.asarray(trace.stale_samples).any()


def test_stream_config_validation():
    from repro.federated.dist import DistConfig

    with pytest.raises(ValueError):
        StreamingEngine(_cfg(refresh_every=0))
    with pytest.raises(ValueError):
        DistConfig(aggregation="psum")  # no axes, no mesh
    with pytest.raises(ValueError):
        DistConfig(aggregation="allgather")


# ---------------------------------------------------------------------------
# mesh mode: psum backend == merge backend
# ---------------------------------------------------------------------------


def test_streaming_psum_matches_merge_on_host_mesh():
    """The dist-layer mesh path (shard_map owned by DistContext) == merge."""
    from repro.federated.dist import DistConfig
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    waves = _make_stream(12, 4, max_clients=4)
    packed = pack_arrival_waves(waves, mesh=mesh)  # wave width padded to dp

    merge_eng = StreamingEngine(_cfg())
    ref, _ = merge_eng.absorb(merge_eng.init(D), packed)

    psum_eng = StreamingEngine(
        _cfg(dist=DistConfig(aggregation="psum", mesh=mesh, donate=False))
    )
    got, _ = psum_eng.absorb(psum_eng.init(D), packed)
    assert psum_eng.dispatches == 1  # the shard_map program is ONE dispatch
    np.testing.assert_allclose(np.asarray(ref.W), np.asarray(got.W),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.L), np.asarray(got.L),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# hot path is transfer-free (regression: per-arrival host loop)
# ---------------------------------------------------------------------------


def test_absorb_hot_path_makes_no_host_transfers():
    packed = pack_arrival_waves(_make_stream(13, 4))
    dev = PackedArrivals(*[jnp.asarray(a) for a in packed])
    eng = StreamingEngine(_cfg())
    state, _ = eng.absorb(eng.init(D), dev)  # warm the trace
    # steady-state arrivals: everything already on device ⇒ zero transfers
    with jax.transfer_guard("disallow"):
        state, _ = eng.absorb(state, dev)
        state, _ = eng.absorb(state, dev)
    assert int(state.wave) == 12


# ---------------------------------------------------------------------------
# factored core state + deprecation of the subtractive path
# ---------------------------------------------------------------------------


def test_factored_update_matches_batch_and_solution_routes():
    rng = np.random.default_rng(14)
    xs = rng.normal(size=(3, 50, D)).astype(np.float32)
    ys = rng.integers(0, C, size=(3, 50)).astype(np.int32)
    st = fed3r.init_factored(D, C, 1e-2)
    stats = fed3r.init_stats(D, C)
    for x, y in zip(xs, ys):
        st = fed3r.factored_update(st, jnp.asarray(x), jnp.asarray(y))
        stats = fed3r.merge(stats, fed3r.client_stats(jnp.asarray(x), jnp.asarray(y), C))
    W_batch = fed3r.solve(stats, 1e-2)
    np.testing.assert_allclose(np.asarray(fed3r.factored_solution(st)),
                               np.asarray(W_batch), rtol=1e-4, atol=1e-5)
    # online_solution routes factored states through the triangular solves
    np.testing.assert_array_equal(
        np.asarray(fed3r.online_solution(st)),
        np.asarray(fed3r.factored_solution(st)),
    )


def test_subtractive_path_is_deprecated():
    with pytest.warns(DeprecationWarning, match="CANCELS"):
        st = fed3r.init_online(8, 3, 1e-3)  # small λ names the fp32 hazard
    with pytest.warns(DeprecationWarning):
        fed3r.online_solution(st)
    with pytest.warns(DeprecationWarning):
        fed3r.init_online(8, 3, 1.0)  # deprecated at any λ


# ---------------------------------------------------------------------------
# arrival schedules
# ---------------------------------------------------------------------------


def test_poisson_schedule_each_client_arrives_once():
    sched = poisson_schedule(40, 12, rate=3.0, seed=0)
    flat = [k for wave in sched for k in wave]
    assert sorted(flat) == list(range(40))  # drain ⇒ exact partition
    assert len(sched) == 12
    sched2 = poisson_schedule(40, 12, rate=3.0, seed=0)
    assert sched == sched2  # seeded determinism
    undrained = poisson_schedule(40, 3, rate=1.0, seed=0, drain=False)
    assert len({k for w in undrained for k in w}) < 40


def test_trace_schedule_replays_arrival_log():
    sched = trace_schedule([2, 0, 2, 5])
    assert sched == [[1], [], [0, 2], [], [], [3]]
    assert len(trace_schedule([1, 0], n_waves=4)) == 4
    with pytest.raises(ValueError):
        trace_schedule([3], n_waves=2)


def test_skewed_schedule_orders_by_dominant_label():
    dom = np.array([3, 0, 3, 1, 0, 2, 1, 2])
    strict = skewed_schedule(dom, 4, skew=1.0, seed=0)
    seen = [int(dom[k]) for wave in strict for k in wave]
    assert seen == sorted(seen)  # skew=1 ⇒ label-sorted arrivals
    flat = sorted(k for wave in strict for k in wave)
    assert flat == list(range(8))
    iid = skewed_schedule(dom, 4, skew=0.0, seed=0)
    assert sorted(k for w in iid for k in w) == list(range(8))


def test_pack_schedule_roundtrips_dataset(fed_stream_data):
    fed = fed_stream_data
    sched = skewed_schedule(dominant_labels(fed), 5, skew=1.0, seed=0)
    packed = pack_schedule(fed, sched)
    assert packed.n_waves == 5
    assert packed.n_clients == fed.n_clients
    assert packed.n_samples == int(fed.client_sizes().sum())
    eng = StreamingEngine(_cfg(n_classes=fed.n_classes))
    state, _ = eng.absorb(eng.init(fed.features.shape[-1]), packed)
    stats = fed3r.init_stats(fed.features.shape[-1], fed.n_classes)
    for k in range(fed.n_clients):
        cd = fed.client(k)
        stats = fed3r.merge(stats, fed3r.client_stats(
            jnp.asarray(cd.features), jnp.asarray(cd.labels), fed.n_classes
        ))
    np.testing.assert_allclose(np.asarray(state.W),
                               np.asarray(fed3r.solve(stats, 1e-2)),
                               rtol=1e-4, atol=1e-4)


@pytest.fixture(scope="module")
def fed_stream_data():
    from repro.data import make_federated_features

    fed, _ = make_federated_features(
        seed=0, n=800, d=D, n_classes=C, n_clients=10, alpha=0.5, noise=1.5
    )
    return fed
