"""Accumulation-engine coverage: parity, kernel dispatch, exact invariance.

The engine's contract (federated/engine.py):
  * packed scan accumulation == naive per-client loop, exactly (same math);
  * the Pallas kernel path (interpret mode on CPU) matches the XLA path
    under odd shapes, padding, and dtypes;
  * A and b are BIT-identical under client reordering and re-sharding
    (canonical packing + strict left fold);
  * idempotent re-send semantics in the drivers (regression for the
    collapsed seen-once branches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r, ncm
from repro.core.random_features import rff_init, rff_map
from repro.data.pipeline import pack_client_shards
from repro.federated.engine import (
    AccumulationEngine,
    EngineConfig,
    aggregate,
    shard_stats,
    to_ncm_stats,
)

D, C = 16, 5


def _make_clients(rng, sizes, d=D, n_classes=C):
    out = []
    for i, n in enumerate(sizes):
        r = np.random.default_rng(rng + i)
        out.append((
            r.normal(size=(n, d)).astype(np.float32),
            r.integers(0, n_classes, size=n).astype(np.int32),
        ))
    return out


def _naive(clients, n_classes=C, d=D):
    stats = fed3r.init_stats(d, n_classes)
    for f, y in clients:
        stats = fed3r.merge(
            stats, fed3r.client_stats(jnp.asarray(f), jnp.asarray(y), n_classes)
        )
    return stats


# ---------------------------------------------------------------------------
# packer
# ---------------------------------------------------------------------------


def test_packer_shapes_masks_and_ids():
    clients = _make_clients(0, [5, 9, 2])
    p = pack_client_shards(clients, 2, round_to=4)
    assert p.inputs.shape == (2, 2, 12, D)  # 9 → 12 (round_to), 3 → 4 slots
    assert p.n_clients == 3
    assert p.n_samples == 16
    assert (p.client_ids.reshape(-1)[:3] == np.arange(3)).all()
    assert p.client_ids.reshape(-1)[3] == -1
    # mask rows agree with client sizes, padding rows are fully zero
    sizes = p.mask.reshape(-1, p.inputs.shape[2]).sum(1)
    assert sorted(sizes.tolist()) == [0.0, 2.0, 5.0, 9.0]


def test_packer_canonical_order_is_input_order_invariant():
    clients = _make_clients(1, [4, 7, 3, 6])
    ids = [11, 3, 7, 5]
    p1 = pack_client_shards(clients, 2, client_ids=ids)
    perm = [2, 0, 3, 1]
    p2 = pack_client_shards(
        [clients[i] for i in perm], 2, client_ids=[ids[i] for i in perm]
    )
    for a, b in zip(p1, p2):
        assert np.array_equal(a, b)


def test_packer_rejects_oversized_client():
    clients = _make_clients(2, [4, 9])
    with pytest.raises(ValueError):
        pack_client_shards(clients, 2, max_n=8)


# ---------------------------------------------------------------------------
# engine vs naive loop — exact parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sizes", [[8], [5, 9, 2], [1, 17, 4, 4, 30]])
def test_engine_matches_naive_loop(sizes):
    clients = _make_clients(3, sizes)
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    acc = eng.accumulate(eng.init(D), pack_client_shards(clients, 2))
    ref = _naive(clients)
    np.testing.assert_allclose(np.asarray(acc.stats.A), np.asarray(ref.A),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(acc.stats.b), np.asarray(ref.b),
                               rtol=1e-6, atol=1e-6)
    assert float(acc.stats.n) == float(ref.n) == sum(sizes)


def test_engine_class_counts_give_ncm():
    clients = _make_clients(4, [6, 11, 3])
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    acc = eng.accumulate(eng.init(D), pack_client_shards(clients, 2))
    ref = ncm.init_stats(D, C)
    for f, y in clients:
        ref = ncm.merge(ref, ncm.client_stats(jnp.asarray(f), jnp.asarray(y), C))
    got = to_ncm_stats(acc)
    np.testing.assert_allclose(np.asarray(got.sums), np.asarray(ref.sums),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.counts), np.asarray(ref.counts))


def test_engine_rff_fusion_matches_host_map():
    clients = _make_clients(5, [7, 12])
    params = rff_init(jax.random.PRNGKey(0), D, 32, sigma=3.0)
    eng = AccumulationEngine(EngineConfig(n_classes=C), rff_params=params)
    acc = eng.accumulate(eng.init(32), pack_client_shards(clients, 2))
    mapped = [(np.asarray(rff_map(params, jnp.asarray(f))), y) for f, y in clients]
    ref = _naive(mapped, d=32)
    np.testing.assert_allclose(np.asarray(acc.stats.A), np.asarray(ref.A),
                               rtol=1e-5, atol=1e-5)


def test_engine_feature_fn_runs_inside_scan():
    clients = _make_clients(6, [5, 8, 2])
    scale = {"w": jnp.asarray(2.5, jnp.float32)}
    eng = AccumulationEngine(
        EngineConfig(n_classes=C), feature_fn=lambda p, x: x * p["w"]
    )
    acc = eng.accumulate(eng.init(D), pack_client_shards(clients, 2), scale)
    ref = _naive([(f * 2.5, y) for f, y in clients])
    np.testing.assert_allclose(np.asarray(acc.stats.A), np.asarray(ref.A),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# exact invariance: reordering + re-sharding
# ---------------------------------------------------------------------------


def test_engine_bit_identical_under_client_permutation():
    clients = _make_clients(7, [9, 3, 14, 6, 1, 11])
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    a1 = eng.accumulate(eng.init(D), pack_client_shards(clients, 3))
    perm = [4, 0, 5, 2, 1, 3]
    a2 = eng.accumulate(
        eng.init(D),
        pack_client_shards(
            [clients[i] for i in perm], 3, client_ids=perm
        ),
    )
    assert np.array_equal(np.asarray(a1.stats.A), np.asarray(a2.stats.A))
    assert np.array_equal(np.asarray(a1.stats.b), np.asarray(a2.stats.b))


@pytest.mark.parametrize("cps", [1, 2, 3, 6])
def test_engine_bit_identical_under_resharding(cps):
    """Strict left fold in canonical order ⇒ shard boundaries are invisible."""
    clients = _make_clients(8, [9, 3, 14, 6, 1, 11])
    ref_eng = AccumulationEngine(EngineConfig(n_classes=C))
    # fixed max_n so per-client block shapes are identical across shardings
    ref = ref_eng.accumulate(
        ref_eng.init(D), pack_client_shards(clients, 2, max_n=16)
    )
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    got = eng.accumulate(eng.init(D), pack_client_shards(clients, cps, max_n=16))
    assert np.array_equal(np.asarray(ref.stats.A), np.asarray(got.stats.A))
    assert np.array_equal(np.asarray(ref.stats.b), np.asarray(got.stats.b))


# ---------------------------------------------------------------------------
# kernel path (Pallas, interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,C_", [(30, 24, 3), (129, 65, 7), (64, 16, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shard_stats_kernel_matches_reference(n, d, C_, dtype, rng):
    feats = jax.random.normal(rng, (n, d), dtype)
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (n,), 0, C_)
    mask = (jax.random.uniform(jax.random.fold_in(rng, 2), (n,)) > 0.3).astype(
        jnp.float32
    )
    ker = shard_stats(feats, labels, C_, mask, use_kernel=True)
    ref = shard_stats(feats, labels, C_, mask, use_kernel=False)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(ker.A), np.asarray(ref.A),
                               rtol=tol, atol=tol * n)
    np.testing.assert_allclose(np.asarray(ker.b), np.asarray(ref.b),
                               rtol=tol, atol=tol * n)
    assert ker.A.dtype == jnp.float32
    np.testing.assert_allclose(float(ker.n), float(ref.n))


def test_engine_kernel_path_matches_xla_path():
    clients = _make_clients(9, [5, 13, 7])
    packed = pack_client_shards(clients, 2)
    xla = AccumulationEngine(EngineConfig(n_classes=C, use_kernel=False))
    ker = AccumulationEngine(EngineConfig(n_classes=C, use_kernel=True))
    a1 = xla.accumulate(xla.init(D), packed)
    a2 = ker.accumulate(ker.init(D), packed)
    np.testing.assert_allclose(np.asarray(a1.stats.A), np.asarray(a2.stats.A),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a1.stats.b), np.asarray(a2.stats.b),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# aggregation backends
# ---------------------------------------------------------------------------


def test_aggregate_merge_is_identity_and_psum_validates():
    s = fed3r.init_stats(4, 3)
    assert aggregate(s, "merge") is s
    with pytest.raises(ValueError):
        aggregate(s, "psum")  # psum without axes is a bug, not a no-op
    with pytest.raises(ValueError):
        aggregate(s, "allgather")


def test_psum_backend_matches_merge_on_host_mesh(rng):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    n = 4 * n_dev
    feats = jax.random.normal(rng, (n, D))
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (n,), 0, C)

    def local(f, l):
        return aggregate(shard_stats(f, l, C, use_kernel=False), "psum", ("data",))

    agg = shard_map(local, mesh=mesh, in_specs=(P("data", None), P("data")),
                    out_specs=P())(feats, labels)
    ref = fed3r.client_stats(feats, labels, C)
    np.testing.assert_allclose(np.asarray(agg.A), np.asarray(ref.A),
                               rtol=1e-5, atol=1e-5)


def test_engine_counts_one_dispatch_per_accumulate():
    clients = _make_clients(10, [4] * 12)
    eng = AccumulationEngine(EngineConfig(n_classes=C))
    acc = eng.init(D)
    acc = eng.accumulate(acc, pack_client_shards(clients[:6], 3))
    acc = eng.accumulate(acc, pack_client_shards(clients[6:], 3, client_ids=range(6, 12)))
    assert eng.dispatches == 2  # 12 clients, 2 dispatches (was 12 in the loop)
    assert float(acc.stats.n) == 48.0
