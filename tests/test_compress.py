"""Compressed statistics uplink: kernels, formats, error feedback, interop.

Covers the wire-format contract end to end:
* quantize/dequantize Pallas kernels vs the ref.py oracles (exact int8
  agreement — both sides round half-to-even under the same jit);
* fp32 format bitwise-identical to the uncompressed engines;
* error feedback telescoping (EF strictly beats no-EF over repeated
  rounds, and the compressed solve stays near the exact one);
* client-permutation invariance under every format (canonical fold order);
* fp8 → int8 fallback warning when the backend lacks float8;
* secure aggregation over integer payloads (mod-2³² masks cancel
  bit-exactly);
* the PSD-guarded Cholesky that keeps compressed streaming finite on
  rank-deficient waves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.core.fed3r import Fed3RStats
from repro.data.pipeline import pack_arrival_waves, pack_client_shards
from repro.federated import compress, secure_agg
from repro.federated.compress import EFState, UplinkCompressor, WireFormat
from repro.federated.costs import CostModel, stats_wire_bytes
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.kernels import dequant_accumulate, quantize_tiles
from repro.kernels.ref import dequant_acc_ref, quantize_tiles_ref

D, C = 48, 7


def _clients(rng, K=6, d=D, n_classes=C, lo=5, hi=20):
    """Synthetic client shards: clustered features → separable classes."""
    out = {}
    centers = rng.normal(size=(n_classes, d)).astype(np.float32) * 3.0
    for k in range(K):
        n = int(rng.integers(lo, hi))
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        x = centers[y] + rng.normal(size=(n, d)).astype(np.float32)
        out[k] = (x, y)
    return out


def _client_stats(x, y, n_classes=C):
    z, yh, n = fed3r.masked_design(
        jnp.asarray(x), jnp.asarray(y), n_classes, None
    )
    return Fed3RStats(A=z.T @ z, b=z.T @ yh, n=n)


def _run_engine(packed, fmt, n_classes=C, d=D):
    eng = AccumulationEngine(
        EngineConfig(n_classes=n_classes, use_kernel=False, wire=fmt)
    )
    acc = eng.accumulate(eng.init(d), packed)
    return eng, acc


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,tile", [
    ((128, 128), 128),  # exactly one tile
    ((256, 128), 64),   # aligned multi-tile
    ((200, 150), 64),   # ragged both dims
    ((33, 190), 128),   # smaller than one tile in M
])
def test_quantize_kernel_matches_oracle_exactly(shape, tile, rng):
    x = 10.0 * jax.random.normal(rng, shape, jnp.float32)
    q, s = quantize_tiles(x, tile=tile)
    # jit the oracle too: XLA folds the divide-by-qmax identically, making
    # the comparison exact rather than 1-ulp
    qr, sr = jax.jit(quantize_tiles_ref, static_argnames=("tile",))(x, tile=tile)
    assert q.dtype == jnp.int8 and q.shape == shape
    assert s.shape == (-(-shape[0] // tile), -(-shape[1] // tile))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("shape,tile", [((200, 150), 64), ((128, 128), 128)])
def test_dequant_accumulate_matches_oracle_exactly(shape, tile, rng):
    x = jax.random.normal(rng, shape, jnp.float32)
    acc = jax.random.normal(jax.random.fold_in(rng, 1), shape, jnp.float32)
    q, s = quantize_tiles(x, tile=tile)
    out = dequant_accumulate(acc, q, s, tile=tile)
    ref = jax.jit(dequant_acc_ref, static_argnames=("tile",))(acc, q, s, tile=tile)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # and the roundtrip is a faithful int8 reconstruction of x
    err = np.max(np.abs(np.asarray(out - acc - x)))
    assert err <= np.max(np.abs(np.asarray(x))) / 127.0


def test_quantize_zero_tile_scale_is_one(rng):
    x = jnp.zeros((64, 64), jnp.float32)
    q, s = quantize_tiles(x, tile=32)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(s), 1.0)


# ---------------------------------------------------------------------------
# WireFormat / roundtrip algebra
# ---------------------------------------------------------------------------


def test_wireformat_validation():
    with pytest.raises(ValueError):
        WireFormat(kind="int4")
    with pytest.raises(ValueError):
        WireFormat(tile=0)
    with pytest.raises(ValueError):
        WireFormat(rank=0)


def test_wire_bytes_ratio():
    fp32 = stats_wire_bytes(64, 50, "fp32")
    int8 = stats_wire_bytes(64, 50, "int8")
    assert fp32 / int8 >= 3.9
    # sketch beats int8 when r ≪ d/4 and C ≪ d
    assert stats_wire_bytes(1280, 10, "sketch", rank=64) < stats_wire_bytes(
        1280, 10, "int8"
    )
    with pytest.raises(ValueError):
        stats_wire_bytes(64, 50, "bf16")


def test_cost_model_wire_pricing():
    cm = CostModel(b=2.22e6, d=1280, C=100)
    assert cm.compressed_stats_bytes("fp32") == cm.tenant_stats_bytes(1)
    assert cm.wire_compression_ratio("int8") >= 3.9
    # fp32 default reproduces the pre-compression two_stage_allreduce
    base = cm.two_stage_allreduce(8, n_pods=2)
    assert cm.two_stage_allreduce(8, n_pods=2, wire="fp32") == base
    int8 = cm.two_stage_allreduce(8, n_pods=2, wire="int8")
    assert int8["payload_bytes"] < base["payload_bytes"]
    assert int8["total_s"] < base["total_s"]


def test_fp32_roundtrip_is_bitwise_identity(rng):
    A = jax.random.normal(rng, (D, D))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (D, C))
    Ah, bh = compress.wire_roundtrip(A, b, WireFormat(), use_kernel=False)
    assert Ah is A and bh is b


def test_roundtrip_add_matches_unfused(rng):
    A = jax.random.normal(rng, (D, D))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (D, C))
    accA = jax.random.normal(jax.random.fold_in(rng, 2), (D, D))
    accb = jax.random.normal(jax.random.fold_in(rng, 3), (D, C))
    fmt = WireFormat(kind="int8", tile=16)
    fa, fb = compress.roundtrip_add(accA, accb, A, b, fmt, use_kernel=False)
    Ah, bh = compress.wire_roundtrip(A, b, fmt, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(accA + Ah))
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(accb + bh))


def test_sketch_exact_on_low_rank(rng):
    r = 8
    Z = jax.random.normal(rng, (r, D))
    A = Z.T @ Z  # rank-r PSD by construction
    Ah = compress.unsketch(compress.sketch_psd(A, r))
    np.testing.assert_allclose(np.asarray(Ah), np.asarray(A), atol=1e-4)


def test_fp8_fallback_warns(monkeypatch):
    monkeypatch.setattr(compress, "fp8_supported", lambda: False)
    compress._warn_fp8_fallback.cache_clear()
    with pytest.warns(RuntimeWarning, match="falling back to int8"):
        resolved = WireFormat(kind="fp8").resolved()
    assert resolved.kind == "int8"
    assert resolved.tile == WireFormat(kind="fp8").tile


def test_fp8_fallback_warns_once_per_process(monkeypatch, recwarn):
    """Regression: every engine construction used to re-emit the fallback
    warning; it must fire exactly once per process no matter how many
    WireFormats resolve (the backend's fp8 support cannot change)."""
    monkeypatch.setattr(compress, "fp8_supported", lambda: False)
    compress._warn_fp8_fallback.cache_clear()
    try:
        for _ in range(5):
            assert WireFormat(kind="fp8").resolved().kind == "int8"
        # engines resolve at construction too — still no second warning
        StreamingEngine(StreamConfig(
            n_classes=C, ridge_lambda=1e-2, wire=WireFormat(kind="fp8"),
        ))
        fallback = [
            w for w in recwarn.list
            if issubclass(w.category, RuntimeWarning)
            and "falling back to int8" in str(w.message)
        ]
        assert len(fallback) == 1
    finally:
        compress._warn_fp8_fallback.cache_clear()


@pytest.mark.skipif(not compress.fp8_supported(), reason="backend lacks fp8")
def test_fp8_roundtrip_accuracy(rng):
    A = jax.random.normal(rng, (D, D))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (D, C))
    Ah, bh = compress.wire_roundtrip(
        A, b, WireFormat(kind="fp8", tile=16), use_kernel=False
    )
    # e4m3 carries a 3-bit mantissa: relative error ≤ 2⁻⁴ elementwise
    assert np.max(np.abs(np.asarray(Ah - A))) <= np.max(np.abs(np.asarray(A))) / 8


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------


def test_error_feedback_telescopes(rng):
    """Over R rounds the EF aggregate error stays O(1) quantization step;
    the deterministic no-EF error accumulates and must be strictly worse."""
    npr = np.random.default_rng(0)
    clients = _clients(npr, K=4)
    R = 10

    def total_err(error_feedback):
        fmt = WireFormat(kind="int8", tile=16, error_feedback=error_feedback)
        up = UplinkCompressor(fmt, use_kernel=False)
        tot = fed3r.init_stats(D, C)
        exact = fed3r.init_stats(D, C)
        for _ in range(R):
            for k, (x, y) in clients.items():
                s = _client_stats(x, y)
                tot = fed3r.merge(tot, up.upload(k, s))
                exact = fed3r.merge(exact, s)
        return float(jnp.max(jnp.abs(tot.A - exact.A))), tot, exact

    e_ef, tot_ef, exact = total_err(True)
    e_no, _, _ = total_err(False)
    assert e_ef < e_no, f"EF ({e_ef}) must beat no-EF ({e_no})"
    assert e_no / max(e_ef, 1e-12) > 2.0  # telescoping, not luck
    # the compressed solve classifies the synthetic eval like the exact one
    W_ef = fed3r.solve(tot_ef, 1e-1)
    W_exact = fed3r.solve(exact, 1e-1)
    xs = jnp.asarray(np.concatenate([x for x, _ in clients.values()]))
    p_ef = jnp.argmax(fed3r.predict(W_ef, xs), axis=1)
    p_exact = jnp.argmax(fed3r.predict(W_exact, xs), axis=1)
    assert float(jnp.mean(p_ef == p_exact)) >= 0.995


def test_ef_fp32_is_exact_passthrough(rng):
    A = jax.random.normal(rng, (D, D))
    b = jax.random.normal(jax.random.fold_in(rng, 1), (D, C))
    ef = compress.ef_init(D, C)
    Ah, bh, ef2 = compress.compress_stats_ef(A, b, ef, WireFormat())
    assert Ah is A and bh is b and ef2 is ef


def test_uplink_compressor_accounting():
    npr = np.random.default_rng(1)
    clients = _clients(npr, K=3)
    up = UplinkCompressor(WireFormat(kind="int8", tile=16), use_kernel=False)
    for k, (x, y) in clients.items():
        up.upload(k, _client_stats(x, y))
    assert up.uploads == 3
    assert up.compression_ratio >= 3.5
    assert up.bytes_sent < up.bytes_fp32


def test_ef_state_isolated_per_client():
    npr = np.random.default_rng(2)
    clients = _clients(npr, K=2)
    up = UplinkCompressor(WireFormat(kind="int8", tile=16), use_kernel=False)
    for k, (x, y) in clients.items():
        up.upload(k, _client_stats(x, y))
    e0, e1 = up._residuals[0], up._residuals[1]
    assert isinstance(e0, EFState)
    assert not np.array_equal(np.asarray(e0.eA), np.asarray(e1.eA))


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------


def test_engine_fp32_wire_bitwise_identical():
    npr = np.random.default_rng(3)
    packed = pack_client_shards(_clients(npr), clients_per_shard=3)
    _, acc_default = _run_engine(packed, WireFormat())
    eng = AccumulationEngine(EngineConfig(n_classes=C, use_kernel=False))
    acc_plain = eng.accumulate(eng.init(D), packed)
    np.testing.assert_array_equal(
        np.asarray(acc_default.stats.A), np.asarray(acc_plain.stats.A)
    )
    np.testing.assert_array_equal(
        np.asarray(acc_default.stats.b), np.asarray(acc_plain.stats.b)
    )


@pytest.mark.parametrize("fmt", [
    WireFormat(kind="int8", tile=16),
    WireFormat(kind="sketch", rank=32),
])
def test_engine_compressed_one_dispatch_and_close(fmt):
    npr = np.random.default_rng(4)
    clients = _clients(npr, K=8, lo=10, hi=30)
    packed = pack_client_shards(clients, clients_per_shard=4)
    eng32, acc32 = _run_engine(packed, WireFormat())
    engc, accc = _run_engine(packed, fmt)
    assert eng32.dispatches == engc.dispatches == 1
    W32 = fed3r.solve(acc32.stats, 1e-1)
    Wc = fed3r.solve(accc.stats, 1e-1)
    # the classifiers agree on the separable synthetic eval
    xs = np.concatenate([x for x, _ in clients.values()])
    ys = np.concatenate([y for _, y in clients.values()])
    p32 = np.argmax(np.asarray(fed3r.predict(W32, jnp.asarray(xs))), axis=1)
    pc = np.argmax(np.asarray(fed3r.predict(Wc, jnp.asarray(xs))), axis=1)
    acc_32 = float(np.mean(p32 == ys))
    acc_c = float(np.mean(pc == ys))
    assert abs(acc_32 - acc_c) <= 0.005


@pytest.mark.parametrize("kind,kw", [
    ("fp32", {}),
    ("int8", {"tile": 16}),
    ("sketch", {"rank": 32}),
])
def test_engine_client_permutation_invariant(kind, kw):
    """Canonical fold order makes A bitwise invariant to client relabeling
    of the SAME shard contents under every wire format."""
    npr = np.random.default_rng(5)
    clients = _clients(npr)
    perm = {k: clients[k] for k in reversed(sorted(clients))}
    fmt = WireFormat(kind=kind, **kw)
    _, acc_a = _run_engine(pack_client_shards(clients, clients_per_shard=3), fmt)
    _, acc_b = _run_engine(pack_client_shards(perm, clients_per_shard=3), fmt)
    np.testing.assert_array_equal(
        np.asarray(acc_a.stats.A), np.asarray(acc_b.stats.A)
    )
    np.testing.assert_array_equal(
        np.asarray(acc_a.stats.b), np.asarray(acc_b.stats.b)
    )


# ---------------------------------------------------------------------------
# Streaming engine under compression
# ---------------------------------------------------------------------------


def _waves(npr, T=5, P=2, d=D, n_classes=C):
    centers = npr.normal(size=(n_classes, d)).astype(np.float32) * 3.0
    waves = []
    for _ in range(T):
        wave = []
        for _ in range(P):
            n = int(npr.integers(4, 12))
            y = npr.integers(0, n_classes, size=n).astype(np.int32)
            wave.append((centers[y] + npr.normal(size=(n, d)).astype(np.float32), y))
        waves.append(wave)
    return pack_arrival_waves(waves)


def _run_stream(packed, fmt):
    eng = StreamingEngine(
        StreamConfig(n_classes=C, ridge_lambda=1e-2, use_kernel=False, wire=fmt)
    )
    state, trace = eng.absorb(eng.init(D), packed)
    return eng, state


def test_streaming_fp32_wire_bitwise_identical():
    packed = _waves(np.random.default_rng(6))
    _, s_wire = _run_stream(packed, WireFormat())
    eng = StreamingEngine(
        StreamConfig(n_classes=C, ridge_lambda=1e-2, use_kernel=False)
    )
    s_plain, _ = eng.absorb(eng.init(D), packed)
    np.testing.assert_array_equal(np.asarray(s_wire.L), np.asarray(s_plain.L))
    np.testing.assert_array_equal(np.asarray(s_wire.W), np.asarray(s_plain.W))


@pytest.mark.parametrize("fmt", [
    WireFormat(kind="int8", tile=16),
    WireFormat(kind="sketch", rank=40),
])
def test_streaming_compressed_finite_one_dispatch(fmt):
    """Rank-deficient early waves make the quantized Gram indefinite; the
    PSD-guarded Cholesky must keep the whole stream finite at 1 dispatch."""
    packed = _waves(np.random.default_rng(7))
    eng32, s32 = _run_stream(packed, WireFormat())
    engc, sc = _run_stream(packed, fmt)
    assert eng32.dispatches == engc.dispatches == 1
    assert bool(jnp.all(jnp.isfinite(sc.L)))
    assert bool(jnp.all(jnp.isfinite(sc.W)))
    rel = float(jnp.max(jnp.abs(sc.W - s32.W)) / jnp.max(jnp.abs(s32.W)))
    assert rel < 0.5  # lossy but sane; accuracy gate lives in bench_compress


def test_psd_cholesky_repairs_indefinite(rng):
    """A Gram pushed indefinite by quantization-scale noise factors finite,
    while a clean PD matrix passes through bit-identically."""
    G_pd = jnp.eye(16) * 2.0
    bound = jnp.asarray(0.5, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(compress.psd_cholesky(G_pd, bound)),
        np.asarray(jnp.linalg.cholesky(G_pd)),
    )
    noise = jax.random.normal(rng, (16, 16)) * 0.1
    G_bad = jnp.eye(16) * 1e-4 + (noise + noise.T) / 2.0
    assert bool(jnp.any(jnp.isnan(jnp.linalg.cholesky(G_bad))))
    L = compress.psd_cholesky(G_bad, jnp.asarray(1.0, jnp.float32))
    assert bool(jnp.all(jnp.isfinite(L)))


def test_quant_spectral_bound_kinds(rng):
    S = jax.random.normal(rng, (32, 32))
    assert float(compress.quant_spectral_bound(S, WireFormat())) == 0.0
    assert float(compress.quant_spectral_bound(S, WireFormat(kind="sketch"))) == 0.0
    b8 = compress.quant_spectral_bound(S, WireFormat(kind="int8"))
    assert float(b8) > 0.0


# ---------------------------------------------------------------------------
# Secure aggregation over integer payloads
# ---------------------------------------------------------------------------


def test_secure_agg_quantized_masks_cancel_exactly():
    npr = np.random.default_rng(8)
    clients = _clients(npr, K=4)
    cohort = sorted(clients)
    stats = [_client_stats(*clients[k]) for k in cohort]
    payloads, sA, sb = compress.cohort_quantize_int8(stats, tile=16)
    masked = [
        secure_agg.mask_quantized_payload(p, k, cohort, seed=11)
        for k, p in zip(cohort, payloads)
    ]
    # each masked upload is NOT the plain payload (the privacy property)
    for m, p in zip(masked, payloads):
        assert not np.array_equal(np.asarray(m.qA), np.asarray(p.qA))
    agg_masked = secure_agg.secure_aggregate_quantized(masked)
    agg_plain = secure_agg.secure_aggregate_quantized(payloads)
    np.testing.assert_array_equal(
        np.asarray(agg_masked.qA), np.asarray(agg_plain.qA)
    )
    np.testing.assert_array_equal(
        np.asarray(agg_masked.qb), np.asarray(agg_plain.qb)
    )
    # the masked integer sum dequantizes to the true cohort aggregate
    A_sum, b_sum = compress.dequantize_int_sum(agg_masked, sA, sb, tile=16)
    exact = stats[0]
    for s in stats[1:]:
        exact = fed3r.merge(exact, s)
    relA = float(jnp.max(jnp.abs(A_sum - exact.A)) / jnp.max(jnp.abs(exact.A)))
    relb = float(jnp.max(jnp.abs(b_sum - exact.b)) / jnp.max(jnp.abs(exact.b)))
    assert relA < 0.02 and relb < 0.02


def test_secure_agg_quantized_rejects_float_payloads(rng):
    bad = compress.IntPayload(
        qA=jax.random.normal(rng, (8, 8)),
        qb=jax.random.normal(rng, (8, 2)),
    )
    with pytest.raises(TypeError):
        secure_agg.mask_quantized_payload(bad, 0, [0, 1], seed=0)


def test_float_masking_still_works():
    """The pre-existing float path is untouched by the integer additions."""
    npr = np.random.default_rng(9)
    clients = _clients(npr, K=3)
    cohort = sorted(clients)
    stats = [_client_stats(*clients[k]) for k in cohort]
    masked = [
        secure_agg.mask_statistics(s, k, cohort, seed=5)
        for k, s in zip(cohort, stats)
    ]
    agg = secure_agg.secure_aggregate(masked)
    exact = stats[0]
    for s in stats[1:]:
        exact = fed3r.merge(exact, s)
    np.testing.assert_allclose(
        np.asarray(agg.A), np.asarray(exact.A), rtol=1e-3, atol=1e-2
    )
