"""Unified telemetry layer (repro.federated.telemetry) coverage.

The layer's contract:
  * every engine's ``dispatches`` back-compat property reads/writes the
    SAME cell as the registry's ``engine_dispatches_total`` counter —
    bitwise equal, including through the benchmarks' reset idiom;
  * log-bucketed histograms report p50/p99 within one bucket of the raw
    sample order statistic at any scale;
  * disabled mode is a structural no-op (shared null span, empty ring)
    while counters keep counting — the dispatch contract is functional;
  * the flight recorder is a bounded ring: memory is capped, drops are
    counted, sequence numbers stay monotone;
  * snapshot (JSON), Prometheus text, and the event JSONL all round-trip
    through their parsers;
  * telemetry adds ZERO device dispatches: the module never holds jax,
    and an engine's dispatch count is identical under an enabled and a
    disabled registry.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.data.pipeline import (
    pack_arrival_waves,
    pack_client_shards,
    pack_cohort_batches,
    pack_personal_cohort,
)
from repro.federated.algorithms import make_algorithm
from repro.federated.async_engine import AsyncConfig, AsyncRoundEngine
from repro.federated.arrivals import UploadEvent
from repro.federated.engine import AccumulationEngine, EngineConfig
from repro.federated.personalization import (
    PersonalizationEngine,
    PersonalizeConfig,
)
from repro.federated.round_engine import RoundConfig, RoundEngine
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.federated.telemetry import (
    Histogram,
    Telemetry,
    dispatch_summary,
    events_from_jsonl,
    parse_prometheus,
    set_telemetry,
)

D, C = 16, 5
LAM = 0.1


@pytest.fixture
def registry():
    """A fresh injected global registry, restored after the test."""
    t = Telemetry()
    prev = set_telemetry(t)
    yield t
    set_telemetry(prev)


def _clients(seed, sizes, d=D, n_classes=C):
    rng = np.random.default_rng(seed)
    return [
        (
            rng.normal(size=(n, d)).astype(np.float32),
            rng.integers(0, n_classes, size=n).astype(np.int32),
        )
        for n in sizes
    ]


# ---------------------------------------------------------------------------
# histograms: quantile accuracy and edge buckets
# ---------------------------------------------------------------------------


def test_histogram_p50_p99_within_one_bucket_of_raw():
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(-7.0, 1.5, size=20_000))  # latency-shaped
    h = Histogram("lat", {})
    for s in samples:
        h.observe(float(s))
    for q, est in ((0.50, h.p50), (0.99, h.p99), (0.999, h.p999)):
        raw = float(np.quantile(samples, q))
        assert abs(Histogram.bucket_of(est) - Histogram.bucket_of(raw)) <= 1, (
            f"q={q}: estimate {est:.3e} vs raw {raw:.3e}"
        )
    assert h.count == len(samples)
    np.testing.assert_allclose(h.sum, samples.sum(), rtol=1e-6)
    assert h.min <= samples.min() and h.max >= samples.max()


def test_histogram_zero_and_negative_land_in_zero_bucket():
    h = Histogram("lat", {})
    h.observe(0.0)
    h.observe(-1.0)
    h.observe(1.0)
    assert h.zero_count == 2 and h.count == 3
    assert h.quantile(0.5) == 0.0  # zero bucket dominates the median


# ---------------------------------------------------------------------------
# exposition round-trips
# ---------------------------------------------------------------------------


def _populated() -> Telemetry:
    t = Telemetry(ring=128)
    t.counter("engine_dispatches_total", engine="accumulation", inst="0").inc(7)
    t.counter("wire_bytes_sent_total", kind="int8", inst="1").inc(4096)
    t.gauge("wire_compression_ratio", kind="int8", inst="1").set(3.98)
    h = t.histogram("span_seconds", stage="solve", engine="serving")
    for v in (1e-4, 2e-4, 5e-3, 0.0):
        h.observe(v)
    t.event("client_demoted", client=3, round=2)
    t.event("request_shed", reason="overflow", tenant=17)
    return t


def test_snapshot_json_roundtrip_identity():
    snap = _populated().snapshot()
    assert json.loads(json.dumps(snap)) == snap


def test_prometheus_roundtrip():
    t = _populated()
    parsed = parse_prometheus(t.prometheus())
    snap = t.snapshot()
    for c in snap["counters"] + snap["gauges"]:
        key = tuple(sorted((k, str(v)) for k, v in c["labels"].items()))
        assert parsed[(c["name"], key)] == pytest.approx(c["value"])
    for h in snap["histograms"]:
        key = tuple(sorted((k, str(v)) for k, v in h["labels"].items()))
        assert parsed[(h["name"] + "_count", key)] == h["count"]
        assert parsed[(h["name"] + "_sum", key)] == pytest.approx(h["sum"])


def test_events_jsonl_roundtrip():
    t = _populated()
    back = events_from_jsonl(t.events_jsonl())
    assert back == list(t.events)
    assert [ev["kind"] for ev in back] == ["client_demoted", "request_shed"]


# ---------------------------------------------------------------------------
# flight recorder: bounded memory
# ---------------------------------------------------------------------------


def test_event_ring_is_bounded_and_counts_drops():
    t = Telemetry(ring=64)
    for i in range(10_000):
        t.event("tick", i=i)
    assert len(t.events) == 64
    assert t.events_dropped == 10_000 - 64
    seqs = [ev["seq"] for ev in t.events]
    assert seqs == list(range(10_000 - 63, 10_001))  # newest 64, monotone


# ---------------------------------------------------------------------------
# disabled mode: structural no-op, counters still functional
# ---------------------------------------------------------------------------


def test_disabled_mode_is_noop_but_counters_count():
    t = Telemetry(enabled=False)
    assert t.span("a") is t.span("b", x=1)  # one shared null span
    with t.span("a"):
        pass
    t.event("client_demoted", client=0)
    assert len(t.events) == 0
    assert t.snapshot()["histograms"] == []  # no span histogram created
    c = t.counter("engine_dispatches_total", engine="e", inst="0")
    c.inc()
    assert c.value == 1  # the dispatch contract survives disabling


def test_disabled_mode_overhead_regression():
    t = Telemetry(enabled=False)
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with t.span("stage", engine="x"):
            pass
        t.event("tick")
    wall = time.perf_counter() - t0
    # generous absolute bound: ~3µs/iteration budget on a shared CI box
    assert wall < 0.3 * (n / 100_000) * 10, f"disabled-mode loop took {wall:.3f}s"


# ---------------------------------------------------------------------------
# spans: nesting paths
# ---------------------------------------------------------------------------


def test_span_paths_nest():
    t = Telemetry()
    with t.span("retire", engine="async"):
        with t.span("fold", engine="async"):
            pass
    stages = {
        h["labels"]["stage"]
        for h in t.snapshot()["histograms"]
        if h["name"] == "span_seconds"
    }
    assert stages == {"retire", "retire/fold"}


# ---------------------------------------------------------------------------
# engine dispatch counters == legacy property, across all four engines
# ---------------------------------------------------------------------------


def _round_engine():
    params0 = {"W": jnp.zeros((D, C), jnp.float32)}
    freeze = jax.tree.map(lambda _: 1.0, params0)

    def loss(params, batch):
        logits = batch["x"] @ params["W"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        return lse - picked

    rc = RoundConfig(algo=make_algorithm("fedavg"), client_lr=0.1,
                     n_total_clients=3)
    return RoundEngine(rc, loss, freeze), params0


def test_all_four_engines_dispatch_counter_equals_legacy(registry):
    clients = _clients(0, [8, 6, 7])

    eng = AccumulationEngine(EngineConfig(n_classes=C))
    st = eng.accumulate(eng.init(D), pack_client_shards(clients, 2, max_n=8))
    st = eng.accumulate(st, pack_client_shards(clients, 2, max_n=8))
    assert eng.dist.dispatches == 2

    s_eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    waves = pack_arrival_waves([_clients(t, [6]) for t in range(3)])
    s_eng.absorb(s_eng.init(D), waves)
    assert s_eng.dist.dispatches == 1

    r_eng, params0 = _round_engine()
    r_eng.step(r_eng.init(params0), pack_cohort_batches(clients, 4, 3))
    assert r_eng.dist.dispatches == 1

    p_eng = PersonalizationEngine(PersonalizeConfig(n_classes=C))
    fac = fed3r.init_factored(D, C, LAM)
    fac = fed3r.factored_update(
        fac,
        jnp.asarray(np.concatenate([x for x, _ in clients])),
        jnp.asarray(np.concatenate([y for _, y in clients])),
    )
    p_eng.solve_heads(fac, pack_personal_cohort(clients, holdout_frac=0.25))
    assert p_eng.dist.dispatches == 1

    # the legacy property and the registry read the SAME cell
    assert dispatch_summary(registry.snapshot()) == {
        "accumulation": 2, "streaming": 1, "rounds": 1, "personalization": 1,
    }

    # the benchmarks' reset idiom writes through to the registry
    eng.dist.dispatches = 0
    assert eng.dist.dispatches == 0
    assert dispatch_summary(registry.snapshot())["accumulation"] == 0

    # per-stage spans landed for every engine
    engines = {
        h["labels"]["engine"]
        for h in registry.snapshot()["histograms"]
        if h["name"] == "span_seconds"
    }
    assert {"accumulation", "streaming", "rounds", "personalization"} <= engines


# ---------------------------------------------------------------------------
# zero device dispatches: telemetry never touches jax on a metric path
# ---------------------------------------------------------------------------


def test_telemetry_module_holds_no_jax():
    import repro.federated.telemetry as T

    assert not any(
        getattr(v, "__name__", "").startswith("jax") for v in vars(T).values()
    ), "telemetry module must not import jax at module level"


def test_dispatch_count_identical_enabled_vs_disabled():
    clients = _clients(3, [8, 6])
    counts = {}
    for enabled in (True, False):
        t = Telemetry(enabled=enabled)
        prev = set_telemetry(t)
        try:
            eng = AccumulationEngine(EngineConfig(n_classes=C))
            st = eng.accumulate(
                eng.init(D), pack_client_shards(clients, 2, max_n=8)
            )
            jax.block_until_ready(st.stats.A)
            counts[enabled] = eng.dist.dispatches
        finally:
            set_telemetry(prev)
    assert counts[True] == counts[False] == 1


# ---------------------------------------------------------------------------
# flight-recorder events from the async engine's health/staleness paths
# ---------------------------------------------------------------------------


def _async_engine(**kw):
    kw.setdefault("staleness_rounds", 0)
    kw.setdefault("early_close", False)
    kw.setdefault("demote_after", 1)
    kw.setdefault("cooldown", 1)
    return AsyncRoundEngine(AsyncConfig(
        n_classes=C, ridge_lambda=LAM, cohort=2, deadline=1.0, **kw,
    ))


def _stats(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(12, D)).astype(np.float32)
    y = rng.integers(0, C, size=12).astype(np.int32)
    return fed3r.client_stats(jnp.asarray(x), jnp.asarray(y), C)


def test_async_engine_emits_health_and_staleness_events(registry):
    eng = _async_engine()
    state = eng.init(D)
    eng.begin_round(0, [0, 1], 0.0)
    state, s = eng.deliver(state, UploadEvent(0.1, 0, 0, 0), _stats(0))
    assert s == "folded"
    state = eng.close_round(state, 0, now=1.0)  # client 1 missed → demoted
    state, s = eng.deliver(state, UploadEvent(1.5, 0, 1, 0), _stats(1))
    assert s == "stale"
    eng.begin_round(1, [0, 1], 2.0)  # past probation: readmitted on arrival
    state, s = eng.deliver(state, UploadEvent(2.1, 1, 1, 0), _stats(1))
    assert s == "folded"
    kinds = [ev["kind"] for ev in registry.events]
    assert "client_demoted" in kinds
    assert "staleness_drop" in kinds
    assert "client_readmitted" in kinds
