"""Tests for the hierarchical N-tier aggregation trees.

The tree's contract is the paper's §4.3 order-invariance made executable:
on the engines' grid-exact statistics, an all-fp32 tree of ANY shape is a
pure reassociation of the flat sum — bitwise equal — while lossy tiers
quantize exactly once per boundary, so the tree result matches a manual
per-boundary roundtrip bit for bit.  Mesh-routed trees must emit the same
program as the two-stage psum; host trees drive the
:class:`~repro.federated.tiers.TieredAbsorber` whose overlapped and
blocking paths must also agree bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fed3r
from repro.federated import compress
from repro.federated.compress import WireFormat
from repro.federated.costs import CostModel
from repro.federated.dist import DistConfig
from repro.federated.engine import AccumulationEngine, EngineConfig, shard_stats
from repro.federated.streaming_engine import StreamConfig, StreamingEngine
from repro.federated.telemetry import Telemetry
from repro.federated.tiers import (
    TIER_WIRE_KINDS,
    AggregationTree,
    TierSpec,
    TieredAbsorber,
    mesh_tree,
    two_stage_tree,
)
from repro.launch.mesh import make_host_mesh, make_tier_host_mesh

D, C, LAM = 16, 5, 0.1

N_DEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    N_DEV < 4,
    reason="needs >=4 simulated devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _grid(rng, shape):
    """Features on a 1/8 grid in [-2, 2]: fp32 partial Gram sums are EXACT
    at this scale, so any reduction order is bitwise identical."""
    return (rng.integers(-16, 17, size=shape) / 8.0).astype(np.float32)


def _leaf_payloads(k, n=12, seed=0):
    rng = np.random.default_rng(seed)
    return [
        fed3r.client_stats(
            jnp.asarray(_grid(rng, (n, D))),
            jnp.asarray(rng.integers(0, C, size=n).astype(np.int32)),
            C,
        )
        for _ in range(k)
    ]


def _flat_sum(payloads):
    return jax.tree.map(lambda *xs: sum(xs[1:], xs[0]), *payloads)


def _bitwise(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_tierspec_validation():
    with pytest.raises(ValueError):
        TierSpec("edge", fan_in=0)
    with pytest.raises(ValueError):
        TierSpec("edge", fan_in=2, staleness=-1)
    with pytest.raises(ValueError):
        TierSpec("edge", fan_in=2, bandwidth=0.0)
    # sketch is a client-uplink format, not a tier-boundary format
    with pytest.raises(ValueError):
        TierSpec("edge", fan_in=2, wire=WireFormat(kind="sketch"))
    for kind in TIER_WIRE_KINDS:
        TierSpec("edge", fan_in=2, wire=WireFormat(kind=kind))


def test_tree_validation():
    with pytest.raises(ValueError):
        AggregationTree(())
    with pytest.raises(ValueError):  # duplicate tier names
        AggregationTree((TierSpec("a", fan_in=2), TierSpec("a", fan_in=2)))
    with pytest.raises(ValueError):  # duplicate mesh axes
        AggregationTree((
            TierSpec("a", fan_in=2, axis="data"),
            TierSpec("b", fan_in=2, axis="data"),
        ))
    tree = AggregationTree((
        TierSpec("edge", fan_in=3),
        TierSpec("region", fan_in=2),
        TierSpec("cloud", fan_in=2),
    ))
    assert tree.leaves == 12
    assert tree.lossy_wire is None
    with pytest.raises(ValueError):  # wrong leaf count
        tree.reduce(_leaf_payloads(5))


def test_two_stage_tree_matches_reduce_order():
    tree = two_stage_tree(("pod", "data"))
    # leaf tier on the INNERMOST axis — the two-stage psum order
    assert tree.axes == ("data", "pod")
    with pytest.raises(ValueError):
        two_stage_tree(())
    tree.validate_mesh_axes(("pod", "data"))
    with pytest.raises(ValueError):
        tree.validate_mesh_axes(("data", "pod"))


def test_lossy_wire_is_topmost_non_fp32():
    tree = AggregationTree((
        TierSpec("edge", fan_in=2, wire=WireFormat(kind="int8")),
        TierSpec("cloud", fan_in=2),
    ))
    assert tree.lossy_wire is not None and tree.lossy_wire.kind == "int8"
    assert AggregationTree((TierSpec("edge", fan_in=2),)).lossy_wire is None


# ---------------------------------------------------------------------------
# fp32 trees are exact reassociations (bitwise)
# ---------------------------------------------------------------------------


def test_tree_reduce_bitwise_equals_flat_sum():
    payloads = _leaf_payloads(12)
    tree = AggregationTree((
        TierSpec("edge", fan_in=3),
        TierSpec("region", fan_in=2),
        TierSpec("cloud", fan_in=2),
    ))
    assert _bitwise(tree.reduce(payloads), _flat_sum(payloads))


def test_single_tier_tree_is_flat_fold():
    payloads = _leaf_payloads(6, seed=3)
    tree = AggregationTree((TierSpec("edge", fan_in=6),))
    assert _bitwise(tree.reduce(payloads), _flat_sum(payloads))


def test_fully_masked_leaves_are_exact_noops():
    rng = np.random.default_rng(7)
    x = _grid(rng, (8, D))
    y = rng.integers(0, C, size=8).astype(np.int32)
    real = shard_stats(jnp.asarray(x), jnp.asarray(y), C)
    pad = shard_stats(
        jnp.asarray(x), jnp.asarray(y), C, jnp.zeros(8, jnp.float32)
    )
    tree = AggregationTree((TierSpec("e", fan_in=2), TierSpec("c", fan_in=2)))
    out = tree.reduce([real, pad, pad, pad])
    assert _bitwise(out, real)


def test_int8_tier_quantizes_exactly_once_per_boundary():
    """A lossy tier must match the manual per-boundary fused
    dequantize-accumulate bit for bit (no double quantization)."""
    payloads = _leaf_payloads(4, seed=5)
    wire = WireFormat(kind="int8")
    tree = AggregationTree((
        TierSpec("edge", fan_in=2),  # exact lower fold
        TierSpec("cloud", fan_in=2, wire=wire),
    ))
    got = tree.reduce(payloads)

    def pairsum(a, b):
        return jax.tree.map(lambda x, y: x + y, a, b)

    mids = [pairsum(payloads[0], payloads[1]), pairsum(payloads[2], payloads[3])]

    def cross(acc, child):  # one roundtrip per 2-D matrix per boundary
        A = compress.matrix_roundtrip_add(acc.A, child.A, wire)
        b = compress.matrix_roundtrip_add(acc.b, child.b, wire)
        return child._replace(A=A, b=b, n=acc.n + child.n)

    zero = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), mids[0])
    want = cross(cross(zero, mids[0]), mids[1])
    # n is a scalar sidecar: stays exact fp32, never quantized
    assert _bitwise((got.A, got.b, got.n), (want.A, want.b, mids[0].n + mids[1].n))


# ---- property: any fan-in assignment, any leaf order, still the flat sum ---

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _PAYLOADS = _leaf_payloads(16, seed=11)

    @st.composite
    def tree_shapes(draw):
        fans = draw(
            st.lists(st.integers(1, 4), min_size=1, max_size=3).filter(
                lambda f: np.prod(f) <= 16
            )
        )
        leaves = int(np.prod(fans))
        order = draw(st.permutations(list(range(leaves))))
        return fans, order

    @settings(max_examples=25, deadline=None)
    @given(tree_shapes())
    def test_property_any_tree_any_order_bitwise(shape):
        fans, order = shape
        tree = AggregationTree(
            tuple(TierSpec(f"t{i}", fan_in=k) for i, k in enumerate(fans))
        )
        chosen = [_PAYLOADS[i] for i in order]
        assert _bitwise(tree.reduce(chosen), _flat_sum(chosen))


# ---------------------------------------------------------------------------
# mesh-routed trees (DistConfig(tree=...))
# ---------------------------------------------------------------------------


def test_dist_tree_requires_psum_backend():
    tree = AggregationTree((TierSpec("data", fan_in=1, axis="data"),))
    with pytest.raises(ValueError):
        DistConfig(aggregation="merge", tree=tree)


def test_dist_tree_axes_must_match_mesh():
    mesh = make_host_mesh()
    bad = AggregationTree((TierSpec("edge", fan_in=1, axis="edge"),))
    with pytest.raises(ValueError):
        DistConfig(aggregation="psum", mesh=mesh, donate=False, tree=bad)


def test_mesh_tree_routes_engine_bitwise_single_host():
    """The degenerate 1-axis mesh tree runs at any device count and must
    route the accumulation engine bitwise onto the merge result."""
    from repro.data.pipeline import pack_client_shards

    mesh = make_tier_host_mesh((N_DEV,))
    tree = mesh_tree(mesh)
    assert tree.axes == ("edge",)
    rng = np.random.default_rng(0)
    clients = [
        (_grid(rng, (8, D)), rng.integers(0, C, size=8).astype(np.int32))
        for _ in range(2 * N_DEV)
    ]
    packed = pack_client_shards(clients, 2, mesh=mesh)
    eng = AccumulationEngine(EngineConfig(
        n_classes=C,
        dist=DistConfig(aggregation="psum", mesh=mesh, donate=False, tree=tree),
    ))
    eng.accumulate(eng.init(D), packed)  # warm the trace
    eng.dispatches = 0
    acc = eng.accumulate(eng.init(D), packed)
    ref_eng = AccumulationEngine(EngineConfig(n_classes=C))
    ref = ref_eng.accumulate(ref_eng.init(D), packed)
    assert _bitwise((acc.stats.A, acc.stats.b), (ref.stats.A, ref.stats.b))
    assert eng.dispatches == 1  # the one-dispatch contract survives routing


@needs4
def test_mesh_tree_two_tier_bitwise_vs_two_stage():
    """On a real multi-axis tier mesh the fp32 tree must emit the SAME
    result as the un-routed two-stage psum AND the merge backend."""
    from repro.data.pipeline import pack_arrival_waves

    mesh = make_tier_host_mesh((2, N_DEV // 2))
    tree = mesh_tree(mesh)
    rng = np.random.default_rng(1)
    waves = [
        [
            (_grid(rng, (8, D)), rng.integers(0, C, size=8).astype(np.int32))
            for _ in range(N_DEV)
        ]
        for _ in range(2)
    ]
    arrivals = pack_arrival_waves(waves, mesh=mesh)
    outs = {}
    for name, dist in (
        ("tree", DistConfig(aggregation="psum", mesh=mesh, donate=False, tree=tree)),
        ("flat", DistConfig(aggregation="psum", mesh=mesh, donate=False)),
        ("merge", None),
    ):
        cfg = dict(n_classes=C, ridge_lambda=LAM)
        eng = StreamingEngine(
            StreamConfig(**cfg) if dist is None else StreamConfig(**cfg, dist=dist)
        )
        state, _ = eng.absorb(eng.init(D), arrivals)
        outs[name] = np.asarray(state.W)
    assert np.array_equal(outs["tree"], outs["flat"])
    assert np.array_equal(outs["tree"], outs["merge"])


@needs4
def test_async_engine_dist_mesh_tree_bitwise():
    """The async ring's retire folds route through the dist-owned mesh
    (slots sharded over the data axes) with and without a tree, bitwise
    equal to the merge backend; K must divide over the shards."""
    from repro.federated.arrivals import UploadEvent
    from repro.federated.async_engine import AsyncConfig, AsyncRoundEngine

    mesh = make_tier_host_mesh((2, N_DEV // 2))
    tree = mesh_tree(mesh)
    K = N_DEV
    payloads = {}
    rng = np.random.default_rng(2)
    for c in range(K):
        x = _grid(rng, (8, D))
        y = rng.integers(0, C, size=8).astype(np.int32)
        payloads[c] = fed3r.client_stats(jnp.asarray(x), jnp.asarray(y), C)

    def run(dist):
        cfg = dict(n_classes=C, ridge_lambda=LAM, cohort=K)
        eng = AsyncRoundEngine(
            AsyncConfig(**cfg) if dist is None else AsyncConfig(**cfg, dist=dist)
        )
        st = eng.init(D)
        eng.begin_round(0, list(range(K)), 0.0)
        for i, c in enumerate(np.random.default_rng(3).permutation(K)):
            st, s = eng.deliver(st, UploadEvent(0.1 * i, 0, int(c), 0), payloads[int(c)])
            assert s == "folded"
        st = eng.close_round(st, 0, now=1.0)
        return np.asarray(eng.drain(st).W)

    ref = run(None)
    dist_tree = DistConfig(aggregation="psum", mesh=mesh, donate=False, tree=tree)
    assert np.array_equal(run(dist_tree), ref)

    with pytest.raises(ValueError):  # K=3 slots do not shard over the axes
        AsyncRoundEngine(AsyncConfig(
            n_classes=C, ridge_lambda=LAM, cohort=3, dist=dist_tree
        ))


# ---------------------------------------------------------------------------
# TieredAbsorber (host tiers)
# ---------------------------------------------------------------------------

_HOST_TREE = AggregationTree((
    TierSpec("edge", fan_in=2),
    TierSpec("cloud", fan_in=2, staleness=1),
))


def _segments(s, leaves, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return [
        (
            _grid(rng, (leaves, n, D)),
            rng.integers(0, C, size=(leaves, n)).astype(np.int32),
            np.ones((leaves, n), np.float32),
        )
        for _ in range(s)
    ]


def _run_absorber(tree, segs, *, overlap, telemetry=None, cost_model=None):
    eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    ab = eng.tiered_absorber(
        tree, overlap=overlap, telemetry=telemetry, cost_model=cost_model
    )
    before = ab.dist.dispatches
    for f, l, m in segs:
        ab.absorb_segment(f, l, m)
    state = ab.drain()
    return state, ab.dist.dispatches - before


def test_absorber_blocking_overlap_flat_bitwise():
    segs = _segments(4, _HOST_TREE.leaves)
    st_b, disp_b = _run_absorber(_HOST_TREE, segs, overlap=False)
    st_o, disp_o = _run_absorber(_HOST_TREE, segs, overlap=True)
    assert np.array_equal(np.asarray(st_b.W), np.asarray(st_o.W))
    assert disp_b == len(segs)  # one fused dispatch per segment
    assert disp_o == 2 * len(segs)  # lower + upper per segment

    eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    st = eng.init(D)
    for f, l, m in segs:
        s = shard_stats(
            jnp.asarray(f).reshape(-1, D), jnp.asarray(l).reshape(-1), C,
            jnp.asarray(m).reshape(-1),
        )
        st = eng.absorb_stats(st, s.A, s.b, s.n)
    assert np.array_equal(np.asarray(st.W), np.asarray(st_o.W))


def test_absorber_int8_tier_paths_agree_bitwise():
    tree = AggregationTree((
        TierSpec("edge", fan_in=2),
        TierSpec("cloud", fan_in=2, wire=WireFormat(kind="int8"), staleness=2),
    ))
    segs = _segments(3, tree.leaves, seed=4)
    st_b, _ = _run_absorber(tree, segs, overlap=False)
    st_o, _ = _run_absorber(tree, segs, overlap=True)
    assert np.array_equal(np.asarray(st_b.W), np.asarray(st_o.W))


def test_absorber_staleness_budget_and_gauges():
    tel = Telemetry()
    segs = _segments(4, _HOST_TREE.leaves, seed=2)
    _run_absorber(_HOST_TREE, segs, overlap=True, telemetry=tel)
    snap = tel.snapshot()
    # ring depth 1: every segment after the first forces the oldest flush
    stale = [e for e in snap["events"] if e["kind"] == "tier_staleness_exceeded"]
    assert len(stale) == len(segs) - 1
    eff = {g["name"]: g["value"] for g in snap["gauges"]}
    assert eff["tier_overlap_efficiency"] == 1.0  # no absorb-path syncs

    tel2 = Telemetry()
    _run_absorber(_HOST_TREE, segs, overlap=False, telemetry=tel2)
    eff2 = {g["name"]: g["value"] for g in tel2.snapshot()["gauges"]}
    assert eff2["tier_overlap_efficiency"] == 0.0  # one sync per segment


def test_absorber_cost_model_drift_gauge():
    tel = Telemetry()
    cm = CostModel(b=1e6, d=D, C=C)
    segs = _segments(3, _HOST_TREE.leaves, seed=6)
    _run_absorber(_HOST_TREE, segs, overlap=False, telemetry=tel, cost_model=cm)
    drift = {g["name"]: g["value"] for g in tel.snapshot()["gauges"]}[
        "tier_cost_model_drift"
    ]
    assert 0.5 <= drift <= 2.0


def test_absorber_validation():
    eng = StreamingEngine(StreamConfig(n_classes=C, ridge_lambda=LAM))
    with pytest.raises(ValueError):  # mesh tiers route through DistConfig
        TieredAbsorber(
            eng, AggregationTree((TierSpec("data", fan_in=1, axis="data"),))
        )
    with pytest.raises(ValueError):  # overlap needs a staleness budget
        TieredAbsorber(
            eng, AggregationTree((TierSpec("edge", fan_in=2),)), overlap=True
        )
    psum_eng = StreamingEngine(StreamConfig(
        n_classes=C, ridge_lambda=LAM,
        dist=DistConfig(aggregation="psum", mesh=make_host_mesh(), donate=False),
    ))
    with pytest.raises(ValueError):  # absorber owns the topology
        TieredAbsorber(psum_eng, _HOST_TREE, overlap=False)
    wired = StreamingEngine(StreamConfig(
        n_classes=C, ridge_lambda=LAM, wire=WireFormat(kind="int8")
    ))
    with pytest.raises(ValueError):  # compression lives on the tiers
        TieredAbsorber(wired, _HOST_TREE, overlap=False)
    ab = eng.tiered_absorber(_HOST_TREE, overlap=False)
    f, l, m = _segments(1, _HOST_TREE.leaves + 1)[0]
    with pytest.raises(ValueError):  # segment width != tree.leaves
        ab.absorb_segment(f, l, m)


def test_obs_report_renders_tier_tree():
    from repro.launch.obs_report import render

    tel = Telemetry()
    segs = _segments(2, _HOST_TREE.leaves, seed=8)
    _run_absorber(_HOST_TREE, segs, overlap=True, telemetry=tel)
    report = render(tel.snapshot())
    assert "aggregation tree (leaf tier first):" in report
    assert "edge" in report and "cloud" in report


def test_merge_snapshot_carries_tier_counters():
    tel = Telemetry()
    segs = _segments(2, _HOST_TREE.leaves, seed=9)
    _run_absorber(_HOST_TREE, segs, overlap=False, telemetry=tel)
    parent = Telemetry()
    parent.merge_snapshot(tel.snapshot())
    parent.merge_snapshot(tel.snapshot())  # counters ADD across workers
    merged = {
        (c["name"], tuple(sorted(c["labels"].items()))): c["value"]
        for c in parent.snapshot()["counters"]
    }
    for c in tel.snapshot()["counters"]:
        key = (c["name"], tuple(sorted(c["labels"].items())))
        assert merged[key] == 2 * c["value"]


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------


def test_tiered_allreduce_two_fp32_tiers_match_two_stage():
    cm = CostModel(b=1e6, d=128, C=32)
    dp, pods = 16, 4
    tree = AggregationTree((
        TierSpec("data", fan_in=dp, bandwidth=50e9),
        TierSpec("pod", fan_in=pods, bandwidth=12.5e9),
    ))
    tiered = cm.tiered_allreduce(tree.as_cost_tiers())
    two = cm.two_stage_allreduce(dp, pods)
    assert tiered["leaves"] == dp * pods
    assert tiered["total_s"] == pytest.approx(two["ici_s"] + two["dcn_s"])
    assert tiered["flat_allreduce_s"] == pytest.approx(two["flat_allreduce_s"])


def test_tiered_allreduce_single_leaf_is_free():
    cm = CostModel(b=1e6, d=64, C=16)
    priced = cm.tiered_allreduce(
        AggregationTree((TierSpec("edge", fan_in=1),)).as_cost_tiers()
    )
    assert priced["leaves"] == 1
    assert priced["total_s"] == 0.0
    assert priced["flat_allreduce_s"] == 0.0


def test_tiered_allreduce_lossy_tier_shrinks_bytes():
    cm = CostModel(b=1e6, d=128, C=32)

    def total(wire):
        tree = AggregationTree((
            TierSpec("edge", fan_in=4),
            TierSpec("cloud", fan_in=4, wire=WireFormat(kind=wire),
                     bandwidth=1.25e9),
        ))
        return cm.tiered_allreduce(tree.as_cost_tiers())["total_s"]

    assert total("int8") < total("fp32")
